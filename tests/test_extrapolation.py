"""Validate the dry-run's layer-count extrapolation against full unrolls.

The roofline numbers depend on metric(n) = (2-n)*m1 + (n-1)*m2 being exact
for structurally-identical layer periods; this checks flops AND collective
wire bytes against a fully-unrolled 4-layer program on a small mesh.
"""
import pytest


def test_extrapolation_matches_full_unroll(subproc):
    subproc("""
import dataclasses
import jax
from repro.configs import get_config, SHAPES
from repro.launch.mesh import make_mesh
from repro.launch import dryrun as dr
from repro.parallel import sharding as sh
from repro.roofline.hlo import parse_collectives

c4 = get_config("granite-8b").reduced(n_layers=4, d_model=64, n_heads=4,
                                      n_kv_heads=2, d_ff=128, vocab=512,
                                      d_head=16)
mesh = make_mesh((2, 2), ("data", "model"))
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=128, global_batch=8)
plan = sh.make_plan(c4, mesh, shape)
with mesh:
    # extrapolated from 1- and 2-layer programs
    f_ex, b_ex, c_ex = dr._metrics_extrapolated(c4, plan, shape, mesh, k=1)
    # ground truth: fully-unrolled 4-layer program
    lowered = dr._lower_metrics_program(c4, plan, shape, shape.global_batch)
    comp = lowered.compile()
    f_tr, b_tr, c_tr = dr._analyze_compiled(comp, mesh.size)

rel_f = abs(f_ex - f_tr) / f_tr
rel_b = abs(b_ex - b_tr) / b_tr
wire_ex, wire_tr = c_ex.total_wire_bytes, c_tr.total_wire_bytes
rel_w = abs(wire_ex - wire_tr) / max(wire_tr, 1)
print(f"flops rel {rel_f:.4f}  bytes rel {rel_b:.4f}  wire rel {rel_w:.4f}")
# XLA fuses differently across unroll depths; measured accuracy at this
# tiny scale: ~5% flops / ~10% bytes+wire (documented in EXPERIMENTS.md).
# Wire bytes drift the most across XLA versions (collective fusion):
# 10-16% observed between the 0.4.x and 0.5.x toolchains.
assert rel_f < 0.08, (f_ex, f_tr)
assert rel_b < 0.15, (b_ex, b_tr)
assert rel_w < 0.20, (wire_ex, wire_tr)
""", n_devices=4, timeout=900)


def test_scan_body_counted_once(subproc):
    """The premise of the metrics pass: XLA cost_analysis counts a
    while-loop body once (verified, so extrapolation is required)."""
    subproc("""
import jax, jax.numpy as jnp

def f_scan(x, w):
    y, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)
    return y

def f_unroll(x, w):
    y, _ = jax.lax.scan(lambda c, wi: (c @ wi, None), x, w, unroll=True)
    return y

def flops_of(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax 0.4.x returns [dict]
        ca = ca[0]
    return ca["flops"]

x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
w = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
f1 = flops_of(jax.jit(f_scan).lower(x, w).compile())
f2 = flops_of(jax.jit(f_unroll).lower(x, w).compile())
assert f2 > 9 * f1, (f1, f2)
print("scan-once premise OK:", f1, f2)
""", n_devices=1)
