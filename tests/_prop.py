"""Property-test shim: real ``hypothesis`` when installed, otherwise a
tiny deterministic fallback.

The tier-1 suite must collect and pass on a bare interpreter (the CI
container does not ship hypothesis).  When the real package is available
we re-export it unchanged and get full shrinking/fuzzing; when it is not,
``given`` degrades to a seeded parametrized sweep: each strategy draws a
fixed number of deterministic examples from ``random.Random`` so the
property still runs against a spread of inputs (just without search).

Usage in test modules::

    from _prop import given, settings, st
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5  # examples per property when hypothesis is absent

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng: random.Random):
            return self._draw(rng)

    class _Namespace:
        """The subset of ``hypothesis.strategies`` the suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            elems = list(seq)
            return _Strategy(lambda rng: elems[rng.randrange(len(elems))])

        @staticmethod
        def characters(codec="ascii", exclude_categories=(), **_kw):
            # printable ASCII, no control/surrogate categories by
            # construction — sufficient for the byte-fallback tokenizer test
            pool = [chr(i) for i in range(32, 127)]
            return _Strategy(lambda rng: pool[rng.randrange(len(pool))])

        @staticmethod
        def text(alphabet=None, min_size=0, max_size=20):
            alpha = alphabet or _Namespace.characters()

            def draw(rng):
                n = rng.randint(min_size, max_size)
                return "".join(alpha.draw(rng) for _ in range(n))

            return _Strategy(draw)

    st = _Namespace()

    def settings(*, max_examples=_FALLBACK_EXAMPLES, **_kw):
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn

        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # NOT functools.wraps: copying __wrapped__ would make pytest
            # introspect the inner signature and demand fixtures for the
            # drawn parameters.  The runner must present a bare signature.
            def runner(*outer_args, **outer_kw):
                # @settings is applied above @given, so it stamps the
                # example budget onto *runner*; read it at call time.
                n = min(getattr(runner, "_prop_max_examples",
                                _FALLBACK_EXAMPLES), _FALLBACK_EXAMPLES)
                for i in range(n):
                    rng = random.Random(0xC0FFEE + i)
                    drawn_args = tuple(s.draw(rng) for s in arg_strategies)
                    drawn_kw = {k: s.draw(rng)
                                for k, s in kw_strategies.items()}
                    fn(*outer_args, *drawn_args, **outer_kw, **drawn_kw)

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner

        return deco
