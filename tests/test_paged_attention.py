"""Paged decode attention: Pallas kernel vs jnp oracle vs dense reference.

Three-way agreement, swept and property-tested:

  * ``kernels.ref.paged_decode_attention_ref`` (the semantics oracle)
    must equal the *dense* ``flash_attention_ref`` on the same history —
    paging is a layout, not a math change;
  * the Pallas kernel (interpret mode on CPU) must match the oracle to
    <= 1e-3 across random slot lengths, block sizes, GQA group counts
    and shuffled block tables (the acceptance bar for the serve decode
    hot path).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.decode_attention import paged_decode_attention

SWEEP = [
    # h, kh, dh, bs, lengths, window, dtype
    (4, 2, 16, 16, (5, 16, 33, 96), None, jnp.float32),
    (4, 4, 32, 8, (1, 7, 8, 64), None, jnp.float32),      # MHA, tiny blocks
    (8, 2, 64, 16, (17, 40), None, jnp.bfloat16),          # wide GQA bf16
    (6, 3, 16, 32, (2, 90, 31), None, jnp.float32),        # odd group count
    (4, 2, 16, 16, (50, 96, 3), 24, jnp.float32),          # windowed
    (2, 1, 64, 16, (33,), 16, jnp.bfloat16),               # windowed bf16
]


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=1e-3, atol=1e-3)


def _paged_setup(lengths, bs, kh, dh, dt, seed=0, max_len=96):
    """Random dense per-sequence KV histories scattered into a pool via
    shuffled block tables (the PagedKVCache layout)."""
    rng = np.random.default_rng(seed)
    b = len(lengths)
    max_blocks = -(-max_len // bs)
    n_blocks = 1 + b * max_blocks
    dense_k = rng.normal(size=(b, max_len, kh, dh)).astype(np.float32)
    dense_v = rng.normal(size=(b, max_len, kh, dh)).astype(np.float32)
    k_pool = np.zeros((n_blocks, bs, kh, dh), np.float32)
    v_pool = np.zeros((n_blocks, bs, kh, dh), np.float32)
    tables = np.zeros((b, max_blocks), np.int32)
    free = list(range(1, n_blocks))
    rng.shuffle(free)
    for i, ln in enumerate(lengths):
        for j in range(-(-int(ln) // bs)):
            blk = free.pop()
            tables[i, j] = blk
            k_pool[blk] = dense_k[i, j * bs:(j + 1) * bs]
            v_pool[blk] = dense_v[i, j * bs:(j + 1) * bs]
    to = lambda x: jnp.asarray(x, jnp.float32).astype(dt)
    return (to(dense_k), to(dense_v), to(k_pool), to(v_pool),
            jnp.asarray(tables), jnp.asarray(np.asarray(lengths, np.int32)))


@pytest.mark.parametrize("h,kh,dh,bs,lengths,window,dt", SWEEP)
def test_paged_kernel_matches_oracle(h, kh, dh, bs, lengths, window, dt):
    rng = np.random.default_rng(1)
    b = len(lengths)
    q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32).astype(dt)
    _, _, k_pool, v_pool, tables, lens = _paged_setup(lengths, bs, kh, dh, dt)
    want = ref.paged_decode_attention_ref(q, k_pool, v_pool, tables, lens,
                                          window=window)
    got = paged_decode_attention(q, k_pool, v_pool, tables, lens,
                                 window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@pytest.mark.parametrize("h,kh,dh,bs,lengths,window,dt", SWEEP[:4])
def test_paged_oracle_matches_dense_reference(h, kh, dh, bs, lengths,
                                              window, dt):
    """Paging is a layout: the paged oracle over the scattered pool must
    equal dense single-token attention over the contiguous history."""
    rng = np.random.default_rng(2)
    b = len(lengths)
    q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32).astype(dt)
    dense_k, dense_v, k_pool, v_pool, tables, lens = _paged_setup(
        lengths, bs, kh, dh, dt)
    got = ref.paged_decode_attention_ref(q, k_pool, v_pool, tables, lens,
                                         window=window)
    for i, ln in enumerate(lengths):   # per sequence: sq=1 suffix decode
        want = ref.flash_attention_ref(q[i:i + 1, None],
                                       dense_k[i:i + 1, :ln],
                                       dense_v[i:i + 1, :ln],
                                       causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got[i], np.float32),
                                   np.asarray(want[0, 0], np.float32),
                                   **_tol(dt))


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2 ** 16),
    bs=st.sampled_from([8, 16, 32]),
    kh=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 3]),
)
def test_paged_kernel_property(seed, bs, kh, g):
    """Property: kernel == oracle (<=1e-3) for random slot lengths, block
    sizes, GQA group counts, and alloc-order-shuffled block tables."""
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 5))
    lengths = rng.integers(1, 97, size=b)
    h, dh = kh * g, 16
    q = jnp.asarray(rng.normal(size=(b, h, dh)), jnp.float32)
    _, _, k_pool, v_pool, tables, lens = _paged_setup(
        lengths, bs, kh, dh, jnp.float32, seed=seed + 1)
    want = ref.paged_decode_attention_ref(q, k_pool, v_pool, tables, lens)
    got = paged_decode_attention(q, k_pool, v_pool, tables, lens,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_truncated_table_gather_is_exact():
    """Gathering only the first nb table columns (the engine's length
    bucketing) must not change the result while nb*bs covers every live
    length — unowned columns hold the trash block and are masked."""
    lengths = (5, 30)
    _, _, k_pool, v_pool, tables, lens = _paged_setup(lengths, 16, 2, 16,
                                                      jnp.float32)
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 4, 16)), jnp.float32)
    full = ref.paged_decode_attention_ref(q, k_pool, v_pool, tables, lens)
    cut = ref.paged_decode_attention_ref(q, k_pool, v_pool, tables[:, :2],
                                         lens)
    np.testing.assert_allclose(np.asarray(cut), np.asarray(full),
                               rtol=1e-6, atol=1e-6)


def test_ops_dispatch_xla_equals_pallas():
    lengths = (9, 48, 96)
    _, _, k_pool, v_pool, tables, lens = _paged_setup(lengths, 16, 2, 16,
                                                      jnp.float32)
    rng = np.random.default_rng(4)
    q = jnp.asarray(rng.normal(size=(3, 4, 16)), jnp.float32)
    a = ops.paged_decode_attention(q, k_pool, v_pool, tables, lens,
                                   impl="xla")
    b = ops.paged_decode_attention(q, k_pool, v_pool, tables, lens,
                                   impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=1e-3)
