import subprocess
import sys
import textwrap

import pytest


def run_in_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a snippet under a multi-device (forced host platform) jax.

    Keeps the main test process at 1 device (per the dry-run contract:
    only repro.launch.dryrun forces 512 devices).
    """
    prelude = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={n_devices}'\n"
        "import sys\n"
        "sys.path.insert(0, 'src')\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, cwd=".")
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_in_subprocess
