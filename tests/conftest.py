import subprocess
import sys
import textwrap

import pytest


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="also run tests marked @pytest.mark.slow")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running test (deselected by default; run with "
        "--runslow or an explicit -m expression)")


def pytest_collection_modifyitems(config, items):
    """Default to ``-m "not slow"``: tier-1 stays fast; an explicit
    ``--runslow`` or any user-supplied ``-m`` expression overrides."""
    if config.option.runslow or config.option.markexpr:
        return
    skip_slow = pytest.mark.skip(
        reason="slow: needs --runslow (or -m slow)")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)


def run_in_subprocess(code: str, n_devices: int = 8, timeout: int = 600):
    """Run a snippet under a multi-device (forced host platform) jax.

    Keeps the main test process at 1 device (per the dry-run contract:
    only repro.launch.dryrun forces 512 devices).
    """
    prelude = (
        "import os\n"
        f"os.environ['XLA_FLAGS'] = "
        f"'--xla_force_host_platform_device_count={n_devices}'\n"
        "import sys\n"
        "sys.path.insert(0, 'src')\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, cwd=".")
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_in_subprocess
