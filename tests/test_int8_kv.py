"""int8-quantized KV block pool: numerics, invariants, and the serve drill.

Covers the ISSUE 10 quantization tier:

  * symmetric per-(block, KV-head) round-trip error bound
    (|x - deq| <= scale/2: int8 codes are round-to-nearest);
  * the paged prefill kernel over an int8 pool matches the int8 oracle
    (dequantization fused into the KV load, <= 1e-3 interpret mode);
  * ``_quantized_block_write``'s monotone-scale invariant — a decode
    write that fits the block's old range leaves every other code
    bit-unchanged, and the scale never decreases;
  * ``PagedKVCache(kv_dtype="int8")`` structure: scale leaves beside
    the pool, pool bytes <= 0.55x the fp budget, doubled worst-case
    concurrency at that budget;
  * prefix-cache CoW (``copy_blocks``) copies scale leaves with their
    int8 blocks;
  * the fp32-vs-int8 token-stream-quality drill on a real reduced
    model through ``ServeEngine``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.prefill_attention import paged_prefill_attention
from repro.kernels import ref
from repro.models import lm
from repro.models.attention import _quantized_block_write
from repro.serve import cache as cache_lib
from repro.serve.cache import PagedKVCache, copy_blocks
from repro.serve.engine import ServeEngine
from repro.serve.requests import Request
from repro.bench.workloads.serve import stream_agreement

_CONFIG = get_config("llama3.2-3b").reduced(dtype="float32",
                                            param_dtype="float32")


def _quantize_pool(pool):
    """(n_blocks, bs, Kh, Dh) fp -> (int8 pool, (n_blocks, Kh) scales),
    the single-layer form of ``cache._quantize_block``."""
    sc = jnp.max(jnp.abs(pool), axis=(1, 3)) / 127.0
    q = jnp.round(pool / jnp.where(sc > 0.0, sc, 1.0)[:, None, :, None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), sc


def test_quantize_block_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 5, 16, 3, 8)) * 4.0, jnp.float32)
    x = x.at[:, 3].set(0.0)                      # an untouched (zero) block
    q, sc = cache_lib._quantize_block(x)
    assert q.dtype == jnp.int8 and sc.shape == (2, 5, 3)
    deq = q.astype(jnp.float32) * sc[:, :, None, :, None]
    # round-to-nearest: reconstruction is within half a quantization step
    err = np.asarray(jnp.abs(deq - x))
    bound = np.asarray(sc)[:, :, None, :, None] / 2.0 + 1e-7
    assert (err <= bound).all()
    assert np.asarray(deq[:, 3] == 0.0).all()    # zero blocks stay exact


def test_prefill_kernel_int8_matches_int8_ref():
    rng = np.random.default_rng(1)
    b, sq, kh, g, dh, bs, npre, n_blocks = 2, 32, 2, 2, 16, 16, 3, 9
    q = jnp.asarray(rng.normal(size=(b, sq, kh * g, dh)), jnp.float32)
    k_suf = jnp.asarray(rng.normal(size=(b, sq, kh, dh)), jnp.float32)
    v_suf = jnp.asarray(rng.normal(size=(b, sq, kh, dh)), jnp.float32)
    k_pool, k_sc = _quantize_pool(
        jnp.asarray(rng.normal(size=(n_blocks, bs, kh, dh)), jnp.float32))
    v_pool, v_sc = _quantize_pool(
        jnp.asarray(rng.normal(size=(n_blocks, bs, kh, dh)), jnp.float32))
    tables = jnp.asarray(
        rng.permutation(np.arange(1, n_blocks))[:b * npre].reshape(b, npre))
    want = ref.paged_prefill_attention_ref(q, k_suf, v_suf, k_pool, v_pool,
                                           tables, k_scale=k_sc,
                                           v_scale=v_sc)
    got = paged_prefill_attention(q, k_suf, v_suf, k_pool, v_pool, tables,
                                  k_scale=k_sc, v_scale=v_sc, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
    # and the int8 path tracks the unquantized answer to quantization
    # error, not to garbage
    dense = ref.paged_prefill_attention_ref(
        q, k_suf, v_suf, k_pool.astype(jnp.float32) * k_sc[:, None, :, None],
        v_pool.astype(jnp.float32) * v_sc[:, None, :, None], tables)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               rtol=1e-3, atol=1e-3)


def test_quantized_block_write_monotone_scale():
    rng = np.random.default_rng(2)
    n, bs, kh, dh = 4, 8, 2, 16
    pool, scale = _quantize_pool(
        jnp.asarray(rng.normal(size=(n, bs, kh, dh)) * 3.0, jnp.float32))
    blk = jnp.asarray([1, 2], jnp.int32)
    off = jnp.asarray([5, 2], jnp.int32)

    # a token well inside the blocks' existing range: scale unchanged,
    # every OTHER code in the written blocks bit-identical
    small = jnp.asarray(rng.uniform(-0.5, 0.5, size=(2, kh, dh)), jnp.float32)
    p1, s1 = _quantized_block_write(pool, scale, small, blk, off)
    assert np.array_equal(np.asarray(s1), np.asarray(scale))
    for i, (b_, o_) in enumerate(zip([1, 2], [5, 2])):
        old = np.asarray(pool[b_])
        new = np.asarray(p1[b_])
        mask = np.ones(bs, bool)
        mask[o_] = False
        assert np.array_equal(new[mask], old[mask])
        deq = new[o_] * np.asarray(s1[b_])[:, None]
        assert np.abs(deq - np.asarray(small[i])).max() \
            <= np.asarray(s1[b_]).max() / 2.0 + 1e-7

    # a token OUTSIDE the range grows the scale; it never shrinks
    big = jnp.full((2, kh, dh), 50.0, jnp.float32)
    p2, s2 = _quantized_block_write(p1, s1, big, blk, off)
    assert (np.asarray(s2) >= np.asarray(s1) - 1e-9).all()
    assert (np.asarray(jnp.take(s2, blk, 0)) >
            np.asarray(jnp.take(s1, blk, 0))).all()
    deq = np.asarray(p2[1, 5], np.float32) * np.asarray(s2[1])[:, None]
    assert np.abs(deq - 50.0).max() <= np.asarray(s2[1]).max() / 2.0 + 1e-7


def _pool_leaves(caches, suffix=""):
    found = []

    def walk(t):
        if isinstance(t, dict):
            for k, v in t.items():
                if k in ("k" + suffix, "v" + suffix) \
                        and not isinstance(v, dict):
                    found.append(v)
                else:
                    walk(v)
        elif isinstance(t, (list, tuple)):
            for v in t:
                walk(v)

    walk(caches)
    return found


def test_int8_cache_structure_and_capacity():
    kw = dict(n_slots=3, max_len=96, block_size=16, params=None)
    fp = PagedKVCache(_CONFIG, kv_dtype="fp32", **kw)
    i8 = PagedKVCache(_CONFIG, kv_dtype="int8", **kw)
    pools = _pool_leaves(i8.caches)
    scales = _pool_leaves(i8.caches, suffix="_scale")
    assert pools and len(scales) == len(pools)
    for p, s in zip(pools, scales):
        assert p.dtype == jnp.int8
        assert s.dtype == jnp.float32
        assert s.shape == (p.shape[0], p.shape[1], p.shape[3])
    assert not _pool_leaves(fp.caches, suffix="_scale")
    # the ISSUE 10 acceptance bar: int8 pool bytes (codes + scales)
    # <= 0.55x the fp byte budget, which doubles how many worst-case
    # -length requests fit in that budget
    assert i8.pool_bytes_fp == fp.pool_bytes_fp == fp.pool_bytes
    assert i8.pool_bytes <= 0.55 * i8.pool_bytes_fp
    assert i8.max_concurrency >= 2 * fp.max_concurrency


def test_copy_blocks_copies_scale_leaves():
    i8 = PagedKVCache(_CONFIG, n_slots=2, max_len=64, block_size=16,
                      params=None, kv_dtype="int8")

    def stamp(t):
        if not isinstance(t, dict):
            return t
        out = {}
        for k, v in t.items():
            if k in ("k", "v", "k_scale", "v_scale"):
                fill = 7 if k in ("k", "v") else 0.25
                out[k] = v.at[:, 1].set(jnp.asarray(fill, v.dtype))
            else:
                out[k] = stamp(v)
        return out

    caches = stamp(i8.caches)
    out = copy_blocks(caches, jnp.asarray([1]), jnp.asarray([3]))

    def check(t):
        if not isinstance(t, dict):
            return
        for k, v in t.items():
            if k in ("k", "v", "k_scale", "v_scale"):
                np.testing.assert_array_equal(np.asarray(v[:, 3]),
                                              np.asarray(v[:, 1]))
            else:
                check(v)

    check(out)


@pytest.mark.parametrize("sched", ["phased"])
def test_engine_int8_stream_quality_drill(sched):
    """fp32-vs-int8 KV on a real (reduced, float32) model: the int8
    engine must complete every request and its greedy token streams
    must agree with the fp32 engine's to a long common prefix — the
    same statistic the serve workload compare-gates
    (``kv_stream_prefix_agreement``)."""
    params = lm.init(jax.random.key(0), _CONFIG)
    rng = np.random.default_rng(5)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, _CONFIG.vocab, p, np.int32),
                    max_new_tokens=b, arrival_s=0.0)
            for i, (p, b) in enumerate([(5, 24), (20, 16), (40, 12)])]

    def run(kv_dtype):
        eng = ServeEngine(_CONFIG, params, n_slots=3, max_len=96,
                          cache="paged", block_size=16, decode_window=8,
                          kv_dtype=kv_dtype)
        out = eng.serve([Request(r.rid, r.prompt, r.max_new_tokens,
                                 arrival_s=r.arrival_s) for r in reqs],
                        sched=sched)
        return {r.rid: list(r.tokens) for r in out.results}

    fp_streams = run("fp32")
    i8_streams = run("int8")
    assert set(i8_streams) == set(fp_streams)
    assert all(len(t) > 0 for t in i8_streams.values())
    agree = stream_agreement(fp_streams, i8_streams)
    # quantization noise may fork a greedy stream eventually; it must
    # not fork it immediately (smoke cell measured 0.85)
    assert agree >= 0.6, f"stream agreement {agree:.3f} < 0.6"
