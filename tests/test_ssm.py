"""Mamba2 SSD: chunked scan vs naive sequential recurrence; decode step;
chunk-size invariance (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.configs import get_config
from repro.models import ssm


def naive_ssd(xdt, dA, B, C):
    """Sequential reference: h_t = h_{t-1} * exp(dA_t) + B_t (x dt)_t."""
    b, s, nh, p = xdt.shape
    n = B.shape[-1]
    h = np.zeros((b, nh, p, n), np.float64)
    ys = []
    xdt = np.asarray(xdt, np.float64)
    dA = np.asarray(dA, np.float64)
    B_ = np.asarray(B, np.float64)
    C_ = np.asarray(C, np.float64)
    for t in range(s):
        h = h * np.exp(dA[:, t])[:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", xdt[:, t], B_[:, t])
        ys.append(np.einsum("bhpn,bn->bhp", h, C_[:, t]))
    return np.stack(ys, 1), h


def _inputs(b=2, s=64, nh=4, p=8, n=16, seed=0):
    ks = jax.random.split(jax.random.key(seed), 4)
    xdt = jax.random.normal(ks[0], (b, s, nh, p), jnp.float32) * 0.5
    dA = -jnp.abs(jax.random.normal(ks[1], (b, s, nh), jnp.float32)) * 0.3
    B = jax.random.normal(ks[2], (b, s, n), jnp.float32) * 0.5
    C = jax.random.normal(ks[3], (b, s, n), jnp.float32) * 0.5
    return xdt, dA, B, C


@pytest.mark.parametrize("chunk", [8, 16, 32, 64])
def test_ssd_chunked_vs_naive(chunk):
    xdt, dA, B, C = _inputs()
    y, h = ssm.ssd_chunked(xdt, dA, B, C, chunk)
    y_ref, h_ref = naive_ssd(xdt, dA, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_ssd_chunk_invariance(seed):
    """Property: result independent of chunk decomposition."""
    xdt, dA, B, C = _inputs(seed=seed)
    y1, h1 = ssm.ssd_chunked(xdt, dA, B, C, 16)
    y2, h2 = ssm.ssd_chunked(xdt, dA, B, C, 64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_decode_step_matches_chunked():
    """One decode step from the chunked final state == step s+1 of a
    sequence computed fully chunked."""
    xdt, dA, B, C = _inputs(s=65)
    y_full, _ = ssm.ssd_chunked(xdt[:, :64], dA[:, :64], B[:, :64],
                                C[:, :64], 16)
    _, h64 = ssm.ssd_chunked(xdt[:, :64], dA[:, :64], B[:, :64], C[:, :64],
                             16)
    # decode step semantics: x raw, dt folded -> pass xdt/dt with dt=1
    y_step, h65 = ssm.ssd_decode_step(
        h64.astype(jnp.float32), xdt[:, 64], jnp.ones(dA[:, 64].shape),
        dA[:, 64], B[:, 64], C[:, 64])
    y_ref, _ = naive_ssd(xdt, dA, B, C)
    np.testing.assert_allclose(np.asarray(y_step), y_ref[:, 64],
                               rtol=3e-4, atol=3e-4)


def test_mamba_forward_decode_continuity():
    """mamba_forward final state + mamba_decode == mamba_forward on s+1."""
    c = get_config("mamba2-1.3b").reduced()
    p = ssm.mamba_init(jax.random.key(0), c)
    x = jax.random.normal(jax.random.key(1), (2, 65, c.d_model),
                          jnp.float32) * 0.5
    y_full = ssm.mamba_forward(c, p, x[:, :65])
    y_pre, (conv_tail, h) = ssm.mamba_forward(c, p, x[:, :64],
                                              return_state=True)
    y_step, conv2, h2 = ssm.mamba_decode(c, p, x[:, 64:65], conv_tail, h)
    np.testing.assert_allclose(np.asarray(y_step[:, 0], np.float32),
                               np.asarray(y_full[:, 64], np.float32),
                               rtol=3e-3, atol=3e-3)
