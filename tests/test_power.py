"""jpwr-analog power measurement: integration properties (hypothesis),
method plumbing, suffix interpolation, export."""
import json
import math
import time

import pytest
from _prop import given, settings, st

from repro.power.ctxmgr import MeasuredScope, expand_suffix, get_power
from repro.power.frame import Frame
from repro.power.methods import (
    RaplPower, SyntheticPower, TPUModelPower, get_method,
    select_power_methods,
)
from repro.power.utilization import roofline_utilization_fn


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_constant_power_energy_exact():
    """Property: integrating constant power P over T seconds = P*T J."""
    clock = FakeClock()
    m = SyntheticPower(n_devices=2, base=150.0, amp=0.0, clock=clock)
    scope = MeasuredScope([m], interval_ms=1e9, clock=clock)  # manual sample
    scope._sample()
    for t in (1.0, 2.0, 3.0):
        clock.t = t
        scope._sample()
    edf, _ = scope.energy()
    for r in edf.records():
        assert math.isclose(r["energy_wh"], 150.0 * 3.0 / 3600.0,
                            rel_tol=1e-9)
        assert math.isclose(r["avg_power_w"], 150.0, rel_tol=1e-9)


@settings(max_examples=20, deadline=None)
@given(base=st.floats(10, 500), slope=st.floats(0, 100),
       n=st.integers(2, 50))
def test_linear_power_trapezoid_exact(base, slope, n):
    """Property: trapezoid integration is exact for linear P(t)."""
    clock = FakeClock()

    class Linear(SyntheticPower):
        def read(self):
            return {"d0": base + slope * clock()}

        def devices(self):
            return ["d0"]

    scope = MeasuredScope([Linear()], interval_ms=1e9, clock=clock)
    for i in range(n + 1):
        clock.t = i / n
        scope._sample()
    edf, _ = scope.energy()
    want_j = base * 1.0 + slope * 0.5  # integral over [0, 1]
    assert math.isclose(edf.records()[0]["energy_wh"], want_j / 3600,
                        rel_tol=1e-9)


def test_background_thread_sampling():
    with get_power([SyntheticPower(n_devices=1, base=100.0)],
                   interval_ms=5) as scope:
        time.sleep(0.08)
    assert len(scope.df) >= 5
    e = scope.total_energy_wh()
    assert e > 0


def test_tpu_model_power_utilization():
    util = {"v": 0.0}
    m = TPUModelPower(n_devices=4, utilization_fn=lambda: util["v"])
    assert all(abs(w - 60.0) < 1e-9 for w in m.read().values())
    util["v"] = 1.0
    assert all(abs(w - 220.0) < 1e-9 for w in m.read().values())
    util["v"] = 0.5
    assert all(abs(w - 140.0) < 1e-9 for w in m.read().values())


def test_rapl_graceful_when_absent():
    m = RaplPower(root="/nonexistent/powercap")
    assert not m.available()
    assert m.read() == {}


def _fake_powercap(tmp_path, uj: float):
    zone = tmp_path / "intel-rapl:0"
    zone.mkdir(exist_ok=True)
    (zone / "energy_uj").write_text(f"{int(uj)}\n")
    return tmp_path


def test_rapl_reads_fake_powercap_tree(tmp_path, monkeypatch):
    import repro.power.methods as pm

    fake_t = {"t": 100.0}
    monkeypatch.setattr(pm.time, "monotonic", lambda: fake_t["t"])
    root = _fake_powercap(tmp_path, 1_000_000)
    m = RaplPower(root=str(root))
    assert m.available()
    assert m.read() == {"intel-rapl:0": 0.0}   # first read: no baseline
    _fake_powercap(tmp_path, 3_000_000)        # +2 J over 2 s -> 1 W
    fake_t["t"] = 102.0
    assert m.read()["intel-rapl:0"] == pytest.approx(1.0)


def test_rapl_counter_wrap_uses_post_wrap_delta(tmp_path, monkeypatch):
    """Regression: when energy_uj wraps (new < old), read() must treat
    the post-wrap counter value as the energy delta — not report a
    negative (or bogus huge) power."""
    import repro.power.methods as pm

    fake_t = {"t": 50.0}
    monkeypatch.setattr(pm.time, "monotonic", lambda: fake_t["t"])
    root = _fake_powercap(tmp_path, 10_000_000)
    m = RaplPower(root=str(root))
    m.read()                                   # baseline at 10 J
    _fake_powercap(tmp_path, 4_000_000)        # counter wrapped to 4 J
    fake_t["t"] = 52.0
    w = m.read()["intel-rapl:0"]
    assert w == pytest.approx(4_000_000 / 2.0 / 1e6)  # 2 W, not negative
    assert w >= 0.0


def test_suffix_interpolation(monkeypatch):
    monkeypatch.setenv("SLURM_PROCID", "7")
    assert expand_suffix("_%q{SLURM_PROCID}") == "_7"
    assert expand_suffix("_%q{MISSING_VAR_XYZ}") == "_"


def test_export_csv(tmp_path):
    with get_power([SyntheticPower(n_devices=1)], interval_ms=5) as scope:
        time.sleep(0.02)
    scope.export(str(tmp_path), "csv", suffix="_r0")
    assert (tmp_path / "power_r0.csv").exists()
    assert (tmp_path / "energy_r0.csv").exists()
    text = (tmp_path / "energy_r0.csv").read_text()
    assert "energy_wh" in text


def test_frame_roundtrip():
    f = Frame.from_records([{"a": 1, "b": 2.5}, {"a": 3, "b": None}])
    assert f.col("a") == [1, 3]
    csv = f.to_csv()
    assert csv.splitlines()[0] == "a,b"
    assert len(f) == 2


# ---------------------------------------------------------------------------
# Roofline-grounded utilization for the analytic TPU model (ISSUE 6:
# the old utilization_fn was a constant 1.0 — full TDP for every cell)
# ---------------------------------------------------------------------------


def _dryrun_artifact(path, frac):
    path.write_text(json.dumps({"roofline": {"roofline_fraction": frac}}))


def test_roofline_utilization_averages_dryrun_fractions(tmp_path):
    _dryrun_artifact(tmp_path / "a.json", 0.2)
    _dryrun_artifact(tmp_path / "b.json", 0.6)
    _dryrun_artifact(tmp_path / "c.json", 7.0)    # clamps to 1.0
    (tmp_path / "junk.json").write_text("not json")          # skipped
    (tmp_path / "other.json").write_text('{"no": "roofline"}')
    fn = roofline_utilization_fn(dryrun_dir=str(tmp_path))
    assert fn() == pytest.approx((0.2 + 0.6 + 1.0) / 3)


def test_roofline_utilization_falls_back_with_warning(tmp_path, caplog):
    with caplog.at_level("WARNING", logger="repro.power.utilization"):
        fn = roofline_utilization_fn(dryrun_dir=str(tmp_path / "missing"),
                                     default=1.0)
    assert fn() == 1.0
    assert any("roofline" in r.message for r in caplog.records)


def test_tpu_model_selection_wires_roofline_occupancy(tmp_path,
                                                     monkeypatch):
    """select_power_methods(prefer='tpu_model') must bill at roofline
    occupancy, not constant TDP, when dry-run artifacts exist."""
    monkeypatch.setenv("REPRO_DRYRUN_DIR", str(tmp_path))
    _dryrun_artifact(tmp_path / "step.json", 0.25)
    methods, label = select_power_methods("tpu_model", n_devices=2)
    assert label == "tpu_model"
    (m,) = methods
    assert m.utilization_fn() == pytest.approx(0.25)
    want_w = m.idle_w + (m.tdp_w - m.idle_w) * 0.25
    assert all(w == pytest.approx(want_w) for w in m.read().values())
    assert want_w < m.tdp_w                      # no longer full-TDP
