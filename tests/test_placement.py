"""Mesh-aware placement: Placement normalization, schema-v3 records,
scaling-metric derivation + compare gating, and deferred SLURM records."""
import json

import pytest
from _prop import given, settings, st

from repro.bench import (
    Placement, ResultRecord, SCHEMA_VERSION, WorkloadRunner, WorkloadSpec,
    compare_sets, placement_label, point_key, stamp_scaling_metrics,
    unregister, workload,
)
from repro.bench.records import load_records, write_result_doc
from repro.bench.spec import Space


# ---------------------------------------------------------------------------
# Placement normalization
# ---------------------------------------------------------------------------


def test_placement_spellings_normalize_to_one_value():
    want = Placement.of({"dp": 2, "tp": 2})
    assert Placement.of("dp2tp2") == want
    assert Placement.of("dp=2,tp=2") == want
    assert Placement.of("tp2 dp2") == want          # order-insensitive
    assert Placement.of(want) is want
    assert want.label == "dp2tp2" and want.n_devices == 4


def test_placement_scalar_upconverts_to_pure_dp():
    p = Placement.of(4)
    assert p.dict() == {"dp": 4} and p.label == "dp4"
    assert Placement.of(None).label == "dp1"


def test_placement_mesh_always_has_data_and_model_axes():
    # the table-driven sharding rules name "data"/"model" unconditionally
    p = Placement.of("dp2")
    assert p.mesh_axes == ("data", "model") and p.mesh_shape == (2, 1)
    p = Placement.of({"dp": 2, "tp": 2})
    assert p.mesh_axes == ("data", "model") and p.mesh_shape == (2, 2)
    p = Placement.of({"pp": 4})
    assert "stage" in p.mesh_axes and p.n_devices == 4
    p = Placement.of({"pod": 2, "dp": 4, "tp": 2})
    assert p.mesh_axes == ("pod", "data", "model")
    assert p.mesh_shape == (2, 4, 2)


def test_placement_rejects_garbage():
    for bad in ("nope", "2dp", "", "dp2dp4"):
        with pytest.raises(ValueError):
            Placement.of(bad)
    with pytest.raises(ValueError):
        Placement.of(0)
    with pytest.raises(ValueError):
        Placement.of({"dp": -1})
    with pytest.raises(TypeError):
        Placement.of(2.5)


# ---------------------------------------------------------------------------
# @workload signature: scalar back-compat, placement kwarg
# ---------------------------------------------------------------------------


def _register_toy(**kw):
    return workload("toy_placement", analog="t",
                    space=Space({"x": [1]}), **kw)(
        lambda pt, ctx: {"run": lambda: {"seconds": 0.0}})


def test_workload_scalar_n_devices_upconverts():
    spec = _register_toy(n_devices=8)
    try:
        assert spec.placement.dict() == {"dp": 8}
        assert spec.n_devices == 8
    finally:
        unregister("toy_placement")


def test_workload_placement_kwarg_and_conflict():
    spec = _register_toy(placement={"dp": 2, "tp": 2})
    try:
        assert spec.placement.label == "dp2tp2"
    finally:
        unregister("toy_placement")
    with pytest.raises(ValueError, match="not both"):
        _register_toy(placement="dp2", n_devices=2)


def test_placement_axis_drives_per_point_resolution():
    spec = WorkloadSpec(
        name="w", analog="t", build=lambda pt, ctx: {},
        space=Space({"placement": ["dp1", "dp2", "dp4"], "bs": [8]}))
    pts = spec.space_for().expand()
    assert [spec.placement_for(p).n_devices for p in pts
            if p["bs"] == 8] == [1, 2, 4]
    assert spec.max_devices() == 4
    # no placement axis -> the spec default answers for every point
    spec2 = WorkloadSpec(name="w2", analog="t", build=lambda pt, ctx: {},
                         space=Space({"bs": [8]}),
                         placement=Placement.of("pp4"))
    assert spec2.placement_for({"bs": 8}).label == "pp4"
    assert spec2.max_devices() == 4


# ---------------------------------------------------------------------------
# schema v3 records
# ---------------------------------------------------------------------------


def test_v2_record_upconverts_to_pure_dp(tmp_path):
    v2 = {"schema_version": 2, "workload": "w", "point": {"bs": 8},
          "metrics": {"tokens_per_s": 10.0}, "power_source": "synthetic",
          "n_devices": 4, "attempts": 1, "status": "ok", "error": None,
          "git_sha": "f" * 40, "noise": {"rel_std": 0.01}}
    path = tmp_path / "r.json"
    path.write_text(json.dumps(
        {"schema_version": 2, "workload": "w", "records": [v2]}))
    [rec] = load_records(path)
    assert rec.placement == {"dp": 4} and rec.n_devices == 4
    assert "plc=dp4" in point_key(rec)
    # and a v3 re-save of the same record joins the upconverted v2 one
    fresh = ResultRecord(workload="w", point={"bs": 8},
                         metrics={"tokens_per_s": 10.0},
                         power_source="synthetic", placement={"dp": 4})
    assert point_key(fresh) == point_key(rec)


def test_placement_label_matches_spec_canonicalization():
    # one canonicalization everywhere: record labels must equal
    # Placement.label even for meshes whose canonical order is not
    # alphabetical (pod sorts first by _AXIS_ORDER, not by name)
    pod = {"pod": 2, "dp": 4}
    assert placement_label(pod) == Placement.of(pod).label == "pod2dp4"
    assert placement_label({"tp": 2, "dp": 2}) == "dp2tp2"


def test_placement_field_reconciles_n_devices():
    r = ResultRecord(workload="w", point={}, placement={"tp": 2, "dp": 2})
    assert r.n_devices == 4
    assert r.flat()["placement"] == "dp2tp2"
    assert r.schema_version == SCHEMA_VERSION == 3
    back = ResultRecord.from_dict(json.loads(json.dumps(r.to_dict())))
    assert back == r


@settings(max_examples=25)
@given(dp=st.integers(1, 64), tp=st.integers(1, 16),
       bs=st.integers(1, 512))
def test_placement_point_key_order_insensitive_property(dp, tp, bs):
    """The join key must not care how the placement dict was ordered —
    nor how the Space ordered its axes."""
    fwd = ResultRecord(workload="w", point={"bs": bs, "mode": "x"},
                       placement={"dp": dp, "tp": tp})
    rev = ResultRecord(workload="w", point={"mode": "x", "bs": bs},
                       placement={"tp": tp, "dp": dp})
    assert point_key(fwd) == point_key(rev)
    assert placement_label(fwd.placement) == placement_label(rev.placement)
    back = ResultRecord.from_dict(json.loads(json.dumps(fwd.to_dict())))
    assert point_key(back) == point_key(fwd)


# ---------------------------------------------------------------------------
# scaling metrics + compare gating
# ---------------------------------------------------------------------------


def _sweep(dp4_tok_s=400.0, dp4_eff_wh=4.0):
    """One llm-style sweep: dp1/dp2/dp4 cells of the same point."""
    def cell(n, tok_s, tokens_per_wh):
        return ResultRecord(
            workload="w", point={"bs": 8, "placement": f"dp{n}"},
            metrics={"tokens_per_s": tok_s, "tokens_per_wh": tokens_per_wh},
            power_source="synthetic", placement={"dp": n})

    recs = [cell(1, 100.0, 2.0), cell(2, 190.0, 1.9),
            cell(4, dp4_tok_s, dp4_eff_wh)]
    stamp_scaling_metrics(recs)
    return recs


def test_stamp_scaling_metrics_against_the_dp1_cell():
    r1, r2, r4 = _sweep()
    assert r1.metrics["tok_s_per_device"] == 100.0
    assert "scaling_efficiency" not in r1.metrics   # 1-dev cell is the ref
    assert r2.metrics["tok_s_per_device"] == 95.0
    assert r2.metrics["scaling_efficiency"] == pytest.approx(0.95)
    # wh/token ratio vs dp1 = eff_1 / eff_n
    assert r2.metrics["wh_per_token_scaling"] == pytest.approx(2.0 / 1.9)
    assert r4.metrics["scaling_efficiency"] == pytest.approx(1.0)
    assert r4.metrics["wh_per_token_scaling"] == pytest.approx(0.5)


def test_stamp_scaling_metrics_emulation_device_cap():
    """device_cap=1 (a 1-core host faking N devices): per-device figures
    normalize by min(n, cap), effective_devices is recorded, and the
    wh ratio is rescaled by n_eff/n to cancel the synthetic power model
    billing each fake device as a full chip."""
    def cell(n, tok_s, tokens_per_wh):
        return ResultRecord(
            workload="w", point={"bs": 8, "placement": f"dp{n}"},
            metrics={"tokens_per_s": tok_s, "tokens_per_wh": tokens_per_wh},
            power_source="synthetic", placement={"dp": n})

    r1, r2 = cell(1, 100.0, 2.0), cell(2, 190.0, 1.9)
    stamp_scaling_metrics([r1, r2], device_cap=1)
    assert r1.metrics["tok_s_per_device"] == 100.0
    assert "effective_devices" not in r1.metrics      # n_eff == n == 1
    assert r2.metrics["effective_devices"] == 1
    assert r2.metrics["tok_s_per_device"] == 190.0    # / n_eff, not / 2
    assert r2.metrics["scaling_efficiency"] == pytest.approx(1.9)
    assert r2.metrics["wh_per_token_scaling"] == pytest.approx(
        (2.0 / 1.9) * 0.5)
    # a cap at/above the mesh is a no-op — real-hardware semantics
    r1b, r2b = cell(1, 100.0, 2.0), cell(2, 190.0, 1.9)
    stamp_scaling_metrics([r1b, r2b], device_cap=8)
    assert r2b.metrics["tok_s_per_device"] == 95.0
    assert "effective_devices" not in r2b.metrics
    assert r2b.metrics["scaling_efficiency"] == pytest.approx(0.95)


def test_scaling_floor_violations_flags_collapsed_cells():
    from repro.bench import scaling_floor_violations
    recs = _sweep(dp4_tok_s=120.0)                    # dp4 eff 0.3
    viol = scaling_floor_violations(recs, floor=0.6)
    assert [(r.point["placement"], round(e, 2)) for r, e in viol] == [
        ("dp4", 0.3)]
    assert scaling_floor_violations(_sweep(), floor=0.6) == []


def test_stamp_scaling_metrics_without_dp1_twin_stays_silent():
    lone = ResultRecord(workload="w", point={"bs": 8, "placement": "dp4"},
                        metrics={"tokens_per_s": 400.0},
                        placement={"dp": 4})
    stamp_scaling_metrics([lone])
    assert lone.metrics["tok_s_per_device"] == 100.0
    assert "scaling_efficiency" not in lone.metrics


def test_compare_classifies_degraded_dp4_cell_as_regressed():
    """The acceptance drill: a dp4 cell whose scaling collapsed gates
    the compare engine even though its raw dp1 twin is untouched."""
    baseline = _sweep()                              # healthy: eff 1.0
    degraded = _sweep(dp4_tok_s=120.0, dp4_eff_wh=0.8)  # eff 0.3, wh 2.5x
    cmp = compare_sets(baseline, degraded)
    by_plc = {p.point["placement"]: p for p in cmp.points}
    assert by_plc["dp1"].status == "unchanged"
    assert by_plc["dp4"].status == "regressed"
    bad = {d.metric for d in by_plc["dp4"].deltas
           if d.status == "regressed"}
    assert "scaling_efficiency" in bad and "wh_per_token_scaling" in bad
    assert cmp.exit_code(fail_on_regression=True) == 3


# ---------------------------------------------------------------------------
# deferred records (mesh exceeds local devices)
# ---------------------------------------------------------------------------


def _toy_sweep_spec():
    def build(pt, ctx):
        assert ctx.placement.n_devices == 1     # dp64 never builds
        return {"run": lambda: {"tokens_per_s": 100.0, "seconds": 0.001}}

    return WorkloadSpec(
        name="toy_defer", analog="t", build=build,
        space=Space({"placement": ["dp1", "dp64"], "bs": [8]}))


def test_oversized_mesh_defers_with_rendered_slurm_script(tmp_path):
    runner = WorkloadRunner(_toy_sweep_spec(), out_dir=str(tmp_path),
                            power="none")
    recs = runner.run(verbose=False)
    by = {r.point["placement"]: r for r in recs}
    assert by["dp1"].ok
    deferred = by["dp64"]
    assert deferred.status == "deferred" and not deferred.ok
    assert deferred.n_devices == 64
    script = deferred.metrics["slurm_script"]
    # one script PER POINT: non-placement axes are in the filename so
    # same-mesh cells of a sweep cannot clobber each other's script
    text = (tmp_path / "toy_defer").joinpath(
        "slurm", "toy_defer_dp64_bs8.sbatch").read_text()
    assert script.endswith("toy_defer_dp64_bs8.sbatch")
    assert "#SBATCH --nodes=16" in text            # 64 chips / 4 per host
    assert "--suite toy_defer" in text and "placement=dp64" in text
    # the invoking run's settings ride along so the cluster record joins
    # the local result set (out tree + power label are in the point key)
    assert f"--out {tmp_path}" in text and "--power none" in text
    # round-trips through the schema and loads back as deferred
    loaded = load_records(tmp_path / "toy_defer" / "results.json")
    assert {r.status for r in loaded} == {"ok", "deferred"}


def test_compare_treats_deferred_as_missing_not_regression(tmp_path):
    ok = ResultRecord(workload="w", point={"placement": "dp64"},
                      metrics={"tokens_per_s": 1.0}, placement={"dp": 64})
    deferred = ResultRecord(workload="w", point={"placement": "dp64"},
                            placement={"dp": 64}, status="deferred",
                            error="mesh dp64 needs 64 devices")
    cmp = compare_sets([ok], [deferred])
    [p] = cmp.points
    assert p.status == "missing" and "deferred" in p.note
    assert cmp.exit_code(fail_on_regression=True) == 0
    assert cmp.exit_code(fail_on_missing=True) == 4
    # a deferred record must never be promoted as a baseline
    from repro.bench import promote
    assert promote([deferred], tmp_path) == []
