"""MoE: scatter dispatch vs dense oracle; capacity semantics; router."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import moe


def _cfg(**kw):
    c = get_config("granite-moe-3b-a800m").reduced()
    return dataclasses.replace(c, **kw) if kw else c


def test_dispatch_matches_dense_oracle():
    # capacity_factor high enough that nothing drops
    c = _cfg(capacity_factor=8.0)
    key = jax.random.key(0)
    p = moe.moe_init(key, c)
    x = jax.random.normal(jax.random.key(1), (2, 32, c.d_model),
                          jnp.float32)
    y_fast, aux_f = moe.moe_forward(c, p, x)
    y_ref, aux_r = moe.moe_forward_dense(c, p, x)
    np.testing.assert_allclose(np.asarray(y_fast), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(aux_f), float(aux_r), rtol=1e-5)


def test_capacity_drops_bounded():
    """With tiny capacity, outputs are a subset (dropped tokens -> residual
    contribution zero), never garbage."""
    c = _cfg(capacity_factor=0.25)
    p = moe.moe_init(jax.random.key(0), c)
    x = jax.random.normal(jax.random.key(1), (2, 64, c.d_model), jnp.float32)
    y, _ = moe.moe_forward(c, p, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    # dropped-token rows are exactly zero (before shared expert)
    if not c.moe_shared:
        norms = jnp.linalg.norm(y.reshape(-1, c.d_model), axis=-1)
        assert float((norms == 0).mean()) > 0  # something dropped


def test_router_topk_normalized():
    c = _cfg()
    p = moe.moe_init(jax.random.key(0), c)
    x = jax.random.normal(jax.random.key(2), (16, c.d_model), jnp.float32)
    w, e, aux = moe.router_topk(c, p, x)
    assert w.shape == (16, c.top_k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(e.max()) < c.n_experts
    assert float(aux) >= 1.0 - 1e-3  # aux >= 1 with equality at balance


def test_shared_expert_added():
    c = _cfg(moe_shared=True, capacity_factor=8.0)
    p = moe.moe_init(jax.random.key(0), c)
    x = jax.random.normal(jax.random.key(1), (1, 16, c.d_model), jnp.float32)
    y_with, _ = moe.moe_forward(c, p, x)
    p_no = dict(p)
    c_no = dataclasses.replace(c, moe_shared=False)
    y_without, _ = moe.moe_forward(c_no, p_no, x)
    assert float(jnp.abs(y_with - y_without).max()) > 1e-6


def test_expert_capacity_formula():
    c = _cfg(capacity_factor=1.25)
    cap = moe.expert_capacity(c, 1024)
    assert cap >= 1024 * c.top_k * 1.25 / c.n_experts - 1
    assert moe.expert_capacity(c, 4) >= 4  # floor
