"""CARAML harness: parameter spaces, runner, straggler watchdog, tables."""
import pytest

from repro.core import (
    BenchmarkSuite, Runner, Space, Step, StragglerWatchdog, divisible_batch,
    heatmap, table, tokens_per_s,
)
from repro.power.methods import SyntheticPower


def test_space_expansion_and_constraints():
    # the paper's exclusion: bs=16 impossible at dp=8 with micro-batch 4
    sp = Space({"global_batch": [16, 64], "dp": [4, 8], "micro_batch": [4]},
               [divisible_batch])
    pts = sp.expand()
    assert {"global_batch": 16, "dp": 8, "micro_batch": 4} not in pts
    assert {"global_batch": 16, "dp": 4, "micro_batch": 4} in pts
    assert len(pts) == 3


def test_runner_executes_and_persists(tmp_path):
    calls = []

    def bench(pt, ctx):
        calls.append(pt)
        return {"tokens_per_s": 100.0 * pt["bs"]}

    suite = BenchmarkSuite(
        name="t", space=Space({"bs": [1, 2]}),
        steps=[Step("run", bench)])
    r = Runner(suite, out_dir=str(tmp_path))
    recs = r.run(verbose=False)
    assert len(recs) == 2
    assert recs[1]["tokens_per_s"] == 200.0
    assert (tmp_path / "t" / "results.json").exists()
    assert (tmp_path / "t" / "manifest.json").exists()


def test_runner_power_measurement(tmp_path):
    import time

    def bench(pt, ctx):
        time.sleep(0.03)
        return {"x": 1}

    suite = BenchmarkSuite("p", Space({"bs": [1]}), [Step("run", bench)])
    r = Runner(suite, power_methods=[SyntheticPower(base=100.0)],
               out_dir=str(tmp_path), power_interval_ms=5)
    recs = r.run(verbose=False)
    assert recs[0]["run_energy_wh"] > 0


def test_runner_retries_then_records_error(tmp_path):
    attempts = []

    def flaky(pt, ctx):
        attempts.append(1)
        if len(attempts) < 2:
            raise RuntimeError("transient")
        return {"ok": 1}

    suite = BenchmarkSuite("f", Space({"bs": [1]}),
                           [Step("run", flaky, retries=3)])
    recs = Runner(suite, out_dir=str(tmp_path)).run(verbose=False)
    assert recs[0]["ok"] == 1 and len(attempts) == 2

    def broken(pt, ctx):
        raise ValueError("boom")

    suite2 = BenchmarkSuite("g", Space({"bs": [1]}),
                            [Step("run", broken, retries=2)])
    recs2 = Runner(suite2, out_dir=str(tmp_path)).run(verbose=False)
    assert "boom" in recs2[0]["run_error"]


def test_straggler_watchdog_flags_simulated_straggler():
    w = StragglerWatchdog(k=3.0, warmup=3)
    flagged = []
    times = [0.10, 0.10, 0.11, 0.10, 0.10, 0.10, 0.95, 0.10]  # one straggler
    for i, dt in enumerate(times):
        if w.observe(i, dt):
            flagged.append(i)
    assert flagged == [6]
    assert w.events[0]["dt"] == 0.95


def test_straggler_watchdog_tolerates_noise():
    w = StragglerWatchdog(k=3.0, warmup=3)
    import random
    rng = random.Random(0)
    flags = sum(w.observe(i, 0.1 + rng.uniform(-0.005, 0.005))
                for i in range(50))
    assert flags == 0


def test_table_and_heatmap_render():
    recs = [{"dp": 1, "bs": 16, "tps": 100.0},
            {"dp": 2, "bs": 16, "tps": 190.0},
            {"dp": 2, "bs": 32, "tps": 210.0}]
    t = table(recs)
    assert "tps" in t and "190.00" in t
    h = heatmap(recs, "dp", "bs", "tps")
    assert "OOM" in h  # missing (1, 32) cell marked like the paper's Fig. 4
    assert "210" in h


def test_tokens_per_s():
    assert tokens_per_s(256, 4096, 1.0) == 256 * 4096
