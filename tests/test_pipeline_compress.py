"""Pipeline parallelism + gradient compression (multi-device subprocess)."""
import pytest

from repro.parallel.pipeline import bubble_fraction


def test_bubble_fraction():
    # the paper attributes the IPU's low GPT throughput to this bubble
    assert bubble_fraction(4, 8) == pytest.approx(3 / 11)
    assert bubble_fraction(1, 8) == 0.0
    assert bubble_fraction(4, 1000) < 0.004  # amortized away


def test_pipeline_matches_sequential(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.data.synthetic import synthetic_tokens
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.models.common import apply_mlp, apply_norm
from repro.models import attention as attn
from repro.parallel.pipeline import pipeline_forward, stage_params_split

c = get_config("gpt-117m").reduced(n_layers=4, d_model=64, d_ff=128,
                                   n_heads=2, n_kv_heads=2, d_head=32,
                                   vocab=512)
mesh = make_mesh((4,), ("stage",))
params = lm.init(jax.random.key(0), c)
stage_params = stage_params_split(params["layers"], 4)

def layer_fn(stage_p, x):
    def body(x, lp):
        sp = lp["slot0"]
        h = apply_norm(c, sp["norm1"], x)
        x = x + attn.self_attention(c, sp["attn"], h, causal=True)
        x = x + apply_mlp(c, sp["mlp"], apply_norm(c, sp["norm2"], x))
        return x, None
    return jax.lax.scan(body, x, stage_p)[0]

toks = jnp.asarray(synthetic_tokens(8, 32, c.vocab)[:, :32])
x = lm._inputs_to_embeds(c, params, toks, None)
got = pipeline_forward(mesh, "stage", layer_fn,
                       stage_params, x.reshape(4, 2, 32, c.d_model))
want = layer_fn(jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]),
                             stage_params), x)
np.testing.assert_allclose(np.asarray(got.reshape(x.shape), np.float32),
                           np.asarray(want, np.float32),
                           rtol=2e-2, atol=2e-2)
print("pipeline == sequential OK")
""", n_devices=4)


def test_quantize_roundtrip_property():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from _prop import given, settings, st
    from repro.parallel.compress import dequantize_int8, quantize_int8

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), scale=st.floats(1e-3, 1e3))
    def prop(seed, scale):
        x = jax.random.normal(jax.random.key(seed), (64,)) * scale
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s) - x))
        assert err.max() <= float(s) / 2 + 1e-6  # half-ulp bound

    prop()
