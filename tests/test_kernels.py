"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps
+ hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels import ops, ref

SWEEP = [
    # b, sq, skv, h, kh, dh, causal, window, dtype
    (1, 128, 128, 1, 1, 64, True, None, jnp.float32),
    (2, 256, 256, 4, 2, 64, True, None, jnp.float32),
    (1, 256, 256, 8, 8, 128, True, None, jnp.bfloat16),
    (2, 128, 256, 4, 4, 64, True, None, jnp.float32),     # suffix decode
    (1, 256, 256, 6, 2, 64, True, 128, jnp.float32),      # windowed
    (1, 512, 512, 2, 1, 128, True, 256, jnp.bfloat16),    # windowed bf16
    (2, 256, 256, 4, 2, 64, False, None, jnp.float32),    # bidirectional
    (1, 384, 384, 3, 3, 64, True, None, jnp.float32),     # odd heads
]


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("b,sq,skv,h,kh,dh,causal,window,dt", SWEEP)
def test_flash_attention_sweep(b, sq, skv, h, kh, dh, causal, window, dt):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, dh), jnp.float32).astype(dt)
    k = jax.random.normal(ks[1], (b, skv, kh, dh), jnp.float32).astype(dt)
    v = jax.random.normal(ks[2], (b, skv, kh, dh), jnp.float32).astype(dt)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@settings(max_examples=10, deadline=None)
@given(
    bq=st.sampled_from([64, 128]),
    bk=st.sampled_from([64, 128]),
    seed=st.integers(0, 2 ** 16),
)
def test_flash_block_size_invariance(bq, bk, seed):
    """Property: output is independent of the block decomposition."""
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, 256, 2, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    a = ops.flash_attention(q, k, v, impl="pallas", interpret=True,
                            block_q=bq, block_k=bk)
    b = ops.flash_attention(q, k, v, impl="pallas", interpret=True,
                            block_q=256, block_k=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       scale_mag=st.floats(0.1, 100.0))
def test_flash_softmax_invariants(seed, scale_mag):
    """Property: attention output is a convex combination of V rows ->
    bounded by min/max of v, and shift-invariant in q scaling direction."""
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64), jnp.float32) * scale_mag
    k = jax.random.normal(ks[1], (1, 128, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), jnp.float32)
    out = np.asarray(ops.flash_attention(q, k, v, impl="pallas",
                                         interpret=True))
    assert np.all(out <= np.max(np.asarray(v)) + 1e-4)
    assert np.all(out >= np.min(np.asarray(v)) - 1e-4)
    assert np.all(np.isfinite(out))


@pytest.mark.parametrize("rows,d,dt", [
    (8, 64, jnp.float32), (37, 512, jnp.bfloat16), (300, 128, jnp.float32),
    (1, 1024, jnp.bfloat16),
])
def test_rmsnorm_sweep(rows, d, dt):
    key = jax.random.key(0)
    x = (jax.random.normal(key, (rows, d), jnp.float32) * 3).astype(dt)
    s = jax.random.normal(jax.random.key(1), (d,), jnp.float32)
    want = ref.rmsnorm_ref(x, s)
    got = ops.rmsnorm(x, s, impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), mag=st.floats(0.5, 50.0))
def test_rmsnorm_scale_invariance(seed, mag):
    """Property: rmsnorm(c*x) ~= rmsnorm(x) for positive c in the regime
    where the eps term is negligible (unit-scale inputs)."""
    x = jax.random.normal(jax.random.key(seed), (4, 256), jnp.float32)
    s = jnp.ones((256,))
    a = np.asarray(ops.rmsnorm(x, s, impl="pallas", interpret=True))
    b = np.asarray(ops.rmsnorm(x * mag, s, impl="pallas", interpret=True))
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
