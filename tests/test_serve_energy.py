"""ServeEngine energy accounting: exact Wh/token & Wh/request against the
SyntheticPower triangle waveform under a fake clock, energy splitting
across co-scheduled requests, and straggler detection on decode steps.

All scripted (no JAX device work): the fake clock advances by exact
amounts at each step, every step boundary lands on a sample, and the
triangle wave is piecewise linear between samples — so the trapezoid
integration in core.metrics is EXACT and the assertions use tight
tolerances.
"""
import math

import numpy as np
import pytest

from repro.core.metrics import window_energy_wh
from repro.core.runner import StragglerWatchdog
from repro.power.methods import SyntheticPower
from repro.serve.engine import ServeEngine
from repro.serve.requests import Request

J_PER_WH = 3600.0
BASE, AMP, PERIOD = 100.0, 100.0, 4.0


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def tri_power(t):
    """The SyntheticPower waveform, re-derived analytically."""
    u = (t / PERIOD) % 1.0
    return BASE + AMP * abs(2 * u - 1)


def tri_energy_wh(t0, t1, n=200_001):
    """Dense-trapezoid reference integral of the triangle waveform."""
    ts = np.linspace(t0, t1, n)
    ws = np.asarray([tri_power(t) for t in ts])
    joules = float(np.sum(0.5 * (ws[1:] + ws[:-1]) * np.diff(ts)))
    return joules / J_PER_WH


def make_engine(n_slots, *, prefill_dt, decode_dt=0.1, watchdog=None,
                decode_hook=None):
    clock = FakeClock()

    def prefill(slot, prompt):
        clock.advance(prefill_dt)
        return 1

    def decode(tokens, positions, active):
        dt = decode_hook() if decode_hook else decode_dt
        clock.advance(dt)
        return np.asarray(tokens) + 1

    eng = ServeEngine(
        n_slots=n_slots, max_len=64, prefill_fn=prefill, decode_fn=decode,
        clock=clock, sleep_fn=clock.advance,
        power_methods=[SyntheticPower(base=BASE, amp=AMP, period=PERIOD,
                                      clock=clock)],
        watchdog=watchdog)
    return eng, clock


def req(rid, budget, arrival=0.0):
    return Request(rid=rid, prompt=np.arange(4, dtype=np.int32),
                   max_new_tokens=budget, arrival_s=arrival)


def test_single_request_energy_exact():
    """One request spanning [0, 2]: P(t) = 200 - 50 t on that range
    (first falling edge of the triangle), so E = 300 J exactly."""
    eng, _ = make_engine(1, prefill_dt=0.5, decode_dt=0.5)
    out = eng.serve([req(0, budget=4)])
    (r,) = out.results
    want_wh = 300.0 / J_PER_WH
    assert math.isclose(r.energy_wh, want_wh, rel_tol=1e-9)
    assert math.isclose(r.wh_per_token, want_wh / 4, rel_tol=1e-9)
    s = out.summary
    assert math.isclose(s.wh_per_request, want_wh, rel_tol=1e-9)
    assert math.isclose(s.wh_per_token, want_wh / 4, rel_tol=1e-9)
    assert s.overhead_wh == pytest.approx(0.0, abs=1e-12)


def test_energy_exact_across_triangle_vertex():
    """Steps cross the waveform's t=2 vertex; samples land on it, so the
    integration stays exact against the dense reference."""
    eng, clock = make_engine(1, prefill_dt=1.0, decode_dt=1.0)
    out = eng.serve([req(0, budget=4)])
    (r,) = out.results
    assert clock.t == 4.0
    assert math.isclose(r.energy_wh, tri_energy_wh(0.0, 4.0), rel_tol=1e-6)


def test_coscheduled_requests_split_window_energy():
    """Two slots decoding together: each decode window's energy splits
    half/half; the solo tail of the longer request is billed solo."""
    eng, _ = make_engine(2, prefill_dt=0.25, decode_dt=0.5)
    out = eng.serve([req(0, budget=2), req(1, budget=4)])
    by = out.by_rid()
    # timeline: prefill0 [0,.25] -> prefill1 [.25,.5] -> shared decode
    # [.5,1.0] -> rid1 solo decodes [1.0,1.5], [1.5,2.0]
    e = lambda a, b: tri_energy_wh(a, b)
    want0 = e(0.0, 0.25) + e(0.5, 1.0) / 2
    want1 = e(0.25, 0.5) + e(0.5, 1.0) / 2 + e(1.0, 2.0)
    assert math.isclose(by[0].energy_wh, want0, rel_tol=1e-6)
    assert math.isclose(by[1].energy_wh, want1, rel_tol=1e-6)
    # attribution is conservative: total == sum of parts (no idle here)
    assert math.isclose(out.summary.attributed_wh,
                        out.summary.total_energy_wh, rel_tol=1e-9)


def test_idle_energy_is_overhead_not_attributed():
    """An arrival gap leaves the engine idle; that energy must land in
    overhead_wh, not on any request. (The idle window itself is only
    sampled at its ends, so the split is asserted against the engine's
    own sampled total, which is what it conserves.)"""
    eng, _ = make_engine(1, prefill_dt=0.5, decode_dt=0.5)
    out = eng.serve([req(0, budget=2, arrival=0.0),
                     req(1, budget=2, arrival=10.0)])
    s = out.summary
    assert s.overhead_wh > 0.0
    assert math.isclose(s.attributed_wh + s.overhead_wh,
                        s.total_energy_wh, rel_tol=1e-9)
    # both requests still billed identically (same work, same waveform
    # phase mod the 4 s period: arrivals 0 and 10 are half a period apart)
    by_energy = {r.rid: r.energy_wh for r in out.results}
    assert by_energy[0] > 0 and by_energy[1] > 0


def test_window_energy_constant_power():
    ts = [0.0, 1.0, 2.0, 3.0]
    ws = [150.0] * 4
    assert math.isclose(window_energy_wh(ts, ws, 0.5, 2.5),
                        150.0 * 2.0 / J_PER_WH, rel_tol=1e-12)


@pytest.mark.slow
def test_serve_bench_smoke_continuous_beats_fixed():
    """End-to-end benchmark acceptance: real jitted model, Poisson load,
    continuous batching sustains >= 1.5x fixed-batch tokens/s and emits
    the energy columns. ~10 s of real decode -> marked slow."""
    import benchmarks.serve_bench as sb

    records = sb.run("llama3.2-3b", seed=0, smoke=True)
    by = {r["policy"]: r for r in records}
    assert by["continuous"]["decode_tok_s"] >= 1.5 * by["fixed"]["decode_tok_s"]
    for rec in records:
        for col in ("decode_tok_s", "ttft_s", "wh_per_token",
                    "wh_per_request"):
            assert rec[col] > 0.0, (rec["policy"], col)


def test_straggler_watchdog_flags_slow_decode_step():
    calls = {"n": 0}

    def hook():
        calls["n"] += 1
        return 5.0 if calls["n"] == 8 else 0.1   # inject one 50x step

    wd = StragglerWatchdog(k=3.0, warmup=3)
    eng, _ = make_engine(1, prefill_dt=0.1, decode_hook=hook, watchdog=wd)
    out = eng.serve([req(0, budget=12)])
    assert len(out.straggler_events) == 1
    assert out.straggler_events[0]["step"] == 7   # 0-indexed decode step
    assert out.straggler_events[0]["dt"] == pytest.approx(5.0)