"""Slotted KV-cache ops: insert/reset/compact row semantics.

Small real-model caches (reduced config, CPU) — these are the primitives
the continuous-batching engine's admission path is built on.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.cache import (
    compact_slots, grow_caches, insert_slot, reset_slot, slotted_cache,
)

N_SLOTS, MAX_LEN, PROMPT = 3, 32, 5


@pytest.fixture(scope="module")
def setup():
    c = get_config("llama3.2-3b").reduced()
    params = lm.init(jax.random.key(0), c)
    return c, params


def _prefill_row(c, params, seed=0):
    tokens = jnp.asarray(
        np.random.default_rng(seed).integers(0, c.vocab, (1, PROMPT)))
    _, row, _ = lm.prefill(c, params, tokens)
    return grow_caches(row, MAX_LEN)


def _leaves(tree):
    return jax.tree.leaves(tree)


def test_slotted_cache_shapes_and_zeros(setup):
    c, params = setup
    caches = slotted_cache(c, N_SLOTS, MAX_LEN, params)
    for leaf in _leaves(caches):
        assert leaf.shape[1] == N_SLOTS          # batch axis is axis 1
        assert not np.any(np.asarray(leaf, np.float32))


def test_insert_slot_writes_only_target_row(setup):
    c, params = setup
    caches = slotted_cache(c, N_SLOTS, MAX_LEN, params)
    row = _prefill_row(c, params)
    caches = insert_slot(caches, row, jnp.int32(1))
    for leaf, rleaf in zip(_leaves(caches), _leaves(row)):
        got = np.asarray(leaf, np.float32)
        np.testing.assert_array_equal(got[:, 1], np.asarray(rleaf,
                                                            np.float32)[:, 0])
        assert not np.any(got[:, 0]) and not np.any(got[:, 2])


def test_reset_slot_zeroes_only_target_row(setup):
    c, params = setup
    caches = slotted_cache(c, N_SLOTS, MAX_LEN, params)
    row = _prefill_row(c, params)
    for s in range(N_SLOTS):
        caches = insert_slot(caches, row, jnp.int32(s))
    caches = reset_slot(caches, jnp.int32(1))
    for leaf, rleaf in zip(_leaves(caches), _leaves(row)):
        got = np.asarray(leaf, np.float32)
        want = np.asarray(rleaf, np.float32)[:, 0]
        assert not np.any(got[:, 1])             # scrubbed
        np.testing.assert_array_equal(got[:, 0], want)   # neighbors intact
        np.testing.assert_array_equal(got[:, 2], want)


def test_compact_slots_gathers_rows(setup):
    c, params = setup
    caches = slotted_cache(c, N_SLOTS, MAX_LEN, params)
    rows = [_prefill_row(c, params, seed=s) for s in range(N_SLOTS)]
    for s, row in enumerate(rows):
        caches = insert_slot(caches, row, jnp.int32(s))
    # pack rows (2, 0) to the front, recycle row 1's content at the back
    caches = compact_slots(caches, jnp.asarray([2, 0, 1]))
    for i, src in enumerate([2, 0, 1]):
        for leaf, rleaf in zip(_leaves(caches), _leaves(rows[src])):
            np.testing.assert_array_equal(
                np.asarray(leaf, np.float32)[:, i],
                np.asarray(rleaf, np.float32)[:, 0])
