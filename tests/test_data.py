"""Data pipeline: tokenizer, indexed dataset, sharded loader."""
import numpy as np
import pytest
from _prop import given, settings, st

from repro.data.indexed import IndexedDatasetReader, IndexedDatasetWriter
from repro.data.loader import ShardedLoader, lm_sample_fn
from repro.data.synthetic import (
    synthetic_images, synthetic_oscar_text, synthetic_tokens,
)
from repro.data.tokenizer import ByteFallbackTokenizer


def test_tokenizer_train_encode_decode():
    docs = synthetic_oscar_text(50, seed=1)
    tok = ByteFallbackTokenizer.train(docs, max_vocab=50257)
    ids = tok.encode("benchmark energy accelerator")
    assert ids[0] == tok.bos and ids[-1] == tok.eos
    text = tok.decode(ids)
    for w in ("benchmark", "energy", "accelerator"):
        assert w in text


@settings(max_examples=20, deadline=None)
@given(st.text(alphabet=st.characters(codec="ascii",
                                      exclude_categories=("Cc", "Cs")),
               min_size=1, max_size=40))
def test_tokenizer_byte_fallback_lossless_words(text):
    """Property: unknown words survive encode/decode via byte fallback."""
    tok = ByteFallbackTokenizer({}, max_vocab=50257)  # empty vocab
    words = text.split()
    out = tok.decode(tok.encode(text))
    for w in words:
        assert w in out


def test_tokenizer_ids_in_range():
    docs = synthetic_oscar_text(20)
    tok = ByteFallbackTokenizer.train(docs, max_vocab=1000)
    for d in docs[:5]:
        assert all(0 <= t < 1000 for t in tok.encode(d))


def test_indexed_dataset_roundtrip(tmp_path):
    w = IndexedDatasetWriter(tmp_path / "ds")
    docs = [np.arange(10), np.arange(5) + 100, np.arange(7) + 200]
    for d in docs:
        w.add_document(d)
    w.finalize(meta={"tokenizer": "test"})
    r = IndexedDatasetReader(tmp_path / "ds")
    assert r.n_documents == 3
    assert r.n_tokens == 22
    np.testing.assert_array_equal(r.document(1), docs[1])
    assert r.meta["tokenizer"] == "test"
    s = r.sample(0, 8)
    assert s.shape == (9,)  # seq_len + 1 for labels


def test_pipeline_text_to_samples(tmp_path):
    docs = synthetic_oscar_text(20, seed=2)
    tok = ByteFallbackTokenizer.train(docs)
    w = IndexedDatasetWriter(tmp_path / "oscar")
    for d in docs:
        w.add_document(tok.encode(d))
    w.finalize()
    r = IndexedDatasetReader(tmp_path / "oscar")
    fn = lm_sample_fn(r, seq_len=16)
    s = fn(3)
    assert s["tokens"].shape == (16,) and s["labels"].shape == (16,)
    np.testing.assert_array_equal(s["tokens"][1:], s["labels"][:-1])


def test_sharded_loader_rank_disjoint():
    seen = {}

    def sample(idx):
        return {"x": np.asarray([idx])}

    loaders = [ShardedLoader(sample, global_batch=8, rank=r, world=4)
               for r in range(4)]
    batches = [next(l) for l in loaders]
    for l in loaders:
        l.close()
    all_idx = np.concatenate([b["x"].ravel() for b in batches])
    assert len(set(all_idx.tolist())) == 8  # disjoint coverage
    assert sorted(all_idx.tolist()) == list(range(8))


def test_loader_deterministic_sequence():
    def sample(idx):
        return {"x": np.asarray([idx * 3])}

    l1 = ShardedLoader(sample, global_batch=4)
    a = [next(l1)["x"].ravel().tolist() for _ in range(3)]
    l1.close()
    l2 = ShardedLoader(sample, global_batch=4)
    b = [next(l2)["x"].ravel().tolist() for _ in range(3)]
    l2.close()
    assert a == b


def test_synthetic_generators():
    t = synthetic_tokens(4, 16, 1000)
    assert t.shape == (4, 17) and t.min() >= 0 and t.max() < 1000
    t2 = synthetic_tokens(4, 16, 1000)
    np.testing.assert_array_equal(t, t2)  # deterministic
    imgs, labels = synthetic_images(2, 32, 10)
    assert imgs.shape == (2, 32, 32, 3) and labels.shape == (2,)
