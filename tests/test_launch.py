"""Launch layer: CLIs end-to-end (tiny presets), Slurm script generation,
mesh helpers, power-measured training."""
import pathlib

import pytest

from repro.launch.slurm import SystemConfig, render_job, write_launch_scripts


def test_train_cli_end_to_end(capsys):
    from repro.launch.train import main
    res = main(["--arch", "llama3.2-3b", "--preset", "tiny", "--steps", "6",
                "--global-batch", "2", "--seq-len", "32"])
    assert res.steps_run == 6
    assert all(l > 0 for l in res.losses)


def test_serve_cli_end_to_end():
    from repro.launch.serve import main
    res = main(["--arch", "gpt-117m", "--preset", "tiny", "--batch", "2",
                "--prompt-len", "16", "--gen", "4"])
    assert res.tokens.shape == (2, 4)


def test_slurm_script_rendering():
    sys_cfg = SystemConfig(container="repro.sif", env={"FOO": "1"})
    script = render_job(job_name="train_granite", module="repro.launch.train",
                        args="--arch granite-8b", system=sys_cfg, n_pods=2)
    assert "#SBATCH --nodes=128" in script       # 2 pods x 64 hosts
    assert "JAX_COORDINATOR_ADDRESS" in script   # multi-pod rendezvous
    assert "SLURM_CPU_BIND=none" in script       # paper Sec V binding lesson
    assert "apptainer exec repro.sif" in script
    assert "export FOO=1" in script


def test_write_launch_scripts(tmp_path):
    written = write_launch_scripts(tmp_path, ["granite-8b", "qwen2-0.5b"])
    assert len(written) == 5  # 2 archs x 2 pod-configs + dryrun
    assert (tmp_path / "dryrun.sbatch").exists()
    text = (tmp_path / "train_granite-8b_pod2.sbatch").read_text()
    assert "--arch granite-8b" in text


def test_mesh_helpers():
    from repro.launch.mesh import axis_size, dp_axes, make_mesh
    m = make_mesh((1,), ("data",))
    assert dp_axes(m) == ("data",)
    assert axis_size(m, "data") == 1
    assert axis_size(m, "nonexistent") == 1
