"""Cross-run comparison engine: point-key join, noise-aware tolerances,
baseline promote/compare round trips, CLI regression gating, and the
power autoselection chain that labels the records compare joins on."""
import json

import pytest
from _prop import given, settings, st

from repro.bench import (
    ResultRecord, SCHEMA_VERSION, WorkloadRunner, WorkloadSpec,
    compare_sets, load_result_set, point_key, promote, save_records,
)
from repro.bench.cli import main
from repro.bench.compare import (
    IMPROVED, MISSING, NEW, POWER_MISMATCH, REGRESSED, UNCHANGED,
    diff_metric, effective_tolerance,
)
from repro.bench.records import load_records, write_result_doc
from repro.bench.spec import Space
from repro.core.runner import StragglerWatchdog
from repro.power.methods import RaplPower, select_power_methods


def rec(workload="w", point=None, metrics=None, power="synthetic",
        rel_std=0.0, **kw):
    return ResultRecord(
        workload=workload, point=point or {"bs": 8},
        metrics=metrics if metrics is not None else {"tokens_per_s": 100.0},
        power_source=power, noise={"rel_std": rel_std}, **kw)


# ---------------------------------------------------------------------------
# point key
# ---------------------------------------------------------------------------


def test_point_key_components():
    r = rec(point={"seq": 64, "global_batch": 8}, n_devices=4)
    key = point_key(r)
    assert key == "w|global_batch=8,seq=64|plc=dp4|power=synthetic"
    assert point_key(r, with_power=False) == "w|global_batch=8,seq=64|plc=dp4"


def test_point_key_distinguishes_power_and_devices():
    base = rec()
    assert point_key(base) != point_key(rec(power="rapl"))
    assert point_key(base) != point_key(rec(n_devices=2))
    assert point_key(base) != point_key(rec(point={"bs": 16}))
    # same device count, different mesh shape -> different measurement
    assert point_key(rec(placement={"dp": 4})) != \
        point_key(rec(placement={"dp": 2, "tp": 2}))


@settings(max_examples=25)
@given(a=st.integers(1, 512), b=st.integers(1, 512),
       c=st.floats(0.1, 100.0))
def test_point_key_order_insensitive_property(a, b, c):
    """The join key the whole engine depends on must not care how the
    Space happened to order its axes."""
    fwd = rec(point={"x": a, "y": b, "rate": c})
    rev = rec(point={"rate": c, "y": b, "x": a})
    assert point_key(fwd) == point_key(rev)
    assert point_key(fwd, with_power=False) == point_key(rev,
                                                         with_power=False)


@settings(max_examples=25)
@given(bs=st.integers(1, 1024), tps=st.floats(0.001, 1e6),
       wh=st.floats(0.0, 10.0), attempts=st.integers(1, 5),
       status=st.sampled_from(["ok", "error", "skipped"]),
       power=st.sampled_from(["rapl", "tpu_model", "synthetic", "none"]))
def test_result_record_json_roundtrip_property(bs, tps, wh, attempts,
                                               status, power):
    r = ResultRecord(workload="w", point={"bs": bs, "mode": "train"},
                     metrics={"tokens_per_s": tps, "wh_per_token": wh},
                     power_source=power, attempts=attempts, status=status,
                     error="boom" if status == "error" else None,
                     git_sha="f" * 40, noise={"rel_std": 0.01})
    back = ResultRecord.from_dict(json.loads(json.dumps(r.to_dict())))
    assert back == r
    assert point_key(back) == point_key(r)


# ---------------------------------------------------------------------------
# classification
# ---------------------------------------------------------------------------


def test_classification_regressed_improved_unchanged_missing_new():
    base = [rec(point={"bs": 1}, metrics={"tokens_per_s": 100.0}),
            rec(point={"bs": 2}, metrics={"tokens_per_s": 100.0}),
            rec(point={"bs": 3}, metrics={"tokens_per_s": 100.0}),
            rec(point={"bs": 4}, metrics={"tokens_per_s": 100.0})]
    cur = [rec(point={"bs": 1}, metrics={"tokens_per_s": 50.0}),   # -50%
           rec(point={"bs": 2}, metrics={"tokens_per_s": 200.0}),  # +100%
           rec(point={"bs": 3}, metrics={"tokens_per_s": 101.0}),  # noise
           # bs=4 vanished                                         -> missing
           rec(point={"bs": 5}, metrics={"tokens_per_s": 1.0})]    # new
    cmp = compare_sets(base, cur)
    by = {p.point["bs"]: p.status for p in cmp.points}
    assert by == {1: REGRESSED, 2: IMPROVED, 3: UNCHANGED,
                  4: MISSING, 5: NEW}
    assert cmp.exit_code() == 0
    assert cmp.exit_code(fail_on_regression=True) != 0
    assert [p.point["bs"] for p in cmp.regressions] == [1]


def test_lower_is_better_metrics_direction():
    base = [rec(metrics={"seconds": 1.0, "wh_per_token": 1.0})]
    slower = [rec(metrics={"seconds": 2.0, "wh_per_token": 0.2})]
    cmp = compare_sets(base, slower)
    (p,) = cmp.points
    assert p.status == REGRESSED          # time regressed wins over energy
    by_metric = {d.metric: d.status for d in p.deltas}
    assert by_metric == {"seconds": REGRESSED, "wh_per_token": IMPROVED}


def test_current_error_at_ok_baseline_point_is_a_regression():
    base = [rec()]
    cur = [rec(metrics={}, status="error", error="OOM")]
    cmp = compare_sets(base, cur)
    assert cmp.points[0].status == REGRESSED
    assert "OOM" in cmp.points[0].note
    # an errored *baseline* record gates nothing
    cmp2 = compare_sets(cur, base)
    assert cmp2.points[0].status == NEW


def test_skipped_current_point_is_missing_not_errored():
    base = [rec()]
    cur = [rec(metrics={}, status="skipped")]
    (p,) = compare_sets(base, cur).points
    assert p.status == MISSING and "skipped" in p.note
    assert compare_sets(base, cur).exit_code(fail_on_regression=True) == 0
    assert compare_sets(base, cur).exit_code(fail_on_missing=True) != 0


def test_additional_power_source_is_reported_not_dropped():
    """When the current run carries both the baseline's power source and
    an extra one, the extra measurement must surface as `new` — not
    vanish from the report."""
    base = [rec(power="synthetic")]
    cur = [rec(power="synthetic"),
           rec(power="rapl", metrics={"tokens_per_s": 90.0})]
    cmp = compare_sets(base, cur)
    by = {p.power_source: p.status for p in cmp.points}
    assert by == {"synthetic": UNCHANGED, "rapl": NEW}


def test_dual_power_baseline_with_clean_match_is_missing_not_mismatch():
    """A baseline measured under two power sources, re-run under one:
    the matched pair compares cleanly, so the other baseline row is
    merely absent — it must not fail --fail-on-regression as a
    power_mismatch."""
    base = [rec(power="synthetic"), rec(power="rapl")]
    cur = [rec(power="synthetic")]
    cmp = compare_sets(base, cur)
    by = {p.power_source: p.status for p in cmp.points}
    assert by == {"synthetic": UNCHANGED, "rapl": MISSING}
    assert cmp.exit_code(fail_on_regression=True) == 0
    assert cmp.exit_code(fail_on_missing=True) != 0


def test_report_notes_are_sanitized_for_csv_and_markdown():
    cur = [rec(metrics={}, status="error",
               error="RESOURCE_EXHAUSTED\nOut of memory, pipe | char")]
    cmp = compare_sets([rec()], cur)
    rows = cmp.points[0].flat()
    assert "\n" not in rows[0]["note"]
    assert "," not in rows[0]["note"] and "|" not in rows[0]["note"]
    assert len(cmp.to_csv().strip().splitlines()) == 2   # header + 1 row


def test_cli_rejects_negative_tolerances():
    from repro.bench.cli import _parse_tols
    with pytest.raises(SystemExit, match=">= 0"):
        _parse_tols("default=-1")
    assert _parse_tols("default=0") == {"default": 0.0}


def test_errored_point_surfaces_even_under_power_mismatch_dedup():
    """An errored record must report its crash even when the baseline
    holds the same point under a different power source — the mismatch
    dedup must not swallow the error."""
    base = [rec(power="synthetic")]
    cur = [rec(power="rapl", metrics={}, status="error", error="crash!")]
    cmp = compare_sets(base, cur)
    notes = " | ".join(p.note for p in cmp.points)
    assert "crash!" in notes
    assert any(p.status == REGRESSED for p in cmp.points)


def test_cli_promote_warns_about_stale_baseline_files(tmp_path, capsys):
    store = tmp_path / "baselines"
    old = _write_run(tmp_path, "old", 100.0)          # workload "wa"
    assert main(["compare", str(store), str(old), "--promote"]) == 0
    renamed = tmp_path / "renamed"
    save_records([rec(workload="wb", point={"bs": 1})], renamed / "wb")
    capsys.readouterr()
    main(["compare", str(store), str(renamed), "--promote"])
    err = capsys.readouterr().err
    assert "wa.json" in err and "removed or renamed" in err


def test_corrupt_records_fail_with_valueerror_not_typeerror(tmp_path):
    """Hand-edited documents must surface as the CLI's clean `error:`
    path (ValueError), never a raw TypeError/AttributeError traceback."""
    doc = {"schema_version": SCHEMA_VERSION, "workload": "w", "records": [
        {"point": {}, "schema_version": SCHEMA_VERSION}]}   # no workload
    p = tmp_path / "results.json"
    p.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="workload"):
        load_records(p)
    nulled = {"workload": "w", "point": {"bs": 1}, "noise": None,
              "schema_version": SCHEMA_VERSION}
    doc["records"] = [nulled]
    p.write_text(json.dumps(doc))
    (r,) = load_records(p)                  # null noise is tolerated...
    assert r.noise == {} and r.rel_std == 0.0
    nulled["metrics"] = "oops"              # ...but wrong types are not
    p.write_text(json.dumps(doc))
    with pytest.raises(ValueError, match="metrics"):
        load_records(p)


def test_power_mismatch_is_flagged_not_silently_joined():
    base = [rec(power="rapl")]
    cur = [rec(power="synthetic", metrics={"tokens_per_s": 1.0})]
    cmp = compare_sets(base, cur)
    (p,) = cmp.points                      # one row, not mismatch + new
    assert p.status == POWER_MISMATCH
    assert "rapl" in p.note and "synthetic" in p.note
    assert cmp.exit_code(fail_on_regression=True) != 0


def test_unknown_metrics_are_ignored_not_gated():
    base = [rec(metrics={"n_rows": 10, "tokens_per_s": 100.0})]
    cur = [rec(metrics={"n_rows": 3, "tokens_per_s": 100.0})]
    (p,) = compare_sets(base, cur).points
    assert p.status == UNCHANGED
    assert {d.metric for d in p.deltas} == {"tokens_per_s"}


def test_lost_metric_is_a_gated_regression():
    """A compared metric that vanishes (e.g. energy accounting broke)
    must fail the gate, not ride along as a footnote."""
    base = [rec(metrics={"tokens_per_s": 100.0, "wh_per_token": 1.0})]
    cur = [rec(metrics={"tokens_per_s": 100.0})]
    (p,) = compare_sets(base, cur).points
    assert p.status == REGRESSED
    assert "wh_per_token" in p.note


def test_new_point_that_errors_fails_the_gate():
    """A just-added point that errors every run must not hide behind
    `new` forever (it is never promoted, so it would stay green)."""
    cur = [rec(point={"bs": 9}, metrics={}, status="error", error="OOM")]
    cmp = compare_sets([rec()], cur)
    by = {p.point["bs"]: p for p in cmp.points}
    assert by[9].status == REGRESSED and "OOM" in by[9].note
    assert cmp.exit_code(fail_on_regression=True) != 0


def test_saturated_tolerance_still_catches_collapse():
    """Ratio-scale classification: even when noise widening pushes the
    threshold past 1.0 (where a relative delta bottoms out at -100%),
    an order-of-magnitude throughput collapse must still regress."""
    base = [rec(rel_std=1.0)]                     # capped to 0.5
    collapse = [rec(metrics={"tokens_per_s": 10.0}, rel_std=1.0)]
    halved = [rec(metrics={"tokens_per_s": 50.0}, rel_std=1.0)]
    tols = {"default": 0.6}                       # the CI gate's widening
    # tol = 0.6 + 2*0.5 = 1.6 -> regress beyond 2.6x worse
    assert compare_sets(base, collapse,
                        tols=tols).points[0].status == REGRESSED
    assert compare_sets(base, halved,
                        tols=tols).points[0].status == UNCHANGED


# ---------------------------------------------------------------------------
# tolerance model
# ---------------------------------------------------------------------------


def test_tolerance_widens_with_recorded_variance():
    base = [rec(rel_std=0.0)]
    # 30% drop: beyond the 20% base tolerance...
    quiet = [rec(metrics={"tokens_per_s": 70.0}, rel_std=0.0)]
    assert compare_sets(base, quiet).points[0].status == REGRESSED
    # ...but a run that itself wobbled 15% widens the gate past it
    noisy = [rec(metrics={"tokens_per_s": 70.0}, rel_std=0.15)]
    assert compare_sets(base, noisy).points[0].status == UNCHANGED
    # the noisier side wins regardless of which side recorded it
    noisy_base = [rec(rel_std=0.15)]
    assert compare_sets(noisy_base, quiet).points[0].status == UNCHANGED
    # noise_k=0 disables widening
    assert compare_sets(base, noisy,
                        noise_k=0.0).points[0].status == REGRESSED


def test_effective_tolerance_caps_noise_and_honors_overrides():
    a, b = rec(rel_std=0.0), rec(rel_std=5.0)   # absurd recorded spread
    tol = effective_tolerance("tokens_per_s", a, b, noise_k=2.0)
    assert tol == pytest.approx(0.20 + 2.0 * 0.5)   # capped at 0.5
    assert effective_tolerance("tokens_per_s", a, a,
                               tols={"tokens_per_s": 0.05}) == 0.05
    assert effective_tolerance("tokens_per_s", a, a,
                               tols={"default": 0.33}) == 0.33


def test_workload_declared_tolerances_stamp_and_outrank_cli_default(
        tmp_path):
    """A spec's compare_tols ride in record.noise and survive a blanket
    CLI --rel-tol default (the CI gate must not re-arm an exempted
    microbench); an explicit CLI per-metric override still wins."""
    spec = WorkloadSpec(name="toy_tols", analog="toy",
                        space=Space({"x": [1]}),
                        build=lambda pt, ctx: {
                            "run": lambda: {"us": 100.0}},
                        compare_tols={"default": float("inf")})
    (r,) = WorkloadRunner(spec, out_dir=str(tmp_path),
                          power="none").run(verbose=False)
    # inf is stamped as the string "inf": a bare `Infinity` literal would
    # make the committed baseline store non-RFC JSON
    assert r.noise["tols"] == {"default": "inf"}
    doc_text = (tmp_path / "toy_tols" / "results.json").read_text()
    json.loads(doc_text, parse_constant=lambda c: (_ for _ in ()).throw(
        ValueError(f"non-RFC JSON constant {c}")))
    (r,) = load_records(tmp_path / "toy_tols" / "results.json")
    slow = ResultRecord(workload="toy_tols", point={"x": 1},
                        metrics={"us": 900.0}, power_source="none",
                        noise=dict(r.noise))
    # 9x slower: exempted by the workload, even under a CLI default
    assert compare_sets([r], [slow]).points[0].status == UNCHANGED
    assert compare_sets([r], [slow],
                        tols={"default": 0.5}).points[0].status == UNCHANGED
    # an explicit per-metric CLI override re-arms the gate
    assert compare_sets([r], [slow],
                        tols={"us": 0.5}).points[0].status == REGRESSED


def test_diff_metric_zero_baseline_edge():
    assert diff_metric("tokens_per_s", 0.0, 0.0, 0.1).status == UNCHANGED
    assert diff_metric("tokens_per_s", 0.0, 5.0, 0.1).status == IMPROVED
    assert diff_metric("seconds", 0.0, 5.0, 0.1).status == REGRESSED


def test_degenerate_measurements_gate_as_regressions():
    """A Wh/time metric collapsing to exactly 0, or any NaN/inf value,
    is a broken measurement path — never 'improved' or 'unchanged'."""
    assert diff_metric("wh_per_token", 0.5, 0.0, 0.25).status == REGRESSED
    assert diff_metric("seconds", 1.0, 0.0, 0.2).status == REGRESSED
    nan, inf = float("nan"), float("inf")
    for bad in (nan, inf):
        assert diff_metric("tokens_per_s", 100.0, bad, 0.2
                           ).status == REGRESSED
        assert diff_metric("wh_per_token", bad, 0.5, 0.2
                           ).status == REGRESSED
    # even a tolerance-exempt workload (tol=inf) cannot launder NaN/zero
    assert diff_metric("us", 100.0, nan, inf).status == REGRESSED
    assert diff_metric("us", 100.0, 0.0, inf).status == REGRESSED
    # collapsing-to-zero *throughput* was already caught by the ratio path
    assert diff_metric("tokens_per_s", 100.0, 0.0, 0.2
                       ).status == REGRESSED


def test_equal_inf_is_unchanged_but_any_inf_transition_gates():
    """inf can be an honest value (wh_per_slo_request when nothing met
    the SLO): a cell saturated on BOTH sides is the same regime and
    must not flag forever, while entering or leaving inf is a regime
    change that gates until a human re-promotes."""
    inf = float("inf")
    same = diff_metric("wh_per_slo_request", inf, inf, 0.2)
    assert same.status == UNCHANGED
    assert diff_metric("wh_per_slo_request", 0.5, inf, 0.2
                       ).status == REGRESSED   # collapsed to inf
    assert diff_metric("wh_per_slo_request", inf, 0.5, 0.2
                       ).status == REGRESSED   # escaped inf: re-promote
    # opposite-sign infinities are NOT the same regime
    assert diff_metric("wh_per_slo_request", inf, -inf, 0.2
                       ).status == REGRESSED


def test_watchdog_rel_std_feeds_the_tolerance_model():
    w = StragglerWatchdog(warmup=3)
    assert w.rel_std() == 0.0
    for i, dt in enumerate([0.1, 0.2, 0.3]):
        w.observe(i, dt)
    assert 0.0 < w.rel_std() < 1.0


def test_runner_stamps_git_sha_and_noise(tmp_path):
    spec = WorkloadSpec(name="toy_cmp", analog="toy",
                        space=Space({"x": [1, 2]}),
                        build=lambda pt, ctx: {
                            "run": lambda: {"tokens_per_s": 10.0 * pt["x"]}})
    recs = WorkloadRunner(spec, out_dir=str(tmp_path),
                          power="none").run(verbose=False)
    for r in recs:
        assert r.schema_version == SCHEMA_VERSION
        assert "rel_std" in r.noise and r.noise["samples"] >= 1
        assert r.noise["source"] == "watchdog"   # build used no ctx.measure
        assert r.git_sha is None or len(r.git_sha) == 40
    # and the stamped records survive the save/load round trip
    assert load_records(tmp_path / "toy_cmp" / "results.json") == recs


def test_measure_split_spread_preferred_over_watchdog(tmp_path):
    """Workloads timed via ctx.measure get a *same-point* noise figure
    (split-window spread), not the watchdog's cross-point spread that
    mixes in sweep heterogeneity and saturates tolerances."""
    def build(pt, ctx):
        def run():
            m = ctx.measure(lambda: sum(range(2000)), power=False)
            return {"seconds": m.seconds}
        return {"run": run}

    spec = WorkloadSpec(name="toy_meas", analog="toy",
                        space=Space({"x": [1, 2, 3, 4]}), build=build)
    recs = WorkloadRunner(spec, out_dir=str(tmp_path), warmup=1, iters=4,
                          power="none").run(verbose=False)
    for r in recs:
        assert r.noise["source"] == "measure_split"
        assert 0.0 <= r.noise["rel_std"] < 1.0   # repetition noise, not
        # the orders-of-magnitude cross-point spread a sweep would show
    # a single timed window cannot estimate spread: it must fall back to
    # the watchdog, never fabricate a zero-noise "measure_split" claim
    recs1 = WorkloadRunner(spec, out_dir=str(tmp_path / "i1"),
                           power="none", iters=1).run(verbose=False)
    assert all(r.noise["source"] == "watchdog" for r in recs1)


# ---------------------------------------------------------------------------
# baseline store: promote -> compare round trip
# ---------------------------------------------------------------------------


def test_promote_compare_roundtrip(tmp_path):
    store = tmp_path / "baselines"
    recs = [rec(workload="wa", point={"bs": b}) for b in (1, 2)] + \
           [rec(workload="wb", point={"n": 1}, metrics={"seconds": 0.5}),
            rec(workload="wb", point={"n": 2}, status="error", error="x",
                metrics={})]
    written = promote(recs, store)
    assert [p.name for p in written] == ["wa.json", "wb.json"]
    back = load_result_set(store)
    assert len(back) == 3                  # error record not promoted
    cmp = compare_sets(back, recs)
    # the three promoted points round-trip unchanged; the error record
    # (never promoted) surfaces as a gated regression on the current side
    statuses = sorted(p.status for p in cmp.points)
    assert statuses == [REGRESSED, UNCHANGED, UNCHANGED, UNCHANGED]
    ok_only = [r for r in recs if r.ok]
    cmp_ok = compare_sets(back, ok_only)
    assert all(p.status == UNCHANGED for p in cmp_ok.points)
    assert cmp_ok.exit_code(fail_on_regression=True,
                            fail_on_missing=True) == 0
    # re-promoting one workload replaces only that file
    promote([rec(workload="wa", point={"bs": 1},
                 metrics={"tokens_per_s": 500.0})], store)
    assert len(load_result_set(store / "wa.json")) == 1
    assert len(load_result_set(store / "wb.json")) == 1


def test_load_result_set_layouts(tmp_path):
    r = [rec()]
    save_records(r, tmp_path / "run" / "w")        # runner tree layout
    assert load_result_set(tmp_path / "run") == r
    assert load_result_set(tmp_path / "run" / "w") == r
    assert load_result_set(tmp_path / "run" / "w" / "results.json") == r
    assert load_result_set(tmp_path / "does-not-exist") == []


# ---------------------------------------------------------------------------
# schema validation (report / load path)
# ---------------------------------------------------------------------------


def test_load_records_rejects_future_and_foreign_docs(tmp_path):
    p = tmp_path / "results.json"
    p.write_text(json.dumps({"schema_version": SCHEMA_VERSION + 7,
                             "records": []}))
    with pytest.raises(ValueError, match="schema_version"):
        load_records(p)
    p.write_text(json.dumps({"rows": []}))
    with pytest.raises(ValueError, match="results document"):
        load_records(p)
    p.write_text(json.dumps([{"workload": "w"}]))   # pre-schema list
    with pytest.raises(ValueError, match="legacy"):
        load_records(p)


def test_v1_records_upconvert_with_default_provenance(tmp_path):
    v1 = {"workload": "w", "point": {"bs": 8}, "metrics": {"seconds": 1.0},
          "power_source": "rapl", "n_devices": 1, "attempts": 1,
          "status": "ok", "error": None, "schema_version": 1}
    p = tmp_path / "results.json"
    p.write_text(json.dumps({"schema_version": 1, "workload": "w",
                             "records": [v1]}))
    (r,) = load_records(p)
    assert r.git_sha is None and r.noise == {} and r.rel_std == 0.0
    # and it joins/compares fine against v2 records
    cur = rec(workload="w", point={"bs": 8}, metrics={"seconds": 1.0},
              power="rapl")
    assert compare_sets([r], [cur]).points[0].status == UNCHANGED


def test_report_cli_rejects_bad_schema_clearly(tmp_path, capsys):
    bad = tmp_path / "mystery" / "results.json"
    bad.parent.mkdir(parents=True)
    bad.write_text(json.dumps({"schema_version": 99, "records": []}))
    assert main(["report", "--out", str(tmp_path)]) == 2
    err = capsys.readouterr().err
    assert "schema_version" in err and "99" in err


# ---------------------------------------------------------------------------
# CLI: compare / --promote / --fail-on-regression
# ---------------------------------------------------------------------------


def _write_run(tmp_path, name, tps):
    out = tmp_path / name
    save_records([rec(workload="wa", point={"bs": 1},
                      metrics={"tokens_per_s": tps})], out / "wa")
    return out


def test_cli_compare_gates_on_regression(tmp_path, capsys):
    base = _write_run(tmp_path, "base", 100.0)
    good = _write_run(tmp_path, "good", 98.0)
    bad = _write_run(tmp_path, "bad", 40.0)
    assert main(["compare", str(base), str(good),
                 "--fail-on-regression"]) == 0
    capsys.readouterr()
    rc = main(["compare", str(base), str(bad), "--fail-on-regression"])
    assert rc != 0
    cap = capsys.readouterr()
    assert "regressed" in cap.out and "GATE" in cap.err
    # without the flag the diff is informational
    assert main(["compare", str(base), str(bad)]) == 0


def test_cli_compare_promote_and_reports(tmp_path, capsys):
    run = _write_run(tmp_path, "run", 100.0)
    store = tmp_path / "baselines"
    assert main(["compare", str(store), str(run), "--promote"]) == 0
    assert (store / "wa.json").exists()
    capsys.readouterr()
    # now the committed store gates an identical re-run green
    report = tmp_path / "report.md"
    assert main(["compare", str(store), str(run), "--fail-on-regression",
                 "--fail-on-missing", "--report-out", str(report)]) == 0
    assert "unchanged" in report.read_text()
    # csv report renders rows
    assert main(["compare", str(store), str(run), "--report", "csv",
                 "--all-points"]) == 0
    assert "workload" in capsys.readouterr().out
    # custom tolerance flips a mild delta into a regression
    mild = _write_run(tmp_path, "mild", 90.0)
    assert main(["compare", str(store), str(mild), "--fail-on-regression",
                 "--rel-tol", "tokens_per_s=0.01", "--noise-k", "0"]) != 0


def test_cli_compare_rejects_empty_current_set(tmp_path, capsys):
    """A typo'd run dir must not read as 'nothing regressed'."""
    base = _write_run(tmp_path, "base", 100.0)
    assert main(["compare", str(base), str(tmp_path / "typo"),
                 "--fail-on-regression"]) == 2
    assert "nothing to compare" in capsys.readouterr().err


def test_cli_promote_warns_on_all_error_workload(tmp_path, capsys):
    store = tmp_path / "baselines"
    good = _write_run(tmp_path, "good", 100.0)
    assert main(["compare", str(store), str(good), "--promote"]) == 0
    before = (store / "wa.json").read_text()
    broken = tmp_path / "broken"
    save_records([rec(workload="wa", point={"bs": 1}, metrics={},
                      status="error", error="boom")], broken / "wa")
    capsys.readouterr()
    main(["compare", str(store), str(broken), "--promote"])
    cap = capsys.readouterr()
    assert "NOT promoted" in cap.err
    assert (store / "wa.json").read_text() == before   # old baseline stands


def test_cli_compare_missing_gate(tmp_path):
    base = tmp_path / "base"
    save_records([rec(workload="wa", point={"bs": 1}),
                  rec(workload="wa", point={"bs": 2})], base / "wa")
    cur = _write_run(tmp_path, "cur", 100.0)   # only bs=1
    assert main(["compare", str(base), str(cur)]) == 0
    assert main(["compare", str(base), str(cur),
                 "--fail-on-missing"]) != 0


def test_cli_gate_lines_name_only_gated_statuses(tmp_path, capsys):
    """CI logs must not send readers chasing statuses the active flags
    did not actually gate on."""
    base = tmp_path / "base"
    save_records([rec(workload="wa", point={"bs": 1}),
                  rec(workload="wa", point={"bs": 2})], base / "wa")
    cur = tmp_path / "cur"                    # bs=1 regressed, bs=2 gone
    save_records([rec(workload="wa", point={"bs": 1},
                      metrics={"tokens_per_s": 10.0})], cur / "wa")
    assert main(["compare", str(base), str(cur),
                 "--fail-on-missing"]) != 0
    err = capsys.readouterr().err
    assert "GATE: missing" in err and "GATE: regressed" not in err
    assert main(["compare", str(base), str(cur),
                 "--fail-on-regression"]) != 0
    err = capsys.readouterr().err
    assert "GATE: regressed" in err and "GATE: missing" not in err


# ---------------------------------------------------------------------------
# power autoselection fallback chain -> labels land in records
# ---------------------------------------------------------------------------


def _run_auto(tmp_path, name):
    spec = WorkloadSpec(name=name, analog="toy", space=Space({"x": [1]}),
                        build=lambda pt, ctx: {
                            "run": lambda: {"tokens_per_s": 1.0}})
    (r,) = WorkloadRunner(spec, out_dir=str(tmp_path),
                          power="auto").run(verbose=False)
    return r


def test_power_fallback_chain_end_to_end(tmp_path, monkeypatch):
    """RAPL unavailable -> TPU model -> synthetic, with the winning label
    stamped into the records compare joins on."""
    # stage 1: fake powercap sysfs present -> rapl wins
    zone = tmp_path / "powercap" / "intel-rapl:0"
    zone.mkdir(parents=True)
    (zone / "energy_uj").write_text("123456\n")
    monkeypatch.setattr(RaplPower, "ROOT", str(tmp_path / "powercap"))
    monkeypatch.setenv("REPRO_TPU", "1")           # rapl must still win
    r1 = _run_auto(tmp_path / "o1", "toy_rapl")
    assert r1.power_source == "rapl"
    # stage 2: no RAPL, TPU flagged -> analytic model
    monkeypatch.setattr(RaplPower, "ROOT", str(tmp_path / "empty"))
    methods, src = select_power_methods("auto", n_devices=2)
    assert src == "tpu_model" and len(methods[0].devices()) == 2
    r2 = _run_auto(tmp_path / "o2", "toy_tpu")
    assert r2.power_source == "tpu_model"
    assert r2.metrics.get("tokens_per_s") == 1.0
    # stage 3: no RAPL, no TPU -> deterministic synthetic floor
    monkeypatch.delenv("REPRO_TPU")
    r3 = _run_auto(tmp_path / "o3", "toy_synth")
    assert r3.power_source == "synthetic"
    # the three labels never join silently: same point, disjoint keys
    keys = {point_key(ResultRecord(workload="t", point={"x": 1},
                                   power_source=r.power_source))
            for r in (r1, r2, r3)}
    assert len(keys) == 3
    cmp = compare_sets(
        [ResultRecord(workload="t", point={"x": 1}, power_source="rapl",
                      metrics={"tokens_per_s": 1.0})],
        [ResultRecord(workload="t", point={"x": 1},
                      power_source="synthetic",
                      metrics={"tokens_per_s": 1.0})])
    assert cmp.points[0].status == POWER_MISMATCH


def test_write_result_doc_is_loadable_and_versioned(tmp_path):
    path = tmp_path / "nested" / "wa.json"
    write_result_doc([rec(workload="wa")], path)
    doc = json.loads(path.read_text())
    assert doc["schema_version"] == SCHEMA_VERSION
    assert doc["workload"] == "wa"
    assert load_records(path) == [rec(workload="wa")]
