"""Optimizers + loss: schedules, clipping, convergence, CE correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.configs import get_config
from repro.train.loss import next_token_loss
from repro.train.optimizer import (
    OptConfig, adafactor_init, adafactor_update, adamw_init, adamw_update,
    clip_by_global_norm, global_norm, lr_at,
)


def test_lr_schedule_shape():
    oc = OptConfig(lr=1e-3, warmup=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_at(oc, jnp.int32(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1e-3) < 1e-9          # peak at end of warmup
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-3)  # min_lr at the end
    assert all(a >= b - 1e-12 for a, b in zip(lrs[2:], lrs[3:]))  # decay


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0}
    clipped, gn = clip_by_global_norm(tree, 1.0)
    assert float(gn) == pytest.approx(np.sqrt(90.0))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # under the limit: untouched
    small = {"a": jnp.ones((4,)) * 0.1}
    out, _ = clip_by_global_norm(small, 10.0)
    np.testing.assert_allclose(np.asarray(out["a"]), 0.1, rtol=1e-6)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges_quadratic(name):
    """min ||w - t||^2 — both optimizers must drive the loss down."""
    oc = OptConfig(name=name, lr=0.05, warmup=1, total_steps=200,
                   weight_decay=0.0, grad_clip=100.0)
    target = jnp.asarray(np.linspace(-1, 1, 32), jnp.float32).reshape(4, 8)
    params = {"w": jnp.zeros((4, 8), jnp.float32)}
    init = adamw_init if name == "adamw" else adafactor_init
    update = adamw_update if name == "adamw" else adafactor_update
    state = init(oc, params)

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(100):
        grads = jax.grad(loss)(params)
        params, state, _ = update(oc, grads, state, params)
    assert float(loss(params)) < l0 * 0.05


def test_adamw_master_weights_fp32():
    oc = OptConfig()
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(oc, params)
    assert state["master"]["w"].dtype == jnp.float32
    grads = {"w": jnp.full((4,), 0.1, jnp.float32)}
    new_p, new_s, info = adamw_update(oc, grads, state, params)
    assert new_p["w"].dtype == jnp.bfloat16
    assert new_s["master"]["w"].dtype == jnp.float32


def test_next_token_loss_matches_naive():
    c = get_config("gpt-117m").reduced(vocab=512)
    key = jax.random.key(0)
    logits = jax.random.normal(key, (2, 8, c.padded_vocab), jnp.float32)
    labels = jax.random.randint(key, (2, 8), 0, c.vocab)
    got = float(next_token_loss(c, logits, labels))
    # naive
    lf = np.asarray(logits, np.float64)
    p = np.exp(lf - lf.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    want = -np.mean([np.log(p[i, j, labels[i, j]])
                     for i in range(2) for j in range(8)])
    assert got == pytest.approx(want, rel=1e-4)


def test_loss_ignores_masked_labels():
    c = get_config("gpt-117m").reduced(vocab=512)
    logits = jax.random.normal(jax.random.key(0), (1, 4, c.padded_vocab))
    labels = jnp.asarray([[3, -1, -1, 7]], jnp.int32)
    full = jnp.asarray([[3, 5, 6, 7]], jnp.int32)
    l_masked = float(next_token_loss(c, logits, labels))
    l_full = float(next_token_loss(c, logits, full))
    assert l_masked != pytest.approx(l_full)


def test_loss_never_assigns_mass_to_vocab_padding():
    c = get_config("whisper-small").reduced(vocab=500)  # padded to 512
    from repro.models.common import unembed
    from repro.models import lm
    params = lm.init(jax.random.key(0), c)
    x = jax.random.normal(jax.random.key(1), (1, 4, c.d_model), jnp.float32)
    logits = unembed(c, params["embed"], x.astype(jnp.bfloat16))
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    assert float(probs[..., c.vocab:].max()) < 1e-6
