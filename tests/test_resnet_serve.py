"""ResNet50 (the paper's CV case) + serving engine end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.resnet50 import CONFIG as RESNET50
from repro.data.synthetic import synthetic_images, synthetic_tokens
from repro.models import lm, resnet
from repro.serve.engine import BatchedServer
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import make_resnet_train_step


def test_resnet_forward_shapes():
    c = RESNET50.reduced()
    p = resnet.init(jax.random.key(0), c)
    imgs, _ = synthetic_images(2, c.img_size, c.n_classes)
    logits = resnet.forward(c, p, jnp.asarray(imgs))
    assert logits.shape == (2, c.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_resnet_train_step_decreases_loss():
    c = RESNET50.reduced()
    oc = OptConfig(lr=1e-2, warmup=1, total_steps=50, weight_decay=0.0)
    p = resnet.init(jax.random.key(0), c)
    o = opt_init(oc, p)
    step = jax.jit(make_resnet_train_step(c, oc))
    imgs, labels = synthetic_images(8, c.img_size, c.n_classes)
    batch = {"images": jnp.asarray(imgs), "labels": jnp.asarray(labels)}
    losses = []
    for _ in range(8):
        p, o, m = step(p, o, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]  # memorizes the fixed batch


def test_resnet50_full_config_structure():
    # full ResNet50 has the (3,4,6,3) bottleneck layout = 50 conv layers
    assert RESNET50.stage_sizes == (3, 4, 6, 3)
    n_convs = 1 + sum(3 * n for n in RESNET50.stage_sizes)  # stem + 3/block
    assert n_convs == 49  # + fc = 50


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-1.3b"])
def test_batched_server_generates(arch):
    c = get_config(arch).reduced()
    params = lm.init(jax.random.key(0), c)
    server = BatchedServer(c, params, max_len=12)
    prompts = jnp.asarray(synthetic_tokens(2, 32, c.vocab)[:, :32])
    res = server.generate(prompts, 8)
    assert res.tokens.shape == (2, 8)
    assert int(res.tokens.max()) < c.padded_vocab
    assert res.decode_tokens_per_s > 0


def test_server_greedy_matches_forward():
    """First generated token == argmax of teacher-forced forward."""
    c = get_config("llama3.2-3b").reduced()
    params = lm.init(jax.random.key(0), c)
    server = BatchedServer(c, params, max_len=4)
    prompts = jnp.asarray(synthetic_tokens(2, 16, c.vocab)[:, :16])
    res = server.generate(prompts, 2)
    logits, _ = lm.forward(c, params, prompts, remat="none")
    want = np.argmax(np.asarray(logits[:, -1], np.float32), -1)
    got = np.asarray(res.tokens[:, 0])
    np.testing.assert_array_equal(got, want)
