"""SLO scoring: goodput edge cases, quantiles, Wh-per-SLO-met-request.

Pure host-side tests over ``serve.slo`` + ``core.metrics.percentile``
using hand-built ``RequestResult`` records with exactly known latencies.
"""
import math

import pytest

from repro.core.metrics import percentile
from repro.serve.requests import RequestResult
from repro.serve.slo import SLO, evaluate_slo


def _result(rid=0, ttft=0.1, tpot=0.01, n_tokens=5, tenant="",
            energy_wh=0.0):
    """A result with exact ttft_s/tpot_s: arrival 0, first token at
    ``ttft``, finish placed so the decode phase averages ``tpot``."""
    return RequestResult(
        rid=rid, prompt_len=4, tokens=list(range(n_tokens)),
        arrival_s=0.0, admitted_s=0.0, first_token_s=ttft,
        finish_s=ttft + tpot * max(n_tokens - 1, 0),
        tenant=tenant, energy_wh=energy_wh)


# -- percentile (nearest-rank) ---------------------------------------------


def test_percentile_edges():
    assert percentile([], 99.0) == 0.0
    assert percentile([3.0], 50.0) == 3.0
    xs = [5.0, 1.0, 3.0, 2.0, 4.0]
    assert percentile(xs, 0.0) == 1.0
    assert percentile(xs, 50.0) == 3.0
    assert percentile(xs, 100.0) == 5.0      # clamped to the max
    assert percentile(xs, 99.0) == 5.0


def test_percentile_nearest_rank_boundaries():
    """Nearest-rank definition pinned at its boundaries: rank
    ``ceil(q/100 * n)`` (1-indexed), with exact-multiple ranks snapped
    so float fuzz never bumps them up an element."""
    xs100 = [float(i) for i in range(1, 101)]
    assert percentile(xs100, 1.0) == 1.0      # rank 1, not 2
    assert percentile(xs100, 50.0) == 50.0    # exact multiple: rank 50
    assert percentile(xs100, 99.0) == 99.0    # rank 99, NOT the max
    assert percentile(xs100, 99.5) == 100.0   # rank ceil(99.5) = 100
    xs4 = [1.0, 2.0, 3.0, 4.0]
    assert percentile(xs4, 25.0) == 1.0       # r = 1.0 lands ON rank 1
    assert percentile(xs4, 75.0) == 3.0
    assert percentile(xs4, 76.0) == 4.0       # just past: next rank
    # p99 of n < 100 samples is the max — what the serve_slo ttft_p99
    # column (n=48 smoke trace) actually reports
    assert percentile(list(range(48, 0, -1)), 99.0) == 48
    # q=60 of 5 elements: r = 3.0 exactly; snap keeps it at rank 3
    assert percentile([1.0, 2.0, 3.0, 4.0, 5.0], 60.0) == 3.0


def test_tpot_edge_single_token():
    r = _result(n_tokens=1, ttft=0.5)
    assert r.tpot_s == 0.0                   # no decode phase to time


# -- goodput ----------------------------------------------------------------


def test_goodput_all_meet():
    rs = [_result(rid=i, ttft=0.1, tpot=0.01) for i in range(4)]
    rep = evaluate_slo(rs, SLO(ttft_s=1.0, tpot_s=0.1))
    assert rep.goodput == 1.0 and rep.n_met == 4


def test_goodput_zero_met_and_empty():
    rs = [_result(rid=i, ttft=5.0) for i in range(3)]
    rep = evaluate_slo(rs, SLO(ttft_s=1.0, tpot_s=0.1))
    assert rep.goodput == 0.0 and rep.n_met == 0
    empty = evaluate_slo([], SLO(ttft_s=1.0, tpot_s=0.1))
    assert empty.goodput == 0.0 and empty.n_requests == 0
    assert empty.wh_per_slo_request == 0.0   # no energy, no work: 0 not inf


def test_goodput_boundary_equality_counts_as_met():
    slo = SLO(ttft_s=0.5, tpot_s=0.02)
    on_budget = _result(ttft=0.5, tpot=0.02)
    assert slo.met_by(on_budget)
    rep = evaluate_slo([on_budget], slo)
    assert rep.goodput == 1.0


def test_goodput_requires_both_targets():
    slo = SLO(ttft_s=1.0, tpot_s=0.01)
    slow_decode = _result(ttft=0.1, tpot=0.5)     # TTFT fine, TPOT blown
    slow_first = _result(ttft=5.0, tpot=0.005)    # TPOT fine, TTFT blown
    rep = evaluate_slo([slow_decode, slow_first], slo)
    assert rep.n_met == 0


# -- energy per SLO-met request --------------------------------------------


def test_wh_per_slo_request():
    rs = [_result(rid=0, ttft=0.1, energy_wh=0.3),
          _result(rid=1, ttft=9.0, energy_wh=0.5)]   # misses
    rep = evaluate_slo(rs, SLO(ttft_s=1.0, tpot_s=1.0))
    # ALL attributed energy divides over only the met requests
    assert rep.energy_wh == pytest.approx(0.8)
    assert rep.wh_per_slo_request == pytest.approx(0.8)
    assert rep.goodput == 0.5


def test_wh_per_slo_request_inf_when_nothing_met():
    rs = [_result(ttft=9.0, energy_wh=0.2)]
    rep = evaluate_slo(rs, SLO(ttft_s=1.0, tpot_s=1.0))
    assert math.isinf(rep.wh_per_slo_request)


def test_total_energy_override():
    rs = [_result(ttft=0.1, energy_wh=0.3)]
    rep = evaluate_slo(rs, SLO(ttft_s=1.0, tpot_s=1.0),
                       total_energy_wh=1.2)
    assert rep.wh_per_slo_request == pytest.approx(1.2)


# -- per-tenant targets -----------------------------------------------------


def test_per_tenant_targets_and_default():
    rs = [_result(rid=0, ttft=0.3, tenant="chat", energy_wh=0.1),
          _result(rid=1, ttft=0.3, tenant="batch", energy_wh=0.2),
          _result(rid=2, ttft=0.3, tenant="unmapped", energy_wh=0.4)]
    rep = evaluate_slo(rs, {"chat": SLO(0.5, 1.0), "batch": SLO(0.1, 1.0)},
                       default=SLO(1.0, 1.0))
    # chat meets, batch misses its tighter target, unmapped uses default
    assert rep.n_met == 2
    assert set(rep.per_tenant) == {"chat", "batch", "unmapped"}
    assert rep.per_tenant["chat"].goodput == 1.0
    assert rep.per_tenant["batch"].goodput == 0.0
    assert rep.per_tenant["unmapped"].energy_wh == pytest.approx(0.4)


def test_missing_tenant_without_default_raises():
    with pytest.raises(AssertionError):
        evaluate_slo([_result(tenant="ghost")], {"chat": SLO(1.0, 1.0)})


def test_quantiles_in_report():
    rs = [_result(rid=i, ttft=float(i + 1) / 10) for i in range(10)]
    rep = evaluate_slo(rs, SLO(ttft_s=10.0, tpot_s=10.0))
    # nearest-rank p50 over 10 samples is the 5th smallest (index 4)
    assert rep.ttft_p50_s == pytest.approx(0.5)
    assert rep.ttft_p99_s == pytest.approx(1.0)
