"""Continuous-batching scheduler: admission/refill ordering, EOS early
exit, queue starvation, exact per-request token accounting.

Everything here drives ``ServeEngine`` in scripted mode (host-side fake
prefill/decode callables + a fake clock) — no JAX device work.
"""
import numpy as np
import pytest

from repro.serve.engine import ServeEngine
from repro.serve.requests import Request
from repro.serve.scheduler import Scheduler


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make_engine(n_slots, *, decode_fn=None, prefill_dt=0.5, decode_dt=1.0,
                max_len=64):
    """Scripted engine: prefill emits 1000 + 10*slot; decode increments
    each slot's token by 1 unless a custom decode_fn is given."""
    clock = FakeClock()

    def prefill(slot, prompt):
        clock.advance(prefill_dt)
        return 1000 + 10 * slot

    def default_decode(tokens, positions, active):
        clock.advance(decode_dt)
        return np.asarray(tokens) + 1

    eng = ServeEngine(
        n_slots=n_slots, max_len=max_len,
        prefill_fn=prefill, decode_fn=decode_fn or default_decode,
        clock=clock, sleep_fn=clock.advance)
    return eng, clock


def reqs(n, *, budget=4, gap=0.0, prompt_len=4, eos=None):
    budgets = budget if isinstance(budget, (list, tuple)) else [budget] * n
    return [Request(rid=i, prompt=np.arange(prompt_len, dtype=np.int32),
                    max_new_tokens=budgets[i], arrival_s=gap * i, eos_id=eos)
            for i in range(n)]


# ---------------------------------------------------------------------------
# Scheduler unit behavior
# ---------------------------------------------------------------------------


def test_submit_sorts_arrivals_and_keeps_equal_arrival_ties_fifo():
    """submit() maintains the arrival list by insertion (insort_right
    keyed on arrival_s): out-of-order submission still serves by
    arrival, and equal-arrival ties keep submission order (stable FIFO
    — right-insertion lands each tie after its equals)."""
    s = Scheduler(4, 64)
    for r in reqs(4, gap=0.0):           # every arrival at t = 0
        s.submit(r)
    assert [sl.request.rid for sl in s.refill(0.0)] == [0, 1, 2, 3]

    s2 = Scheduler(4, 64)
    for r in reversed(reqs(4, gap=1.0)):   # submit newest-first
        s2.submit(r)
    assert s2.next_arrival_s() == 0.0
    assert [sl.request.rid for sl in s2.refill(10.0)] == [0, 1, 2, 3]


def test_refill_admits_in_arrival_order():
    s = Scheduler(2, 64)
    for r in reqs(4, gap=1.0):
        s.submit(r)
    assert [sl.request.rid for sl in s.refill(0.0)] == [0]   # only rid 0
    assert [sl.request.rid for sl in s.refill(2.5)] == [1]   # 1 arrived
    # both slots busy; rid 2 arrived but must queue
    assert s.refill(2.5) == []
    assert s.n_pending == 2


def test_refill_fills_free_slots_fifo_after_exit():
    s = Scheduler(2, 64)
    for r in reqs(4, budget=1):        # every request finishes in 1 token
        s.submit(r)
    first = s.refill(0.0)
    assert [sl.request.rid for sl in first] == [0, 1]
    for sl in first:
        assert s.record_token(sl, 7) == "length"   # budget 1 -> done
    nxt = s.refill(0.0)
    assert [sl.request.rid for sl in nxt] == [2, 3]  # FIFO refill


def test_positions_track_prompt_plus_generated():
    s = Scheduler(1, 64)
    r = reqs(1, budget=5, prompt_len=7)[0]
    s.submit(r)
    (slot,) = s.refill(0.0)
    assert slot.pos == 7                       # prefill filled [0, 7)
    s.record_token(slot, 11)                   # token 1 (from prefill)
    assert s.positions()[0] == 7               # it writes at row 7 next
    s.record_token(slot, 12)                   # token 2 (decode step 1)
    assert s.positions()[0] == 8
    assert s.input_tokens()[0] == 12


def test_fixed_policy_admits_only_when_drained():
    s = Scheduler(2, 64, policy="fixed")
    for r in reqs(4, budget=2):
        s.submit(r)
    batch = s.refill(0.0)
    assert [sl.request.rid for sl in batch] == [0, 1]
    s.record_token(batch[0], 5)
    assert s.refill(0.0) == []                 # batch not drained
    for sl in batch:
        while sl.active:
            s.record_token(sl, 5)
    assert [sl.request.rid for sl in s.refill(0.0)] == [2, 3]


# ---------------------------------------------------------------------------
# Engine loop (scripted fake decode)
# ---------------------------------------------------------------------------


def test_exact_token_counts_and_values():
    eng, _ = make_engine(2)
    out = eng.serve(reqs(3, budget=3))
    by = out.by_rid()
    # slot s prefill emits 1000+10s; each decode step adds 1
    assert by[0].tokens == [1000, 1001, 1002]
    assert by[1].tokens == [1010, 1011, 1012]
    # rid 2 reuses a freed slot; counts stay exact
    assert len(by[2].tokens) == 3
    assert all(r.finish_reason == "length" for r in by.values())


def test_eos_early_exit_frees_slot_for_queue():
    calls = {"n": 0}

    def decode(tokens, positions, active):
        calls["n"] += 1
        out = np.asarray(tokens) + 1
        if calls["n"] == 1:
            out[0] = 99                        # slot 0 emits EOS
        return out

    eng, clock = make_engine(2, decode_fn=decode)
    # hold clock still during decode so admission order is deterministic
    eng.sleep_fn = clock.advance
    out = eng.serve(reqs(3, budget=10, eos=99))
    by = out.by_rid()
    assert by[0].finish_reason == "eos"
    assert by[0].tokens[-1] == 99
    assert by[0].n_tokens == 2                 # prefill token + EOS
    # rid 2 must take over slot 0 the moment it freed
    assert by[2].slot == 0
    assert by[1].finish_reason == "length" and by[1].n_tokens == 10
    assert by[2].finish_reason == "length" and by[2].n_tokens == 10


def test_queue_starvation_many_requests_few_slots():
    eng, _ = make_engine(2)
    n = 7
    out = eng.serve(reqs(n, budget=2))
    assert len(out.results) == n
    assert all(r.n_tokens == 2 for r in out.results)
    # never more than n_slots requests in any decode window
    for s in out.steps:
        if s.kind == "decode":
            assert 1 <= len(s.rids) <= 2
    # FIFO service: admission order == arrival (= rid) order
    admits = [s.rids[0] for s in out.steps if s.kind == "prefill"]
    assert admits == list(range(n))


def test_arrival_gaps_respected():
    eng, clock = make_engine(1, prefill_dt=0.25, decode_dt=0.25)
    out = eng.serve(reqs(2, budget=2, gap=100.0))
    by = out.by_rid()
    assert by[0].finish_s < 100.0              # rid 0 done before rid 1 exists
    assert by[1].admitted_s >= 100.0           # rid 1 waits for its arrival
    assert by[1].queue_s == pytest.approx(0.0, abs=0.06)  # admitted promptly


def test_continuous_beats_fixed_in_steps():
    """Same scripted workload: continuous takes fewer decode windows than
    the batch-fill baseline when budgets are ragged."""
    workload = dict(budget=[1, 8, 1, 8, 1, 8], gap=0.0)

    def run(policy):
        eng, _ = make_engine(2)
        out = eng.serve(reqs(6, **workload), policy=policy)
        return sum(1 for s in out.steps if s.kind == "decode")

    assert run("continuous") < run("fixed")