"""Fault injection + recovery: schedule determinism, checkpoint
durability (crash-mid-save atomicity, digest fallback), the bounded
supervisor, elastic rescale inputs, serve degradation, and the
transient/fatal retry classifier."""
import numpy as np
import pytest

import repro.ckpt.checkpoint as ckpt
from repro.bench.spec import Placement
from repro.ckpt.checkpoint import (CheckpointManager, latest_step,
                                   latest_valid_step, restore_resilient,
                                   save, verify_step)
from repro.ckpt.elastic import plan_rescale
from repro.configs import SHAPES, get_config
from repro.core.runner import AttemptInfo, classify_error, run_attempts
from repro.faults.schedule import (DeviceLoss, FaultEvent, FaultSchedule,
                                   FlakyPower, InjectedCrash,
                                   corrupt_checkpoint)
from repro.faults.supervisor import run_supervised
from repro.power.methods import FallbackPower, SyntheticPower
from repro.serve.engine import ServeEngine
from repro.serve.requests import Request
from repro.serve.slo import SLO, evaluate_slo


# ---------------------------------------------------------------------------
# fault schedules
# ---------------------------------------------------------------------------


def test_schedule_bit_reproducible_and_hashed():
    a = FaultSchedule.from_preset("crash_mid", seed=7, total_steps=40)
    b = FaultSchedule.from_preset("crash_mid", seed=7, total_steps=40)
    assert a.events == b.events
    assert a.schedule_hash == b.schedule_hash
    # the hash covers (preset, seed, total_steps, events): any change
    # to the failure story changes the stamp
    c = FaultSchedule.from_preset("crash_mid", seed=8, total_steps=40)
    assert c.schedule_hash != a.schedule_hash
    assert FaultSchedule.from_preset("none", seed=7).events == ()

    with pytest.raises(ValueError, match="unknown fault preset"):
        FaultSchedule.from_preset("meteor_strike")


def test_crash_events_fire_once_per_schedule():
    s = FaultSchedule.from_preset("crash_mid", seed=0, total_steps=30)
    at = s.events[0].at
    assert s.crash_at(at - 1) is None
    ev = s.crash_at(at)
    assert ev is not None and ev.kind == "crash"
    # the supervisor shares the schedule across restarts: the resumed
    # attempt walks past the same step without re-crashing
    assert s.crash_at(at) is None
    assert s.crash_at(s.total_steps) is None


def test_crash_at_catches_skipped_steps():
    """A resume that lands past the scheduled step still fires it."""
    s = FaultSchedule(
        "crash_mid", 0, 30, (FaultEvent("crash", at=10),))
    assert s.crash_at(15) is not None   # e.at <= step


def test_slowdown_and_overload_queries():
    s = FaultSchedule(
        "flaky", 0, 20,
        (FaultEvent("slowdown", at=5, seconds=0.02, span=2),
         FaultEvent("overload", at=3, n=2, span=4)))
    assert s.slowdown_s(4) == 0.0
    assert s.slowdown_s(5) == pytest.approx(0.02)
    assert s.slowdown_s(6) == pytest.approx(0.02)
    assert s.slowdown_s(7) == 0.0
    assert s.queue_cap_at(2) is None
    assert s.queue_cap_at(3) == 2
    assert s.queue_cap_at(6) == 2
    assert s.queue_cap_at(7) is None


# ---------------------------------------------------------------------------
# checkpoint durability
# ---------------------------------------------------------------------------


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(4, 8)).astype(np.float32),
            "nested": {"b": np.arange(10, dtype=np.int32),
                       "s": np.float32(1.5 + seed)}}


def test_crash_mid_save_keeps_previous_step(tmp_path, monkeypatch):
    """Kill the writer between the tmp dir and the atomic publish: the
    previous step must stay the latest (valid) checkpoint."""
    save(_tree(1), tmp_path, step=1)

    def boom(src, dst):
        raise OSError("simulated crash mid-publish")

    monkeypatch.setattr(ckpt.os, "replace", boom)
    with pytest.raises(OSError, match="mid-publish"):
        save(_tree(2), tmp_path, step=2)
    monkeypatch.undo()
    assert latest_step(tmp_path) == 1
    assert latest_valid_step(tmp_path) == 1
    got, manifest, skipped = restore_resilient(_tree(), tmp_path)
    assert manifest["step"] == 1 and skipped == []
    np.testing.assert_array_equal(got["w"], _tree(1)["w"])


def test_async_save_failure_reraised_at_wait(tmp_path, monkeypatch):
    mgr = CheckpointManager(tmp_path)
    monkeypatch.setattr(ckpt, "save",
                        lambda *a, **kw: (_ for _ in ()).throw(
                            OSError("disk full")))
    mgr.save_async(_tree(), 1)
    with pytest.raises(OSError, match="disk full"):
        mgr.wait()
    # the exception is consumed once surfaced; the manager is reusable
    monkeypatch.undo()
    mgr.save_async(_tree(), 2)
    mgr.wait()
    assert latest_step(tmp_path) == 2


def test_corrupt_checkpoint_detected_and_skipped(tmp_path):
    save(_tree(2), tmp_path, step=2)
    save(_tree(4), tmp_path, step=4)
    assert corrupt_checkpoint(tmp_path) == 4
    assert latest_step(tmp_path) == 4           # naive view: still newest
    assert not verify_step(tmp_path, 4)         # digest catches the flip
    assert latest_valid_step(tmp_path) == 2
    got, manifest, skipped = restore_resilient(_tree(), tmp_path)
    assert manifest["step"] == 2 and skipped == [4]
    np.testing.assert_array_equal(got["w"], _tree(2)["w"])


def test_restore_resilient_raises_when_nothing_valid(tmp_path):
    save(_tree(), tmp_path, step=3)
    corrupt_checkpoint(tmp_path, step=3)
    assert latest_valid_step(tmp_path) is None
    with pytest.raises(FileNotFoundError, match=r"corrupted: \[3\]"):
        restore_resilient(_tree(), tmp_path)


def test_restore_onto_smaller_mesh_numeric_equality(tmp_path, subproc):
    """A checkpoint written under a dp8 mesh restores bit-equal under a
    dp2 mesh (elastic restart: restore() reshards via device_put)."""
    out = subproc(f"""
    import jax, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.ckpt.checkpoint import save, restore

    devs = jax.devices()
    x = np.arange(64, dtype=np.float32).reshape(8, 8)
    big = Mesh(np.array(devs[:8]), ("data",))
    xs = jax.device_put(x, NamedSharding(big, P("data", None)))
    save({{"x": xs}}, {str(tmp_path)!r}, step=1)

    small = Mesh(np.array(devs[:2]), ("data",))
    sh = {{"x": NamedSharding(small, P("data", None))}}
    got, manifest = restore({{"x": x}}, {str(tmp_path)!r}, shardings=sh)
    assert manifest["step"] == 1
    assert got["x"].sharding.mesh.shape["data"] == 2
    np.testing.assert_array_equal(np.asarray(got["x"]), x)
    print("RESHARD_OK")
    """, n_devices=8)
    assert "RESHARD_OK" in out


# ---------------------------------------------------------------------------
# supervisor
# ---------------------------------------------------------------------------


class _FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_supervisor_resumes_and_prices_recovery(tmp_path):
    save(_tree(), tmp_path, step=4)
    clock = _FakeClock()
    sleeps = []
    calls = {"n": 0}

    def run_once(hook):
        calls["n"] += 1
        if calls["n"] == 1:
            clock.t += 1.0
            raise InjectedCrash(6)     # crashed at step 6, ckpt at 4
        clock.t += 0.5                 # time to rebuild + reach a step
        hook(4, {}, 0.0)
        return "done"

    out = run_supervised(run_once, ckpt_dir=tmp_path, seed=0,
                         sleep_fn=sleeps.append, clock=clock)
    assert out.result == "done"
    assert out.restarts == 1
    assert out.crash_steps == [6] and out.resume_steps == [4]
    assert out.wasted_steps == 2       # steps 5..6 recomputed
    assert out.ckpt_fallbacks == 0
    # the fake sleep doesn't advance the fake clock, so recovery_s here
    # is purely the rebuild-to-first-step time (real runs include backoff)
    assert out.recovery_s == pytest.approx(0.5)
    assert out.backoff_s == pytest.approx(sum(sleeps))


def test_supervisor_bounded_restarts_reraise():
    sleeps = []

    def always_crash(hook):
        raise InjectedCrash(3)

    with pytest.raises(InjectedCrash):
        run_supervised(always_crash, ckpt_dir=None, max_restarts=2,
                       seed=0, sleep_fn=sleeps.append,
                       clock=_FakeClock())
    # 2 restarts slept; the 3rd crash re-raises without sleeping.
    # Exponential envelope: delay_k in base*factor**(k-1) * [1, 1+jitter]
    assert len(sleeps) == 2
    assert 0.05 <= sleeps[0] <= 0.05 * 1.25
    assert 0.10 <= sleeps[1] <= 0.10 * 1.25


def test_supervisor_counts_ckpt_fallback_and_rescale(tmp_path):
    save(_tree(2), tmp_path, step=2)
    save(_tree(5), tmp_path, step=5)
    corrupt_checkpoint(tmp_path, step=5)
    losses = []
    calls = {"n": 0}

    def run_once(hook):
        calls["n"] += 1
        if calls["n"] == 1:
            raise DeviceLoss(6, 2)
        return "done"

    out = run_supervised(run_once, ckpt_dir=tmp_path, seed=0,
                         sleep_fn=lambda s: None, clock=_FakeClock(),
                         on_device_loss=losses.append)
    assert out.result == "done"
    assert out.resume_steps == [2]     # step 5 failed its digest
    assert out.ckpt_fallbacks == 1
    assert out.wasted_steps == 4       # crashed at 6, resumed from 2
    assert out.rescales == 1 and losses[0].n_lost == 2


# ---------------------------------------------------------------------------
# elastic rescale planning
# ---------------------------------------------------------------------------


def test_plan_rescale_accepts_placement():
    c = get_config("granite-8b")
    shape = SHAPES["train_4k"]
    from_tuple = plan_rescale(c, shape, (16, 16), lost_devices=32)
    from_placement = plan_rescale(c, shape,
                                  Placement.of({"dp": 16, "tp": 16}),
                                  lost_devices=32)
    assert from_tuple == from_placement
    assert from_placement.old_shape == (16, 16)
    assert from_placement.new_shape[1] == 16    # TP degree preserved
    # data axis shrank to the largest batch-divisible size <= 14
    assert from_placement.new_shape[0] <= 14
    assert shape.global_batch % from_placement.new_shape[0] == 0


def test_plan_rescale_rejects_pipeline_axes():
    c = get_config("granite-8b")
    with pytest.raises(ValueError, match="dp/tp placements only"):
        plan_rescale(c, SHAPES["train_4k"],
                     Placement.of({"dp": 8, "tp": 4, "pp": 2}),
                     lost_devices=8)
    with pytest.raises(ValueError, match="ambiguous bare mesh shape"):
        plan_rescale(c, SHAPES["train_4k"], (4, 4, 2), lost_devices=8)


# ---------------------------------------------------------------------------
# serve degradation
# ---------------------------------------------------------------------------


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _engine(n_slots=1, decode_dt=0.1):
    clock = _Clock()

    def prefill(slot, prompt):
        clock.advance(0.05)
        return 1

    def decode(tokens, positions, active):
        clock.advance(decode_dt)
        return np.asarray(tokens) + 1

    eng = ServeEngine(n_slots=n_slots, max_len=64, prefill_fn=prefill,
                      decode_fn=decode, clock=clock,
                      sleep_fn=clock.advance)
    return eng, clock


def _req(rid, budget=3, arrival=0.0, deadline=None):
    return Request(rid=rid, prompt=np.arange(4, dtype=np.int32),
                   max_new_tokens=budget, arrival_s=arrival,
                   deadline_s=deadline)


def _overload_schedule(cap, span=10_000):
    return FaultSchedule("overload", 0, 0,
                         (FaultEvent("overload", at=0, n=cap, span=span),))


def test_overload_sheds_newest_first_oldest_completes():
    eng, _ = _engine(n_slots=1)
    reqs = [_req(rid) for rid in range(5)]
    res = eng.serve(reqs, faults=_overload_schedule(cap=2))
    by = {r.rid: r for r in res.results}
    # FIFO degradation: the cap evicts from the queue TAIL — the oldest
    # waiting request is never the one shed
    assert by[0].finish_reason != "shed" and by[0].n_tokens > 0
    # cap=2 keeps the two OLDEST queued requests; rids 2-4 (the newest
    # arrivals) are shed, rids 0-1 both complete
    shed = sorted(r.rid for r in res.results if r.finish_reason == "shed")
    assert shed == [2, 3, 4]
    assert by[1].n_tokens > 0
    assert eng.shed == 3


def test_overload_shed_is_deterministic():
    outs = []
    for _ in range(2):
        eng, _ = _engine(n_slots=1)
        res = eng.serve([_req(rid) for rid in range(6)],
                        faults=_overload_schedule(cap=3))
        outs.append(tuple(sorted(
            (r.rid, r.finish_reason, r.n_tokens) for r in res.results)))
    assert outs[0] == outs[1]


def test_deadline_expiry_sheds_queued_request():
    eng, _ = _engine(n_slots=1, decode_dt=0.2)
    # rid 0 monopolizes the only slot for ~20 decode steps; rid 1's
    # admission deadline expires while it waits in the queue
    res = eng.serve([_req(0, budget=20),
                     _req(1, budget=2, deadline=0.5)])
    by = {r.rid: r for r in res.results}
    assert by[0].n_tokens == 20
    assert by[1].finish_reason == "shed" and by[1].n_tokens == 0


def test_slo_counts_shed_against_goodput():
    eng, _ = _engine(n_slots=1, decode_dt=0.2)
    res = eng.serve([_req(0, budget=20),
                     _req(1, budget=2, deadline=0.5)])
    report = evaluate_slo(res.results, SLO(ttft_s=100.0, tpot_s=100.0))
    assert report.n_requests == 2
    assert report.n_met == 1               # the shed request never meets
    assert report.goodput == pytest.approx(0.5)
    assert report.ttft_p99_s < 100.0       # quantiles over served only


# ---------------------------------------------------------------------------
# power-backend resilience
# ---------------------------------------------------------------------------


def test_fallback_power_degrades_with_labeled_source():
    primary = FlakyPower(SyntheticPower(n_devices=2, base=100.0),
                         fail_from=0, fail_count=100)
    fb = FallbackPower(primary, SyntheticPower(n_devices=1, base=50.0),
                       max_failures=3)
    assert fb.label == primary.name        # untouched until a fallback read
    for i in range(4):
        out = fb.read()                    # never raises
        assert set(out) == set(primary.devices())
        assert sum(out.values()) == pytest.approx(50.0)
    assert fb.degraded and fb.fallback_reads == 4
    assert fb.label.endswith("+fallback:synthetic")


def test_fallback_power_recovers_primary():
    primary = FlakyPower(SyntheticPower(n_devices=1, base=100.0),
                         fail_from=0, fail_count=2)
    fb = FallbackPower(primary, SyntheticPower(n_devices=1, base=50.0),
                       max_failures=3)
    assert sum(fb.read().values()) == pytest.approx(50.0)   # fail 1
    assert sum(fb.read().values()) == pytest.approx(50.0)   # fail 2
    assert sum(fb.read().values()) == pytest.approx(100.0)  # primary back
    assert not fb.degraded and fb.failures == 0


def test_flaky_power_window():
    p = FlakyPower(SyntheticPower(n_devices=1, base=10.0),
                   fail_from=1, fail_count=2)
    p.read()
    with pytest.raises(OSError, match="injected power-backend"):
        p.read()
    with pytest.raises(OSError):
        p.read()
    assert sum(p.read().values()) == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# retry classification + backoff
# ---------------------------------------------------------------------------


def test_classify_error_policy():
    assert not classify_error(ValueError("bad config"))
    assert not classify_error(AssertionError())
    assert classify_error(RuntimeError("env hiccup"))
    assert classify_error(InjectedCrash(3))        # transient attr wins

    class CacheOOM(Exception):
        pass

    assert classify_error(CacheOOM())              # transient by name

    class KnownBad(ValueError):
        transient = True                           # attr beats the type

    assert classify_error(KnownBad())


def test_run_attempts_fails_fast_on_fatal():
    calls = []

    def fatal():
        calls.append(1)
        raise ValueError("typo'd sweep")

    ok, metrics, info = run_attempts("pt", fatal, retries=5,
                                     sleep_fn=lambda s: None)
    assert not ok and len(calls) == 1
    assert isinstance(info, AttemptInfo)
    assert info.attempts == 1 and info.fatal
    assert "typo'd sweep" in metrics["pt_error"]


def test_run_attempts_backoff_schedule():
    sleeps = []
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return {"v": 1}

    ok, metrics, info = run_attempts("pt", flaky, retries=5,
                                     backoff_base=0.05, seed=0,
                                     sleep_fn=sleeps.append)
    assert ok and metrics == {"v": 1}
    assert info.attempts == 3 and not info.fatal
    assert info.backoff_s == pytest.approx(sum(sleeps))
    assert len(sleeps) == 2
    assert 0.05 <= sleeps[0] <= 0.05 * 1.25
    assert 0.10 <= sleeps[1] <= 0.10 * 1.25
