"""Prefix caching: index semantics, refcounted sharing, CoW, equivalence.

The contract mirrors test_paged_serve: prefix caching is a *performance*
feature — adopting shared KV blocks and prefilling only the suffix must
be invisible in the token streams. Float32 model for exact argmax
equality; allocator tests run host-side on abstract params.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.configs import get_config
from repro.models import lm
from repro.serve.cache import (
    CacheOOM, PagedKVCache, PrefixIndex, copy_blocks,
)
from repro.serve.engine import ServeEngine
from repro.serve.traffic import TenantSpec, TraceConfig, generate_trace

N_SLOTS, MAX_LEN, BS = 3, 64, 16

_CONFIG = get_config("llama3.2-3b").reduced(dtype="float32",
                                            param_dtype="float32")


@pytest.fixture(scope="module")
def setup():
    return _CONFIG, lm.init(jax.random.key(0), _CONFIG)


def _blank_cache(**kw):
    cache = PagedKVCache(_CONFIG, N_SLOTS, MAX_LEN, None, block_size=BS,
                         **kw)
    cache.enable_prefix_cache()
    return cache


def _toks(seed, n):
    return np.random.default_rng(seed).integers(1, 500, n).tolist()


# ---------------------------------------------------------------------------
# PrefixIndex (pure host)
# ---------------------------------------------------------------------------


def test_index_match_register_roundtrip():
    idx = PrefixIndex(BS)
    toks = _toks(0, 3 * BS + 5)
    new = idx.register(toks, [10, 11, 12])
    assert new == [10, 11, 12] and len(idx) == 3
    assert idx.match(toks) == [10, 11, 12]
    # a diverging last block matches only the common chain
    fork = toks[: 2 * BS] + _toks(1, BS)
    assert idx.match(fork) == [10, 11]
    # a different first token matches nothing (exact-chain keys)
    assert idx.match([999] + toks[1:]) == []


def test_index_match_cap_leaves_suffix():
    idx = PrefixIndex(BS)
    toks = _toks(2, 2 * BS)
    idx.register(toks, [7, 8])
    # uncapped: both blocks; capped at len-1: a fully-cached prompt
    # still leaves >= 1 token to prefill
    assert idx.match(toks) == [7, 8]
    assert idx.match(toks, max_tokens=len(toks) - 1) == [7]


def test_index_register_dedups_first_registrant_wins():
    idx = PrefixIndex(BS)
    toks = _toks(3, 2 * BS)
    assert idx.register(toks, [5, 6]) == [5, 6]
    # same content from other physical blocks: no new entries, the
    # canonical blocks stay
    assert idx.register(toks, [8, 9]) == []
    assert idx.match(toks) == [5, 6]
    # extending the chain registers only the new depth
    longer = toks + _toks(4, BS)
    assert idx.register(longer, [8, 9, 10]) == [10]


def test_index_lru_leaf_eviction_never_orphans():
    idx = PrefixIndex(BS)
    a = _toks(5, 2 * BS)
    idx.register(a, [1, 2])               # chain 1 -> 2
    b = _toks(6, BS)
    idx.register(b, [3])                  # independent root
    idx.match(b)                          # touch b: 2 is now LRU leaf
    e = idx.pop_lru_leaf()
    assert e.block == 2                   # the interior block 1 survives
    assert idx.match(a) == [1]
    assert {e2.block for e2 in [idx.pop_lru_leaf(), idx.pop_lru_leaf()]} \
        == {1, 3}
    assert idx.pop_lru_leaf() is None


def test_index_pop_all():
    idx = PrefixIndex(BS)
    idx.register(_toks(7, 2 * BS), [4, 5])
    assert sorted(idx.pop_all()) == [4, 5]
    assert len(idx) == 0


# ---------------------------------------------------------------------------
# Refcounted sharing on the allocator
# ---------------------------------------------------------------------------


def _conservation(cache):
    """Every pool block is either free or referenced; slot-owned and
    index-pinned references account for the full refcount mass."""
    refs = sum(cache._ref[1:])
    owned = sum(len(o) for o in cache._owned)
    pinned = len(cache.prefix_index.blocks()) if cache.prefix_index else 0
    assert refs == owned + pinned
    live = {b for o in cache._owned for b in o}
    if cache.prefix_index:
        live |= set(cache.prefix_index.blocks())
    assert len(live) + cache.free_blocks == cache.n_blocks - 1


def test_adopt_shares_and_free_keeps_shared_blocks():
    cache = _blank_cache()
    toks = _toks(8, 2 * BS + 4)
    cache.ensure(0, len(toks))
    assert cache.prefix_register(0, toks) == 2
    shared = cache.block_ids(0, 2 * BS).tolist()
    cache.adopt(1, shared)
    cache.ensure(1, len(toks))
    assert cache.block_ids(1, 2 * BS).tolist() == shared
    _conservation(cache)
    free0 = cache.free_blocks
    cache.free(0)
    # slot 0's tail block frees; the shared prefix blocks stay live
    assert cache.free_blocks == free0 + 1
    assert cache.block_ids(1, 2 * BS).tolist() == shared
    cache.free(1)
    _conservation(cache)
    # still pinned by the index, reclaimable on demand
    assert cache.reclaimable_blocks == 2
    cache.clear_prefix()
    assert cache.free_blocks == cache.n_blocks - 1
    _conservation(cache)


def test_ensure_reclaims_index_blocks_instead_of_oom():
    cache = PagedKVCache(_CONFIG, 2, MAX_LEN, None, block_size=BS,
                         n_blocks=1 + MAX_LEN // BS)   # one slot's worth
    cache.enable_prefix_cache()
    toks = _toks(9, MAX_LEN)
    cache.ensure(0, MAX_LEN)
    cache.prefix_register(0, toks)
    cache.free(0)
    assert cache.free_blocks == 0 and cache.reclaimable_blocks == 4
    assert cache.available_blocks == 4
    # a new slot's growth evicts LRU index entries instead of raising
    cache.ensure(1, MAX_LEN)
    assert cache.owned(1) == 4
    _conservation(cache)


def test_make_writable_cow_on_shared_block():
    cache = _blank_cache()
    cache.ensure(0, 2 * BS)
    blocks = cache.block_ids(0, 2 * BS).tolist()
    cache.adopt(1, blocks)
    src, dst = cache.make_writable(1, BS + 2)   # write into shared block 1
    assert src == [blocks[1]] and len(dst) == 1 and dst[0] != blocks[1]
    # slot 1 now owns a private copy; slot 0 untouched
    assert cache.block_ids(1, 2 * BS).tolist() == [blocks[0], dst[0]]
    assert cache.block_ids(0, 2 * BS).tolist() == blocks
    # exclusive blocks need no copy
    assert cache.make_writable(1, BS + 2) == ([], [])
    _conservation(cache)


def test_copy_blocks_moves_kv_content(setup):
    c, params = setup
    cache = PagedKVCache(c, N_SLOTS, MAX_LEN, params, block_size=BS)
    tok = jnp.asarray(_toks(10, BS), jnp.int32)[None]
    _, rows, _ = lm.prefill(c, params, tok)
    from repro.serve.cache import insert_paged_rows
    caches = insert_paged_rows(cache.caches, rows,
                               jnp.asarray([[2]], jnp.int32),
                               jnp.asarray([0], jnp.int32), block_size=BS)
    caches = copy_blocks(caches, jnp.asarray([2], jnp.int32),
                         jnp.asarray([5], jnp.int32))
    for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
        if getattr(path[-1], "key", None) in ("k", "v"):
            got = np.asarray(leaf, np.float32)
            np.testing.assert_array_equal(got[:, 5], got[:, 2])
            assert np.any(got[:, 5])          # real content moved


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_refcount_conservation_property(seed):
    """Random adopt/ensure/free/register/reclaim sequences preserve the
    pool-accounting invariants (no leaks, no double-frees)."""
    rng = np.random.default_rng(seed)
    cache = _blank_cache()
    prompts = {}
    for _ in range(30):
        op = rng.choice(["ensure", "register", "adopt", "free", "clear"])
        slot = int(rng.integers(0, N_SLOTS))
        if op == "ensure" and cache.available_blocks >= 4:
            if not cache._owned[slot]:
                toks = _toks(int(rng.integers(0, 5)),
                             int(rng.integers(1, MAX_LEN)))
                pre = cache.prefix_match(toks)
                cache.adopt(slot, pre)
                cache.ensure(slot, len(toks))
                prompts[slot] = toks
        elif op == "register" and cache._owned[slot] and slot in prompts:
            cache.prefix_register(slot, prompts[slot])
        elif op == "adopt":
            continue   # covered by ensure's match+adopt path
        elif op == "free":
            cache.free(slot)
            prompts.pop(slot, None)
        elif op == "clear":
            cache.clear_prefix()
        _conservation(cache)
    for s in range(N_SLOTS):
        cache.free(s)
    cache.clear_prefix()
    assert cache.free_blocks == cache.n_blocks - 1
    _conservation(cache)


# ---------------------------------------------------------------------------
# Engine equivalence: prefix caching is invisible in the token streams
# ---------------------------------------------------------------------------


def _shared_trace(n=8, prefix_len=32, seed=11):
    return generate_trace(TraceConfig(
        tenants=(TenantSpec("a", weight=0.4, rate_hz=300.0,
                            prompt_len=(3, 9), output_len=(3, 8),
                            prefix_group="sys", prefix_len=prefix_len),
                 TenantSpec("b", weight=0.4, rate_hz=300.0,
                            prompt_len=(3, 9), output_len=(3, 8),
                            prefix_group="sys", prefix_len=prefix_len),
                 TenantSpec("misc", weight=0.2, rate_hz=150.0,
                            prompt_len=(4, 10), output_len=(3, 6))),
        n_requests=n, vocab=_CONFIG.vocab, seed=seed))


@pytest.fixture(scope="module")
def served(setup):
    c, params = setup
    reqs = _shared_trace()

    def run(prefix):
        eng = ServeEngine(c, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                          cache="paged", block_size=BS,
                          prefix_cache=prefix, decode_window=4)
        out = eng.serve(reqs, policy="continuous")
        return eng, out

    return run(False), run(True)


def test_prefix_engine_tokens_bit_identical(served):
    (_, base), (_, pref) = served
    assert {r.rid: r.tokens for r in base.results} \
        == {r.rid: r.tokens for r in pref.results}


def test_prefix_engine_actually_hit(served):
    _, (eng, out) = served
    assert eng.prefix_stats["hit_requests"] > 0
    assert eng.prefix_stats["reused_blocks"] >= \
        2 * eng.prefix_stats["hit_requests"]   # 32-token prefix = 2 blocks
    assert eng.prefix_stats["registered_blocks"] >= 2
    # tenants rode through into the results
    assert {r.tenant for r in out.results} == {"a", "b", "misc"}


def test_prefix_engine_pool_drains_clean(served):
    _, (eng, _) = served
    paged = eng._paged
    assert all(len(o) == 0 for o in paged._owned)
    assert paged.free_blocks + paged.reclaimable_blocks \
        == paged.n_blocks - 1
    eng.reset_prefix_cache()
    assert paged.free_blocks == paged.n_blocks - 1
    assert eng.prefix_stats["hit_requests"] == 0


def test_prefix_requires_paged():
    with pytest.raises(AssertionError):
        ServeEngine(_CONFIG, None, cache="slotted", prefix_cache=True)
