"""Paged prefill flash attention: Pallas kernel vs oracle vs dense.

Three-way agreement plus the engine-dispatch contract:

  * ``kernels.ref.paged_prefill_attention_ref`` (the semantics oracle)
    must equal dense full-sequence attention on the concatenated
    [prefix ++ suffix] history, sliced to the suffix positions — paged
    prefill is a layout, not a math change, and suffix attention is
    independent of the prefix rows' own queries;
  * the Pallas kernel (interpret mode on CPU) must match the oracle to
    <= 1e-3 across shapes, block sizes, GQA group counts, prefix depths
    (pos_offset), shuffled block tables, windows and dtypes (the
    ISSUE 10 acceptance bar for the serve prefill hot path);
  * ``ServeEngine``'s chunked prefill and prefix-cache suffix prefill
    must actually dispatch ``ops.paged_prefill_attention`` — no dense
    prefix-KV gather on the paged path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.configs import get_config
from repro.kernels import ops, ref
from repro.kernels.prefill_attention import paged_prefill_attention
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.serve.requests import Request

SWEEP = [
    # g, kh, dh, bs, sq, npre, window, dtype
    (2, 2, 16, 16, 32, 3, None, jnp.float32),
    (1, 4, 32, 8, 24, 2, None, jnp.float32),     # MHA, tiny blocks
    (4, 2, 64, 16, 16, 1, None, jnp.bfloat16),   # wide GQA bf16
    (3, 2, 16, 16, 48, 2, None, jnp.float32),    # odd group count
    (2, 2, 16, 16, 32, 4, 40, jnp.float32),      # window crosses prefix
    (2, 1, 64, 16, 16, 2, 16, jnp.bfloat16),     # window == block bf16
    (2, 2, 16, 16, 144, 3, None, jnp.float32),   # Sq % 128 != 0 tile walk
]


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=1e-3, atol=1e-3)


def _prefill_setup(b, sq, npre, bs, kh, dh, g, dt, seed=0):
    """Random dense prefix histories scattered into a pool via shuffled
    block tables + a fresh suffix chunk (the chunked / prefix-cached
    serve prefill layout)."""
    rng = np.random.default_rng(seed)
    h = kh * g
    n_blocks = 1 + b * npre + 2          # trash + prefixes + idle spares
    dense_k = rng.normal(size=(b, npre * bs, kh, dh)).astype(np.float32)
    dense_v = rng.normal(size=(b, npre * bs, kh, dh)).astype(np.float32)
    k_pool = rng.normal(size=(n_blocks, bs, kh, dh)).astype(np.float32)
    v_pool = rng.normal(size=(n_blocks, bs, kh, dh)).astype(np.float32)
    tables = np.zeros((b, npre), np.int32)
    free = list(range(1, n_blocks))
    rng.shuffle(free)
    for i in range(b):
        for j in range(npre):
            blk = free.pop()
            tables[i, j] = blk
            k_pool[blk] = dense_k[i, j * bs:(j + 1) * bs]
            v_pool[blk] = dense_v[i, j * bs:(j + 1) * bs]
    q = rng.normal(size=(b, sq, h, dh)).astype(np.float32)
    k_suf = rng.normal(size=(b, sq, kh, dh)).astype(np.float32)
    v_suf = rng.normal(size=(b, sq, kh, dh)).astype(np.float32)
    to = lambda x: jnp.asarray(x, jnp.float32).astype(dt)
    return (to(q), to(k_suf), to(v_suf), to(k_pool), to(v_pool),
            jnp.asarray(tables), to(dense_k), to(dense_v))


@pytest.mark.parametrize("g,kh,dh,bs,sq,npre,window,dt", SWEEP)
def test_prefill_kernel_matches_oracle(g, kh, dh, bs, sq, npre, window, dt):
    (q, k_suf, v_suf, k_pool, v_pool, tables,
     _, _) = _prefill_setup(2, sq, npre, bs, kh, dh, g, dt)
    want = ref.paged_prefill_attention_ref(q, k_suf, v_suf, k_pool, v_pool,
                                           tables, window=window)
    got = paged_prefill_attention(q, k_suf, v_suf, k_pool, v_pool, tables,
                                  window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@pytest.mark.parametrize("g,kh,dh,bs,sq,npre,window,dt", SWEEP[:5])
def test_prefill_oracle_matches_dense_full_sequence(g, kh, dh, bs, sq, npre,
                                                    window, dt):
    """Paging is a layout: the paged oracle over the scattered pool must
    equal dense full-sequence causal attention over the contiguous
    [prefix ++ suffix], read at the suffix positions. The prefix rows'
    queries are free variables (suffix attention never sees them)."""
    (q, k_suf, v_suf, k_pool, v_pool, tables,
     dense_k, dense_v) = _prefill_setup(2, sq, npre, bs, kh, dh, g, dt,
                                        seed=3)
    got = ref.paged_prefill_attention_ref(q, k_suf, v_suf, k_pool, v_pool,
                                          tables, window=window)
    rng = np.random.default_rng(4)
    q_pre = jnp.asarray(rng.normal(size=(2, npre * bs, kh * g, dh)),
                        jnp.float32).astype(dt)
    q_full = jnp.concatenate([q_pre, q], axis=1)
    k_full = jnp.concatenate([dense_k, k_suf], axis=1)
    v_full = jnp.concatenate([dense_v, v_suf], axis=1)
    want = ref.flash_attention_ref(q_full, k_full, v_full, causal=True,
                                   window=window)[:, npre * bs:]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2 ** 16),
    bs=st.sampled_from([8, 16]),
    kh=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 3]),
    npre=st.integers(1, 4),
)
def test_prefill_kernel_property(seed, bs, kh, g, npre):
    """Property: kernel == oracle (<=1e-3) for random batch/suffix
    shapes (including Sq the tile walk-down must split unevenly),
    prefix depths, GQA groups and shuffled tables, with and without a
    sliding window."""
    rng = np.random.default_rng(seed)
    b = int(rng.integers(1, 4))
    sq = int(rng.integers(1, 49))
    window = None if rng.random() < 0.5 \
        else int(rng.integers(bs, npre * bs + sq))
    (q, k_suf, v_suf, k_pool, v_pool, tables,
     _, _) = _prefill_setup(b, sq, npre, bs, kh, 16, g, jnp.float32,
                            seed=seed + 1)
    want = ref.paged_prefill_attention_ref(q, k_suf, v_suf, k_pool, v_pool,
                                           tables, window=window)
    got = paged_prefill_attention(q, k_suf, v_suf, k_pool, v_pool, tables,
                                  window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_ops_dispatch_xla_equals_pallas():
    (q, k_suf, v_suf, k_pool, v_pool, tables,
     _, _) = _prefill_setup(2, 32, 3, 16, 2, 16, 2, jnp.float32, seed=7)
    a = ops.paged_prefill_attention(q, k_suf, v_suf, k_pool, v_pool, tables,
                                    impl="xla")
    b = ops.paged_prefill_attention(q, k_suf, v_suf, k_pool, v_pool, tables,
                                    impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Engine dispatch: the paged path never gathers dense prefix KV
# ---------------------------------------------------------------------------


def test_engine_prefill_paths_dispatch_paged_kernel(monkeypatch):
    """Both chunked prefill (non-first chunks) and prefix-cache suffix
    prefill must route through ``ops.paged_prefill_attention``. The spy
    wraps the op BEFORE the engines compile, so every traced prefill
    program records its dispatch; streams must stay bit-identical to
    the phased/cold runs made without the spy."""
    c = get_config("llama3.2-3b").reduced(dtype="float32",
                                          param_dtype="float32")
    params = lm.init(jax.random.key(0), c)
    rng = np.random.default_rng(21)
    shared = rng.integers(0, c.vocab, 32, np.int32)
    tails = rng.integers(0, c.vocab, (6, 6), np.int32)
    # more requests than slots: the first wave registers the shared
    # prefix, the second wave's admissions hit it (suffix prefill path)
    prefix_reqs = [Request(rid=i, prompt=np.concatenate([shared, tails[i]]),
                           max_new_tokens=8) for i in range(6)]
    long_reqs = [Request(rid=i, prompt=rng.integers(0, c.vocab, p, np.int32),
                         max_new_tokens=6)
                 for i, p in enumerate([48, 64, 40])]

    def make(**kw):
        return ServeEngine(c, params, n_slots=3, max_len=96, cache="paged",
                           block_size=16, decode_window=8, **kw)

    base_prefix = make(prefix_cache=True).serve(list(prefix_reqs),
                                                policy="continuous")
    base_chunk = make().serve(list(long_reqs), policy="continuous",
                              sched="chunked")

    calls = []
    real = ops.paged_prefill_attention

    def spy(*args, **kw):
        calls.append(kw.get("impl", "xla"))
        return real(*args, **kw)

    monkeypatch.setattr(ops, "paged_prefill_attention", spy)

    eng = make(prefix_cache=True)
    out = eng.serve(list(prefix_reqs), policy="continuous")
    assert eng.prefix_stats["hit_requests"] > 0
    n_prefix = len(calls)
    assert n_prefix > 0, "prefix-cache suffix prefill bypassed the kernel"

    eng2 = make()
    out2 = eng2.serve(list(long_reqs), policy="continuous", sched="chunked")
    assert len(calls) > n_prefix, "chunked prefill bypassed the kernel"

    # dispatching through the paged kernel is invisible in the streams
    assert {r.rid: r.tokens for r in out.results} \
        == {r.rid: r.tokens for r in base_prefix.results}
    assert {r.rid: r.tokens for r in out2.results} \
        == {r.rid: r.tokens for r in base_chunk.results}
