"""Config registry: param counts match published sizes; cell accounting."""
import pytest

from repro.configs import (
    ASSIGNED, PAPER_MODELS, REGISTRY, SHAPES, cells, get_config,
    skipped_cells, vocab_pad,
)

# (arch, expected total params in B, expected active in B, rel tolerance)
EXPECTED = [
    ("granite-8b", 8.25, 8.25, 0.12),
    ("qwen2-0.5b", 0.49, 0.49, 0.15),
    ("command-r-35b", 30.3, 30.3, 0.2),
    ("llama3.2-3b", 3.2, 3.2, 0.15),
    ("whisper-small", 0.24, 0.24, 0.3),
    ("llava-next-34b", 34.4, 34.4, 0.15),
    ("jamba-v0.1-52b", 51.5, 12.0, 0.15),
    ("mamba2-1.3b", 1.45, 1.45, 0.25),
    ("granite-moe-3b-a800m", 3.3, 0.95, 0.25),
    ("llama4-maverick-400b-a17b", 400.0, 17.0, 0.1),
    ("gpt-117m", 0.117, 0.117, 0.15),
    ("gpt-800m", 0.8, 0.8, 0.15),
    ("gpt-13b", 13.0, 13.0, 0.1),
    ("gpt-175b", 175.0, 175.0, 0.1),
]


@pytest.mark.parametrize("arch,total,active,tol", EXPECTED)
def test_param_counts(arch, total, active, tol):
    c = get_config(arch)
    assert abs(c.param_count() / 1e9 - total) / total < tol
    assert abs(c.active_param_count() / 1e9 - active) / active < tol


def test_registry_complete():
    assert len(ASSIGNED) == 10
    assert len(PAPER_MODELS) == 4
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                           "long_500k"}


def test_cells_and_skips():
    cs = cells()
    skips = skipped_cells()
    # 10 archs x 4 shapes = 40 total; long_500k runs only for the
    # sub-quadratic archs (mamba2, jamba, llama4-with-window)
    assert len(cs) + len(skips) == 40
    assert len(skips) == 7
    long_ok = {c.name for c, s in cs if s.name == "long_500k"}
    assert long_ok == {"mamba2-1.3b", "jamba-v0.1-52b",
                       "llama4-maverick-400b-a17b"}


def test_vocab_padding():
    assert vocab_pad(51865) % 256 == 0
    assert vocab_pad(51865) >= 51865
    assert vocab_pad(49152) == 49152
    for a in REGISTRY.values():
        assert a.padded_vocab % 16 == 0  # model-axis shardable


def test_reduced_configs_small():
    for a in ASSIGNED.values():
        r = a.reduced()
        assert r.param_count() < 20e6, (a.name, r.param_count())
        assert r.family == a.family


def test_layer_patterns():
    jamba = get_config("jamba-v0.1-52b")
    attn_layers = [i for i in range(jamba.n_layers) if jamba.is_attn_layer(i)]
    assert len(attn_layers) == 4  # 1:7 interleave over 32 layers
    moe_layers = [i for i in range(jamba.n_layers) if jamba.is_moe_layer(i)]
    assert len(moe_layers) == 16  # every 2nd layer
    l4 = get_config("llama4-maverick-400b-a17b")
    assert sum(l4.is_moe_layer(i) for i in range(l4.n_layers)) == 24
