"""Paged KV cache + serve engine equivalence.

The contract: the paged cache, the batched prefill, and the fused decode
window are *performance* features — they must be invisible in the token
streams. Everything here runs a small float32 model (bf16 argmax ties
would flake) and asserts exact equality between

  slotted/legacy-window == slotted/fused == paged/fused == paged/legacy

plus allocator invariants (no block aliasing across alloc/free/refill
sequences, OOM signalling, trash-block discipline) and the paged insert.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.configs import get_config
from repro.models import lm
from repro.serve.cache import (
    CacheOOM, PagedKVCache, grow_caches, insert_paged_rows, insert_rows,
    slotted_cache,
)
from repro.serve.engine import ServeEngine
from repro.serve.requests import Request, poisson_requests

N_SLOTS, MAX_LEN, BS, PROMPT = 3, 64, 16, 5

_CONFIG = get_config("llama3.2-3b").reduced(dtype="float32",
                                            param_dtype="float32")


@pytest.fixture(scope="module")
def setup():
    return _CONFIG, lm.init(jax.random.key(0), _CONFIG)


# ---------------------------------------------------------------------------
# Allocator invariants (host-side; abstract params — no real weights)
# ---------------------------------------------------------------------------


def _blank_cache(**kw):
    return PagedKVCache(_CONFIG, N_SLOTS, MAX_LEN, None, block_size=BS, **kw)


def test_paged_pool_shapes_and_trash_block():
    cache = _blank_cache()
    assert cache.max_blocks == MAX_LEN // BS
    assert cache.n_blocks == 1 + N_SLOTS * cache.max_blocks
    for path, leaf in jax.tree_util.tree_flatten_with_path(cache.caches)[0]:
        key = getattr(path[-1], "key", None)
        if key in ("k", "v"):
            assert leaf.shape[1:3] == (cache.n_blocks, BS)
        else:
            assert leaf.shape[1] == N_SLOTS   # state leaves stay slotted
    assert cache.free_blocks == cache.n_blocks - 1   # block 0 reserved
    assert np.all(cache.tables_np == 0)              # all columns -> trash


def test_ensure_allocates_and_frees_return():
    cache = _blank_cache()
    cache.ensure(1, PROMPT)                  # 5 tokens -> 1 block
    assert cache.owned(1) == 1
    cache.ensure(1, BS + 1)                  # crosses a block boundary
    assert cache.owned(1) == 2
    cache.ensure(1, BS + 1)                  # idempotent
    assert cache.owned(1) == 2
    ids = cache.block_ids(1, BS + 1)
    assert len(set(ids.tolist())) == 2 and 0 not in ids
    free_before = cache.free_blocks
    cache.free(1)
    assert cache.owned(1) == 0
    assert cache.free_blocks == free_before + 2
    assert np.all(cache.tables_np[1] == 0)   # row reverted to trash


def test_pool_oom_raises():
    cache = PagedKVCache(_CONFIG, N_SLOTS, MAX_LEN, None, block_size=BS,
                         n_blocks=1 + MAX_LEN // BS)   # one full slot only
    cache.ensure(0, MAX_LEN)
    with pytest.raises(CacheOOM):
        cache.ensure(1, 1)
    cache.free(0)
    cache.ensure(1, 1)                       # freed blocks are reusable


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16))
def test_alloc_free_never_aliases_blocks(seed):
    """Property: across random alloc/free/refill sequences, no physical
    block (except trash) is ever owned by two slots, and the table rows
    mirror the owned lists exactly."""
    cache = _blank_cache()
    rng = np.random.default_rng(seed)
    lengths = [0] * N_SLOTS
    for _ in range(50):
        slot = int(rng.integers(N_SLOTS))
        if rng.random() < 0.3 and lengths[slot]:
            cache.free(slot)
            lengths[slot] = 0
        else:
            lengths[slot] = min(lengths[slot] + int(rng.integers(1, 20)),
                                MAX_LEN)
            cache.ensure(slot, lengths[slot])
        owned = [cache.tables_np[s, :cache.owned(s)].tolist()
                 for s in range(N_SLOTS)]
        flat = [b for row in owned for b in row]
        assert 0 not in flat                      # trash is never owned
        assert len(flat) == len(set(flat))        # no aliasing
        for s in range(N_SLOTS):                  # unowned columns -> trash
            assert np.all(cache.tables_np[s, cache.owned(s):] == 0)
        assert len(flat) + cache.free_blocks == cache.n_blocks - 1


# ---------------------------------------------------------------------------
# Insert + decode equivalence (real model, fp32)
# ---------------------------------------------------------------------------


def test_paged_insert_then_decode_matches_slotted(setup):
    """One prefilled prompt inserted into both layouts, then a decode
    step: logits must agree (same math, different addressing)."""
    c, params = setup
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, c.vocab, (1, BS)))  # one block
    logits_p, row, _ = lm.prefill(c, params, tokens)
    rows = jax.tree.map(lambda l: l, row)

    slot, plen = 1, BS
    slotted = slotted_cache(c, N_SLOTS, MAX_LEN, params)
    slotted = insert_rows(slotted, rows, jnp.asarray([slot], jnp.int32))

    paged = PagedKVCache(c, N_SLOTS, MAX_LEN, params, block_size=BS)
    paged.ensure(slot, plen)
    blocks = paged.block_ids(slot, plen)[None]
    caches_p = insert_paged_rows(paged.caches, rows, jnp.asarray(blocks),
                                 jnp.asarray([slot], jnp.int32),
                                 block_size=BS)
    paged.ensure(slot, plen + 1)   # the engine grows before each decode

    tok = jnp.asarray(np.full((N_SLOTS, 1),
                              int(jnp.argmax(logits_p[0, -1]))), jnp.int32)
    pos = np.full((N_SLOTS,), MAX_LEN - 1, np.int32)
    pos[slot] = plen
    out_s, _ = lm.decode_step(c, params, tok, slotted, jnp.asarray(pos))
    out_p, _ = lm.decode_step(c, params, tok, caches_p, jnp.asarray(pos),
                              block_tables=paged.device_tables(),
                              n_kv_blocks=2)
    np.testing.assert_allclose(np.asarray(out_p[slot]),
                               np.asarray(out_s[slot]),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Engine-level: layouts and fused windows are invisible in token streams
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served(setup):
    c, params = setup
    reqs = poisson_requests(12, 400.0, c.vocab, prompt_len=PROMPT, seed=3,
                            short=(2, 8), long=(30, 50))
    out = {}
    for kind in ("slotted", "paged"):
        for window in (1, 8):
            eng = ServeEngine(c, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                              cache=kind, block_size=BS,
                              decode_window=window)
            out[(kind, window)] = eng.serve(reqs, policy="continuous"), eng
    return out


def test_layouts_and_fusion_produce_identical_tokens(served):
    ref = served[("slotted", 1)][0].by_rid()
    for key, (run, _) in served.items():
        got = run.by_rid()
        for rid in ref:
            assert got[rid].tokens == ref[rid].tokens, (key, rid)
            assert got[rid].finish_reason == ref[rid].finish_reason


def test_fused_runs_record_exact_token_accounting(served):
    """Fused decode windows must credit each rid once per micro-step."""
    run, _ = served[("paged", 8)]
    for rec in run.steps:
        if rec.kind == "decode":
            assert rec.n_tokens == len(rec.rids) == rec.n_steps * (
                len(set(rec.rids)))
    total_gen = sum(r.n_tokens for r in run.results)
    credited = sum(s.n_tokens for s in run.steps)
    assert credited == total_gen
    assert 0.0 < run.summary.mean_occupancy <= 1.0


def test_paged_engine_frees_all_blocks_after_drain(served):
    _, eng = served[("paged", 8)]
    pool = eng._paged
    assert pool.free_blocks == pool.n_blocks - 1
    assert np.all(pool.tables_np == 0)


def test_eos_frees_paged_blocks_early(setup):
    """EOS early-exit must release a slot's blocks immediately (and the
    scheduler falls back to the per-token window when EOS is possible)."""
    c, params = setup
    eos = 7
    prompts = np.zeros((2, PROMPT), np.int32)
    reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=40,
                    arrival_s=0.0, eos_id=eos) for i in range(2)]
    eng = ServeEngine(c, params, n_slots=2, max_len=MAX_LEN, cache="paged",
                      block_size=BS, decode_window=8)
    out = eng.serve(reqs, policy="continuous")
    assert eng._paged.free_blocks == eng._paged.n_blocks - 1
    for rec in out.steps:   # EOS-capable slots force single-step windows
        if rec.kind == "decode":
            assert rec.n_steps == 1
    for r in out.results:
        if r.finish_reason == "eos":
            assert r.tokens[-1] == eos


def test_ssm_family_batched_prefill_keeps_exact_state():
    """Stacks with mamba layers must prefill at exact prompt length:
    right-padding would run the SSD recurrence/conv tail through pad
    tokens and corrupt decode state (masking protects attention only).
    The engine's serve tokens must match a manual unpadded
    prefill+decode chain."""
    c = get_config("mamba2-1.3b").reduced(dtype="float32",
                                          param_dtype="float32")
    params = lm.init(jax.random.key(1), c)
    rng = np.random.default_rng(5)
    prompt = rng.integers(0, c.vocab, PROMPT).astype(np.int32)
    budget = 6

    logits, caches, _ = lm.prefill(c, params, jnp.asarray(prompt[None]))
    caches = grow_caches(caches, 32)
    want = [int(jnp.argmax(logits[0, -1]))]
    pos = PROMPT
    for _ in range(budget - 1):
        tok = jnp.asarray([[want[-1]]], jnp.int32)
        logits, caches = lm.decode_step(c, params, tok, caches,
                                        jnp.int32(pos))
        want.append(int(jnp.argmax(logits[0, -1])))
        pos += 1

    eng = ServeEngine(c, params, n_slots=2, max_len=32, cache="slotted",
                      decode_window=4)
    out = eng.serve([Request(rid=0, prompt=prompt, max_new_tokens=budget)],
                    policy="continuous")
    assert out.by_rid()[0].tokens == want


def test_paged_matches_slotted_for_ssm_hybrid_stack():
    """The trickiest layout interaction: ssm/hybrid stacks prefill at
    EXACT prompt length while attention KV pages into the shared pool —
    per-slot SSM/conv state rides beside (L, n_blocks, bs, ...) leaves.
    Token streams must be identical to the slotted reference."""
    c = get_config("mamba2-1.3b").reduced(dtype="float32",
                                          param_dtype="float32")
    params = lm.init(jax.random.key(1), c)
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, c.vocab, PROMPT).astype(np.int32),
                    max_new_tokens=6, arrival_s=0.0) for i in range(3)]

    def run(cache):
        eng = ServeEngine(c, params, n_slots=2, max_len=32, cache=cache,
                          block_size=16, decode_window=4)
        out = eng.serve(list(reqs), policy="continuous")
        return {r.rid: r.tokens for r in out.results}

    assert run("paged") == run("slotted")


def test_oversubscribed_pool_serves_when_load_fits(setup):
    """The HBM lever: a pool with fewer blocks than n_slots*max_blocks
    still serves short requests (they only touch what they own)."""
    c, params = setup
    n_blocks = 1 + (MAX_LEN // BS) + 2      # one full slot + 2 spare
    eng = ServeEngine(c, params, n_slots=2, max_len=MAX_LEN, cache="paged",
                      block_size=BS, n_blocks=n_blocks, decode_window=4)
    reqs = [Request(rid=i, prompt=np.zeros(PROMPT, np.int32),
                    max_new_tokens=8, arrival_s=0.0) for i in range(4)]
    out = eng.serve(reqs, policy="continuous")
    assert all(r.finish_reason == "length" for r in out.results)
    assert eng._paged.free_blocks == n_blocks - 1


def test_oversubscribed_pool_defers_admission_instead_of_oom(setup):
    """Admission control: concurrent worst-case demand that OUTGROWS the
    pool must defer admissions (requests wait in the queue for finishing
    slots to free blocks) and serve everyone — the pre-admission-control
    engine died on CacheOOM here."""
    c, params = setup
    # pool holds exactly one full slot + trash; three slots' worth of
    # near-max-budget requests is 3x the pool
    n_blocks = 1 + MAX_LEN // BS
    eng = ServeEngine(c, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                      cache="paged", block_size=BS, n_blocks=n_blocks,
                      decode_window=4)
    budget = MAX_LEN - PROMPT
    reqs = [Request(rid=i, prompt=np.zeros(PROMPT, np.int32),
                    max_new_tokens=budget, arrival_s=0.0)
            for i in range(3)]
    out = eng.serve(reqs, policy="continuous")
    assert sorted(r.rid for r in out.results) == [0, 1, 2]
    assert all(r.finish_reason == "length" for r in out.results)
    assert all(len(r.tokens) == budget for r in out.results)
    # FIFO preserved under deferral: rid 0 finishes no later than rid 2
    by = out.by_rid()
    assert by[0].finish_s <= by[2].finish_s
    # every block returned; reservation ledger empty
    assert eng._paged.free_blocks == n_blocks - 1
    assert eng._slot_cap == {}


def test_deferred_admission_token_streams_match_roomy_pool(setup):
    """Deferral is scheduling only: the tokens a request generates are
    identical to a run where the pool never had to defer."""
    c, params = setup
    rng = np.random.default_rng(9)
    budget = MAX_LEN - PROMPT
    prompts = [rng.integers(0, c.vocab, PROMPT).astype(np.int32)
               for _ in range(3)]

    def run(n_blocks):
        eng = ServeEngine(c, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                          cache="paged", block_size=BS, n_blocks=n_blocks,
                          decode_window=4)
        reqs = [Request(rid=i, prompt=prompts[i], max_new_tokens=budget,
                        arrival_s=0.0) for i in range(3)]
        return {r.rid: r.tokens for r in
                eng.serve(reqs, policy="continuous").results}

    tight = run(1 + MAX_LEN // BS)          # one slot at a time
    roomy = run(None)                       # full worst-case reservation
    assert tight == roomy


def test_deferred_head_does_not_block_fused_windows(setup):
    """The deferral-fusion bug: a headroom-deferred queue head used to
    count as 'free slot + pending work', dropping the whole pool to
    per-token cadence (plus re-admit/unadmit churn every loop) for as
    long as the deferral lasted. A blocked head cannot admit until a
    finish frees blocks, and finishes land only on window edges — so
    the solo resident must still take fused windows."""
    c, params = setup
    n_blocks = 1 + MAX_LEN // BS             # one full slot at a time
    eng = ServeEngine(c, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                      cache="paged", block_size=BS, n_blocks=n_blocks,
                      decode_window=8)
    budget = MAX_LEN - PROMPT
    reqs = [Request(rid=i, prompt=np.zeros(PROMPT, np.int32),
                    max_new_tokens=budget, arrival_s=0.0)
            for i in range(2)]
    out = eng.serve(reqs, policy="continuous")
    by = out.by_rid()
    # rid 1 really was deferred for rid 0's whole residency
    assert by[1].admitted_s >= by[0].finish_s
    # ...and fused decode windows ran while it waited for blocks
    solo = [r for r in out.steps if r.kind == "decode"
            and set(r.rids) == {0} and r.t1 <= by[1].admitted_s]
    assert solo, "no solo decode windows recorded during the deferral"
    assert max(r.n_steps for r in solo) > 1, \
        "deferred head forced per-token cadence on the solo resident"
    # scheduling change only: outcomes and the pool ledger are untouched
    assert all(r.finish_reason == "length" and len(r.tokens) == budget
               for r in out.results)
    assert eng._paged.free_blocks == n_blocks - 1
    assert eng._slot_cap == {}
