"""Chunked prefill + block-granular preemption (the iteration-level
scheduler).

The contract mirrors test_paged_serve.py's: chunked scheduling is a
*performance* feature — token streams must be bit-identical to the
phased path, including across preempt/resume cycles (the resume replays
its emitted tail through the decode program precisely so that every KV
row is rebuilt by the program that built it the first time). Everything
runs the small float32 model so greedy argmax never flakes on bf16 ties.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.serve.requests import Request, RequestResult
from repro.serve.scheduler import Scheduler

N_SLOTS, MAX_LEN, BS = 3, 96, 16

_CONFIG = get_config("llama3.2-3b").reduced(dtype="float32",
                                            param_dtype="float32")


@pytest.fixture(scope="module")
def setup():
    return _CONFIG, lm.init(jax.random.key(0), _CONFIG)


def _engine(setup, **kw):
    c, params = setup
    kw.setdefault("n_slots", N_SLOTS)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("block_size", BS)
    kw.setdefault("decode_window", 8)
    return ServeEngine(c, params, cache="paged", **kw)


def _streams(out):
    return {r.rid: (r.tokens, r.finish_reason) for r in out.results}


# ---------------------------------------------------------------------------
# Stream bit-identity: chunked == phased
# ---------------------------------------------------------------------------


def test_chunked_streams_match_phased_mixed_prompts(setup):
    """Mixed prompt lengths — shorter than, equal to, and spanning
    several chunk_tokens slices — generate identical streams under both
    schedulers (ample pool: no preemption in play yet)."""
    c, _ = setup
    rng = np.random.default_rng(11)
    shapes = [(5, 40), (20, 30), (40, 20), (64, 10)]
    reqs = [Request(rid=i, prompt=rng.integers(0, c.vocab, p, np.int32),
                    max_new_tokens=b, arrival_s=0.0)
            for i, (p, b) in enumerate(shapes)]
    eng = _engine(setup)
    phased = eng.serve(list(reqs), policy="continuous", sched="phased")
    chunked = eng.serve(list(reqs), policy="continuous", sched="chunked")
    assert _streams(chunked) == _streams(phased)
    assert eng.preemptions == 0
    assert eng._paged.free_blocks == eng._paged.n_blocks - 1


def test_chunked_streams_match_phased_with_prefix_cache(setup):
    """Chunked prefill reuses the suffix-prefill program for its
    non-first chunks AND for prefix-index hits — the combination must
    still be invisible in the streams, and a late arrival sharing a
    full-block prefix must actually hit the index under chunked."""
    c, _ = setup
    rng = np.random.default_rng(13)
    shared = rng.integers(0, c.vocab, 48, np.int32)
    tails = rng.integers(0, c.vocab, (2, 8), np.int32)
    reqs = [Request(rid=i, prompt=np.concatenate([shared, tails[i]]),
                    max_new_tokens=20) for i in range(2)]

    plain = _engine(setup)
    want = _streams(plain.serve(list(reqs), policy="continuous",
                                sched="phased"))
    pref = _engine(setup, prefix_cache=True)
    for mode in ("phased", "chunked"):
        pref.reset_prefix_cache()
        # two serve() calls against the persistent index: the second
        # request deterministically finds the first one's registered
        # prefix (a same-wave admission could race the registration)
        out0 = pref.serve([reqs[0]], policy="continuous", sched=mode)
        out1 = pref.serve([reqs[1]], policy="continuous", sched=mode)
        assert {**_streams(out0), **_streams(out1)} == want, mode
        assert pref.prefix_stats["hit_requests"] == 1, mode


# ---------------------------------------------------------------------------
# Preemption: oversubscribed pool completes, streams stay bit-identical
# ---------------------------------------------------------------------------


def _oversubscribed(setup):
    """Two near-max requests against a 5-usable-block pool: worst-case
    demand is 3 + 3 blocks, so phased can only serve them serially while
    chunked admits both optimistically and preempts the younger when its
    decode growth overruns the pool."""
    c, _ = setup
    rng = np.random.default_rng(17)
    prompts = rng.integers(0, c.vocab, (2, 5), np.int32)
    reqs = [Request(rid=0, prompt=prompts[0], max_new_tokens=43),
            Request(rid=1, prompt=prompts[1], max_new_tokens=40)]
    eng = _engine(setup, n_slots=2, max_len=64, n_blocks=6)
    return eng, reqs


def test_preemption_forcing_pool_completes_where_phased_defers(setup):
    eng, reqs = _oversubscribed(setup)
    phased = eng.serve(list(reqs), policy="continuous", sched="phased")
    by_p = phased.by_rid()
    # phased has no move but deferral: rid 1 waits out rid 0's lifetime
    assert eng.preemptions == 0
    assert by_p[1].admitted_s >= by_p[0].finish_s

    chunked = eng.serve(list(reqs), policy="continuous", sched="chunked")
    by_c = chunked.by_rid()
    # chunked admits rid 1 immediately and evicts it when the pool runs
    # dry — it resumes and still completes its full budget
    assert eng.preemptions >= 1
    assert by_c[1].first_token_s < by_c[0].finish_s
    assert all(r.finish_reason == "length" for r in chunked.results)
    assert len(by_c[0].tokens) == 43 and len(by_c[1].tokens) == 40
    # the preempted-then-resumed stream is bit-identical to the
    # never-preempted (phased) one — the decode-replay guarantee
    assert _streams(chunked) == _streams(phased)
    # FIFO survives eviction: the older request finishes first
    assert by_c[0].finish_s <= by_c[1].finish_s
    # pool fully drained, reservation ledger empty
    assert eng._paged.free_blocks == eng._paged.n_blocks - 1
    assert eng._slot_cap == {}


def test_replay_windows_keep_token_accounting_exact(setup):
    """Replay steps burn compute (rids credited) but emit nothing
    (n_tokens counts only appended tokens): totals must balance and
    replay must force per-token windows (forced host-side inputs can't
    ride a fused on-device argmax chain)."""
    eng, reqs = _oversubscribed(setup)
    out = eng.serve(list(reqs), policy="continuous", sched="chunked")
    assert eng.preemptions >= 1
    total_gen = sum(r.n_tokens for r in out.results)
    credited = sum(s.n_tokens for s in out.steps)
    assert credited == total_gen
    for rec in out.steps:
        if rec.kind == "decode":
            assert rec.n_tokens <= len(rec.rids)
            assert len(rec.rids) % rec.n_steps == 0


def test_preemption_with_pinned_prefix_index_completes(setup):
    """Eviction composes with prefix-index refcounts: a registered
    block stays pinned across its owner finishing, and preemption's
    reclaim must still free enough to complete every request — with
    streams equal to a roomy phased run."""
    c, _ = setup
    rng = np.random.default_rng(19)
    prompts = rng.integers(0, c.vocab, (2, 16), np.int32)
    reqs = [Request(rid=0, prompt=prompts[0], max_new_tokens=32),
            Request(rid=1, prompt=prompts[1], max_new_tokens=32)]

    roomy = _engine(setup, n_slots=2, max_len=64, prefix_cache=True)
    want = _streams(roomy.serve(list(reqs), policy="continuous",
                                sched="phased"))
    tight = _engine(setup, n_slots=2, max_len=64, n_blocks=6,
                    prefix_cache=True)
    out = tight.serve(list(reqs), policy="continuous", sched="chunked")
    assert tight.preemptions >= 1
    assert tight.prefix_stats["registered_blocks"] >= 1
    assert all(r.finish_reason == "length" for r in out.results)
    assert _streams(out) == want
    # index pins survive the run but count as reclaimable headroom
    assert tight._paged.available_blocks == tight._paged.n_blocks - 1


# ---------------------------------------------------------------------------
# Admission-side preemption + FIFO (scheduler/engine unit level)
# ---------------------------------------------------------------------------


def test_admission_preempts_younger_running_slot(setup):
    """_admit_paged under chunked: a queue head older than a running
    slot reclaims that slot's blocks instead of deferring behind it.
    The victim re-queues at the FRONT carrying its emitted history as a
    replay tail."""
    eng = _engine(setup, n_slots=2, max_len=64, n_blocks=5,
                  decode_window=1)
    eng._ensure_cache()
    sched = Scheduler(2, 64)
    young = Request(rid=1, prompt=np.zeros(5, np.int32),
                    max_new_tokens=40, arrival_s=1.0)
    sched.submit(young)
    (yslot,) = sched.refill(2.0)
    eng._slot_cap[yslot.index] = 1
    eng._paged.ensure(yslot.index, 33)          # grown to 3 of 4 blocks
    yslot.prefill_pos = 5
    yslot.generated, yslot.pos, yslot.last_token = 7, 11, 6
    results = {0: RequestResult(rid=0, prompt_len=20),
               1: RequestResult(rid=1, prompt_len=5)}
    results[1].tokens = list(range(7))

    old = Request(rid=0, prompt=np.zeros(20, np.int32),
                  max_new_tokens=30, arrival_s=0.0)
    sched.submit(old)
    (oslot,) = sched.refill(2.0)
    ok = eng._admit_paged(sched, [oslot], results, chunked=True)

    assert ok == [oslot] and oslot.request is old
    assert eng.preemptions == 1
    assert eng._paged.owned(yslot.index) == 0
    assert eng._slot_cap == {oslot.index: 2}    # ceil(21 / 16)
    resume = sched.queue[0]
    assert resume.rid == 1 and resume.resumed
    assert resume.n_replay == 7 and resume.prompt_len == 5 + 7
    assert resume.max_new_tokens == 40 - 7
    assert [int(t) for t in resume.prompt[5:]] == list(range(7))


def test_unadmit_mid_chunked_prefill_preserves_fifo(setup):
    """A long prompt chunk-prefills while the pool is too tight for the
    whole wave: the tail unadmits back to the queue front and service
    order (admitted_s) still follows arrival order, with streams equal
    to phased."""
    c, _ = setup
    rng = np.random.default_rng(23)
    reqs = [Request(rid=0, prompt=rng.integers(0, c.vocab, 64, np.int32),
                    max_new_tokens=15, arrival_s=0.0),
            Request(rid=1, prompt=rng.integers(0, c.vocab, 5, np.int32),
                    max_new_tokens=10, arrival_s=0.0),
            Request(rid=2, prompt=rng.integers(0, c.vocab, 5, np.int32),
                    max_new_tokens=10, arrival_s=0.0)]
    eng = _engine(setup, n_slots=3, max_len=80, n_blocks=7)
    phased = eng.serve(list(reqs), policy="continuous", sched="phased")
    chunked = eng.serve(list(reqs), policy="continuous", sched="chunked")
    assert _streams(chunked) == _streams(phased)
    by = chunked.by_rid()
    assert by[2].queue_s > 0                    # rid 2 really was deferred
    assert by[0].admitted_s <= by[1].admitted_s <= by[2].admitted_s
    assert all(r.finish_reason == "length" for r in chunked.results)
    assert eng._paged.free_blocks == eng._paged.n_blocks - 1
