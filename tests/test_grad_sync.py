"""Gradient-sync correctness (ISSUE 6): bucketed/overlapped dp sync vs
naive per-leaf psum, compressed_psum error-feedback convergence, ZeRO-2
vs replicated-grad train-state equality, and the sharding/recompile
audit that caught the dp-scaling collapse."""
import numpy as np
import pytest

from repro.parallel.grad_sync import GradSyncConfig, default_sync

# ---------------------------------------------------------------------------
# Config + bucketing (single device, no subprocess)
# ---------------------------------------------------------------------------


def test_grad_sync_config_rejects_unknown_mode():
    with pytest.raises(ValueError, match="grad_sync mode"):
        GradSyncConfig(mode="fp16")


def test_default_sync_disables_overlap_on_cpu():
    # overlap pays only where collectives run async; the test host is CPU
    s = default_sync("int8")
    assert s.mode == "int8" and s.overlap is False


def test_flatten_buckets_round_trip():
    import jax.numpy as jnp
    from repro.parallel.grad_sync import (flatten_buckets, n_buckets,
                                          unflatten_buckets)
    tree = {"a": jnp.arange(7, dtype=jnp.float32).reshape(7),
            "b": jnp.ones((3, 5), jnp.bfloat16),
            "c": jnp.zeros((), jnp.float32)}
    buckets, meta = flatten_buckets(tree, bucket_elems=6)
    assert len(buckets) == n_buckets(tree, 6) == 4   # 23 elems / 6
    assert all(b.shape == (6,) for b in buckets)
    back = unflatten_buckets(buckets, meta)
    for k in tree:
        assert back[k].dtype == tree[k].dtype
        np.testing.assert_array_equal(np.asarray(back[k], np.float32),
                                      np.asarray(tree[k], np.float32))


# ---------------------------------------------------------------------------
# Multi-device equivalences (forced host platform, subprocess)
# ---------------------------------------------------------------------------


_DP_PRELUDE = """
import jax, jax.numpy as jnp
import numpy as np
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.bench.spec import Placement
from repro.launch.mesh import mesh_for
from repro.models import lm
from repro.data.synthetic import synthetic_tokens
from repro.parallel import sharding as shd, grad_sync as gs
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step

c = get_config("gpt-117m").reduced(d_model=64, n_layers=2, d_ff=128,
                                   vocab=512, n_heads=2, n_kv_heads=2,
                                   d_head=32)
oc = OptConfig(warmup=2, total_steps=100)
params = lm.init(jax.random.key(0), c)
opt_state = opt_init(oc, params)
mesh = mesh_for(Placement.of("dp2"))
plan = shd.make_plan(c, mesh, ShapeConfig("t", 0, 0, "train"))
p_s, o_s, psh, osh, gsh = shd.shard_train_state(plan, params, opt_state, c)
gb, seq, k = 8, 16, 4
toks = jnp.asarray(synthetic_tokens(gb, seq, c.vocab)[:, :seq])
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
pbatch = jax.device_put(batch, {kk: shd.batch_sharding(plan, v.shape)
                                for kk, v in batch.items()})

def run_steps(step, n, with_sync=None):
    p = jax.device_put(jax.tree.map(jnp.copy, p_s), psh)
    o = jax.device_put(jax.tree.map(jnp.copy, o_s), osh)
    s = with_sync
    for _ in range(n):
        if s is not None:
            p, o, s, m = step(p, o, s, pbatch)
        else:
            p, o, m = step(p, o, pbatch)
    return p, m

def maxdiff(a, b):
    ds = jax.tree.map(lambda x, y: float(jnp.max(jnp.abs(
        x.astype(jnp.float32) - y.astype(jnp.float32)))), a, b)
    return max(jax.tree.leaves(ds))
"""


def test_bucketed_sync_matches_naive_psum_and_single_device(subproc):
    """Fixed seed, few fp32 steps: the bucketed dp2 step (overlap on AND
    off, tiny buckets forcing multiple) lands on the same params as (a)
    a shard_map step using naive per-leaf psum and (b) the plain
    single-logical-batch GSPMD step."""
    subproc(_DP_PRELUDE + """
from repro.parallel.compat import shard_map
from jax.sharding import PartitionSpec as P
from functools import partial

sc = StepConfig(microbatches=k)

# (a) naive per-leaf psum reference, same scan, no buckets
from repro.train.step import make_loss_fn, scan_microbatch_grads
from repro.train.optimizer import opt_update
vg = jax.value_and_grad(make_loss_fn(c, sc), has_aux=True)
axis = plan.dp if len(plan.dp) > 1 else plan.dp[0]
ndev = shd.dp_size(plan)

def naive_local(params, batch):
    g, _, l, ce, aux = scan_microbatch_grads(vg, params, batch, k,
                                             jnp.float32)
    g = gs.naive_psum_sync(g, axis, ndev)
    g = jax.tree.map(lambda x: (x / k).astype(jnp.float32), g)
    return g, jax.lax.pmean(l / k, axis)

smap = shard_map(naive_local, mesh=mesh, in_specs=(P(), P(plan.dp)),
                 out_specs=(P(), P()), check_vma=False)

def naive_step(p, o, batch):
    g, l = smap(p, batch)
    np_, no, info = opt_update(oc, g, o, p)
    return np_, no, {"loss": l, **info}

naive = jax.jit(naive_step, out_shardings=(psh, osh, None),
                donate_argnums=(0, 1))
p_naive, _ = run_steps(naive, 3)

for overlap in (False, True):
    sync = gs.GradSyncConfig(mode="fp32", bucket_mb=0.001, overlap=overlap)
    step = jax.jit(gs.make_dp_train_step(c, oc, sc, plan=plan, sync=sync),
                   out_shardings=(psh, osh, gs.sync_state_sharding(plan),
                                  None),
                   donate_argnums=(0, 1, 2))
    p_b, _ = run_steps(step, 3, with_sync=gs.init_sync_state(
        plan, params, sync))
    d = maxdiff(p_b, p_naive)
    assert d < 2e-3, f"overlap={overlap}: bucketed vs naive diff {d}"
    print("overlap", overlap, "vs naive diff", d)

# (b) the plain single-device-semantics GSPMD step
ref = jax.jit(make_train_step(c, oc, sc), out_shardings=(psh, osh, None),
              donate_argnums=(0, 1))
p_ref, _ = run_steps(ref, 3)
d = maxdiff(p_naive, p_ref)
assert d < 2e-3, f"naive-psum vs gspmd diff {d}"
print("OK")
""", n_devices=2)


def test_zero2_grad_shardings_match_replicated_grads(subproc):
    """ZeRO-2 (dp-sharded grad accumulators) is a layout change, not a
    numeric one: few fp32 steps with grad_shardings=gsh equal the
    replicated-grad (grad_shardings=None) step."""
    subproc(_DP_PRELUDE + """
sc = StepConfig(microbatches=k)
mb = gb // k
mbsh = {"tokens": shd.batch_sharding(plan, (mb, seq)),
        "labels": shd.batch_sharding(plan, (mb, seq))}
z2 = jax.jit(make_train_step(c, oc, sc, grad_shardings=gsh,
                             batch_shardings=mbsh),
             out_shardings=(psh, osh, None), donate_argnums=(0, 1))
rep = jax.jit(make_train_step(c, oc, sc, batch_shardings=mbsh),
              out_shardings=(psh, osh, None), donate_argnums=(0, 1))
p_z2, m_z2 = run_steps(z2, 3)
p_rep, m_rep = run_steps(rep, 3)
d = maxdiff(p_z2, p_rep)
assert d < 1e-5, f"zero2 vs replicated diff {d}"
assert abs(float(m_z2["loss"]) - float(m_rep["loss"])) < 1e-5
# the accumulator really is dp-sharded: at least one gsh leaf names an
# axis its psh twin leaves free
import jax.tree_util as jtu
extra = [g for p, g in zip(jax.tree.leaves(psh), jax.tree.leaves(gsh))
         if p.spec != g.spec]
assert extra, "gsh identical to psh — ZeRO-2 sharded nothing"
print("OK, zero2 shards", len(extra), "leaves further")
""", n_devices=2)


def test_pinned_step_neither_recompiles_nor_reshards(subproc):
    """The collapse regression drill: the pinned+donated dp step keeps
    jit cache size 1 and returns params on exactly the input shardings
    (the unpinned seed step recompiled on call 1 and resharded all
    leaves — scaling_efficiency 0.10)."""
    subproc(_DP_PRELUDE + """
from repro.train.diagnose import audit_shardings
sc = StepConfig(microbatches=k)
sync = gs.GradSyncConfig(mode="fp32", overlap=False)
step = jax.jit(gs.make_dp_train_step(c, oc, sc, plan=plan, sync=sync),
               out_shardings=(psh, osh, gs.sync_state_sharding(plan), None),
               donate_argnums=(0, 1, 2))
p = jax.device_put(jax.tree.map(jnp.copy, p_s), psh)
o = jax.device_put(jax.tree.map(jnp.copy, o_s), osh)
s = gs.init_sync_state(plan, params, sync)
for i in range(3):
    p, o, s, m = step(p, o, s, pbatch)
    assert step._cache_size() == 1, f"recompiled at call {i}"
assert audit_shardings(p, psh) == 0, "outputs left the input placement"
print("OK")
""", n_devices=2)


def test_compressed_psum_error_feedback_converges(subproc):
    """Error feedback keeps the cumulative int8-compressed mean unbiased:
    over repeated reduces of the same gradients, the accumulated
    compressed means approach the accumulated true means (residual
    stays bounded) — the Seide-style convergence property."""
    subproc("""
import jax, jax.numpy as jnp
import numpy as np
from functools import partial
from repro.parallel.compat import shard_map
from repro.parallel.compress import compressed_psum
from jax.sharding import PartitionSpec as P
from jax.sharding import Mesh

mesh = Mesh(np.array(jax.devices()[:4]), ("data",))
key = jax.random.key(0)
x = jax.random.normal(key, (4, 256)) * jnp.linspace(0.1, 3.0, 256)

def one_round(x, err):
    out, new_err = compressed_psum(x, "data", err)
    return out, new_err

smap = jax.jit(shard_map(one_round, mesh=mesh,
                         in_specs=(P("data"), P("data")),
                         out_specs=(P("data"), P("data")),
                         check_vma=False))
true_mean = jnp.mean(x, axis=0)
err = jnp.zeros_like(x)
acc = jnp.zeros_like(true_mean)
drifts = []
for t in range(1, 33):
    out, err = smap(x, err)
    acc = acc + out[0]
    drifts.append(float(jnp.max(jnp.abs(acc / t - true_mean))))
# the RUNNING mean drift shrinks as the residual is fed back; without
# error feedback it would plateau at the quantization bin size
assert drifts[-1] < drifts[0] / 4, drifts
assert drifts[-1] < 0.02, drifts[-1]
# residual itself stays bounded by one quantization bin
assert float(jnp.max(jnp.abs(err))) < float(jnp.max(jnp.abs(x))) / 100
print("drift", drifts[0], "->", drifts[-1])
""", n_devices=4)
