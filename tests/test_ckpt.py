"""Checkpointing: roundtrip, atomicity, retention, async, auto-resume,
elastic rescale plans."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager, latest_step, restore, save
from repro.ckpt.elastic import plan_rescale
from repro.configs import SHAPES, get_config


def _tree(seed=0):
    k = jax.random.key(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = _tree()
    save(t, tmp_path, step=5)
    got, manifest = restore(t, tmp_path)
    assert manifest["step"] == 5
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, got)


def test_latest_step_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save_sync(t, s)
    assert latest_step(tmp_path) == 4
    assert not (tmp_path / "step_1").exists()
    assert not (tmp_path / "step_2").exists()
    assert (tmp_path / "step_3").exists()


def test_atomicity_no_tmp_published(tmp_path):
    t = _tree()
    save(t, tmp_path, step=1)
    leftovers = [p for p in tmp_path.iterdir() if p.name.endswith(".tmp")]
    assert leftovers == []
    # restore never sees a partial state: only step_N dirs count
    assert latest_step(tmp_path) == 1


def test_async_save(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    t = _tree()
    mgr.save_async(t, 7)
    mgr.wait()
    assert latest_step(tmp_path) == 7
    got, _ = restore(t, tmp_path)
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))


def test_restore_casts_dtype(tmp_path):
    t = {"w": jnp.ones((4,), jnp.float32)}
    save(t, tmp_path, step=1)
    template = {"w": jnp.zeros((4,), jnp.bfloat16)}
    got, _ = restore(template, tmp_path)
    assert got["w"].dtype == jnp.bfloat16


def test_train_loop_auto_resume(tmp_path):
    """Inject a failure mid-training; rerun resumes from the checkpoint."""
    from repro.launch.train import main
    args = ["--arch", "gpt-117m", "--preset", "tiny", "--steps", "8",
            "--global-batch", "2", "--seq-len", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "2"]
    with pytest.raises(RuntimeError, match="injected failure"):
        main(args + ["--fail-at-step", "5"])
    assert latest_step(tmp_path) is not None
    res = main(args)  # resumes
    assert res.resumed_from is not None and res.resumed_from >= 2
    assert res.final_step == 8


def test_elastic_rescale_plan():
    c = get_config("granite-8b")
    shape = SHAPES["train_4k"]
    plan = plan_rescale(c, shape, (16, 16), lost_devices=32)
    assert plan.new_shape[1] == 16  # TP degree preserved
    assert plan.new_shape[0] <= 14
    assert shape.global_batch % plan.new_shape[0] == 0

    with pytest.raises(ValueError):
        plan_rescale(c, shape, (16, 16), lost_devices=256 - 8)
