"""Per-arch smoke tests (reduced configs): forward + one train step on CPU,
output shapes + no NaNs; prefill==forward; decode continuity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, PAPER_MODELS, get_config
from repro.models import lm
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step

ARCHS = sorted(ASSIGNED) + ["gpt-117m"]


def _batch(c, b=2, s=48, seed=0):
    key = jax.random.key(seed)
    s_text = s - (c.n_patches if c.family == "vlm" else 0)
    out = {
        "tokens": jax.random.randint(key, (b, s_text), 0, c.vocab, jnp.int32),
        "labels": jax.random.randint(key, (b, s_text), 0, c.vocab, jnp.int32),
    }
    if c.family == "vlm":
        out["patch_embeds"] = jax.random.normal(
            key, (b, c.n_patches, c.d_model), jnp.float32).astype(jnp.bfloat16)
    if c.family == "encdec":
        out["enc_frames"] = jax.random.normal(
            key, (b, c.enc_seq, c.d_model), jnp.float32).astype(jnp.bfloat16)
    return out


def _extras(batch):
    return {k: v for k, v in batch.items()
            if k in ("patch_embeds", "enc_frames")}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_smoke(arch):
    c = get_config(arch).reduced()
    # ssm chunk must divide seq; reduced chunk=32, s=48 -> use s=64
    s = 64
    batch = _batch(c, 2, s)
    params = lm.init(jax.random.key(0), c)
    logits, aux = lm.forward(c, params, batch["tokens"], **_extras(batch))
    s_text = batch["tokens"].shape[1]
    assert logits.shape == (2, s_text, c.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch):
    c = get_config(arch).reduced()
    batch = _batch(c, 2, 64)
    params = lm.init(jax.random.key(0), c)
    oc = OptConfig(warmup=2, total_steps=10)
    opt_state = opt_init(oc, params)
    step = jax.jit(make_train_step(c, oc, StepConfig(microbatches=2)))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    assert int(new_opt["step"]) == 1
    # params actually changed
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("arch", ["granite-8b", "jamba-v0.1-52b",
                                  "mamba2-1.3b", "whisper-small",
                                  "granite-moe-3b-a800m"])
def test_prefill_matches_forward(arch):
    c = get_config(arch).reduced()
    batch = _batch(c, 2, 64)
    params = lm.init(jax.random.key(0), c)
    logits_f, _ = lm.forward(c, params, batch["tokens"], remat="none",
                             **_extras(batch))
    logits_p, caches, enc_kv = lm.prefill(c, params, batch["tokens"],
                                          **_extras(batch))
    np.testing.assert_allclose(
        np.asarray(logits_f[:, -1:], np.float32),
        np.asarray(logits_p, np.float32), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-1.3b",
                                  "jamba-v0.1-52b"])
def test_decode_continuity(arch):
    """decode_step at position s must match teacher-forced forward."""
    c = get_config(arch).reduced()
    b, s = 2, 64
    key = jax.random.key(1)
    full = jax.random.randint(key, (b, s + 1), 0, c.vocab, jnp.int32)
    params = lm.init(jax.random.key(0), c)
    # teacher-forced logits at position s (predicting s+1)
    logits_f, _ = lm.forward(c, params, full, remat="none")
    want = np.asarray(logits_f[:, -1], np.float32)
    # prefill on s tokens, then decode token s
    _, caches, enc_kv = lm.prefill(c, params, full[:, :s])
    caches = jax.tree_util.tree_map_with_path(
        lambda p, l: (jnp.pad(l, [(0, 0), (0, 0), (0, 8)]
                              + [(0, 0)] * (l.ndim - 3))
                      if getattr(p[-1], "key", None) in ("k", "v") else l),
        caches)
    logits_d, _ = lm.decode_step(c, params, full[:, s:s + 1], caches,
                                 jnp.int32(s), enc_kv=enc_kv)
    got = np.asarray(logits_d[:, -1], np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
    # and the argmax agrees (bf16 tolerance)
    assert (np.argmax(got, -1) == np.argmax(want, -1)).mean() > 0.9
