"""Parallelism: sharding rules produce valid (divisible) specs for every
(arch x shape) cell; ZeRO-1; multi-device semantics via subprocess (8
forced host devices): sharded train step == single-device step, compressed
all-reduce with error feedback, pipeline == sequential."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, SHAPES, cells, get_config
from repro.launch.mesh import axis_size
from repro.parallel import sharding as sh


class _FakeMesh:
    """Shape-only mesh stand-in (no devices needed for rule checks)."""

    def __init__(self, shape_map):
        self.shape = dict(shape_map)
        self.axis_names = tuple(shape_map)
        self.size = 1
        for v in shape_map.values():
            self.size *= v


MESH16 = _FakeMesh({"data": 16, "model": 16})
MESH512 = _FakeMesh({"pod": 2, "data": 16, "model": 16})


@pytest.mark.parametrize("mesh", [MESH16, MESH512], ids=["pod1", "pod2"])
@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_param_specs_divisible_all_archs(arch, mesh):
    """Every param leaf's spec must evenly divide its dims (else the real
    NamedSharding construction would fail in the dry-run)."""
    from repro.models import lm
    c = get_config(arch)
    plan = sh.make_plan(c, mesh, SHAPES["train_4k"])
    aps = lm.init_abstract(c)

    def check(path, leaf):
        spec = sh._param_rule(c, plan, path, tuple(leaf.shape))
        for dim, part in enumerate(spec):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            n = 1
            for a in axes:
                n *= mesh.shape[a]
            assert leaf.shape[dim] % n == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, aps)


def test_plan_flags():
    p8 = sh.make_plan(get_config("granite-8b"), MESH16, SHAPES["train_4k"])
    assert p8.tp_heads and not p8.fsdp
    pq = sh.make_plan(get_config("qwen2-0.5b"), MESH16, SHAPES["train_4k"])
    assert not pq.tp_heads
    pl4 = sh.make_plan(get_config("llama4-maverick-400b-a17b"), MESH16,
                       SHAPES["train_4k"])
    assert pl4.fsdp and pl4.ep
    pgm = sh.make_plan(get_config("granite-moe-3b-a800m"), MESH16,
                       SHAPES["train_4k"])
    assert not pgm.ep  # 40 experts don't divide 16
    plong = sh.make_plan(get_config("jamba-v0.1-52b"), MESH16,
                         SHAPES["long_500k"])
    assert plong.seq_axis == "data"  # batch=1 -> sequence-sharded cache


def test_zero1_adds_data_axis(subproc):
    subproc("""
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, SHAPES
from repro.launch.mesh import make_mesh
from repro.parallel import sharding as sh

mesh = make_mesh((2, 2), ("data", "model"))
plan = sh.make_plan(get_config("granite-8b"), mesh, SHAPES["train_4k"])
# model-sharded param -> optimizer state additionally sharded over data
ns = sh.zero1_sharding(plan, NamedSharding(mesh, P(None, "model")), (8, 4))
assert ns.spec == P("data", "model"), ns.spec
# data-sharded param -> the extended ZeRO-1 also uses the free model axis
ns2 = sh.zero1_sharding(plan, NamedSharding(mesh, P("data", None)), (8, 4))
assert ns2.spec == P("data", "model"), ns2.spec
# indivisible dims -> untouched
ns3 = sh.zero1_sharding(plan, NamedSharding(mesh, P()), (7,))
assert ns3.spec == P(None), ns3.spec
print("zero1 OK")
""", n_devices=4)


def test_sharded_train_equals_single_device(subproc):
    """2x2 (data x model) sharded train step == unsharded, same batch."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config, SHAPES
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.parallel import sharding as sh
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step
import dataclasses

c = get_config("granite-8b").reduced(n_layers=2, d_model=64, n_heads=4,
                                     n_kv_heads=2, d_ff=128, vocab=512,
                                     d_head=16)
oc = OptConfig(warmup=1, total_steps=10)
params = lm.init(jax.random.key(0), c)
opt = opt_init(oc, params)
toks = jax.random.randint(jax.random.key(1), (8, 32), 0, c.vocab, jnp.int32)
batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
step = make_train_step(c, oc, StepConfig())

# single device
p1, o1, m1 = jax.jit(step)(params, opt, batch)

# sharded 2x2
mesh = make_mesh((2, 2), ("data", "model"))
plan = sh.make_plan(c, mesh, SHAPES["train_4k"])
psh = sh.param_shardings(c, plan, params)
params_s = jax.device_put(params, psh)
opt_s = jax.device_put(opt, jax.tree.map(lambda _: sh.replicated(plan), opt))
batch_s = jax.device_put(batch, sh.batch_sharding(plan, (8, 32)))
with mesh:
    p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch_s)

assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3, (m1["loss"], m2["loss"])
d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
    a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
assert max(jax.tree.leaves(d)) < 3e-2, max(jax.tree.leaves(d))
print("sharded == single OK")
""", n_devices=4)


def test_compressed_psum_error_feedback(subproc):
    """int8 EF all-reduce: mean error shrinks and EF keeps long-run sum
    unbiased (property: accumulated compressed updates -> true mean)."""
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.parallel.compat import shard_map
from repro.parallel.compress import compressed_psum

mesh = make_mesh((8,), ("data",))

@partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
         out_specs=(P("data"), P("data")), check_vma=False)
def sync(g, e):
    out, e2 = compressed_psum(g[0], "data", e[0])
    return out[None], e2[None]

key = jax.random.key(0)
g = jax.random.normal(key, (8, 64), jnp.float32)
true_mean = jnp.mean(g, 0)
e = jnp.zeros((8, 64), jnp.float32)
acc_c = jnp.zeros((64,))
acc_t = jnp.zeros((64,))
for i in range(30):
    gi = g * (1.0 + 0.01 * i)
    out, e = sync(gi, e)
    acc_c = acc_c + out[0]
    acc_t = acc_t + jnp.mean(gi, 0)
rel = float(jnp.linalg.norm(acc_c - acc_t) / jnp.linalg.norm(acc_t))
assert rel < 0.01, rel  # error feedback keeps the accumulated sum honest
print("compressed psum EF OK, rel err", rel)
""", n_devices=8)


def test_dryrun_small_mesh_end_to_end(subproc):
    """The dry-run machinery on a small (2,2) mesh for a reduced arch:
    lower+compile+cost/memory analysis + collective parsing all work."""
    subproc("""
import jax
from repro.configs import get_config, SHAPES
from repro.launch.mesh import make_mesh
from repro.launch.dryrun import lower_cell
import dataclasses

c = get_config("granite-8b").reduced(n_layers=2, d_model=64, n_heads=4,
                                     n_kv_heads=2, d_ff=128, vocab=512,
                                     d_head=16)
mesh = make_mesh((2, 2), ("data", "model"))
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=128, global_batch=8)
rec, compiled = lower_cell(c, shape, mesh, "tiny", metrics_pass=True)
assert rec["cost_analysis"]["flops"] > 0
assert rec["roofline"]["bottleneck"] in ("compute", "memory", "collective")
print("dryrun small mesh OK:", rec["roofline"]["bottleneck"])
""", n_devices=4)
