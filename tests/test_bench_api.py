"""Unified WorkloadSpec API: registry, tag filtering, runner, records, CLI."""
import json
import logging
import os
import subprocess
import sys

import pytest

import repro.bench.workloads  # noqa: F401 - populate the registry
from repro.bench import (
    ResultRecord, SCHEMA_VERSION, UnknownWorkloadError, WorkloadRunner,
    WorkloadSpec, get_workload, iter_workloads, register, save_records,
    unregister, workload_names,
)
from repro.bench.records import load_records
from repro.bench.spec import Space
from repro.core.results import atomic_write_text, save_results
from repro.core.runner import StragglerWatchdog
from repro.power.methods import SyntheticPower, select_power_methods

SEVEN = ["heatmap", "kernels", "llm_train", "pipeline_gpt", "resnet50",
         "roofline", "serve"]


# ---------------------------------------------------------------------------
# registry + tags
# ---------------------------------------------------------------------------


def test_all_seven_paper_workloads_registered():
    assert set(SEVEN) <= set(workload_names())


def test_unknown_workload_error_names_the_registry():
    with pytest.raises(UnknownWorkloadError) as ei:
        get_workload("nope")
    msg = str(ei.value)
    assert "nope" in msg and "llm_train" in msg


def test_duplicate_registration_rejected():
    spec = _toy_spec("dup_workload")
    register(spec)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register(spec)
    finally:
        unregister("dup_workload")


def test_tag_filtering():
    assert [s.name for s in iter_workloads(tags=["serve"])] \
        == ["serve", "serve_slo"]
    assert [s.name for s in iter_workloads(tags=["vision"])] == ["resnet50"]
    smoke = {s.name for s in iter_workloads(tags=["smoke"])}
    assert set(SEVEN) <= smoke        # every paper workload has a smoke run
    # names validate even when combined with tags
    with pytest.raises(UnknownWorkloadError):
        iter_workloads(names=["serve", "bogus"], tags=["smoke"])


def test_smoke_space_is_narrower_and_points_override():
    spec = get_workload("llm_train")
    full = spec.space_for(False).expand()
    smoke = spec.space_for(True).expand()
    assert 0 < len(smoke) < len(full)
    only16 = spec.space_for(False, {"global_batch": 16}).expand()
    assert {pt["global_batch"] for pt in only16} == {16}
    with pytest.raises(KeyError, match="no axis"):
        spec.space_for(False, {"bogus_axis": 1})


def test_multi_device_workloads_declare_their_floor():
    # pipeline_gpt's spec-level placement maps its stages onto "pp"
    pg = get_workload("pipeline_gpt")
    assert pg.placement.dict() == {"pp": 4} and pg.n_devices == 4
    # heatmap sweeps a placement AXIS up to dp8; the CLI sizes the host
    # platform from the sweep, not a scalar floor
    hm = get_workload("heatmap")
    assert hm.n_devices == 1 and hm.max_devices() == 8
    assert hm.max_devices(smoke=True) == 2
    assert get_workload("llm_train").max_devices() == 4


# ---------------------------------------------------------------------------
# ResultRecord schema
# ---------------------------------------------------------------------------


def test_result_record_roundtrip():
    rec = ResultRecord(workload="w", point={"bs": 8}, metrics={"tps": 1.5},
                       power_source="synthetic", n_devices=2, attempts=2)
    back = ResultRecord.from_dict(json.loads(json.dumps(rec.to_dict())))
    assert back == rec
    flat = rec.flat()
    assert flat["schema_version"] == SCHEMA_VERSION
    assert flat["bs"] == 8 and flat["tps"] == 1.5
    assert flat["power_source"] == "synthetic" and flat["attempts"] == 2


def test_result_record_rejects_unknown_schema_version():
    d = ResultRecord(workload="w", point={}).to_dict()
    d["schema_version"] = SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema_version"):
        ResultRecord.from_dict(d)
    d["schema_version"] = 0
    with pytest.raises(ValueError, match="schema_version"):
        ResultRecord.from_dict(d)


def test_save_and_load_records(tmp_path):
    recs = [ResultRecord(workload="w", point={"bs": b},
                         metrics={"tps": 10.0 * b}) for b in (1, 2)]
    save_records(recs, tmp_path)
    doc = json.loads((tmp_path / "results.json").read_text())
    assert doc["schema_version"] == SCHEMA_VERSION
    assert load_records(tmp_path / "results.json") == recs
    csv = (tmp_path / "results.csv").read_text()
    assert csv.splitlines()[0].startswith("schema_version,workload")


# ---------------------------------------------------------------------------
# WorkloadRunner
# ---------------------------------------------------------------------------


def _toy_spec(name, build=None, **kw):
    def default_build(pt, ctx):
        return {"run": lambda: {"value": pt["x"] * 10,
                                "seconds": 0.001}}

    return WorkloadSpec(name=name, analog="toy", space=Space({"x": [1, 2]}),
                        build=build or default_build,
                        tags=frozenset({"smoke"}), **kw)


def test_workload_runner_end_to_end(tmp_path):
    spec = _toy_spec("toy")
    runner = WorkloadRunner(spec, out_dir=str(tmp_path),
                            power_methods=[SyntheticPower(base=100.0)],
                            power_source="synthetic")
    recs = runner.run(verbose=False)
    assert [r.metrics["value"] for r in recs] == [10, 20]
    assert all(r.ok and r.power_source == "synthetic" for r in recs)
    out = tmp_path / "toy"
    assert (out / "results.json").exists()
    assert (out / "results.csv").exists()
    assert (out / "manifest.json").exists()
    assert load_records(out / "results.json") == recs


def test_workload_runner_retries_are_counted_and_logged(tmp_path, caplog):
    attempts = []

    def flaky_build(pt, ctx):
        def step():
            attempts.append(1)
            if len(attempts) < 2:
                raise RuntimeError("transient glitch")
            return {"ok": 1}
        return {"run": step}

    spec = _toy_spec("toy_flaky", build=flaky_build)
    with caplog.at_level(logging.WARNING, logger="repro.bench"):
        recs = WorkloadRunner(spec, out_dir=str(tmp_path), power="none",
                              retries=3,
                              point_overrides={"x": 1}).run(verbose=False)
    assert recs[0].ok and recs[0].attempts == 2
    assert "transient glitch" in caplog.text   # retried failure is visible


def test_workload_runner_fails_fast_on_fatal_error(tmp_path):
    # a ValueError is a deterministic bug, not a transient: retrying it
    # would burn the retry budget reproducing the same crash
    calls = []

    def broken_build(pt, ctx):
        def run():
            calls.append(1)
            raise ValueError("boom")
        return {"run": run}

    spec = _toy_spec("toy_broken", build=broken_build)
    recs = WorkloadRunner(spec, out_dir=str(tmp_path), power="none",
                          retries=2).run(verbose=False)
    assert all(r.status == "error" and "boom" in r.error for r in recs)
    assert all(r.attempts == 1 for r in recs)
    assert len(calls) == len(recs)     # exactly one attempt per point


def test_workload_runner_records_error_after_exhausted_retries(tmp_path):
    def broken_build(pt, ctx):
        return {"run": lambda: (_ for _ in ()).throw(
            RuntimeError("boom transient"))}

    spec = _toy_spec("toy_broken2", build=broken_build)
    recs = WorkloadRunner(spec, out_dir=str(tmp_path), power="none",
                          retries=2).run(verbose=False)
    assert all(r.status == "error" and "boom" in r.error for r in recs)
    assert all(r.attempts == 2 for r in recs)


def test_power_autoselect_labels_source():
    methods, source = select_power_methods("auto")
    assert source in ("rapl", "tpu_model", "synthetic")
    assert methods and methods[0].name == source
    assert select_power_methods("none") == ([], "none")
    ms, src = select_power_methods("synthetic", n_devices=3)
    assert src == "synthetic" and len(ms[0].devices()) == 3
    with pytest.raises(KeyError):
        select_power_methods("flux_capacitor")


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_list_shows_all_workloads(capsys):
    from repro.bench.cli import main
    assert main(["list"]) == 0
    out = capsys.readouterr().out
    for name in SEVEN:
        assert name in out


def test_cli_points_parsing():
    from repro.bench.cli import _parse_points
    assert _parse_points("global_batch=16,global_batch=32,arch=x") == {
        "global_batch": [16, 32], "arch": ["x"]}
    assert _parse_points("rate_hz=1.5") == {"rate_hz": [1.5]}
    assert _parse_points(None) is None


def test_cli_run_and_report_roofline(tmp_path, capsys):
    """Cheapest full CLI pass: run the analysis-only workload, then render
    its saved records with `report` (no model execution, synthetic power)."""
    from repro.bench.cli import main
    assert main(["run", "--suite", "roofline", "--power", "synthetic",
                 "--out", str(tmp_path)]) == 0
    out = capsys.readouterr().out
    assert "roofline" in out and "all benchmarks complete" in out
    recs = load_records(tmp_path / "roofline" / "results.json")
    assert {r.point["mesh"] for r in recs} == {"single", "multi"}
    assert all(r.ok and r.power_source == "synthetic" for r in recs)
    assert main(["report", "--out", str(tmp_path)]) == 0
    assert "roofline" in capsys.readouterr().out


@pytest.mark.slow
def test_cli_smoke_suite_end_to_end():
    """The CI gate: every smoke-tagged workload through one CLI call on
    synthetic power (multi-device workloads via the XLA_FLAGS re-exec)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.bench", "run", "--tags", "smoke",
         "--power", "synthetic", "--out", "artifacts/bench-smoke"],
        capture_output=True, text=True, timeout=1800, cwd=".", env=env)
    assert proc.returncode == 0, proc.stdout[-4000:] + proc.stderr[-4000:]
    assert "all benchmarks complete" in proc.stdout


# ---------------------------------------------------------------------------
# satellite fixes: watchdog warmup variance, atomic persistence
# ---------------------------------------------------------------------------


def test_straggler_watchdog_seeds_variance_from_warmup():
    w = StragglerWatchdog(k=3.0, warmup=3)
    for i, dt in enumerate([0.1, 0.2, 0.3]):
        assert not w.observe(i, dt)
    assert w.var > 0                       # warmup seeded the variance
    # ordinary spread after a noisy warmup must not flag (a zero-variance
    # baseline would have: 0.3 > 0.2 + 3 * 0.05 * 0.2)
    assert not w.observe(3, 0.3)
    assert w.observe(4, 5.0)               # a real straggler still flags


def test_save_results_survives_interrupted_write(tmp_path, monkeypatch):
    save_results([{"a": 1}], tmp_path, "results")
    before = (tmp_path / "results.json").read_text()

    def boom(src, dst):
        raise OSError("simulated crash mid-save")

    monkeypatch.setattr("repro.core.results.os.replace", boom)
    with pytest.raises(OSError):
        save_results([{"a": 1}, {"a": 2}], tmp_path, "results")
    monkeypatch.undo()
    assert (tmp_path / "results.json").read_text() == before
    leftovers = [p for p in tmp_path.iterdir() if p.name.startswith(".")]
    assert leftovers == []                 # tmp files cleaned up on failure


def test_atomic_write_text_replaces_content(tmp_path):
    p = tmp_path / "f.txt"
    atomic_write_text(p, "one")
    atomic_write_text(p, "two")
    assert p.read_text() == "two"
    assert [q.name for q in tmp_path.iterdir()] == ["f.txt"]
