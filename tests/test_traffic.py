"""Multi-tenant trace generator: determinism, mixes, envelopes, prefixes.

Pure host-side tests (no jax device work) over ``serve.traffic``: the
same seeded config must reproduce the same trace bit-for-bit, tenant
allocations follow largest-remainder weights exactly, diurnal thinning
stays inside its envelope, and shared-prefix populations share exactly
their group's system-prompt tokens.
"""
import numpy as np
import pytest

from repro.serve.requests import exponential_arrivals, poisson_requests
from repro.serve.traffic import (
    TRACE_NAMES, TenantSpec, TraceConfig, _tenant_counts, diurnal_envelope,
    generate_trace, preset_trace,
)


def _trace(name="poisson", n=40, seed=0, **kw):
    return generate_trace(preset_trace(name, n_requests=n, vocab=512,
                                       seed=seed, **kw))


def _key(reqs):
    return [(r.rid, r.tenant, tuple(r.prompt), r.max_new_tokens,
             r.arrival_s) for r in reqs]


# -- determinism ------------------------------------------------------------


@pytest.mark.parametrize("name", TRACE_NAMES)
def test_trace_deterministic_per_seed(name):
    assert _key(_trace(name, seed=7)) == _key(_trace(name, seed=7))


def test_trace_differs_across_seeds():
    assert _key(_trace(seed=0)) != _key(_trace(seed=1))


def test_config_hash_stable_and_sensitive():
    cfg = preset_trace("poisson", n_requests=40, vocab=512, seed=0)
    assert cfg.config_hash() == cfg.config_hash()
    assert len(cfg.config_hash()) == 12
    bumped = preset_trace("poisson", n_requests=40, vocab=512, seed=1)
    assert cfg.config_hash() != bumped.config_hash()
    other = preset_trace("bursty", n_requests=40, vocab=512, seed=0)
    assert cfg.config_hash() != other.config_hash()


# -- arrival structure ------------------------------------------------------


def test_arrivals_sorted_rids_in_order_first_at_zero():
    reqs = _trace(n=60, seed=3)
    assert reqs[0].arrival_s == 0.0
    arr = [r.arrival_s for r in reqs]
    assert arr == sorted(arr)
    assert [r.rid for r in reqs] == list(range(len(reqs)))


def test_tenant_counts_largest_remainder():
    tenants = (TenantSpec("a", weight=0.5), TenantSpec("b", weight=0.3),
               TenantSpec("c", weight=0.2))
    assert _tenant_counts(tenants, 10) == [5, 3, 2]
    # remainders decide who rounds up; the total always lands exactly
    assert sum(_tenant_counts(tenants, 7)) == 7
    reqs = _trace("poisson", n=40)
    by = {t: sum(r.tenant == t for r in reqs)
          for t in {r.tenant for r in reqs}}
    assert by == {"chat": 20, "search": 12, "code": 8}


def test_diurnal_envelope_bounds():
    t = np.linspace(0.0, 10.0, 500)
    env = diurnal_envelope(t, period_s=4.0, depth=0.6)
    assert np.all(env <= 1.0 + 1e-12) and np.all(env >= 0.4 - 1e-12)
    assert env[0] == pytest.approx(1.0)      # peak at t=0
    # disabled envelope is identically 1
    assert np.all(diurnal_envelope(t, 0.0, 0.5) == 1.0)
    assert np.all(diurnal_envelope(t, 4.0, 0.0) == 1.0)


def test_diurnal_trace_keeps_allocation_and_determinism():
    kw = dict(diurnal_period_s=0.5, diurnal_depth=0.7)
    reqs = _trace("poisson", n=48, seed=2, **kw)
    assert len(reqs) == 48
    assert _key(reqs) == _key(_trace("poisson", n=48, seed=2, **kw))


# -- shared prefixes --------------------------------------------------------


def test_shared_prefix_population():
    reqs = _trace("shared_prefix", n=30, seed=4)
    shared = [r for r in reqs if r.tenant in ("assist-a", "assist-b")]
    assert len(shared) >= 2
    heads = {tuple(r.prompt[:48]) for r in shared}
    assert len(heads) == 1                   # one system prompt per group
    bodies = {tuple(r.prompt[48:]) for r in shared}
    assert len(bodies) > 1                   # suffixes genuinely vary
    misc = [r for r in reqs if r.tenant == "misc"]
    assert all(tuple(r.prompt[:48]) not in heads for r in misc
               if len(r.prompt) >= 48)


def test_prefix_group_stable_across_tenant_split():
    # two tenants in the same group get the same tokens; a different
    # group (or seed) gets different ones
    mk = lambda grp, seed: generate_trace(TraceConfig(
        tenants=(TenantSpec("x", prefix_group=grp, prefix_len=16),),
        n_requests=3, vocab=512, seed=seed))
    a0 = tuple(mk("sys", 0)[0].prompt[:16])
    assert a0 == tuple(mk("sys", 0)[0].prompt[:16])
    assert a0 != tuple(mk("other", 0)[0].prompt[:16])
    assert a0 != tuple(mk("sys", 1)[0].prompt[:16])


def test_prompts_fit_slot_capacity():
    # every preset's worst case must fit the serve_slo MAX_LEN=96 slots
    for name in TRACE_NAMES:
        for r in _trace(name, n=40, seed=0):
            assert r.prompt_len + r.max_new_tokens <= 96, (name, r.rid)
            assert all(0 < t < 512 for t in r.prompt)


# -- poisson_requests seeding (satellite: shared arrival primitive) ---------


def test_exponential_arrivals_matches_inline_stream():
    rng = np.random.default_rng(11)
    got = exponential_arrivals(rng, 32, 100.0)
    rng2 = np.random.default_rng(11)
    gaps = rng2.exponential(1.0 / 100.0, size=32)
    np.testing.assert_array_equal(got, np.cumsum(gaps) - gaps[0])
    assert got[0] == 0.0


def test_poisson_requests_deterministic():
    a = poisson_requests(16, 200.0, 512, seed=5)
    b = poisson_requests(16, 200.0, 512, seed=5)
    assert [(r.arrival_s, r.max_new_tokens, tuple(np.asarray(r.prompt)))
            for r in a] == \
           [(r.arrival_s, r.max_new_tokens, tuple(np.asarray(r.prompt)))
            for r in b]
    c = poisson_requests(16, 200.0, 512, seed=6)
    assert [r.arrival_s for r in a] != [r.arrival_s for r in c]


# -- validation -------------------------------------------------------------


def test_tenant_spec_validation():
    with pytest.raises(AssertionError):
        TenantSpec("bad", arrival="fractal")
    with pytest.raises(AssertionError):
        TenantSpec("bad", weight=0.0)
    with pytest.raises(AssertionError):
        TenantSpec("bad", prefix_len=16)      # group without name
    with pytest.raises(AssertionError):
        TenantSpec("bad", prefix_group="sys")  # name without length
