"""Roofline machinery: HLO collective parsing, term model, buffer tool."""
import pytest

from repro.configs import SHAPES, get_config
from repro.roofline.analysis import analyze, model_flops
from repro.roofline.buffers import largest_shapes
from repro.roofline.hlo import CollectiveStats, parse_collectives, shape_bytes

HLO = """
ENTRY %main {
  %ag = bf16[16,4096,512]{2,1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={2}
  %ar = f32[1024,1024]{1,0} all-reduce(%p1), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = bf16[64,128]{1,0} reduce-scatter(%p2), replica_groups=[8,2]<=[16]
  %cp = bf16[4,512]{1,0} collective-permute(%p3), source_target_pairs={{0,1}}
  %a2a = f32[32,64]{1,0} all-to-all(%p4), replica_groups=[4,4]<=[16]
  %ars = (f32[128]{0}, f32[128]{0}) all-reduce-start(%p5, %p6), replica_groups={{0,1}}
  %ard = (f32[128]{0}, f32[128]{0}) all-reduce-done(%ars)
}
"""


def test_shape_bytes():
    assert shape_bytes("bf16[16,4096,512]") == 16 * 4096 * 512 * 2
    assert shape_bytes("(f32[128], f32[128])") == 1024
    assert shape_bytes("f32[]") == 4


def test_parse_collectives_counts_and_groups():
    st = parse_collectives(HLO, 256)
    assert st.counts["all-gather"] == 1
    assert st.counts["all-reduce"] == 2          # plain + start (done skipped)
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["collective-permute"] == 1
    assert st.counts["all-to-all"] == 1
    # ring wire-byte models
    ag = 16 * 4096 * 512 * 2
    assert st.wire_bytes["all-gather"] == pytest.approx(ag * 15 / 16)
    ar = 1024 * 1024 * 4
    start = 2 * 128 * 4
    assert st.wire_bytes["all-reduce"] == pytest.approx(
        2 * ar * 3 / 4 + 2 * start * 1 / 2)
    rs = 64 * 128 * 2
    assert st.wire_bytes["reduce-scatter"] == pytest.approx(rs * 1)  # g=2
    assert st.wire_bytes["collective-permute"] == 4 * 512 * 2


def test_analyze_terms_and_bottleneck():
    c = get_config("granite-8b")
    shape = SHAPES["train_4k"]
    r = analyze(c, shape, mesh_name="single", n_devices=256,
                flops_per_device=1e15, hbm_bytes_per_device=1e12,
                wire_bytes_per_device=1e10)
    assert r.compute_s == pytest.approx(1e15 / 197e12)
    assert r.memory_s == pytest.approx(1e12 / 819e9)
    assert r.collective_s == pytest.approx(1e10 / 50e9)
    assert r.bottleneck == "compute"
    assert 0 < r.roofline_fraction <= 1.0
    # MODEL_FLOPS = 6 N D for training
    assert r.model_flops == pytest.approx(
        6.0 * c.active_param_count() * 256 * 4096)


def test_model_flops_decode():
    c = get_config("mamba2-1.3b")
    r = model_flops(c, SHAPES["decode_32k"])
    assert r == pytest.approx(2.0 * c.active_param_count() * 128)


def test_largest_shapes():
    out = largest_shapes(HLO, top=3)
    assert out[0][2] == "bf16[16,4096,512]"
    assert out[0][0] == 16 * 4096 * 512 * 2
