"""CARAML-style automated sweep (the paper's core workflow): explore
(global batch x microbatch) for an LLM with the BenchmarkSuite harness,
power measurement, constraint filtering, and a final result table +
heatmap — the JUBE `run -> continue -> result` flow in one script.

  PYTHONPATH=src python examples/llm_sweep.py
"""
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    BenchmarkSuite, Runner, Space, Step, divisible_batch, heatmap, table,
)
from repro.data.synthetic import synthetic_tokens
from repro.models import lm
from repro.power.methods import RaplPower, TPUModelPower
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step

SEQ = 64


def main():
    c = get_config("qwen2-0.5b").reduced(vocab=4096)
    oc = OptConfig(warmup=1, total_steps=100)
    params = lm.init(jax.random.key(0), c)
    opt_state = opt_init(oc, params)
    steps = {}

    def bench(pt, ctx):
        gb, mb = pt["global_batch"], pt["micro_batch"]
        k = gb // mb
        if k not in steps:
            steps[k] = jax.jit(make_train_step(
                c, oc, StepConfig(microbatches=k)))
        toks = jnp.asarray(synthetic_tokens(gb, SEQ, c.vocab)[:, :SEQ])
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        p, o, _ = steps[k](params, opt_state, batch)
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        for _ in range(3):
            p, o, m = steps[k](params, opt_state, batch)
        jax.block_until_ready(p)
        dt = (time.perf_counter() - t0) / 3
        return {"tokens_per_s": gb * SEQ / dt, "ms_per_step": dt * 1e3}

    space = Space({"global_batch": [8, 16, 32], "micro_batch": [4, 8],
                   "dp": [1]}, [divisible_batch])
    suite = BenchmarkSuite(
        "llm_sweep", space, [Step("train", bench, retries=2)],
        result_columns=["global_batch", "micro_batch", "tokens_per_s",
                        "ms_per_step", "train_energy_wh"])
    rapl = RaplPower()
    methods = [rapl] if rapl.available() else [TPUModelPower(1, lambda: 1.0)]
    runner = Runner(suite, power_methods=methods,
                    out_dir="artifacts/examples")
    runner.run(verbose=True)
    print("\n== result table (jube result analog) ==")
    print(runner.result_table())
    print("== tokens/s heatmap (Fig. 4 analog) ==")
    print(heatmap(runner.records, "micro_batch", "global_batch",
                  "tokens_per_s"))


if __name__ == "__main__":
    main()
