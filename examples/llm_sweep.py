"""CARAML-style automated sweep (the paper's core workflow): explore
(global batch x microbatch) for an LLM through the unified WorkloadSpec
API — registry, runner-owned power selection, constraint filtering, and
a final result table + heatmap — the JUBE `run -> continue -> result`
flow in one script, with zero hand-rolled runner plumbing.

  PYTHONPATH=src python examples/llm_sweep.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.bench import WorkloadRunner, get_workload, workload
from repro.configs import get_config
from repro.core import Space, divisible_batch, heatmap
from repro.data.synthetic import synthetic_tokens
from repro.models import lm
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step

SEQ = 64


def _setup():
    c = get_config("qwen2-0.5b").reduced(vocab=4096)
    oc = OptConfig(warmup=1, total_steps=100)
    params = lm.init(jax.random.key(0), c)
    return c, oc, params, opt_init(oc, params)


@workload(
    "llm_sweep",
    analog="example: (global batch x microbatch) train-step sweep",
    space=Space({"global_batch": [8, 16, 32], "micro_batch": [4, 8],
                 "dp": [1]}, [divisible_batch]),
    tags=("example",),
    result_columns=["global_batch", "micro_batch", "tokens_per_s",
                    "ms_per_step", "energy_wh_per_step", "power_source"],
    primary_metric="tokens_per_s",
    heatmap_keys=("micro_batch", "global_batch", "tokens_per_s"),
)
def build(pt, ctx):
    """Example sweep: everything the old BenchmarkSuite version
    hand-rolled (power pick, warmup/timing, per-k jit cache) is
    ctx/runner-owned now."""
    c, oc, params, opt_state = ctx.memo("llm_sweep_state", _setup)
    gb, mb = pt["global_batch"], pt["micro_batch"]
    k = gb // mb
    step = ctx.memo(("llm_sweep_step", k), lambda: jax.jit(
        make_train_step(c, oc, StepConfig(microbatches=k))))
    toks = jnp.asarray(synthetic_tokens(gb, SEQ, c.vocab)[:, :SEQ])
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

    def train():
        m = ctx.measure(lambda: step(params, opt_state, batch)[0])
        return {"tokens_per_s": gb * SEQ / m.seconds,
                "ms_per_step": m.ms, "seconds": m.seconds,
                "energy_wh_per_step": m.energy_wh}

    return {"train": train}


def main():
    spec = get_workload("llm_sweep")
    runner = WorkloadRunner(spec, out_dir="artifacts/examples",
                            power="auto", retries=2)
    records = runner.run(verbose=True)
    print("\n== result table (jube result analog) ==")
    print(runner.result_table())
    print("== tokens/s heatmap (Fig. 4 analog) ==")
    flat = [r.flat() for r in records if r.ok]
    print(heatmap(flat, "micro_batch", "global_batch", "tokens_per_s"))


if __name__ == "__main__":
    main()
