"""Fault-tolerance demo: a seeded fault schedule crashes training mid-run,
the bounded-restart supervisor backs off and auto-resumes from the newest
valid atomic checkpoint, and an elastic rescale is planned after losing
devices — a thin driver over the ``repro.faults`` subsystem, recorded as
a WorkloadSpec through the unified bench runner (one ResultRecord with
crash/resume/rescale metrics under artifacts/examples/).

  PYTHONPATH=src python examples/fault_tolerance.py
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.bench import WorkloadRunner, get_workload, workload
from repro.bench.spec import Placement
from repro.ckpt.elastic import plan_rescale
from repro.configs import SHAPES, get_config
from repro.core import Space
from repro.faults.schedule import FaultSchedule
from repro.launch.train import main as train_main

STEPS = 30


@workload(
    "fault_tolerance",
    analog="example: fault schedule -> supervised resume -> elastic rescale",
    space=Space({"fault_preset": ["crash_mid"]}),
    tags=("example",),
    result_columns=["fault_preset", "schedule_hash", "resumed_from",
                    "final_step", "rescale_ok"],
    primary_metric="final_step",
)
def build(pt, ctx):
    """Supervised crash/resume train + elastic rescale plan."""
    preset = pt["fault_preset"]
    ckpt = ctx.memo("ft_ckpt_dir", tempfile.mkdtemp)

    def supervised():
        faults = FaultSchedule.from_preset(preset, seed=0, total_steps=STEPS)
        print(f"== 1. train under fault schedule {faults!r}")
        print("   (the supervisor catches the crash, backs off, and "
              "resumes from the newest valid checkpoint)")
        res = train_main(["--arch", "gpt-117m", "--preset", "tiny",
                          "--steps", str(STEPS), "--global-batch", "4",
                          "--seq-len", "64", "--ckpt-dir", ckpt,
                          "--ckpt-every", "10",
                          "--fault-preset", preset, "--fault-seed", "0"])
        assert res.final_step == STEPS, res
        assert res.resumed_from is not None, "run never crashed/resumed"
        return {"schedule_hash": faults.schedule_hash,
                "resumed_from": res.resumed_from,
                "final_step": res.final_step}

    def rescale():
        print("== 2. elastic rescale plan after losing 32 chips of a "
              "256-pod")
        c = get_config("granite-8b")
        plan = plan_rescale(c, SHAPES["train_4k"],
                            Placement.of({"dp": 16, "tp": 16}),
                            lost_devices=32)
        print(f"   {plan.old_shape} -> {plan.new_shape} ({plan.note})")
        print("   checkpoints are mesh-agnostic: restore() against the "
              "new mesh's shardings reshards automatically")
        return {"rescale_ok": 1}

    return {"supervised": supervised, "rescale": rescale}


def main():
    runner = WorkloadRunner(get_workload("fault_tolerance"),
                            out_dir="artifacts/examples", power="none")
    records = runner.run(verbose=False)
    print("\n== recorded ==")
    print(runner.result_table())
    assert all(r.ok for r in records), [r.error for r in records]


if __name__ == "__main__":
    main()
