"""Fault-tolerance demo: train, crash mid-run, auto-resume from the atomic
checkpoint, and plan an elastic rescale after losing devices.

  PYTHONPATH=src python examples/fault_tolerance.py
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.ckpt.checkpoint import latest_step
from repro.ckpt.elastic import plan_rescale
from repro.configs import SHAPES, get_config
from repro.launch.train import main as train_main


def main():
    ckpt = tempfile.mkdtemp()
    base = ["--arch", "gpt-117m", "--preset", "tiny", "--steps", "30",
            "--global-batch", "4", "--seq-len", "64",
            "--ckpt-dir", ckpt, "--ckpt-every", "10"]

    print("== 1. train with an injected failure at step 25")
    try:
        train_main(base + ["--fail-at-step", "25"])
    except RuntimeError as e:
        print(f"   crashed as injected: {e}")
    print(f"   latest atomic checkpoint: step {latest_step(ckpt)}")

    print("== 2. restart with the same command -> auto-resume")
    res = train_main(base)
    assert res.resumed_from is not None
    print(f"   resumed from step {res.resumed_from}, "
          f"finished at {res.final_step}")

    print("== 3. elastic rescale plan after losing 32 chips of a 256-pod")
    c = get_config("granite-8b")
    plan = plan_rescale(c, SHAPES["train_4k"], (16, 16), lost_devices=32)
    print(f"   {plan.old_shape} -> {plan.new_shape} ({plan.note})")
    print("   checkpoints are mesh-agnostic: restore() against the new "
          "mesh's shardings reshards automatically")


if __name__ == "__main__":
    main()
