"""Fault-tolerance demo: train, crash mid-run, auto-resume from the atomic
checkpoint, and plan an elastic rescale after losing devices — driven as
a WorkloadSpec through the unified bench runner, so the demo's phases are
ordinary recorded steps (one ResultRecord with crash/resume/rescale
metrics under artifacts/examples/) instead of hand-rolled script logic.

  PYTHONPATH=src python examples/fault_tolerance.py
"""
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.bench import WorkloadRunner, get_workload, workload
from repro.ckpt.checkpoint import latest_step
from repro.ckpt.elastic import plan_rescale
from repro.configs import SHAPES, get_config
from repro.core import Space
from repro.launch.train import main as train_main


@workload(
    "fault_tolerance",
    analog="example: crash -> atomic-checkpoint resume -> elastic rescale",
    space=Space({"fail_at_step": [25]}),
    tags=("example",),
    result_columns=["fail_at_step", "crashed_at_ckpt", "resumed_from",
                    "final_step", "rescale_ok"],
    primary_metric="final_step",
)
def build(pt, ctx):
    """Injected-failure train + auto-resume + rescale plan."""
    ckpt = ctx.memo("ft_ckpt_dir", tempfile.mkdtemp)
    base = ["--arch", "gpt-117m", "--preset", "tiny", "--steps", "30",
            "--global-batch", "4", "--seq-len", "64",
            "--ckpt-dir", ckpt, "--ckpt-every", "10"]

    def crash():
        print("== 1. train with an injected failure at step "
              f"{pt['fail_at_step']}")
        try:
            train_main(base + ["--fail-at-step", str(pt["fail_at_step"])])
        except RuntimeError as e:
            print(f"   crashed as injected: {e}")
        step = latest_step(ckpt)
        print(f"   latest atomic checkpoint: step {step}")
        return {"crashed_at_ckpt": step}

    def resume():
        print("== 2. restart with the same command -> auto-resume")
        res = train_main(base)
        assert res.resumed_from is not None
        print(f"   resumed from step {res.resumed_from}, "
              f"finished at {res.final_step}")
        return {"resumed_from": res.resumed_from,
                "final_step": res.final_step}

    def rescale():
        print("== 3. elastic rescale plan after losing 32 chips of a "
              "256-pod")
        c = get_config("granite-8b")
        plan = plan_rescale(c, SHAPES["train_4k"], (16, 16),
                            lost_devices=32)
        print(f"   {plan.old_shape} -> {plan.new_shape} ({plan.note})")
        print("   checkpoints are mesh-agnostic: restore() against the "
              "new mesh's shardings reshards automatically")
        return {"rescale_ok": 1}

    return {"crash": crash, "resume": resume, "rescale": rescale}


def main():
    runner = WorkloadRunner(get_workload("fault_tolerance"),
                            out_dir="artifacts/examples", power="none")
    records = runner.run(verbose=False)
    print("\n== recorded ==")
    print(runner.result_table())
    assert all(r.ok for r in records), [r.error for r in records]


if __name__ == "__main__":
    main()
