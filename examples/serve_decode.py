"""Batched serving example: prefill + continuous greedy decode with KV
caches, across three architecture families (dense GQA, SSM, MoE) —
the ``serve_step`` the decode_* dry-run shapes lower, runnable end to end.

  PYTHONPATH=src python examples/serve_decode.py
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import synthetic_tokens
from repro.models import lm
from repro.serve.engine import BatchedServer


def main():
    for arch in ("llama3.2-3b", "mamba2-1.3b", "granite-moe-3b-a800m"):
        c = get_config(arch).reduced()
        params = lm.init(jax.random.key(0), c)
        server = BatchedServer(c, params, max_len=24)
        prompts = jnp.asarray(synthetic_tokens(4, 32, c.vocab)[:, :32])
        res = server.generate(prompts, 16)
        assert res.tokens.shape == (4, 16)
        assert bool(jnp.all(res.tokens >= 0))
        print(f"{arch:24s} prefill {res.prefill_s * 1e3:7.1f} ms | "
              f"decode {res.decode_s * 1e3:7.1f} ms | "
              f"{res.decode_tokens_per_s:8,.0f} tok/s | "
              f"sample: {res.tokens[0, :8].tolist()}")


if __name__ == "__main__":
    main()
