"""Quickstart: the end-to-end driver — train a GPT LM from scratch on
synthetic OSCAR-like data with the full pipeline (tokenizer -> indexed
dataset -> sharded loader -> train loop with checkpointing), measuring
throughput and energy CARAML-style.

  PYTHONPATH=src python examples/quickstart.py              # quick (tiny)
  PYTHONPATH=src python examples/quickstart.py --full-117m  # ~100M params,
      a few hundred steps (hours on this CPU host; minutes on one v5e chip)
"""
import argparse
import pathlib
import sys
import tempfile

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.metrics import tokens_per_s
from repro.data.indexed import IndexedDatasetReader, IndexedDatasetWriter
from repro.data.loader import ShardedLoader, lm_sample_fn
from repro.data.synthetic import synthetic_oscar_text
from repro.data.tokenizer import ByteFallbackTokenizer
from repro.models import lm
from repro.power.ctxmgr import get_power
from repro.power.methods import RaplPower, TPUModelPower
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-117m", action="store_true",
                    help="train the real GPT-117M for a few hundred steps")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()

    if args.full_117m:
        c = get_config("gpt-117m")
        steps, gb, seq = args.steps or 300, 8, 256
    else:
        c = get_config("gpt-117m").reduced(d_model=128, n_layers=4, d_ff=512,
                                           n_heads=4, n_kv_heads=4, d_head=32,
                                           vocab=8192)
        steps, gb, seq = args.steps or 60, 8, 128

    print(f"== 1. data pipeline: synthetic OSCAR -> tokenizer -> "
          f"indexed dataset")
    docs = synthetic_oscar_text(2000, seed=0)
    tok = ByteFallbackTokenizer.train(docs, max_vocab=c.vocab)
    tmp = tempfile.mkdtemp()
    w = IndexedDatasetWriter(pathlib.Path(tmp) / "oscar")
    for d in docs:
        w.add_document(tok.encode(d))
    w.finalize(meta={"tokenizer": "byte-fallback", "docs": len(docs)})
    reader = IndexedDatasetReader(pathlib.Path(tmp) / "oscar")
    print(f"   {reader.n_documents} docs, {reader.n_tokens:,} tokens")

    print(f"== 2. model: {c.name} ({c.param_count() / 1e6:.1f}M params)")
    oc = OptConfig(lr=3e-4, warmup=max(steps // 20, 5), total_steps=steps)
    params = lm.init(jax.random.key(0), c)
    opt_state = opt_init(oc, params)
    step = jax.jit(make_train_step(c, oc, StepConfig()), donate_argnums=(0, 1))

    loader = ShardedLoader(lm_sample_fn(reader, seq), gb)

    def batches():
        for b in loader:
            yield {k: jnp.asarray(v) for k, v in b.items()}

    print(f"== 3. train {steps} steps (batch {gb} x seq {seq}) with "
          f"energy measurement")
    rapl = RaplPower()
    methods = [rapl] if rapl.available() else [
        TPUModelPower(1, lambda: 1.0)]
    cfg = LoopConfig(total_steps=steps, ckpt_every=max(steps // 2, 10),
                     ckpt_dir=str(pathlib.Path(tmp) / "ckpt"),
                     log_every=max(steps // 6, 5), seq_len=seq,
                     global_batch=gb)
    with get_power(methods, interval_ms=100) as scope:
        res = train_loop(step, params, opt_state, batches(), cfg)
    loader.close()
    wh = scope.total_energy_wh()
    n_tok = res.steps_run * gb * seq
    print(f"\n== results ==")
    print(f"loss: {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"(decreased: {res.losses[-1] < res.losses[0]})")
    print(f"throughput: {res.tokens_per_s:,.0f} tokens/s")
    print(f"energy: {wh:.4f} Wh ({methods[0].name}) -> "
          f"{n_tok / wh if wh else 0:,.0f} tokens/Wh")
    assert res.losses[-1] < res.losses[0], "training must reduce loss"


if __name__ == "__main__":
    main()
