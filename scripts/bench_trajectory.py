#!/usr/bin/env python
"""Append a cross-run compare entry to a BENCH_<workload>.json trajectory.

The baseline-store files under ``artifacts/bench/baselines/`` are
*replaced* on every ``compare --promote``; this script is the memory
they lose: each invocation appends one entry — the compare summary plus
the headline per-point metric ratios — to a committed, append-only
``BENCH_<workload>.json`` at the repo root, so the performance history
of a workload survives across promotions and PRs.

    PYTHONPATH=src python scripts/bench_trajectory.py --workload serve \
        --baseline artifacts/bench/baselines --current artifacts/ci-bench \
        --label "PR 4: paged KV + fused decode"

``--workload`` is repeatable: each named workload appends one entry to
its own ``BENCH_<workload>.json`` from the same baseline/current pair.

``--backfill-axis key=value`` (repeatable) handles Space schema growth:
when a workload gains a new axis, the old baseline's points predate it
and would no longer join by point key. Backfilling stamps the given
value into every *baseline* point that lacks the key — comparing the
pre-axis measurement against the named configuration of the new sweep.
Use it only with the value that describes what the old code actually
ran (e.g. the serve workload grew ``cache={slotted,paged}`` in PR 4; the
pre-PR engine was the dense slotted layout at every cell).
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.bench.compare import NOISE_K, compare_sets, load_result_set  # noqa: E402
from repro.bench.records import compare_metrics  # noqa: E402
from repro.core.manifest import git_sha  # noqa: E402
from repro.core.results import atomic_write_text  # noqa: E402

#: headline metrics recorded per point (full deltas stay in the report)
TRAJECTORY_METRICS = ("decode_tok_s", "tokens_per_s", "images_per_s",
                      "wh_per_token", "occupancy", "speedup_vs_fixed",
                      "speedup_vs_slotted", "tok_s_per_device",
                      "scaling_efficiency", "wh_per_token_scaling",
                      "speedup_vs_fp_kv", "kv_stream_prefix_agreement",
                      "max_concurrency",
                      "us", "ms", "goodput", "ttft_p99", "tpot_p99",
                      "wh_per_slo_request", "goodput_tokens_per_s",
                      "recovery_s", "wasted_tokens",
                      "wh_overhead_resilience")


def _num(x):
    """RFC-JSON-safe number: non-finite floats become strings (the
    trajectory file is committed; bare NaN/Infinity tokens are not JSON)."""
    if isinstance(x, (int, float)) and not math.isfinite(x):
        return str(x)
    return x


def parse_axis(kv: str) -> tuple[str, str]:
    if "=" not in kv:
        raise argparse.ArgumentTypeError(f"--backfill-axis wants key=value, "
                                         f"got {kv!r}")
    k, v = kv.split("=", 1)
    return k, v


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="append a compare entry to BENCH_<workload>.json")
    ap.add_argument("--workload", required=True, action="append",
                    dest="workloads", metavar="WORKLOAD",
                    help="repeatable: each workload appends to its own "
                         "BENCH_<workload>.json")
    ap.add_argument("--baseline", default="artifacts/bench/baselines")
    ap.add_argument("--current", default="artifacts/ci-bench")
    ap.add_argument("--out", default=None,
                    help="trajectory file (default BENCH_<workload>.json)")
    ap.add_argument("--label", default="",
                    help="one-line description of what changed")
    ap.add_argument("--backfill-axis", type=parse_axis, action="append",
                    default=[], metavar="KEY=VALUE",
                    help="stamp a missing Space axis into baseline points "
                         "(schema-growth join; see module docstring)")
    ap.add_argument("--noise-k", type=float, default=NOISE_K,
                    help="noise-widening multiplier for classification "
                         "(0 classifies on base tolerances alone — for "
                         "trajectories against old records whose stamped "
                         "watchdog noise is a cross-point artifact)")
    args = ap.parse_args(argv)
    if args.out and len(args.workloads) > 1:
        print("[trajectory] --out only applies to a single --workload",
              file=sys.stderr)
        return 2
    all_base = load_result_set(args.baseline)
    all_cur = load_result_set(args.current)
    rc = 0
    for workload in args.workloads:
        rc = max(rc, _append_one(workload, all_base, all_cur, args))
    return rc


def _append_one(workload: str, all_base, all_cur, args) -> int:
    base = [r for r in all_base if r.workload == workload]
    cur = [r for r in all_cur if r.workload == workload]
    if not cur:
        print(f"[trajectory] no {workload!r} records in "
              f"{args.current}", file=sys.stderr)
        return 2
    for key, value in args.backfill_axis:
        for r in base:
            r.point.setdefault(key, value)

    cmp = compare_sets(base, cur, noise_k=args.noise_k,
                       baseline_label=str(args.baseline),
                       current_label=str(args.current))
    points = []
    cur_by = {}
    for r in cur:
        cur_by[json.dumps(dict(r.point), sort_keys=True, default=str)] = r
    for p in cmp.points:
        row = {"point": p.point, "status": p.status, "metrics": {}}
        for d in p.deltas:
            if d.metric in TRAJECTORY_METRICS:
                ratio = (d.current / d.base) if d.base else None
                if ratio is not None and math.isfinite(ratio):
                    ratio = round(ratio, 4)
                row["metrics"][d.metric] = {
                    "baseline": _num(d.base), "current": _num(d.current),
                    "ratio": _num(ratio),
                    "status": d.status,
                }
        rec = cur_by.get(json.dumps(dict(p.point), sort_keys=True,
                                    default=str))
        if rec is not None:   # metrics with no baseline twin (new axes)
            for m, v in compare_metrics(rec).items():
                if m in TRAJECTORY_METRICS and m not in row["metrics"]:
                    row["metrics"][m] = {"current": _num(v)}
        points.append(row)

    entry = {
        "workload": workload,
        "git_sha": git_sha(),
        "label": args.label,
        "baseline": str(args.baseline),
        "current": str(args.current),
        "backfilled_axes": dict(args.backfill_axis),
        "noise_k": args.noise_k,
        "summary": cmp.counts(),
        "points": points,
    }
    out = pathlib.Path(args.out or f"BENCH_{workload}.json")
    history = json.loads(out.read_text()) if out.exists() else []
    if not isinstance(history, list):
        print(f"[trajectory] {out} is not a JSON list; refusing to clobber",
              file=sys.stderr)
        return 2
    history.append(entry)
    atomic_write_text(out, json.dumps(history, indent=1, default=str) + "\n")
    print(f"[trajectory] {out}: {len(history)} entries; {cmp.summary()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
