"""TTFT-cliff gate: chunked scheduling must beat phased where it matters.

Drives the serve_slo long_prefill cell directly (same trace, same tight
pool, float32 so greedy argmax never flakes) under both schedulers and
asserts the ISSUE-8 acceptance criteria:

  * chunked ttft_p99 <= 0.7x phased (median of RUNS repeats per sched —
    single-run tail quantiles on a shared CI host are too noisy to gate);
  * chunked goodput >= phased goodput;
  * every run of either scheduler produced the SAME token streams
    (preemption + replay included — the bit-identity contract);
  * the chunked runs actually exercised preemption (the cell is tuned
    so phased can only defer: zero preemptions means the tight-pool
    regime silently went slack and the gate is measuring nothing).

Run from the repo root:  PYTHONPATH=src python scripts/check_ttft_gate.py
"""
import statistics
import sys

import jax

from repro.bench.workloads.serve_slo import (
    BLOCK_SIZE, MAX_LEN, N_REQUESTS_SMOKE, N_SLOTS, POOL_BY_TRACE, SEED,
    SLO_BY_TENANT, SLO_TIGHT, _stream_hash,
)
from repro.configs import get_config
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.serve.slo import evaluate_slo
from repro.serve.traffic import generate_trace, preset_trace

RUNS = 3
TTFT_RATIO_MAX = 0.7


def main() -> int:
    c = get_config("llama3.2-3b").reduced(dtype="float32",
                                          param_dtype="float32")
    params = lm.init(jax.random.key(SEED), c)
    engine = ServeEngine(c, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                         cache="paged", block_size=BLOCK_SIZE,
                         n_blocks=POOL_BY_TRACE["long_prefill"])
    cfg = preset_trace("long_prefill", n_requests=N_REQUESTS_SMOKE,
                       vocab=c.vocab, seed=SEED)
    requests = generate_trace(cfg)

    stats = {}
    hashes = set()
    for sched in ("phased", "chunked"):
        engine.warmup(requests=requests, sched=sched)
        p99s, goodputs, preemptions = [], [], 0
        for _ in range(RUNS):
            out = engine.serve(requests, policy="continuous", sched=sched)
            rep = evaluate_slo(out.results, SLO_BY_TENANT,
                               default=SLO_TIGHT)
            if rep.n_requests != len(requests):
                return f"{sched}: served {rep.n_requests}/{len(requests)}"
            p99s.append(rep.ttft_p99_s)
            goodputs.append(rep.goodput)
            preemptions += engine.preemptions
            hashes.add(_stream_hash(out.results))
        stats[sched] = {"ttft_p99": statistics.median(p99s),
                        "goodput": min(goodputs),
                        "preemptions": preemptions}

    ph, ch = stats["phased"], stats["chunked"]
    ratio = ch["ttft_p99"] / max(ph["ttft_p99"], 1e-12)
    print(f"ttft gate: phased p99={ph['ttft_p99'] * 1e3:.1f}ms "
          f"chunked p99={ch['ttft_p99'] * 1e3:.1f}ms ratio={ratio:.3f} "
          f"(max {TTFT_RATIO_MAX}) goodput={ph['goodput']:.3f}->"
          f"{ch['goodput']:.3f} preemptions={ch['preemptions']}")
    if len(hashes) != 1:
        return f"token streams diverged across runs/schedulers: {hashes}"
    if ph["preemptions"] != 0:
        return "phased run preempted — phased must only defer"
    if ch["preemptions"] == 0:
        return ("chunked never preempted: the long_prefill pool is no "
                "longer tight enough to measure the cliff")
    if ratio > TTFT_RATIO_MAX:
        return (f"chunked/phased ttft_p99 ratio {ratio:.3f} > "
                f"{TTFT_RATIO_MAX}: the chunked scheduler stopped "
                f"collapsing the admission-stall cliff")
    if ch["goodput"] < ph["goodput"]:
        return (f"chunked goodput {ch['goodput']:.3f} < phased "
                f"{ph['goodput']:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
