#!/usr/bin/env bash
# Tier-1 CI gate: collection must be clean, then the suite must pass,
# then the smoke benchmark suite must run end-to-end.
#
# Run from the repo root:  bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# 1. Collection errors fail fast and loudly (a module-level ImportError
#    in any test file must never be mistaken for a "skipped" test —
#    that is how the hypothesis import broke the seed suite unnoticed).
python -m pytest -q --collect-only >/dev/null

# 2. The tier-1 command from ROADMAP.md.
python -m pytest -x -q

# 3. Every smoke-tagged workload end-to-end through the unified CLI on
#    the deterministic synthetic power backend (multi-device workloads
#    get their forced host platform via the CLI's XLA_FLAGS re-exec,
#    sized to the largest placement in the selected sweeps).
#    The serve workload's smoke points cover BOTH KV layouts
#    (cache=slotted and cache=paged) on the XLA paged path; the
#    llm_train/resnet50/heatmap smoke spaces each include a dp-scaling
#    cell (placement=dp2 beside dp1), so the sharded execution path and
#    the derived scaling metrics (tok_s_per_device, scaling_efficiency,
#    wh_per_token_scaling) are exercised and baseline-gated on every run.
python -m repro.bench list
rm -rf artifacts/ci-bench   # no stale results from earlier local runs
python -m repro.bench run --tags smoke --power synthetic \
    --out artifacts/ci-bench

# 3a. The dp-scaling smoke cells must actually have recorded scaling
#     metrics — a silent stamping regression would otherwise disarm the
#     scaling gate while every raw-throughput cell stayed green — AND
#     multi-device llm_train cells must clear the scaling_efficiency
#     floor (ISSUE 6: dp2 collapsed to 0.10 via jit recompile churn;
#     the floor keeps scaling from silently inverting again). The
#     efficiency is emulation-aware (normalized by min(n_devices, host
#     cores) — runner._emulation_device_cap), so the floor is
#     meaningful even on a 1-core CI host faking N devices.
python - <<'EOF'
import json, sys
FLOOR = 0.6
recs = json.load(open("artifacts/ci-bench/llm_train/results.json"))["records"]
dp2 = [r for r in recs if r["point"].get("placement") == "dp2"
       and r["status"] == "ok"]
missing = [r["point"] for r in dp2
           if "scaling_efficiency" not in r["metrics"]
           or "wh_per_token_scaling" not in r["metrics"]]
if not dp2 or missing:
    sys.exit(f"dp-scaling smoke cell broken: dp2 cells={len(dp2)} "
             f"missing scaling metrics={missing}")
low = [(r["point"], r["metrics"]["scaling_efficiency"])
       for r in recs
       if r["status"] == "ok" and r.get("n_devices", 1) > 1
       and r["metrics"].get("scaling_efficiency", 1.0) < FLOOR]
if low:
    sys.exit(f"scaling_efficiency floor {FLOOR} violated: {low}")
effs = [round(r["metrics"]["scaling_efficiency"], 3) for r in dp2]
print(f"dp-scaling smoke: {len(dp2)} dp2 cell(s), "
      f"scaling_efficiency={effs} (floor {FLOOR})")
EOF

# 3b. Prefix-caching effectiveness gate (ISSUE 7 acceptance): the
#     serve_slo smoke run must show the shared_prefix trace's
#     cache=paged+prefix cell actually hitting the prefix index AND
#     measurably beating the plain paged twin on TTFT p99 and
#     Wh-per-SLO-met-request. Thresholds sit well above the measured
#     ratios (ttft ~0.34x, wh ~0.77x) so only a broken prefix path —
#     not noise — trips them. Token-stream equality is the pytest
#     suite's job (tests/test_prefix_cache.py); this gate covers the
#     performance half of the contract.
python - <<'EOF'
import json, sys
recs = json.load(open("artifacts/ci-bench/serve_slo/results.json"))["records"]
# the sched axis doubles every (trace, cache) cell: the prefix gate
# compares the phased twins so the ratio isolates caching alone
cells = {(r["point"]["trace"], r["point"]["cache"]): r["metrics"]
         for r in recs
         if r["status"] == "ok" and r["point"].get("sched") == "phased"}
base = cells.get(("shared_prefix", "paged"))
pref = cells.get(("shared_prefix", "paged+prefix"))
if base is None or pref is None:
    sys.exit(f"serve_slo shared_prefix cells missing: {sorted(cells)}")
if pref.get("prefix_hit_requests", 0) <= 0:
    sys.exit("prefix cache never hit on the shared_prefix trace")
ttft_ratio = pref["ttft_p99"] / max(base["ttft_p99"], 1e-12)
wh_ratio = pref["wh_per_slo_request"] / max(base["wh_per_slo_request"], 1e-12)
if ttft_ratio > 0.8:
    sys.exit(f"prefix caching stopped helping TTFT p99: ratio {ttft_ratio:.3f}")
if wh_ratio > 0.95:
    sys.exit(f"prefix caching stopped helping Wh/SLO-request: "
             f"ratio {wh_ratio:.3f}")
print(f"prefix-cache gate: hits={pref['prefix_hit_requests']} "
      f"ttft_p99 ratio={ttft_ratio:.3f} wh/slo ratio={wh_ratio:.3f}")
EOF

# 3c. Paged decode-attention kernel drill: one serve cell with every
#     decode step routed through the Pallas kernel in interpret mode on
#     CPU (REPRO_PAGED_IMPL=pallas-interpret). This is a correctness
#     gate only — interpret-mode timings are meaningless, so the run
#     lands in a scratch dir and is never compared or promoted.
#     Pinned to sched=phased: the sched axis would double the (slow)
#     interpret cell count, and chunked decode runs the exact same
#     paged-attention program (tests/test_chunked_serve.py covers the
#     chunked paths at full fidelity). Pinned to kv_dtype=fp32 for the
#     same reason — the int8 interpret coverage lives in the kernels
#     smoke cases (paged_prefill_int8) and tests/test_int8_kv.py.
rm -rf artifacts/ci-paged-kernel
REPRO_PAGED_IMPL=pallas-interpret python -m repro.bench run --suite serve \
    --points cache=paged,policy=continuous,sched=phased,kv_dtype=fp32 \
    --tags smoke --power synthetic --out artifacts/ci-paged-kernel

# 3d. TTFT-cliff gate (ISSUE 8 acceptance): on the tight-pool
#     long_prefill trace, the chunked scheduler must hold its median
#     ttft_p99 at <= 0.7x phased with goodput no worse, token streams
#     bit-identical across every run of both schedulers, and real
#     preemptions recorded (zero would mean the oversubscribed regime
#     went slack and the gate is measuring nothing). Median-of-3 per
#     sched: single-run tail quantiles are too noisy to gate on a
#     shared host — the serve_slo workload rows still record the
#     single-run vs_phased ratios with a generous compare tolerance.
python scripts/check_ttft_gate.py

# 3e. Resilience gate (ISSUE 9 acceptance): the crash_mid smoke cell
#     must complete through the bounded-restart supervisor — crash
#     mid-run, backoff, resume from the newest valid checkpoint — with
#     the recompute bounded by the checkpoint cadence and the resumed
#     loss trace element-equal to the fault-free twin's (loss_bitmatch:
#     resume restored the real state and the step-indexed data stream
#     stayed aligned). The none-preset twin cell must not restart at
#     all, and every cell carries its schedule_hash stamp.
python - <<'EOF'
import json, math, sys
recs = json.load(open("artifacts/ci-bench/resilience/results.json"))["records"]
cells = {r["point"]["fault_preset"]: r for r in recs if r["status"] == "ok"}
crash, none = cells.get("crash_mid"), cells.get("none")
if crash is None or none is None:
    sys.exit(f"resilience smoke cells missing: have {sorted(cells)}")
m, ck = crash["metrics"], int(crash["point"]["ckpt_every"])
if m["final_step"] != 30:
    sys.exit(f"crash_mid cell never finished: final_step={m['final_step']}")
if m["restarts"] < 1:
    sys.exit("crash_mid cell never crashed — the schedule went dead")
bound = ck * m["tokens_per_step"]
if m["wasted_tokens"] > bound:
    sys.exit(f"wasted_tokens {m['wasted_tokens']} exceeds ckpt cadence "
             f"bound {bound} — resume skipped a usable checkpoint")
if m["loss_bitmatch"] != 1.0:
    sys.exit("resumed loss trace diverged from the fault-free twin")
if not math.isfinite(m["wh_overhead_resilience"]):
    sys.exit(f"wh_overhead_resilience not finite: "
             f"{m['wh_overhead_resilience']}")
if none["metrics"]["restarts"] != 0 or none["metrics"]["loss_bitmatch"] != 1.0:
    sys.exit(f"fault-free twin cell dirty: {none['metrics']}")
missing_hash = [p for p, r in cells.items()
                if not r["metrics"].get("schedule_hash")]
if missing_hash:
    sys.exit(f"cells without a schedule_hash stamp: {missing_hash}")
print(f"resilience gate: restarts={m['restarts']} "
      f"wasted_tokens={m['wasted_tokens']}<={bound} "
      f"recovery_s={m['recovery_s']:.3f} loss_bitmatch=1 "
      f"wh_overhead={m['wh_overhead_resilience']:.4f}")
EOF

# 3f. Paged prefill-attention kernel drill (ISSUE 10): one serve_slo
#     cell whose shared-prefix hits route every suffix prefill through
#     the Pallas paged-prefill kernel in interpret mode
#     (engine._prefix_prefill_fn -> lm.prefill(paged_prefix=...) ->
#     kernels.prefill_attention). Correctness-drill only, like 3c: the
#     run proves the scalar-prefetch block-table walk executes end to
#     end on this host; oracle bit-exactness is pytest's job
#     (tests/test_prefill_kernel.py, tests/test_prefix_cache.py).
rm -rf artifacts/ci-prefill-kernel
REPRO_PAGED_IMPL=pallas-interpret python -m repro.bench run \
    --suite serve_slo \
    --points trace=shared_prefix,cache=paged+prefix,sched=phased,kv_dtype=fp32 \
    --tags smoke --power synthetic --out artifacts/ci-prefill-kernel
python - <<'EOF'
import json, sys
recs = json.load(
    open("artifacts/ci-prefill-kernel/serve_slo/results.json"))["records"]
ok = [r for r in recs if r["status"] == "ok"]
if not ok:
    sys.exit("paged-prefill kernel drill produced no ok cell")
hits = ok[0]["metrics"].get("prefix_hit_requests", 0)
if hits <= 0:
    sys.exit("paged-prefill kernel drill never hit the prefix index — "
             "the Pallas prefill path was not exercised")
print(f"paged-prefill kernel drill: {hits} prefix-hit requests through "
      f"the interpret-mode kernel")
EOF

# 3g. int8 KV-block gate (ISSUE 10 acceptance): every paged continuous
#     fp32/int8 twin pair in the smoke run must show (a) the quantized
#     pool at <= 0.55x the fp32 bytes for the SAME block count (int8
#     blocks + f32 per-block-per-head scales against bf16 blocks —
#     measured 0.508), (b) max_concurrency at least doubled at the fp
#     byte budget, (c) energy per token no worse than ~parity (measured
#     ratio 0.92; the 1.10 ceiling only absorbs single-run CPU wobble,
#     a real int8-path slowdown lands far above it), and (d) greedy
#     token streams tracking the fp32 twin's (mean longest-common-prefix
#     fraction >= 0.70; measured 0.85 — quantization flips some argmax
#     ties mid-stream, but a kernel/scale bug collapses agreement toward
#     0 because streams diverge at the first token).
python - <<'EOF'
import json, sys
recs = json.load(open("artifacts/ci-bench/serve/results.json"))["records"]
cells = {}
for r in recs:
    p = r["point"]
    if (r["status"] == "ok" and p.get("cache") == "paged"
            and p.get("policy") == "continuous"):
        key = (p["slots"], p["rate_hz"], p["sched"])
        cells.setdefault(key, {})[p["kv_dtype"]] = r["metrics"]
pairs = {k: v for k, v in cells.items() if "fp32" in v and "int8" in v}
if not pairs:
    sys.exit(f"no fp32/int8 paged-continuous twin cells: {sorted(cells)}")
for key, v in sorted(pairs.items()):
    fp, i8 = v["fp32"], v["int8"]
    pool = i8["pool_bytes"] / max(fp["pool_bytes"], 1)
    if pool > 0.55:
        sys.exit(f"{key}: int8 pool_bytes ratio {pool:.3f} > 0.55")
    if i8["max_concurrency"] < 2 * fp["max_concurrency"]:
        sys.exit(f"{key}: int8 max_concurrency {i8['max_concurrency']} "
                 f"< 2x fp32 {fp['max_concurrency']}")
    wh = i8["wh_per_token"] / max(fp["wh_per_token"], 1e-12)
    if wh > 1.10:
        sys.exit(f"{key}: int8 wh_per_token ratio {wh:.3f} > 1.10")
    agree = i8.get("kv_stream_prefix_agreement")
    if agree is None or agree < 0.70:
        sys.exit(f"{key}: kv_stream_prefix_agreement {agree} < 0.70")
    if "speedup_vs_fp_kv" not in i8:
        sys.exit(f"{key}: int8 cell missing speedup_vs_fp_kv")
    print(f"int8 gate {key}: pool={pool:.3f} "
          f"conc={fp['max_concurrency']}->{i8['max_concurrency']} "
          f"wh_ratio={wh:.3f} agree={agree:.3f} "
          f"speedup={i8['speedup_vs_fp_kv']:.3f}")
EOF

# 4. Regression gate: the smoke run just produced must not be slower or
#    hungrier than the committed baselines beyond tolerance. The base
#    tolerance is 0.3 (was 0.45, was 0.6): every workload stamps
#    same-point measure_split noise (rel_std 0.03-0.15) and the compare
#    engine widens per point by noise_k * rel_std, so the blanket only
#    needs to cover systematic host drift, not per-point wobble;
#    workloads that genuinely can't hold 0.3 carry their own
#    compare_tols. `make bench-compare` runs the tight default gate
#    locally. Refresh the store after an intentional perf change with
#    `make bench-promote` and commit artifacts/bench/baselines/.
python -m repro.bench compare artifacts/bench/baselines artifacts/ci-bench \
    --fail-on-regression --fail-on-missing --rel-tol default=0.3 \
    --report-out artifacts/ci-bench/compare-report.md
