#!/usr/bin/env bash
# Tier-1 CI gate: collection must be clean, then the suite must pass,
# then the smoke benchmark suite must run end-to-end.
#
# Run from the repo root:  bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# 1. Collection errors fail fast and loudly (a module-level ImportError
#    in any test file must never be mistaken for a "skipped" test —
#    that is how the hypothesis import broke the seed suite unnoticed).
python -m pytest -q --collect-only >/dev/null

# 2. The tier-1 command from ROADMAP.md.
python -m pytest -x -q

# 3. Every smoke-tagged workload end-to-end through the unified CLI on
#    the deterministic synthetic power backend (multi-device workloads
#    get their forced host platform via the CLI's XLA_FLAGS re-exec).
python -m repro.bench list
python -m repro.bench run --tags smoke --power synthetic \
    --out artifacts/ci-bench
