#!/usr/bin/env bash
# Tier-1 CI gate: collection must be clean, then the suite must pass.
#
# Run from the repo root:  bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# 1. Collection errors fail fast and loudly (a module-level ImportError
#    in any test file must never be mistaken for a "skipped" test —
#    that is how the hypothesis import broke the seed suite unnoticed).
python -m pytest -q --collect-only >/dev/null

# 2. The tier-1 command from ROADMAP.md.
python -m pytest -x -q
