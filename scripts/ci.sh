#!/usr/bin/env bash
# Tier-1 CI gate: collection must be clean, then the suite must pass,
# then the smoke benchmark suite must run end-to-end.
#
# Run from the repo root:  bash scripts/ci.sh
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# 1. Collection errors fail fast and loudly (a module-level ImportError
#    in any test file must never be mistaken for a "skipped" test —
#    that is how the hypothesis import broke the seed suite unnoticed).
python -m pytest -q --collect-only >/dev/null

# 2. The tier-1 command from ROADMAP.md.
python -m pytest -x -q

# 3. Every smoke-tagged workload end-to-end through the unified CLI on
#    the deterministic synthetic power backend (multi-device workloads
#    get their forced host platform via the CLI's XLA_FLAGS re-exec).
#    The serve workload's smoke points cover BOTH KV layouts
#    (cache=slotted and cache=paged) on the XLA paged path.
python -m repro.bench list
rm -rf artifacts/ci-bench   # no stale results from earlier local runs
python -m repro.bench run --tags smoke --power synthetic \
    --out artifacts/ci-bench

# 3b. Paged decode-attention kernel drill: one serve cell with every
#     decode step routed through the Pallas kernel in interpret mode on
#     CPU (REPRO_PAGED_IMPL=pallas-interpret). This is a correctness
#     gate only — interpret-mode timings are meaningless, so the run
#     lands in a scratch dir and is never compared or promoted.
rm -rf artifacts/ci-paged-kernel
REPRO_PAGED_IMPL=pallas-interpret python -m repro.bench run --suite serve \
    --points cache=paged,policy=continuous --tags smoke --power synthetic \
    --out artifacts/ci-paged-kernel

# 4. Regression gate: the smoke run just produced must not be slower or
#    hungrier than the committed baselines beyond tolerance. The base
#    tolerance is widened here (default=0.6) because shared CI hosts are
#    noisy — the gate is for order-of-magnitude regressions, not 5%
#    drift; `make bench-compare` runs the tight default gate locally.
#    Refresh the store after an intentional perf change with
#    `make bench-promote` and commit artifacts/bench/baselines/.
python -m repro.bench compare artifacts/bench/baselines artifacts/ci-bench \
    --fail-on-regression --fail-on-missing --rel-tol default=0.6 \
    --report-out artifacts/ci-bench/compare-report.md
