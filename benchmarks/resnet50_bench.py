"""Compatibility shim for the `resnet50` workload (Fig. 3 / Table III).

The benchmark now lives in `repro.bench.workloads.resnet50`; run it via

  PYTHONPATH=src python -m repro.bench run --suite resnet50
"""
from __future__ import annotations

import sys

from repro.bench.cli import main as bench_main


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    return bench_main(["run", "--suite", "resnet50", *argv])


if __name__ == "__main__":
    sys.exit(main())
