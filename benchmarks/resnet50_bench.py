"""Paper Fig. 3 / Table III analog: ResNet50 training throughput + energy.

images/s and images/Wh across a batch sweep (single device), using the
data-parallel train step (the Horovod-analog path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_step
from repro.configs.resnet50 import CONFIG
from repro.core.results import save_results, table
from repro.data.synthetic import synthetic_images
from repro.models import resnet
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import make_resnet_train_step


def run(batches=(16, 32, 64)):
    c = CONFIG.reduced(img_size=64, width=16)
    oc = OptConfig(warmup=2, total_steps=1000)
    params = resnet.init(jax.random.key(0), c)
    opt_state = opt_init(oc, params)
    step = jax.jit(make_resnet_train_step(c, oc))
    records = []
    for gb in batches:
        imgs, labels = synthetic_images(gb, c.img_size, c.n_classes)
        batch = {"images": jnp.asarray(imgs), "labels": jnp.asarray(labels)}
        p, o = params, opt_state

        def one():
            nonlocal p, o
            p, o, m = step(p, o, batch)
            return m["loss"]

        dt, wh, src = time_step(one, warmup=1, iters=3)
        ips = gb / dt
        rec = {"model": c.name, "global_batch": gb, "images_per_s": ips,
               "ms_per_step": dt * 1e3, "energy_wh_per_step": wh,
               "images_per_wh": (gb / wh) if wh > 0 else 0.0,
               "power_source": src}
        records.append(rec)
        emit(f"resnet50/gb{gb}", dt * 1e6, f"images_per_s={ips:.1f}")
    save_results(records, "artifacts/bench", "resnet50_fig3")
    return records


def main():
    print(table(run(), floatfmt="{:.2f}"))


if __name__ == "__main__":
    main()
