"""Compatibility shim for the `pipeline_gpt` workload (paper Table II).

The benchmark now lives in `repro.bench.workloads.pipeline_gpt`; run it
via (the CLI forces the 4-device host platform itself)

  PYTHONPATH=src python -m repro.bench run --suite pipeline_gpt
"""
from __future__ import annotations

import sys

from repro.bench.cli import main as bench_main


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    return bench_main(["run", "--suite", "pipeline_gpt", *argv])


if __name__ == "__main__":
    sys.exit(main())
