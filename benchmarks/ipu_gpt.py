"""Paper Table II analog: GPT-117M trained with PIPELINE parallelism.

The Graphcore case: the model's layers are split over 4 devices (pipeline
parallelism was the only way it fit in per-tile SRAM), throughput measured
in tokens/s across a batch sweep, plus the pipeline-bubble overhead. Run
via benchmarks.run so a forced 4-device host platform is available.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_step
from repro.configs import get_config
from repro.core.results import save_results, table
from repro.data.synthetic import synthetic_tokens
from repro.launch.mesh import make_mesh
from repro.models.common import apply_mlp, apply_norm
from repro.models import lm
from repro.parallel.pipeline import (
    bubble_fraction, pipeline_forward, stage_params_split,
)

SEQ = 64
N_STAGES = 4


def run(batches=(16, 32, 64)):
    assert jax.device_count() >= N_STAGES, "run via benchmarks.run"
    c = get_config("gpt-117m").reduced(n_layers=8, d_model=128, d_ff=512,
                                       n_heads=4, n_kv_heads=4, d_head=32,
                                       vocab=4096)
    mesh = make_mesh((N_STAGES,), ("stage",))
    params = lm.init(jax.random.key(0), c)
    stage_params = stage_params_split(params["layers"], N_STAGES)

    def layer_fn(stage_p, x):
        # apply this stage's layers sequentially
        def body(x, lp):
            sp = lp["slot0"]
            h = apply_norm(c, sp["norm1"], x)
            from repro.models import attention as attn
            h = attn.self_attention(c, sp["attn"], h, causal=True)
            x = x + h
            x = x + apply_mlp(c, sp["mlp"], apply_norm(c, sp["norm2"], x))
            return x, None
        x, _ = jax.lax.scan(body, x, stage_p)
        return x

    records = []
    n_mb = 8
    for gb in batches:
        mb = gb // n_mb
        toks = jnp.asarray(synthetic_tokens(gb, SEQ, c.vocab)[:, :SEQ])
        x = lm._inputs_to_embeds(c, params, toks, None)
        x_mb = x.reshape(n_mb, mb, SEQ, c.d_model)

        fwd = jax.jit(lambda sp, xs: pipeline_forward(
            mesh, "stage", layer_fn, sp, xs))
        dt, wh, src = time_step(fwd, stage_params, x_mb, warmup=1, iters=3)
        tps = gb * SEQ / dt
        rec = {"global_batch": gb, "tokens_per_s": tps,
               "ms_per_iter": dt * 1e3, "energy_wh": wh,
               "tokens_per_wh": (gb * SEQ / wh) if wh > 0 else 0.0,
               "bubble_fraction": bubble_fraction(N_STAGES, n_mb),
               "power_source": src}
        records.append(rec)
        emit(f"ipu_gpt/pp{N_STAGES}/gb{gb}", dt * 1e6,
             f"tokens_per_s={tps:.0f}")
    save_results(records, "artifacts/bench", "ipu_gpt_table2")
    return records


def verify_pipeline_correctness():
    """Pipeline output == sequential execution of the same layers."""
    import numpy as np
    c = get_config("gpt-117m").reduced(n_layers=4, d_model=64, d_ff=128,
                                       n_heads=2, n_kv_heads=2, d_head=32,
                                       vocab=512)
    mesh = make_mesh((N_STAGES,), ("stage",))
    params = lm.init(jax.random.key(0), c)
    stage_params = stage_params_split(params["layers"], N_STAGES)

    def layer_fn(stage_p, x):
        def body(x, lp):
            sp = lp["slot0"]
            from repro.models import attention as attn
            h = apply_norm(c, sp["norm1"], x)
            x = x + attn.self_attention(c, sp["attn"], h, causal=True)
            x = x + apply_mlp(c, sp["mlp"], apply_norm(c, sp["norm2"], x))
            return x, None
        return jax.lax.scan(body, x, stage_p)[0]

    toks = jnp.asarray(synthetic_tokens(8, 32, c.vocab)[:, :32])
    x = lm._inputs_to_embeds(c, params, toks, None)
    x_mb = x.reshape(4, 2, 32, c.d_model)
    got = pipeline_forward(mesh, "stage", layer_fn, stage_params, x_mb)
    want = layer_fn(jax.tree.map(
        lambda a: a.reshape(-1, *a.shape[2:]), stage_params), x)
    np.testing.assert_allclose(
        np.asarray(got.reshape(x.shape), np.float32),
        np.asarray(want, np.float32), rtol=2e-2, atol=2e-2)
    print("pipeline == sequential: OK")


def main():
    verify_pipeline_correctness()
    print(table(run(), floatfmt="{:.2f}"))


if __name__ == "__main__":
    main()
