"""Render the roofline table (EXPERIMENTS.md par.Roofline source) from the
dry-run artifacts in artifacts/dryrun/."""
from __future__ import annotations

import json
import pathlib

from repro.core.results import save_results, table

ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(mesh: str = "single"):
    rows = []
    for f in sorted(ART.glob(f"{mesh}__*.json")):
        r = json.loads(f.read_text())
        if "roofline" not in r:
            if "skipped" in r:
                rows.append({"arch": r["arch"], "shape": r["shape"],
                             "bottleneck": "SKIP",
                             "note": r["skipped"]})
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "bottleneck": rf["bottleneck"],
            "roofline_frac": rf["roofline_fraction"],
            "useful_flops": rf["useful_flops_ratio"],
            "hbm_gib": r.get("bytes_per_device_tpu",
                             r.get("bytes_per_device", 0)) / 2**30,
            "fits": r.get("fits_hbm_16g"),
        })
    return rows


def main():
    for mesh in ("single", "multi"):
        rows = load(mesh)
        if not rows:
            continue
        print(f"\n== {mesh}-pod roofline (per-device seconds/step) ==")
        print(table(rows, floatfmt="{:.4f}"))
        save_results(rows, "artifacts/bench", f"roofline_{mesh}")
        for r in rows:
            if r.get("bottleneck") != "SKIP":
                print(f"roofline/{mesh}/{r['arch']}/{r['shape']},"
                      f"{r['compute_s'] * 1e6:.0f},"
                      f"frac={r['roofline_frac']:.3f}")


if __name__ == "__main__":
    main()
