"""Compatibility shim for the `roofline` workload (par.Roofline table).

The benchmark now lives in `repro.bench.workloads.roofline`; run it via

  PYTHONPATH=src python -m repro.bench run --suite roofline
"""
from __future__ import annotations

import sys

from repro.bench.cli import main as bench_main


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    return bench_main(["run", "--suite", "roofline", *argv])


if __name__ == "__main__":
    sys.exit(main())
