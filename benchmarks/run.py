"""Compatibility shim: the benchmark driver is now `python -m repro.bench`.

The old per-benchmark subprocess loop is gone — one process runs every
workload through the WorkloadSpec registry, and multi-device workloads
are satisfied by a single XLA_FLAGS host-platform re-exec when needed.

  PYTHONPATH=src python -m repro.bench run              # everything
  PYTHONPATH=src python -m repro.bench run --tags smoke # CI smoke set
"""
from __future__ import annotations

import sys

from repro.bench.cli import main as bench_main


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    return bench_main(["run", *argv])


if __name__ == "__main__":
    sys.exit(main())
