"""Benchmark driver: one harness per paper table/figure.

Each benchmark runs in its own subprocess so multi-device cases (pipeline
parallelism, DP heatmaps) can force their own host-platform device count
without affecting the others. Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

BENCHES = [
    # (module, paper analog, forced device count)
    ("benchmarks.llm_throughput", "Fig. 2 (LLM tokens/s + energy)", 1),
    ("benchmarks.serve_bench", "serving: continuous batching + Wh/token", 1),
    ("benchmarks.resnet50_bench", "Fig. 3/Table III (ResNet50)", 1),
    ("benchmarks.ipu_gpt", "Table II (pipeline-parallel GPT-117M)", 4),
    ("benchmarks.heatmap", "Fig. 4 (dp x batch heatmap)", 8),
    ("benchmarks.kernels_bench", "kernel microbench", 1),
    ("benchmarks.roofline_table", "par.Roofline table", 1),
]


def main() -> None:
    root = pathlib.Path(__file__).resolve().parents[1]
    failures = []
    for mod, desc, ndev in BENCHES:
        print(f"\n###### {mod} — {desc} ######", flush=True)
        env = dict(os.environ)
        env["PYTHONPATH"] = f"{root}/src:{root}"
        if ndev > 1:
            env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                                f" --xla_force_host_platform_device_count={ndev}")
        proc = subprocess.run([sys.executable, "-m", mod], env=env,
                              cwd=root, timeout=3600)
        if proc.returncode != 0:
            failures.append(mod)
            print(f"FAILED: {mod}", flush=True)
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
