"""Compatibility shim for the `heatmap` workload (paper Fig. 4).

The benchmark now lives in `repro.bench.workloads.heatmap`; run it via
(the CLI forces the 8-device host platform itself)

  PYTHONPATH=src python -m repro.bench run --suite heatmap
"""
from __future__ import annotations

import sys

from repro.bench.cli import main as bench_main


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    return bench_main(["run", "--suite", "heatmap", *argv])


if __name__ == "__main__":
    sys.exit(main())
