"""Paper Fig. 4 analog: throughput heatmap over (data-parallel degree x
global batch size), with infeasible cells marked OOM.

Uses the CARAML harness (Space + constraints + Runner) end-to-end — this
is the ablation-automation the paper's JUBE layer provides. Run via
benchmarks.run so an 8-device host platform is available.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import (
    BenchmarkSuite, Runner, Space, Step, divisible_batch, heatmap,
)
from repro.core.results import save_results
from repro.data.synthetic import synthetic_tokens
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step

SEQ = 64


def make_bench_step():
    c = get_config("gpt-117m").reduced(n_layers=2, d_model=128, d_ff=256,
                                       n_heads=4, n_kv_heads=4, d_head=32,
                                       vocab=2048)
    oc = OptConfig(warmup=1, total_steps=100)
    params = lm.init(jax.random.key(0), c)
    opt_state = opt_init(oc, params)
    step_fns = {}

    def bench(pt, ctx):
        import time
        dp, gb = pt["dp"], pt["global_batch"]
        if dp not in step_fns:
            mesh = make_mesh((dp,), ("data",))
            bsh = NamedSharding(mesh, P("data"))
            step_fns[dp] = (jax.jit(
                make_train_step(c, oc, StepConfig())), bsh)
        step, bsh = step_fns[dp]
        toks = jax.device_put(
            jnp.asarray(synthetic_tokens(gb, SEQ, c.vocab)[:, :SEQ]), bsh)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
        p, o, _ = step(params, opt_state, batch)  # compile+warm
        jax.block_until_ready(p)
        t0 = time.perf_counter()
        for _ in range(3):
            p, o, m = step(params, opt_state, batch)
        jax.block_until_ready(p)
        dt = (time.perf_counter() - t0) / 3
        return {"tokens_per_s": gb * SEQ / dt, "ms": dt * 1e3}

    return bench


def main():
    assert jax.device_count() >= 8, "run via benchmarks.run"
    space = Space(
        {"dp": [1, 2, 4, 8], "global_batch": [8, 16, 32],
         "micro_batch": [1]},
        [divisible_batch, lambda pt: pt["global_batch"] >= pt["dp"]])
    suite = BenchmarkSuite("heatmap_fig4", space,
                           [Step("run", make_bench_step())],
                           result_columns=["dp", "global_batch",
                                           "tokens_per_s", "ms"])
    runner = Runner(suite, out_dir="artifacts/bench")
    recs = runner.run(verbose=False)
    print(heatmap(recs, "dp", "global_batch", "tokens_per_s"))
    save_results(recs, "artifacts/bench", "heatmap_fig4")
    for r in recs:
        emit(f"heatmap/dp{r['dp']}/gb{r['global_batch']}",
             r.get("ms", 0) * 1e3, f"tokens_per_s={r.get('tokens_per_s', 0):.0f}")


if __name__ == "__main__":
    main()
