"""Kernel microbenchmarks: Pallas (interpret) vs XLA reference.

Interpret mode executes the kernel body in Python — the timing column is
a correctness-scale signal only; the real figure of merit on TPU is the
roofline delta accounted in EXPERIMENTS.md par.Perf (flash attention
removes the O(S*T) score traffic from the memory term).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_step
from repro.core.results import save_results, table
from repro.kernels import ops


def run():
    records = []
    key = jax.random.key(0)
    for (b, s, h, kh, dh) in [(1, 256, 4, 2, 64), (2, 512, 8, 8, 64)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, kh, dh), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, kh, dh), jnp.float32)
        for impl in ("xla", "pallas"):
            dt, _, _ = time_step(
                lambda: ops.flash_attention(
                    q, k, v, impl=impl, interpret=impl == "pallas"),
                warmup=1, iters=2, measure_power=False)
            name = f"flash/{impl}/b{b}s{s}h{h}kh{kh}"
            records.append({"kernel": name, "us": dt * 1e6})
            emit(name, dt * 1e6, "interpret=1" if impl == "pallas" else "ref")
    x = jax.random.normal(key, (512, 1024), jnp.float32)
    sc = jnp.ones((1024,))
    for impl in ("xla", "pallas"):
        dt, _, _ = time_step(
            lambda: ops.rmsnorm(x, sc, impl=impl, interpret=impl == "pallas"),
            warmup=1, iters=3, measure_power=False)
        records.append({"kernel": f"rmsnorm/{impl}", "us": dt * 1e6})
        emit(f"rmsnorm/{impl}", dt * 1e6, "fused" if impl == "pallas" else "ref")
    save_results(records, "artifacts/bench", "kernels")
    return records


def main():
    print(table(run(), floatfmt="{:.1f}"))


if __name__ == "__main__":
    main()
