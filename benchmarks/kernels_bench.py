"""Compatibility shim for the `kernels` workload (Pallas microbench).

The benchmark now lives in `repro.bench.workloads.kernels`; run it via

  PYTHONPATH=src python -m repro.bench run --suite kernels
"""
from __future__ import annotations

import sys

from repro.bench.cli import main as bench_main


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    return bench_main(["run", "--suite", "kernels", *argv])


if __name__ == "__main__":
    sys.exit(main())
