"""Compatibility shim for the `llm_train` workload (paper Fig. 2).

The benchmark now lives in `repro.bench.workloads.llm_train`; run it via

  PYTHONPATH=src python -m repro.bench run --suite llm_train
"""
from __future__ import annotations

import sys

from repro.bench.cli import main as bench_main


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    return bench_main(["run", "--suite", "llm_train", *argv])


if __name__ == "__main__":
    sys.exit(main())
