"""Paper Fig. 2 analog: LLM training throughput + energy vs global batch.

Trains the paper's GPT decoder (reduced for this CPU host) across a global
batch sweep; reports tokens/s, energy/step, tokens/Wh — the exact figures
of merit of CARAML's LLM benchmark.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_step
from repro.configs import get_config
from repro.core.results import save_results, table
from repro.data.synthetic import synthetic_tokens
from repro.models import lm
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step

SEQ = 128
BATCHES = (16, 32, 64)


def run(arch: str = "gpt-800m", batches=BATCHES, seq: int = SEQ):
    c = get_config(arch).reduced(d_model=128, n_layers=4, d_ff=512,
                                 vocab=8192, n_heads=4, n_kv_heads=4,
                                 d_head=32)
    oc = OptConfig(warmup=2, total_steps=1000)
    params = lm.init(jax.random.key(0), c)
    opt_state = opt_init(oc, params)
    step = jax.jit(make_train_step(c, oc, StepConfig(microbatches=4)))
    records = []
    for gb in batches:
        toks = jnp.asarray(synthetic_tokens(gb, seq, c.vocab)[:, :seq])
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        p, o = params, opt_state

        def one(p_o_batch=batch):
            nonlocal p, o
            p, o, m = step(p, o, p_o_batch)
            return m["loss"]

        dt, wh, src = time_step(one, warmup=1, iters=3)
        tps = gb * seq / dt
        rec = {"arch": c.name, "global_batch": gb, "seq": seq,
               "tokens_per_s": tps, "ms_per_step": dt * 1e3,
               "energy_wh_per_step": wh,
               "tokens_per_wh": (gb * seq / wh) if wh > 0 else 0.0,
               "power_source": src}
        records.append(rec)
        emit(f"llm_throughput/{arch}/gb{gb}", dt * 1e6,
             f"tokens_per_s={tps:.0f}")
    save_results(records, "artifacts/bench", "llm_throughput")
    return records


def main():
    print(table(run(), floatfmt="{:.2f}"))


if __name__ == "__main__":
    main()
