"""Shared benchmark plumbing: timing, power, CSV emission.

Benchmarks measure REAL wall-time throughput of reduced-config models on
this host (the CARAML "hardware under test" role), with jpwr-style energy:
RAPL counters when the host exposes them, otherwise the analytic TPU power
model clearly labeled as modeled. Full-scale TPU numbers live in the
dry-run/roofline artifacts, not here.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

from repro.power.ctxmgr import get_power
from repro.power.methods import RaplPower, SyntheticPower, TPUModelPower


def pick_power_methods():
    rapl = RaplPower()
    if rapl.available():
        return [rapl], "rapl"
    return [TPUModelPower(n_devices=1, utilization_fn=lambda: 1.0)], "tpu_model"


def time_step(fn: Callable, *args, warmup: int = 1, iters: int = 3,
              measure_power: bool = True, **kw):
    """Returns (seconds_per_call, energy_wh, power_source)."""
    out = None
    for _ in range(warmup):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    methods, source = pick_power_methods() if measure_power else ([], "none")
    t0 = time.perf_counter()
    if methods:
        with get_power(methods, interval_ms=20) as scope:
            for _ in range(iters):
                out = fn(*args, **kw)
            jax.block_until_ready(out)
        energy = scope.total_energy_wh() / iters
    else:
        for _ in range(iters):
            out = fn(*args, **kw)
        jax.block_until_ready(out)
        energy = 0.0
    dt = (time.perf_counter() - t0) / iters
    return dt, energy, source


def emit(name: str, us_per_call: float, derived: str):
    """The required ``name,us_per_call,derived`` CSV line."""
    print(f"{name},{us_per_call:.1f},{derived}")
