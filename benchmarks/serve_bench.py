"""Serving benchmark: continuous batching vs fixed batch under Poisson load.

The MLPerf-Power/CARAML serving point: drive the ServeEngine with a
seeded synthetic Poisson arrival process and variable per-request token
budgets, and report — per (arrival-rate x slot-count) cell and policy —

  decode_tok_s    useful generated tokens per wall second
  ttft_s          mean time-to-first-token (includes queueing)
  wh_per_token    energy per generated token (attributed per request)
  wh_per_request  energy per served request

Energy comes from RAPL when the host exposes powercap counters,
otherwise the analytic TPU power model (clearly labeled). Both policies
run the SAME jitted programs on the SAME slot pool; the only difference
is admission (iteration-level refill vs batch-fill barrier), so the
speedup column isolates the scheduling win.

  PYTHONPATH=src:. python -m benchmarks.serve_bench --smoke
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import emit, pick_power_methods
from repro.configs import get_config
from repro.core.results import save_results, table
from repro.data.synthetic import synthetic_tokens
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.serve.requests import Request

PROMPT_LEN = 8          # fixed: one prefill trace for the whole sweep
MAX_LEN = 96            # slot capacity (multiple of reduced ssm_chunk)
# Bimodal token budgets (the realistic serving mix: mostly short
# answers, a tail of long generations). The fixed-batch policy pays
# max(batch) decode steps to produce mean(batch) useful tokens, so the
# short/long mix is precisely what iteration-level refill monetizes.
SHORT_LO, SHORT_HI = 2, 8
LONG_LO, LONG_HI = 64, 88
P_LONG = 0.25


def poisson_requests(n: int, rate_hz: float, vocab: int,
                     seed: int = 0) -> list[Request]:
    """Seeded synthetic request stream: exponential inter-arrival gaps
    (Poisson process) and bimodal short/long token budgets."""
    rng = np.random.default_rng(seed)
    prompts = synthetic_tokens(n, PROMPT_LEN, vocab, seed)[:, :PROMPT_LEN]
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    arrivals = np.cumsum(gaps) - gaps[0]   # first request arrives at t=0
    long = rng.random(n) < P_LONG
    budgets = np.where(long, rng.integers(LONG_LO, LONG_HI + 1, size=n),
                       rng.integers(SHORT_LO, SHORT_HI + 1, size=n))
    return [Request(rid=i, prompt=prompts[i], max_new_tokens=int(budgets[i]),
                    arrival_s=float(arrivals[i])) for i in range(n)]


def run_cell(engine: ServeEngine, requests, policy: str) -> dict:
    out = engine.serve(requests, policy=policy)
    s = out.summary
    return {
        "policy": policy,
        "n_requests": s.n_requests,
        "n_tokens": s.n_tokens,
        "decode_tok_s": s.decode_tok_s,
        "ttft_s": s.mean_ttft_s,
        "p95_ttft_s": s.p95_ttft_s,
        "wh_per_token": s.wh_per_token,
        "wh_per_request": s.wh_per_request,
        "overhead_wh": s.overhead_wh,
        "wall_s": s.wall_s,
    }


def run(arch: str = "llama3.2-3b", *, n_requests: int = 48,
        rates=(100.0, 400.0), slots=(4, 8), seed: int = 0,
        smoke: bool = False):
    if smoke:
        # enough requests that the drain tail (last long generations
        # finishing with partially-empty slots) amortizes away
        n_requests, rates, slots = 64, (300.0,), (4,)
    c = get_config(arch).reduced()
    params = lm.init(jax.random.key(seed), c)
    methods, source = pick_power_methods()
    records = []
    for n_slots in slots:
        engine = ServeEngine(c, params, n_slots=n_slots, max_len=MAX_LEN,
                             power_methods=methods)
        # warmup: compile prefill + slot decode outside any measured cell
        # (the first serve() otherwise charges XLA compilation to the
        # first policy's wall clock and energy)
        engine.serve(poisson_requests(n_slots, 1e6, c.vocab, seed + 1))
        for rate in rates:
            requests = poisson_requests(n_requests, rate, c.vocab, seed)
            cells = {}
            for policy in ("fixed", "continuous"):
                rec = run_cell(engine, requests, policy)
                rec.update(arch=c.name, slots=n_slots, rate_hz=rate,
                           power_source=source)
                cells[policy] = rec
                records.append(rec)
                emit(f"serve/{arch}/s{n_slots}/r{rate:g}/{policy}",
                     rec["wall_s"] * 1e6,
                     f"decode_tok_s={rec['decode_tok_s']:.1f}")
            speedup = (cells["continuous"]["decode_tok_s"]
                       / max(cells["fixed"]["decode_tok_s"], 1e-9))
            for policy in cells:
                cells[policy]["speedup_vs_fixed"] = speedup
            print(f"[serve_bench] slots={n_slots} rate={rate:g}/s "
                  f"continuous/fixed tokens/s = {speedup:.2f}x")
    save_results(records, "artifacts/bench", "serve_bench")
    return records


COLUMNS = ["arch", "policy", "slots", "rate_hz", "n_tokens", "decode_tok_s",
           "ttft_s", "wh_per_token", "wh_per_request", "speedup_vs_fixed"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="single sweep cell, <60s on CPU")
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    records = run(args.arch, n_requests=args.requests, seed=args.seed,
                  smoke=args.smoke)
    print(table([{k: r.get(k) for k in COLUMNS} for r in records],
                floatfmt="{:.4g}"))
    return records


if __name__ == "__main__":
    main()
