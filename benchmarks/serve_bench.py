"""Compatibility shim for the `serve` workload (continuous batching +
Wh/token; see benchmarks/README.md).

The benchmark now lives in `repro.bench.workloads.serve`; run it via

  PYTHONPATH=src python -m repro.bench run --suite serve --tags smoke
  PYTHONPATH=src python -m repro.bench run --suite serve   # full sweep

``run()`` is kept callable for the acceptance test
(tests/test_serve_energy.py): it drives the WorkloadRunner directly and
returns the flat per-(cell x policy) records.
"""
from __future__ import annotations

import sys

from repro.bench.cli import main as bench_main
from repro.bench.runner import WorkloadRunner
from repro.bench.spec import get_workload


def run(arch: str = "llama3.2-3b", *, rates=None, slots=None,
        seed: int = 0, smoke: bool = False):
    """Run the serve workload in-process; returns flat result records."""
    assert seed == 0, "the registered serve workload runs the seed-0 stream"
    overrides: dict = {"arch": [arch]}
    if rates is not None:
        overrides["rate_hz"] = list(rates)
    if slots is not None:
        overrides["slots"] = list(slots)
    runner = WorkloadRunner(get_workload("serve"), smoke=smoke,
                            point_overrides=overrides)
    return [r.flat() for r in runner.run(verbose=False)]


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    fwd = ["run", "--suite", "serve"]
    if "--smoke" in argv:
        argv.remove("--smoke")
        fwd += ["--tags", "smoke"]
    return bench_main(fwd + argv)


if __name__ == "__main__":
    sys.exit(main())
