# Repo entry points. `make test` is the tier-1 gate (ROADMAP.md).

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-all ci bench bench-smoke bench-serve bench-list

test:
	$(PY) -m pytest -x -q

test-all:        ## includes @pytest.mark.slow integration tests
	$(PY) -m pytest -x -q --runslow

ci:
	bash scripts/ci.sh

bench:           ## every workload, full point sets
	$(PY) -m repro.bench run

bench-smoke:     ## the smoke-tagged suite on synthetic power (CI gate)
	$(PY) -m repro.bench run --tags smoke --power synthetic

bench-serve:
	$(PY) -m repro.bench run --suite serve --tags smoke

bench-list:
	$(PY) -m repro.bench list
