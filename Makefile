# Repo entry points. `make test` is the tier-1 gate (ROADMAP.md).

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-all ci bench bench-smoke bench-serve bench-slo \
        bench-list bench-compare bench-promote bench-trajectory

test:
	$(PY) -m pytest -x -q

test-all:        ## includes @pytest.mark.slow integration tests
	$(PY) -m pytest -x -q --runslow

ci:
	bash scripts/ci.sh

bench:           ## every workload, full point sets
	$(PY) -m repro.bench run

bench-smoke:     ## the smoke-tagged suite on synthetic power (CI gate)
	$(PY) -m repro.bench run --tags smoke --power synthetic

bench-serve:
	$(PY) -m repro.bench run --suite serve --tags smoke

bench-slo:       ## multi-tenant SLO goodput + prefix caching sweep
	$(PY) -m repro.bench run --suite serve_slo --tags smoke

bench-list:
	$(PY) -m repro.bench list

BASELINES ?= artifacts/bench/baselines

# the run dir is cleared first: `run` only overwrites per-workload dirs
# it executes, so a stale results.json from a removed/renamed workload
# would otherwise be compared (or promoted!) as if current
bench-compare:   ## fresh smoke run gated against the committed baselines
	rm -rf artifacts/ci-bench
	$(PY) -m repro.bench run --tags smoke --power synthetic \
	    --out artifacts/ci-bench
	$(PY) -m repro.bench compare $(BASELINES) artifacts/ci-bench \
	    --fail-on-regression --fail-on-missing

WORKLOADS ?= serve llm_train kernels serve_slo resilience heatmap \
             pipeline_gpt resnet50 roofline
LABEL ?= local run

# promotion REPLACES the baseline store, so the old->new compare is
# appended to the BENCH_<workload>.json trajectories first (the perf
# history the store loses); commit both
bench-promote:   ## refresh the committed baselines from a fresh smoke run
	rm -rf artifacts/ci-bench
	$(PY) -m repro.bench run --tags smoke --power synthetic \
	    --out artifacts/ci-bench
	$(PY) scripts/bench_trajectory.py \
	    $(foreach w,$(WORKLOADS),--workload $(w)) \
	    --baseline $(BASELINES) --current artifacts/ci-bench \
	    --label "$(LABEL)"
	$(PY) -m repro.bench compare $(BASELINES) artifacts/ci-bench --promote

# append-only perf history (BENCH_<workload>.json at the repo root)
# without promoting
bench-trajectory:  ## fresh smoke run diffed against baselines -> BENCH_*.json
	rm -rf artifacts/ci-bench
	$(PY) -m repro.bench run --tags smoke --power synthetic \
	    --out artifacts/ci-bench
	$(PY) scripts/bench_trajectory.py \
	    $(foreach w,$(WORKLOADS),--workload $(w)) \
	    --baseline $(BASELINES) --current artifacts/ci-bench \
	    --label "$(LABEL)"
