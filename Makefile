# Repo entry points. `make test` is the tier-1 gate (ROADMAP.md).

PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test test-all ci bench bench-serve

test:
	$(PY) -m pytest -x -q

test-all:        ## includes @pytest.mark.slow integration tests
	$(PY) -m pytest -x -q --runslow

ci:
	bash scripts/ci.sh

bench:
	PYTHONPATH=src:. $(PY) -m benchmarks.run

bench-serve:
	PYTHONPATH=src:. $(PY) -m benchmarks.serve_bench --smoke
