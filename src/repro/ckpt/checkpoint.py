"""Sharded checkpointing: atomic, async, reshardable.

Design (no orbax offline; built on numpy + JSON manifests):
  - every leaf is saved as one .npy per *host-local shard set* (single-host
    here: the fully materialized leaf), with a JSON manifest recording the
    pytree structure, dtypes, shapes, and the step;
  - writes go to ``step_N.tmp/`` then ``os.replace`` -> ``step_N/`` so a
    crash mid-save never corrupts the latest checkpoint (atomicity);
  - ``save_async`` snapshots to host memory synchronously (cheap) and
    writes in a background thread (training continues);
  - ``restore`` accepts target shardings for a DIFFERENT mesh than the one
    that saved — device_put against the new sharding = elastic resharding.

At multi-pod scale each process would write only its addressable shards;
the manifest format already records per-leaf shape/dtype so per-shard
files are a strict extension (process id in the filename).
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

Params = Any
_SEP = "/"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(tree, out_dir, step: int, extra_meta: Optional[dict] = None) -> str:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    tmp = out / f"step_{step}.tmp"
    final = out / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "meta": extra_meta or {}}
    for i, (key, arr) in enumerate(sorted(flat.items())):
        fn = f"leaf_{i:05d}.npy"
        logical = str(arr.dtype)
        if arr.dtype.kind == "V" or logical in ("bfloat16", "float8_e4m3fn",
                                                "float8_e5m2"):
            # numpy can't round-trip ml_dtypes: store as a same-width uint
            # view and record the logical dtype in the manifest
            width = {1: np.uint8, 2: np.uint16, 4: np.uint32}[arr.dtype.itemsize]
            np.save(tmp / fn, arr.view(width))
        else:
            np.save(tmp / fn, arr)
        manifest["leaves"][key] = {
            "file": fn, "shape": list(arr.shape), "dtype": logical}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return str(final)


def latest_step(out_dir) -> Optional[int]:
    out = pathlib.Path(out_dir)
    if not out.exists():
        return None
    steps = [int(m.group(1)) for p in out.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


def restore(template, out_dir, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``template``.

    ``shardings``: optional matching pytree of NamedSharding — leaves are
    device_put against them, which reshards onto the CURRENT mesh even if
    the checkpoint was written under a different one (elastic restart).
    """
    out = pathlib.Path(out_dir)
    if step is None:
        step = latest_step(out_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {out_dir}")
    d = out / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    import ml_dtypes
    _ML = {"bfloat16": ml_dtypes.bfloat16,
           "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
           "float8_e5m2": ml_dtypes.float8_e5m2}

    def _load(v):
        arr = np.load(d / v["file"])
        if v["dtype"] in _ML:
            arr = arr.view(_ML[v["dtype"]])
        return arr

    flat = {k: _load(v) for k, v in manifest["leaves"].items()}

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, leaf), shard in zip(paths, shard_leaves):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = flat[key].astype(leaf.dtype) if hasattr(leaf, "dtype") else flat[key]
        if shard is not None:
            arr = jax.device_put(arr, shard)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class CheckpointManager:
    """Async, retention-managed checkpointing for the training loop."""

    def __init__(self, out_dir, keep: int = 3):
        self.out_dir = pathlib.Path(out_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def save_async(self, tree, step: int, extra_meta: Optional[dict] = None):
        self.wait()  # one in-flight save at a time
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot (sync, cheap)

        def work():
            save(host_tree, self.out_dir, step, extra_meta)
            self.last_saved = step
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, tree, step: int, extra_meta: Optional[dict] = None):
        self.wait()
        save(tree, self.out_dir, step, extra_meta)
        self.last_saved = step
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for p in self.out_dir.iterdir()
            if (m := re.fullmatch(r"step_(\d+)", p.name)))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.out_dir / f"step_{s}", ignore_errors=True)
