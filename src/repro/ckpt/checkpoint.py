"""Sharded checkpointing: atomic, async, reshardable.

Design (no orbax offline; built on numpy + JSON manifests):
  - every leaf is saved as one .npy per *host-local shard set* (single-host
    here: the fully materialized leaf), with a JSON manifest recording the
    pytree structure, dtypes, shapes, and the step;
  - writes go to ``step_N.tmp/`` then ``os.replace`` -> ``step_N/`` so a
    crash mid-save never corrupts the latest checkpoint (atomicity);
  - ``save_async`` snapshots to host memory synchronously (cheap) and
    writes in a background thread (training continues);
  - ``restore`` accepts target shardings for a DIFFERENT mesh than the one
    that saved — device_put against the new sharding = elastic resharding.

At multi-pod scale each process would write only its addressable shards;
the manifest format already records per-leaf shape/dtype so per-shard
files are a strict extension (process id in the filename).
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

Params = Any
_SEP = "/"


def _fsync_dir(path) -> None:
    """fsync a directory so its entries (renames, new files) are durable."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return  # platform without directory fds; best effort
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(tree, out_dir, step: int, extra_meta: Optional[dict] = None) -> str:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    tmp = out / f"step_{step}.tmp"
    final = out / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(tree)
    manifest = {"step": step, "leaves": {}, "meta": extra_meta or {}}
    for i, (key, arr) in enumerate(sorted(flat.items())):
        fn = f"leaf_{i:05d}.npy"
        logical = str(arr.dtype)
        if arr.dtype.kind == "V" or logical in ("bfloat16", "float8_e4m3fn",
                                                "float8_e5m2"):
            # numpy can't round-trip ml_dtypes: store as a same-width uint
            # view and record the logical dtype in the manifest
            width = {1: np.uint8, 2: np.uint16, 4: np.uint32}[arr.dtype.itemsize]
            data = arr.view(width)
        else:
            data = arr
        with open(tmp / fn, "wb") as f:
            np.save(f, data)
            f.flush()
            os.fsync(f.fileno())
        manifest["leaves"][key] = {
            "file": fn, "shape": list(arr.shape), "dtype": logical,
            "sha1": hashlib.sha1(data.tobytes()).hexdigest()[:16]}
    with open(tmp / "manifest.json", "w") as f:
        f.write(json.dumps(manifest, indent=1))
        f.flush()
        os.fsync(f.fileno())
    _fsync_dir(tmp)
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    _fsync_dir(out)         # make the rename itself durable
    return str(final)


def latest_step(out_dir) -> Optional[int]:
    out = pathlib.Path(out_dir)
    if not out.exists():
        return None
    steps = [int(m.group(1)) for p in out.iterdir()
             if (m := re.fullmatch(r"step_(\d+)", p.name))]
    return max(steps) if steps else None


def _all_steps(out_dir) -> list[int]:
    out = pathlib.Path(out_dir)
    if not out.exists():
        return []
    return sorted(int(m.group(1)) for p in out.iterdir()
                  if (m := re.fullmatch(r"step_(\d+)", p.name)))


def verify_step(out_dir, step: int) -> bool:
    """True iff ``step_N/`` is a complete, uncorrupted checkpoint:
    parseable manifest, every leaf file present, and (when the manifest
    carries digests) per-leaf sha1 matching the bytes on disk."""
    d = pathlib.Path(out_dir) / f"step_{step}"
    try:
        manifest = json.loads((d / "manifest.json").read_text())
        for v in manifest["leaves"].values():
            arr = np.load(d / v["file"])
            if list(arr.shape) != list(v["shape"]):
                return False  # same-width uint views preserve shape
            want = v.get("sha1")
            if want is not None and hashlib.sha1(
                    arr.tobytes()).hexdigest()[:16] != want:
                return False
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        return False
    return True


def latest_valid_step(out_dir) -> Optional[int]:
    """Newest step that passes :func:`verify_step` (corruption-aware
    variant of :func:`latest_step`)."""
    for s in reversed(_all_steps(out_dir)):
        if verify_step(out_dir, s):
            return s
    return None


def restore_resilient(template, out_dir, shardings=None):
    """Restore the newest *valid* checkpoint, skipping corrupted or
    partial steps (falls back to the previous atomic step).

    Returns ``(tree, manifest, skipped)`` where ``skipped`` lists the
    step numbers that failed verification, newest first.
    """
    skipped: list[int] = []
    for s in reversed(_all_steps(out_dir)):
        if verify_step(out_dir, s):
            tree, manifest = restore(template, out_dir, step=s,
                                     shardings=shardings)
            return tree, manifest, skipped
        skipped.append(s)
    raise FileNotFoundError(
        f"no valid checkpoints in {out_dir}"
        + (f" (corrupted: {skipped})" if skipped else ""))


def restore(template, out_dir, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``template``.

    ``shardings``: optional matching pytree of NamedSharding — leaves are
    device_put against them, which reshards onto the CURRENT mesh even if
    the checkpoint was written under a different one (elastic restart).
    """
    out = pathlib.Path(out_dir)
    if step is None:
        step = latest_step(out_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {out_dir}")
    d = out / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    import ml_dtypes
    _ML = {"bfloat16": ml_dtypes.bfloat16,
           "float8_e4m3fn": ml_dtypes.float8_e4m3fn,
           "float8_e5m2": ml_dtypes.float8_e5m2}

    def _load(v):
        arr = np.load(d / v["file"])
        if v["dtype"] in _ML:
            arr = arr.view(_ML[v["dtype"]])
        return arr

    flat = {k: _load(v) for k, v in manifest["leaves"].items()}

    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    leaves = []
    for (path, leaf), shard in zip(paths, shard_leaves):
        key = _SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        arr = flat[key].astype(leaf.dtype) if hasattr(leaf, "dtype") else flat[key]
        if shard is not None:
            arr = jax.device_put(arr, shard)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest


class CheckpointManager:
    """Async, retention-managed checkpointing for the training loop."""

    def __init__(self, out_dir, keep: int = 3):
        self.out_dir = pathlib.Path(out_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._exc: Optional[BaseException] = None
        self.last_saved: Optional[int] = None

    def save_async(self, tree, step: int, extra_meta: Optional[dict] = None):
        self.wait()  # one in-flight save at a time (re-raises prior failure)
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot (sync, cheap)

        def work():
            try:
                save(host_tree, self.out_dir, step, extra_meta)
                self.last_saved = step
                self._gc()
            except BaseException as e:  # surfaced at wait()/next save
                self._exc = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, tree, step: int, extra_meta: Optional[dict] = None):
        self.wait()
        save(tree, self.out_dir, step, extra_meta)
        self.last_saved = step
        self._gc()

    def wait(self):
        """Join any in-flight save; re-raise an exception it captured
        (a failed background save must not be silently dropped)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._exc is not None:
            exc, self._exc = self._exc, None
            raise exc

    def _gc(self):
        steps = sorted(
            int(m.group(1)) for p in self.out_dir.iterdir()
            if (m := re.fullmatch(r"step_(\d+)", p.name)))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.out_dir / f"step_{s}", ignore_errors=True)
