"""Elastic scaling: reshape the training job onto a different mesh.

On failure of a pod/slice, the controller restarts with fewer (or more)
devices; checkpoints are mesh-agnostic (host arrays + manifest), so
restore() with the new mesh's shardings is all that's needed. This module
derives the rescale plan and validates batch divisibility.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Union

import jax

from repro.bench.spec import Placement
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_mesh
from repro.parallel import sharding as sh


@dataclass
class RescalePlan:
    old_shape: tuple
    new_shape: tuple
    new_axes: tuple
    global_batch: int
    note: str


def _as_placement(old: Union[Placement, dict, str, int, tuple]) -> Placement:
    """Normalize the pre-failure mesh spelling. Bare tuples are the
    legacy ``(data, model)`` mesh shape; everything else goes through
    :meth:`Placement.of` so axes are named, not positional."""
    if isinstance(old, tuple):
        if len(old) == 1:
            return Placement.of({"dp": old[0]})
        if len(old) == 2:
            return Placement.of({"dp": old[0], "tp": old[1]})
        raise ValueError(
            f"ambiguous bare mesh shape {old!r}; pass a Placement "
            f"(e.g. {{'dp': 4, 'tp': 2}}) so axes are named")
    return Placement.of(old)


def plan_rescale(c: ModelConfig, shape: ShapeConfig,
                 old_placement: Union[Placement, dict, str, int, tuple],
                 lost_devices: int) -> RescalePlan:
    """Shrink the data axis to the largest feasible size after losing
    ``lost_devices`` chips; keep the model axis (TP degree is a property
    of the model fit, not of cluster health).

    Only dp/tp placements are rescalable here: a pipeline (``pp``) or
    pod axis changes the program, not just the shardings, so those are
    rejected rather than silently mis-planned.
    """
    p = _as_placement(old_placement)
    sizes = p.dict()
    unsupported = sorted(a for a, n in sizes.items()
                         if a not in ("dp", "tp") and n > 1)
    if unsupported:
        raise ValueError(
            f"plan_rescale supports dp/tp placements only; cannot rescale "
            f"axes {unsupported} of {p.label!r} (a pipeline/pod mesh needs "
            f"a stage-aware plan, not a data-axis shrink)")
    model = sizes.get("tp", 1)
    old_total = p.n_devices
    avail = old_total - lost_devices
    new_data = avail // model
    # batch must stay divisible by the data axis
    while new_data > 1 and shape.global_batch % new_data != 0:
        new_data -= 1
    if new_data < 1:
        raise ValueError("not enough devices for TP degree")
    return RescalePlan(
        old_shape=(sizes.get("dp", 1), model),
        new_shape=(new_data, model),
        new_axes=("data", "model"),
        global_batch=shape.global_batch,
        note=f"lost {lost_devices} chips -> data axis {new_data}",
    )


def reshard_state(state, c: ModelConfig, plan: RescalePlan,
                  shape: ShapeConfig):
    """Build the new mesh + shardings and device_put the state onto it."""
    mesh = make_mesh(plan.new_shape, plan.new_axes)
    p = sh.make_plan(c, mesh, shape)
    params, opt_state = state
    param_sh = sh.param_shardings(c, p, params)
    new_params = jax.device_put(params, param_sh)
    return mesh, p, (new_params, opt_state)
