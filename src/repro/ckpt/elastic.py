"""Elastic scaling: reshape the training job onto a different mesh.

On failure of a pod/slice, the controller restarts with fewer (or more)
devices; checkpoints are mesh-agnostic (host arrays + manifest), so
restore() with the new mesh's shardings is all that's needed. This module
derives the rescale plan and validates batch divisibility.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_mesh
from repro.parallel import sharding as sh


@dataclass
class RescalePlan:
    old_shape: tuple
    new_shape: tuple
    new_axes: tuple
    global_batch: int
    note: str


def plan_rescale(c: ModelConfig, shape: ShapeConfig, old_shape: tuple,
                 lost_devices: int) -> RescalePlan:
    """Shrink the data axis to the largest feasible size after losing
    ``lost_devices`` chips; keep the model axis (TP degree is a property
    of the model fit, not of cluster health)."""
    old_total = 1
    for s in old_shape:
        old_total *= s
    model = old_shape[-1]
    avail = old_total - lost_devices
    new_data = avail // model
    # batch must stay divisible by the data axis
    while new_data > 1 and shape.global_batch % new_data != 0:
        new_data -= 1
    if new_data < 1:
        raise ValueError("not enough devices for TP degree")
    return RescalePlan(
        old_shape=tuple(old_shape),
        new_shape=(new_data, model),
        new_axes=("data", "model"),
        global_batch=shape.global_batch,
        note=f"lost {lost_devices} chips -> data axis {new_data}",
    )


def reshard_state(state, c: ModelConfig, plan: RescalePlan,
                  shape: ShapeConfig):
    """Build the new mesh + shardings and device_put the state onto it."""
    mesh = make_mesh(plan.new_shape, plan.new_axes)
    p = sh.make_plan(c, mesh, shape)
    params, opt_state = state
    param_sh = sh.param_shardings(c, p, params)
    new_params = jax.device_put(params, param_sh)
    return mesh, p, (new_params, opt_state)
