"""Training loop with the fault-tolerance features the paper's scale needs:
auto-resume from the latest checkpoint, async periodic checkpointing,
straggler watchdog, power measurement hooks, throughput accounting.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import (CheckpointManager, latest_step,
                                   latest_valid_step, restore_resilient)
from repro.core.metrics import tokens_per_s
from repro.core.runner import StragglerWatchdog
from repro.faults.schedule import (DeviceLoss, FaultSchedule, InjectedCrash,
                                   corrupt_checkpoint)

Params = Any


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    seq_len: int = 512
    global_batch: int = 8
    keep_ckpts: int = 3


@dataclass
class LoopResult:
    steps_run: int
    final_step: int
    losses: list
    tokens_per_s: float
    straggler_events: list
    resumed_from: Optional[int]
    ckpt_skipped: list = field(default_factory=list)  # corrupt steps skipped


def train_loop(train_step: Callable, params: Params, opt_state: Params,
               data_iter, cfg: LoopConfig, *,
               hooks: Optional[list[Callable]] = None,
               fail_at_step: Optional[int] = None,
               faults: Optional[FaultSchedule] = None,
               sleep_fn: Callable[[float], None] = time.sleep) -> LoopResult:
    """Run training with auto-resume + async checkpointing.

    ``data_iter`` may be a plain iterator or a *step-indexed* callable
    ``data(step) -> batch``; the callable form keeps the data stream
    aligned with the step counter across crash/resume, which is what
    makes a resumed run bit-identical to an uninterrupted one.

    ``fail_at_step`` injects a simulated failure (tests/fault-tolerance
    example): the loop raises after that step, and a rerun with the same
    ckpt_dir resumes from the latest checkpoint. ``faults`` is the
    general form — a seeded :class:`FaultSchedule` whose crash-class
    events (crash / device loss / checkpoint corruption) raise here and
    whose slowdown events stretch the timed step (so the straggler
    watchdog sees them). Resume goes through ``restore_resilient``:
    corrupted checkpoints are skipped (recorded in ``ckpt_skipped``)
    and the previous atomic step is used instead.
    """
    mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts) \
        if cfg.ckpt_dir else None
    start_step = 0
    resumed_from = None
    ckpt_skipped: list = []
    if mgr is not None and latest_step(cfg.ckpt_dir) is not None:
        if latest_valid_step(cfg.ckpt_dir) is not None:
            (params, opt_state), manifest, ckpt_skipped = restore_resilient(
                (params, opt_state), cfg.ckpt_dir)
            start_step = manifest["step"]
            resumed_from = start_step
        # else: only corrupt checkpoints exist — start from scratch

    get_batch = (data_iter if callable(data_iter)
                 else lambda _step, it=iter(data_iter): next(it))
    watchdog = StragglerWatchdog()
    losses = []
    t_start = time.perf_counter()
    step = start_step
    n_run = 0
    for step in range(start_step, cfg.total_steps):
        batch = get_batch(step)
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        if faults is not None:
            slow = faults.slowdown_s(step + 1)
            if slow > 0:
                sleep_fn(slow)  # inside the timed region: watchdog sees it
        dt = time.perf_counter() - t0
        watchdog.observe(step, dt)
        losses.append(loss)
        n_run += 1
        if hooks:
            for h in hooks:
                h(step, metrics, dt)
        if cfg.log_every and (step + 1) % cfg.log_every == 0:
            tps = tokens_per_s(cfg.global_batch, cfg.seq_len, dt)
            print(f"  step {step + 1}/{cfg.total_steps} loss={loss:.4f} "
                  f"({dt * 1e3:.0f} ms, {tps:,.0f} tok/s)")
        if mgr is not None and (step + 1) % cfg.ckpt_every == 0:
            mgr.save_async((params, opt_state), step + 1)
        if fail_at_step is not None and step + 1 >= fail_at_step:
            if mgr is not None:
                mgr.wait()
            raise InjectedCrash(step + 1)
        if faults is not None:
            ev = faults.crash_at(step + 1)
            if ev is not None:
                if mgr is not None:
                    mgr.wait()  # the crash lands after any in-flight save
                if ev.kind == "ckpt_corrupt" and cfg.ckpt_dir:
                    corrupt_checkpoint(cfg.ckpt_dir)
                if ev.kind == "device_loss":
                    raise DeviceLoss(step + 1, ev.n)
                raise InjectedCrash(step + 1)
    if mgr is not None:
        mgr.save_sync((params, opt_state), cfg.total_steps)
        mgr.wait()
    wall = time.perf_counter() - t_start
    tps = (n_run * cfg.global_batch * cfg.seq_len) / max(wall, 1e-9)
    return LoopResult(n_run, step + 1 if n_run else start_step, losses, tps,
                      watchdog.events, resumed_from, ckpt_skipped)
