"""Training loop with the fault-tolerance features the paper's scale needs:
auto-resume from the latest checkpoint, async periodic checkpointing,
straggler watchdog, power measurement hooks, throughput accounting.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager, latest_step, restore
from repro.core.metrics import tokens_per_s
from repro.core.runner import StragglerWatchdog

Params = Any


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    seq_len: int = 512
    global_batch: int = 8
    keep_ckpts: int = 3


@dataclass
class LoopResult:
    steps_run: int
    final_step: int
    losses: list
    tokens_per_s: float
    straggler_events: list
    resumed_from: Optional[int]


def train_loop(train_step: Callable, params: Params, opt_state: Params,
               data_iter, cfg: LoopConfig, *,
               hooks: Optional[list[Callable]] = None,
               fail_at_step: Optional[int] = None) -> LoopResult:
    """Run training with auto-resume + async checkpointing.

    ``fail_at_step`` injects a simulated failure (tests/fault-tolerance
    example): the loop raises after that step, and a rerun with the same
    ckpt_dir resumes from the latest checkpoint.
    """
    mgr = CheckpointManager(cfg.ckpt_dir, keep=cfg.keep_ckpts) \
        if cfg.ckpt_dir else None
    start_step = 0
    resumed_from = None
    if mgr is not None and latest_step(cfg.ckpt_dir) is not None:
        (params, opt_state), manifest = restore(
            (params, opt_state), cfg.ckpt_dir)
        start_step = manifest["step"]
        resumed_from = start_step

    watchdog = StragglerWatchdog()
    losses = []
    t_start = time.perf_counter()
    step = start_step
    n_run = 0
    for step in range(start_step, cfg.total_steps):
        batch = next(data_iter)
        t0 = time.perf_counter()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        watchdog.observe(step, dt)
        losses.append(loss)
        n_run += 1
        if hooks:
            for h in hooks:
                h(step, metrics, dt)
        if cfg.log_every and (step + 1) % cfg.log_every == 0:
            tps = tokens_per_s(cfg.global_batch, cfg.seq_len, dt)
            print(f"  step {step + 1}/{cfg.total_steps} loss={loss:.4f} "
                  f"({dt * 1e3:.0f} ms, {tps:,.0f} tok/s)")
        if mgr is not None and (step + 1) % cfg.ckpt_every == 0:
            mgr.save_async((params, opt_state), step + 1)
        if fail_at_step is not None and step + 1 >= fail_at_step:
            if mgr is not None:
                mgr.wait()
            raise RuntimeError(f"injected failure at step {step + 1}")
    if mgr is not None:
        mgr.save_sync((params, opt_state), cfg.total_steps)
        mgr.wait()
    wall = time.perf_counter() - t_start
    tps = (n_run * cfg.global_batch * cfg.seq_len) / max(wall, 1e-9)
    return LoopResult(n_run, step + 1 if n_run else start_step, losses, tps,
                      watchdog.events, resumed_from)
