"""dp train-step diagnosis: attribute multi-device scaling loss.

The instrument that found the dp-scaling collapse (ISSUE 6): the jitted
sharded step had no output-sharding pin, so the returned params' layout
drifted from the placed inputs and every call after the first
recompiled (~seconds of XLA work billed into the measured window —
BENCH_llm_train.json recorded dp2 scaling_efficiency 0.10).

Usage::

  XLA_FLAGS=--xla_force_host_platform_device_count=2 PYTHONPATH=src \
      python -m repro.train.diagnose --placement dp2

Reports, per step variant:

- **donation/pinning audit** — whether output leaf shardings match the
  placed inputs (mismatched leaves => per-call resharding churn), and
  the jit cache size across calls (>1 => recompile churn);
- **per-call wall time** — call 0 (compile), call 1 (the one a
  warmup=1 benchmark actually measures), steady state;
- **collective-vs-compute attribution** — the sync=none variant runs
  the identical local step without any cross-device reduce, so
  (variant - none) isolates what gradient synchronization costs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.bench.spec import Placement
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data.synthetic import synthetic_tokens
from repro.launch.mesh import mesh_for
from repro.models import lm
from repro.parallel import grad_sync as gs
from repro.parallel import sharding as shd
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step


def audit_shardings(outputs, expected) -> int:
    """Count output leaves whose sharding differs from the expected
    placement — each one is a per-call reshard on the next donation."""
    mismatched = 0
    for got, want in zip(jax.tree.leaves(outputs), jax.tree.leaves(expected)):
        if not got.sharding.is_equivalent_to(want, got.ndim):
            mismatched += 1
    return mismatched


def _block(tree):
    jax.block_until_ready(jax.tree.leaves(tree)[0])


def time_step(step, args_fn, calls: int = 6):
    """Per-call wall times + jit cache size. ``args_fn()`` returns fresh
    (donatable) step arguments each call batchset."""
    times = []
    args = args_fn()
    for _ in range(calls):
        t0 = time.perf_counter()
        out = step(*args)
        _block(out[0])
        times.append(time.perf_counter() - t0)
        args = tuple(out[:len(args) - 1]) + (args[-1],)
    cache = step._cache_size() if hasattr(step, "_cache_size") else -1
    return times, cache, out


def build_variants(placement: str, gb: int, seq: int, k: int):
    c = get_config("gpt-800m").reduced(d_model=128, n_layers=4, d_ff=512,
                                       vocab=8192, n_heads=4, n_kv_heads=4,
                                       d_head=32)
    oc = OptConfig(warmup=2, total_steps=1000)
    params = lm.init(jax.random.key(0), c)
    opt_state = opt_init(oc, params)
    mesh = mesh_for(Placement.of(placement))
    plan = shd.make_plan(c, mesh, ShapeConfig("diag", seq, gb, "train"))
    p_s, o_s, psh, osh, gsh = shd.shard_train_state(plan, params,
                                                    opt_state, c)
    toks = jnp.asarray(synthetic_tokens(gb, seq, c.vocab)[:, :seq])
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    batch = jax.device_put(
        batch, {kk: shd.batch_sharding(plan, v.shape)
                for kk, v in batch.items()})
    sc = StepConfig(microbatches=k)
    mb = gb // k
    mbsh = {"tokens": shd.batch_sharding(plan, (mb, seq)),
            "labels": shd.batch_sharding(plan, (mb, seq))}

    def fresh(extra=None):
        def args_fn():
            p = jax.device_put(jax.tree.map(jnp.copy, p_s), psh)
            o = jax.device_put(jax.tree.map(jnp.copy, o_s), osh)
            if extra is None:
                return (p, o, batch)
            return (p, o, extra(), batch)
        return args_fn

    variants = {}
    # the pre-fix path: GSPMD step, no out pinning, no donation
    variants["gspmd-unpinned"] = (
        jax.jit(make_train_step(c, oc, sc, grad_shardings=psh,
                                batch_shardings=mbsh)),
        fresh(), psh)
    # the fix: pinned outputs + donation + ZeRO-2 grad shardings
    variants["gspmd-pinned-zero2"] = (
        jax.jit(make_train_step(c, oc, sc, grad_shardings=gsh,
                                batch_shardings=mbsh),
                out_shardings=(psh, osh, None), donate_argnums=(0, 1)),
        fresh(), psh)
    for label, sync in (
            ("bucketed-fp32", gs.GradSyncConfig(mode="fp32")),
            ("bucketed-fp32-noolap", gs.GradSyncConfig(mode="fp32",
                                                       overlap=False)),
            ("bucketed-int8", gs.GradSyncConfig(mode="int8"))):
        variants[label] = (
            jax.jit(gs.make_dp_train_step(c, oc, sc, plan=plan, sync=sync),
                    out_shardings=(psh, osh, gs.sync_state_sharding(plan),
                                   None),
                    donate_argnums=(0, 1, 2)),
            fresh(lambda s=sync: gs.init_sync_state(plan, params, s)), psh)
    return variants, gb, seq


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--placement", default="dp2")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--calls", type=int, default=6)
    args = ap.parse_args(argv)

    n = Placement.of(args.placement).n_devices
    if n > jax.device_count():
        raise SystemExit(
            f"placement {args.placement} needs {n} devices; set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n}")
    variants, gb, seq = build_variants(args.placement, args.global_batch,
                                       args.seq, args.microbatches)
    print(f"[diagnose] placement={args.placement} gb={gb} seq={seq} "
          f"mb={args.microbatches} devices={jax.device_count()}")
    rows = []
    for label, (step, args_fn, psh) in variants.items():
        times, cache, out = time_step(step, args_fn, calls=args.calls)
        mism = audit_shardings(out[0], psh)
        steady = sum(times[2:]) / max(len(times) - 2, 1)
        tps = gb * seq / steady
        rows.append((label, times[0], times[1], steady, tps, cache, mism))
        print(f"  {label:22s} call0={times[0]*1e3:8.1f}ms "
              f"call1={times[1]*1e3:8.1f}ms steady={steady*1e3:8.1f}ms "
              f"tok/s={tps:9.1f} cache={cache} resharded_leaves={mism}")
    base = next((r for r in rows if r[0] == "gspmd-unpinned"), None)
    best = min(rows, key=lambda r: r[3])
    if base is not None:
        print(f"[diagnose] call-1 penalty of unpinned step: "
              f"{(base[2] - best[3])*1e3:.1f}ms over best steady state "
              f"(recompile churn when cache>1, reshard churn when "
              f"resharded_leaves>0)")
    print(f"[diagnose] best steady variant: {best[0]} "
          f"({best[4]:.1f} tok/s)")
    return rows


if __name__ == "__main__":
    main()
