"""Optimizers: AdamW with fp32 master weights (Megatron mixed precision)
and Adafactor (factored second moment — the memory fallback for very large
MoE archs). Optimizer states are sharded ZeRO-1 style by the caller
(repro.parallel.sharding.opt_state_shardings) — the "distributed optimizer"
the paper's Megatron-LM benchmark enables.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # "adamw" | "adafactor"
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    master_dtype: str = "float32"


def lr_at(oc: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay (Megatron's default schedule)."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(oc.warmup, 1), 1.0)
    t = jnp.clip((s - oc.warmup) / jnp.maximum(oc.total_steps - oc.warmup, 1), 0, 1)
    cos = oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return oc.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), tree), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw_init(oc: OptConfig, params: Params) -> Params:
    md = jnp.dtype(oc.master_dtype)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "master": jax.tree.map(lambda p: p.astype(md), params),
    }


def adamw_update(oc: OptConfig, grads: Params, state: Params, params: Params):
    grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
    step = state["step"] + 1
    lr = lr_at(oc, step)
    b1, b2 = oc.b1, oc.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master, p):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + oc.eps)
        if master.ndim >= 1:  # decoupled weight decay, skip scalars/norms
            delta = delta + oc.weight_decay * master
        master = master - lr * delta
        return m, v, master, master.astype(p.dtype)

    out = jax.tree.map(upd, grads, state["m"], state["v"], state["master"], params)
    m = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda o: o[3], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"step": step, "m": m, "v": v, "master": master}
    return new_params, new_state, {"gnorm": gnorm, "lr": lr}


# ---------------------------------------------------------------------------
# Adafactor (factored v, no master copy) — memory-lean fallback
# ---------------------------------------------------------------------------


def adafactor_init(oc: OptConfig, params: Params) -> Params:
    def rows_cols(p):
        if p.ndim < 2:
            return jnp.zeros(p.shape, jnp.float32), jnp.zeros((), jnp.float32)
        return (jnp.zeros(p.shape[:-1], jnp.float32),
                jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))

    rc = jax.tree.map(rows_cols, params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "vr": jax.tree.map(lambda o: o[0], rc, is_leaf=lambda x: isinstance(x, tuple)),
        "vc": jax.tree.map(lambda o: o[1], rc, is_leaf=lambda x: isinstance(x, tuple)),
    }


def adafactor_update(oc: OptConfig, grads: Params, state: Params, params: Params):
    grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
    step = state["step"] + 1
    lr = lr_at(oc, step)
    decay = 1.0 - (step.astype(jnp.float32) + 1.0) ** -0.8

    def upd(g, vr, vc, p):
        g2 = jnp.square(g) + 1e-30
        if p.ndim < 2:
            vr_n = decay * vr + (1 - decay) * g2
            update = g * jax.lax.rsqrt(vr_n + 1e-30)
            return vr_n, vc, (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        vr_n = decay * vr + (1 - decay) * g2.mean(axis=-1)
        vc_n = decay * vc + (1 - decay) * g2.mean(axis=-2)
        r = vr_n / jnp.maximum(vr_n.mean(axis=-1, keepdims=True), 1e-30)
        update = g * jax.lax.rsqrt(r[..., None] * vc_n[..., None, :] + 1e-30)
        newp = p.astype(jnp.float32) - lr * (update + oc.weight_decay * p.astype(jnp.float32))
        return vr_n, vc_n, newp.astype(p.dtype)

    out = jax.tree.map(upd, grads, state["vr"], state["vc"], params)
    vr = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    vc = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    newp = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, {"step": step, "vr": vr, "vc": vc}, {"gnorm": gnorm, "lr": lr}


def opt_init(oc: OptConfig, params):
    return adamw_init(oc, params) if oc.name == "adamw" else adafactor_init(oc, params)


def opt_update(oc: OptConfig, grads, state, params):
    if oc.name == "adamw":
        return adamw_update(oc, grads, state, params)
    return adafactor_update(oc, grads, state, params)
