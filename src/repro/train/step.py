"""train_step factories: LM (all 10 archs + GPT family) and ResNet50.

Implements the paper's Megatron-style recipe: bf16 compute, fp32 master
weights, activation recomputation (remat in the layer scan), gradient
accumulation over micro-batches (micro-batch-size 4 in the paper's runs),
distributed (ZeRO-1-sharded) optimizer.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.resnet50 import ResNetConfig
from repro.models import lm, resnet
from repro.train.loss import classification_loss, next_token_loss
from repro.train.optimizer import OptConfig, opt_init, opt_update

Params = Any


@dataclass(frozen=True)
class StepConfig:
    microbatches: int = 1
    impl: str = "repeat"       # attention einsum formulation
    remat: str = "full"        # activation recomputation
    z_coef: float = 0.0
    unroll: bool = False       # unroll layer scans (dry-run metrics pass)
    grad_dtype: str = "float32"  # grad buffer (Megatron bf16-grad option)


def _split_mb(x: jax.Array, k: int) -> jax.Array:
    return x.reshape(k, x.shape[0] // k, *x.shape[1:])


def scan_microbatch_grads(vg, params, batch: dict, k: int, gdt,
                          *, mb_hook=None, grad_hook=None, acc_hook=None,
                          hook_state=None, init_grads=None):
    """Gradient accumulation over ``k`` microbatches via ``jax.lax.scan``.

    ``vg`` is a ``value_and_grad(loss_fn, has_aux=True)``; the per-leaf
    accumulator dtype is ``gdt``. Three hooks let callers thread per-step
    behaviour through the scan without owning the loop:

    - ``mb_hook(mb) -> mb`` transforms each microbatch (e.g. re-applying
      batch-axis sharding constraints lost in the (k, mb) reshape);
    - ``grad_hook(g, state) -> (g, state)`` runs on each microbatch's raw
      gradients *before* accumulation — the hook point for an overlapped
      bucketed all-reduce that syncs microbatch *i*'s contribution while
      microbatch *i+1*'s backward is still running (state carries e.g.
      compression error feedback);
    - ``acc_hook(g_acc) -> g_acc`` runs on the running accumulator (e.g.
      ZeRO-style sharding constraints).

    Returns ``(grads, hook_state, loss, ce, aux)`` — sums over the k
    steps; callers divide by ``k`` themselves.
    """
    mbs = jax.tree.map(lambda x: _split_mb(x, k), batch)
    g0 = init_grads
    if g0 is None:
        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt), params)

    def body(carry, mb):
        g_acc, hs, l_acc, ce_acc, aux_acc = carry
        if mb_hook is not None:
            mb = mb_hook(mb)
        (l, (ce, aux)), g = vg(params, mb)
        if grad_hook is not None:
            g, hs = grad_hook(g, hs)
        g_acc = jax.tree.map(lambda a, x: a + x.astype(gdt), g_acc, g)
        if acc_hook is not None:
            g_acc = acc_hook(g_acc)
        return (g_acc, hs, l_acc + l, ce_acc + ce, aux_acc + aux), None

    init = (g0, hook_state, 0.0, 0.0, jnp.zeros((), jnp.float32))
    (grads, hs, loss, ce, aux), _ = jax.lax.scan(body, init, mbs)
    return grads, hs, loss, ce, aux


def make_loss_fn(c: ModelConfig, sc: StepConfig):
    def loss_fn(params: Params, batch: dict):
        logits, aux = lm.forward(
            c, params, batch["tokens"],
            patch_embeds=batch.get("patch_embeds"),
            enc_frames=batch.get("enc_frames"),
            impl=sc.impl, remat=sc.remat, unroll=sc.unroll)
        ce = next_token_loss(c, logits, batch["labels"], z_coef=sc.z_coef)
        total = ce + c.router_aux_coef * aux
        return total, (ce, aux)
    return loss_fn


def make_train_step(c: ModelConfig, oc: OptConfig, sc: StepConfig = StepConfig(),
                    grad_shardings=None, batch_shardings=None):
    """grad_shardings: optional pytree of NamedShardings for the gradient
    accumulator (ZeRO-style DP-sharded grad buffer, like Megatron's
    distributed optimizer). Constraining the scan carry makes GSPMD
    reduce-scatter each microbatch's grads instead of all-reducing.
    batch_shardings: optional shardings re-applied to each microbatch —
    the (global_batch,)->(k, mb) reshape otherwise loses the batch-axis
    sharding through GSPMD's reshape handling."""
    loss_fn = make_loss_fn(c, sc)
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(tree, shardings):
        if shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree, shardings)

    def train_step(params: Params, opt_state: Params, batch: dict):
        gdt = jnp.dtype(sc.grad_dtype)
        if sc.microbatches <= 1:
            (loss, (ce, aux)), grads = vg(params, batch)
            grads = constrain(jax.tree.map(
                lambda g: g.astype(gdt), grads), grad_shardings)
        else:
            k = sc.microbatches
            g0 = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, gdt), params),
                grad_shardings)
            grads, _, loss, ce, aux = scan_microbatch_grads(
                vg, params, batch, k, gdt,
                mb_hook=lambda mb: constrain(mb, batch_shardings),
                acc_hook=lambda g: constrain(g, grad_shardings),
                init_grads=g0)
            grads = jax.tree.map(lambda g: (g / k).astype(jnp.float32), grads)
            loss, ce, aux = loss / k, ce / k, aux / k

        new_params, new_state, info = opt_update(oc, grads, opt_state, params)
        metrics = {"loss": loss, "ce": ce, "aux": aux, **info}
        return new_params, new_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# ResNet50 (data-parallel, Horovod-analog all-reduce via GSPMD)
# ---------------------------------------------------------------------------


def make_resnet_train_step(c: ResNetConfig, oc: OptConfig):
    def loss_fn(params, batch):
        logits = resnet.forward(c, params, batch["images"])
        return classification_loss(logits, batch["labels"])

    vg = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state, batch):
        loss, grads = vg(params, batch)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_params, new_state, info = opt_update(oc, grads, opt_state, params)
        return new_params, new_state, {"loss": loss, **info}

    return train_step


def init_train_state(c, oc: OptConfig, key=None, abstract: bool = False):
    """(params, opt_state) — concrete or abstract (eval_shape)."""
    if isinstance(c, ResNetConfig):
        def mk(k):
            p = resnet.init(k, c)
            return p, opt_init(oc, p)
    else:
        def mk(k):
            p = lm.init(k, c)
            return p, opt_init(oc, p)
    if abstract:
        return jax.eval_shape(mk, jax.random.key(0))
    return mk(key if key is not None else jax.random.key(0))
