"""Losses: next-token cross-entropy (fp32), router aux, z-loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

IGNORE = -1


def next_token_loss(c: ModelConfig, logits: jax.Array, labels: jax.Array,
                    z_coef: float = 0.0):
    """logits: (B, S, V); labels: (B, S) with IGNORE masked. fp32 softmax."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    # vocab-parallel gather: one-hot contraction keeps the vocab dim sharded
    # (take_along_axis on a sharded dim would all-gather the logits)
    tgt = jnp.clip(labels, 0, c.padded_vocab - 1)
    vpos = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    picked = jnp.sum(jnp.where(vpos == tgt[..., None], lf, 0.0), axis=-1)
    nll = lse - picked
    mask = (labels != IGNORE).astype(jnp.float32)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    if z_coef:
        ce = ce + z_coef * ((lse * mask) ** 2).sum() / denom
    return ce


def classification_loss(logits: jax.Array, labels: jax.Array):
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked)
