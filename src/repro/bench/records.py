"""ResultRecord — the normalized, schema-versioned benchmark result.

Every workload point produces exactly one record: the point, the metrics,
the labeled power source, device count, attempt count, and status. The
on-disk layout under ``artifacts/bench/<workload>/`` is

  results.json   {"schema_version": N, "workload": ..., "records": [...]}
  results.csv    flat rows (point + metrics columns), schema_version column
  manifest.json  host/jax/flags provenance (core.manifest)

written through :mod:`repro.core.results` so the files are atomic and a
partially-interrupted sweep never truncates completed points.
"""
from __future__ import annotations

import json
import pathlib
from dataclasses import asdict, dataclass, field
from typing import Optional

from repro.core.results import atomic_write_text
from repro.power.frame import Frame

SCHEMA_VERSION = 1


@dataclass
class ResultRecord:
    """One (workload x point) outcome in the normalized schema."""

    workload: str
    point: dict
    metrics: dict = field(default_factory=dict)
    power_source: str = "none"
    n_devices: int = 1
    attempts: int = 1
    status: str = "ok"                 # "ok" | "error" | "skipped"
    error: Optional[str] = None
    schema_version: int = SCHEMA_VERSION

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    def flat(self) -> dict:
        """Single-level dict for CSV/result tables: point + metrics merged,
        prefixed by the bookkeeping columns."""
        out = {"schema_version": self.schema_version,
               "workload": self.workload}
        out.update(self.point)
        out.update(self.metrics)
        out.update(power_source=self.power_source, n_devices=self.n_devices,
                   attempts=self.attempts, status=self.status)
        if self.error:
            out["error"] = self.error
        return out

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ResultRecord":
        d = dict(d)
        version = d.get("schema_version", 0)
        if version > SCHEMA_VERSION or version < 1:
            raise ValueError(
                f"ResultRecord schema_version {version} not supported "
                f"(this reader understands <= {SCHEMA_VERSION})")
        return cls(**d)


def save_records(records: list, out_dir, name: str = "results") -> None:
    """Write the schema-versioned JSON + flat CSV pair (atomically)."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    workload = records[0].workload if records else ""
    doc = {"schema_version": SCHEMA_VERSION, "workload": workload,
           "records": [r.to_dict() for r in records]}
    atomic_write_text(out / f"{name}.json",
                      json.dumps(doc, indent=1, default=str))
    atomic_write_text(out / f"{name}.csv",
                      Frame.from_records([r.flat() for r in records]).to_csv())


def load_records(path) -> list:
    """Read a results.json back into ResultRecords (version-checked)."""
    doc = json.loads(pathlib.Path(path).read_text())
    if isinstance(doc, list):   # pre-schema layout (plain record list)
        raise ValueError(f"{path}: unversioned legacy results; re-run the "
                         f"benchmark through `python -m repro.bench run`")
    return [ResultRecord.from_dict(d) for d in doc.get("records", [])]
