"""ResultRecord — the normalized, schema-versioned benchmark result.

Every workload point produces exactly one record: the point, the metrics,
the labeled power source, device count, attempt count, and status. The
on-disk layout under ``artifacts/bench/<workload>/`` is

  results.json   {"schema_version": N, "workload": ..., "records": [...]}
  results.csv    flat rows (point + metrics columns), schema_version column
  manifest.json  host/jax/flags provenance (core.manifest)

written through :mod:`repro.core.results` so the files are atomic and a
partially-interrupted sweep never truncates completed points.

Schema history
--------------
  v1  point/metrics/power_source/n_devices/attempts/status/error
  v2  adds ``git_sha`` (commit of the benchmarked tree) and ``noise``
      (tolerance inputs for cross-run comparison: the relative step-time
      spread the runner's straggler watchdog observed). v1 documents load
      transparently — the new fields default to "unknown provenance" and
      comparison falls back to the per-metric base tolerance.
  v3  adds ``placement`` (the device mesh shape by parallelism axis,
      e.g. ``{"dp": 2, "tp": 2}``) and a ``"deferred"`` status (the mesh
      exceeded local devices; a rendered SLURM script carries the work).
      The placement replaces the bare device count in the canonical
      point key — a dp4 and a dp2tp2 measurement are different points
      even though both span 4 devices. v1/v2 documents upconvert to
      pure data parallel (``{"dp": n_devices}``), which is what every
      pre-placement workload actually ran.

This module also owns the two helpers the cross-run comparison engine
(:mod:`repro.bench.compare`) joins on: the canonical :func:`point_key`
and :func:`compare_metrics` extraction with per-metric direction — plus
:func:`stamp_scaling_metrics`, which derives the cross-placement scaling
figures of merit (``tok_s_per_device``, ``scaling_efficiency``,
``wh_per_token_scaling``) each sweep's records are gated on.
"""
from __future__ import annotations

import json
import math
import pathlib
from dataclasses import asdict, dataclass, field, fields
from typing import Optional

from repro.core.results import atomic_write_text
from repro.power.frame import Frame

SCHEMA_VERSION = 3

#: metrics the comparison engine understands: name -> (higher_is_better,
#: default relative tolerance). Anything else a workload emits (structural
#: counts, booleans, notes) is carried in the record but not delta-gated.
COMPARED_METRICS: dict[str, tuple[bool, float]] = {
    # throughput — higher is better
    "tokens_per_s": (True, 0.20),
    "images_per_s": (True, 0.20),
    "decode_tok_s": (True, 0.20),
    "speedup_vs_fixed": (True, 0.25),
    "speedup_vs_slotted": (True, 0.25),
    # scheduler health — mean decode-step batch occupancy (active slots /
    # n_slots); a drop means admission/refill regressed even when raw
    # throughput noise hides it
    "occupancy": (True, 0.25),
    # energy efficiency — higher is better
    "tokens_per_wh": (True, 0.20),
    "images_per_wh": (True, 0.20),
    # step/latency time — lower is better
    "seconds": (False, 0.20),
    "ms_per_step": (False, 0.20),
    "ms_per_iter": (False, 0.20),
    "ms": (False, 0.20),
    "us": (False, 0.20),
    "ttft_s": (False, 0.30),
    # energy cost — lower is better
    "wh_per_token": (False, 0.25),
    "wh_per_request": (False, 0.25),
    "energy_wh_per_step": (False, 0.25),
    "energy_wh": (False, 0.25),
    # cross-placement scaling (stamp_scaling_metrics) — per-device
    # throughput, parallel efficiency vs the 1-device cell of the same
    # sweep, and the energy-per-token ratio vs that cell
    "tok_s_per_device": (True, 0.20),
    "scaling_efficiency": (True, 0.20),
    "wh_per_token_scaling": (False, 0.25),
    # SLO serving (serve_slo / repro.serve.slo) — fraction of requests
    # meeting their per-tenant TTFT+TPOT targets, tail latency
    # quantiles, and energy per SLO-met request (the MLPerf-Power
    # energy-per-useful-inference figure). Tail quantiles get wide
    # tolerances: a p99 on CPU timing is the noisiest figure gated here.
    "goodput": (True, 0.15),
    "ttft_p99": (False, 0.35),
    "tpot_p99": (False, 0.35),
    "wh_per_slo_request": (False, 0.30),
    # int8 KV pool (kv_dtype axis): pool_bytes/max_concurrency are
    # structural (deterministic functions of config + dtype — near-zero
    # tolerance so a silent layout change gates); speedup_vs_fp_kv is a
    # same-cell throughput ratio vs the fp32 twin;
    # kv_stream_prefix_agreement is the token-stream quality figure
    # (mean longest-common-prefix fraction vs the fp32 twin's streams) —
    # a drop means quantization error is steering greedy decoding.
    "pool_bytes": (False, 0.01),
    "max_concurrency": (True, 0.01),
    "speedup_vs_fp_kv": (True, 0.25),
    "kv_stream_prefix_agreement": (True, 0.10),
    # chunked-vs-phased scheduler ratios (sched axis): same-cell pairs,
    # so trace noise largely cancels — except ttft_p99_vs_phased, a
    # ratio of two SINGLE-RUN p99s whose run-to-run wobble is multiples,
    # not percent. Its wide tolerance lives on the serve_slo workload
    # (compare_tols stamp — a registry base here would be outranked by
    # the CI's blanket --rel-tol); the real cliff gate is
    # scripts/check_ttft_gate.py (median-of-3 per sched). The base
    # below only matters for compares run without a CLI default.
    "speedup_vs_phased": (True, 0.25),
    "ttft_p99_vs_phased": (False, 1.5),
    "goodput_vs_phased": (True, 0.15),
    # resilience (faults/ + bench.workloads.resilience) — crash-to-first-
    # resumed-step wall time, recompute cost in tokens, end-to-end
    # delivered-token rate including recovery, and the energy premium vs
    # the fault-free twin. recovery_s and the Wh overhead are differences
    # of CPU wall-clock quantities an order of magnitude noisier than a
    # throughput cell, hence the wide bases (the workload stamps wider
    # still via compare_tols).
    "recovery_s": (False, 0.50),
    "wasted_tokens": (False, 0.30),
    "goodput_tokens_per_s": (True, 0.25),
    "wh_overhead_resilience": (False, 2.0),
}


@dataclass
class ResultRecord:
    """One (workload x point) outcome in the normalized schema."""

    workload: str
    point: dict
    metrics: dict = field(default_factory=dict)
    power_source: str = "none"
    n_devices: int = 1
    attempts: int = 1
    status: str = "ok"            # "ok" | "error" | "skipped" | "deferred"
    error: Optional[str] = None
    git_sha: Optional[str] = None      # commit of the benchmarked tree (v2)
    noise: dict = field(default_factory=dict)  # tolerance inputs (v2)
    #: device mesh by parallelism axis, e.g. {"dp": 2, "tp": 2} (v3);
    #: None upconverts to {"dp": n_devices} — pure data parallel is what
    #: every pre-placement record measured
    placement: Optional[dict] = None
    schema_version: int = SCHEMA_VERSION

    def __post_init__(self):
        if self.placement is None:
            self.placement = {"dp": int(self.n_devices)}
        else:
            n = 1
            for size in self.placement.values():
                n *= int(size)
            self.n_devices = n

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def rel_std(self) -> float:
        """Recorded relative step-time spread (0.0 when not recorded)."""
        if not isinstance(self.noise, dict):
            return 0.0
        try:
            return max(float(self.noise.get("rel_std", 0.0)), 0.0)
        except (TypeError, ValueError):
            return 0.0

    def flat(self) -> dict:
        """Single-level dict for CSV/result tables: point + metrics merged,
        prefixed by the bookkeeping columns."""
        out = {"schema_version": self.schema_version,
               "workload": self.workload}
        out.update(self.point)
        out.update(self.metrics)
        out.update(power_source=self.power_source, n_devices=self.n_devices,
                   attempts=self.attempts, status=self.status)
        if "placement" not in out:     # a placement Space axis wins
            out["placement"] = placement_label(self.placement)
        if self.git_sha:
            out["git_sha"] = self.git_sha
        if self.error:
            out["error"] = self.error
        return out

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ResultRecord":
        d = dict(d)
        version = d.get("schema_version", 0)
        if version > SCHEMA_VERSION or version < 1:
            raise ValueError(
                f"ResultRecord schema_version {version} not supported "
                f"(this reader understands 1..{SCHEMA_VERSION})")
        # v1 -> v2: provenance fields did not exist; dataclass defaults
        # (git_sha=None, noise={}) are the correct upconversion. Unknown
        # keys from a same-version writer are rejected loudly rather than
        # surfacing later as an opaque TypeError/KeyError.
        known = {f.name for f in fields(cls)}
        extra = set(d) - known
        if extra:
            raise ValueError(
                f"ResultRecord v{version} has unknown fields {sorted(extra)}"
                f"; known fields: {sorted(known)}")
        if d.get("noise") is None:      # hand-edited/null noise tolerated
            d["noise"] = {}
        for name in ("point", "metrics", "noise"):
            if name in d and not isinstance(d[name], dict):
                raise ValueError(
                    f"ResultRecord field {name!r} must be an object, "
                    f"got {type(d[name]).__name__}")
        try:
            return cls(**d)
        except TypeError as e:   # missing required field etc. — corrupt
            raise ValueError(f"malformed ResultRecord: {e}") from None


def placement_label(placement: Optional[dict]) -> str:
    """Canonical compact spelling of a placement dict (axis-name order
    insensitive): ``{"tp": 2, "dp": 2}`` -> ``"dp2tp2"``. Delegates to
    ``spec.Placement`` so records, point keys, sbatch filenames, and
    Space-axis values all share ONE canonicalization."""
    from repro.bench.spec import Placement
    if not placement:
        return "dp1"
    return Placement.of(dict(placement)).label


def point_key(rec: ResultRecord, *, with_power: bool = True) -> str:
    """Canonical join key for cross-run comparison.

    Two records describe the same measurement point iff their workload,
    Space parameters (order-insensitive), device placement (mesh shape
    by axis, order-insensitive — a dp4 and a dp2tp2 run are different
    measurements even though both span 4 devices) — and, unless
    ``with_power=False``, power source — agree. The power source is part
    of the key so RAPL-measured and synthetic-modeled energies are never
    silently diffed against each other; the power-stripped variant lets
    the compare engine *detect* that situation and flag it.
    """
    params = ",".join(f"{k}={rec.point[k]}" for k in sorted(rec.point))
    key = f"{rec.workload}|{params}|plc={placement_label(rec.placement)}"
    if with_power:
        key += f"|power={rec.power_source}"
    return key


def compare_metrics(rec: ResultRecord) -> dict[str, float]:
    """The subset of a record's metrics the comparison engine delta-gates,
    as floats, in ``COMPARED_METRICS`` order."""
    out = {}
    for name in COMPARED_METRICS:
        if name in rec.metrics:
            try:
                out[name] = float(rec.metrics[name])
            except (TypeError, ValueError):
                continue
    return out


#: throughput metrics a sweep's scaling figures derive from, in
#: preference order (the first one a record carries wins)
THROUGHPUT_METRICS = ("tokens_per_s", "images_per_s", "decode_tok_s")
#: energy-efficiency metrics (higher is better) the wh/token scaling
#: ratio derives from
EFFICIENCY_METRICS = ("tokens_per_wh", "images_per_wh")


def scaling_base_key(rec: ResultRecord) -> tuple:
    """The join key of a record's own sweep, placement stripped: the
    1-device cell every scaled cell's efficiency is measured against."""
    params = tuple(sorted((k, str(v)) for k, v in rec.point.items()
                          if k != "placement"))
    return (rec.workload, params, rec.power_source)


def stamp_scaling_metrics(records: list,
                          device_cap: Optional[int] = None) -> None:
    """Derive the cross-placement scaling metrics for one result set.

    Every ok record with a throughput metric gains ``tok_s_per_device``
    (throughput / mesh size — the paper's per-accelerator figure);
    multi-device records whose sweep also measured the 1-device cell of
    the same point gain ``scaling_efficiency`` (per-device throughput
    relative to 1 device: 1.0 = linear scaling) and
    ``wh_per_token_scaling`` (energy per token relative to 1 device:
    1.0 = energy parity, above = each token costs more at scale). All
    three are in ``COMPARED_METRICS``, so a scaling collapse gates the
    compare engine even when the raw throughput cell stays green.

    ``device_cap`` makes the derivation emulation-aware: when the mesh
    is forced host-platform fake devices (``device_count > cpu cores``),
    an N-"device" cell has at most ``cap`` cores of real compute, so
    dividing by N would bill the cell for parallelism the host cannot
    physically deliver. The per-device figures then normalize by
    ``n_eff = min(n, cap)`` (recorded as the ``effective_devices``
    metric), and ``wh_per_token_scaling`` is rescaled by ``n_eff / n``
    to cancel the synthetic-power model billing each fake device as a
    full chip. On real hardware ``device_cap=None`` leaves the classic
    semantics untouched.
    """
    ones = {}
    for r in records:
        if r.ok and r.n_devices == 1:
            ones.setdefault(scaling_base_key(r), r)
    for r in records:
        if not r.ok:
            continue
        tp_name = next((m for m in THROUGHPUT_METRICS if m in r.metrics),
                       None)
        if tp_name is None:
            continue
        try:
            tp = float(r.metrics[tp_name])
        except (TypeError, ValueError):
            continue
        if not math.isfinite(tp):
            continue
        n = max(r.n_devices, 1)
        n_eff = n if device_cap is None else max(min(n, int(device_cap)), 1)
        if n_eff != n:
            r.metrics.setdefault("effective_devices", n_eff)
        r.metrics.setdefault("tok_s_per_device", tp / n_eff)
        if n == 1:
            continue
        base = ones.get(scaling_base_key(r))
        if base is None:
            continue
        try:
            base_tp = float(base.metrics.get(tp_name))
        except (TypeError, ValueError):
            continue
        if math.isfinite(base_tp) and base_tp > 0.0:
            r.metrics["scaling_efficiency"] = (tp / n_eff) / base_tp
        eff_name = next((m for m in EFFICIENCY_METRICS
                         if m in r.metrics and m in base.metrics), None)
        if eff_name is None:
            continue
        try:
            cur_eff = float(r.metrics[eff_name])
            base_eff = float(base.metrics[eff_name])
        except (TypeError, ValueError):
            continue
        if all(math.isfinite(v) and v > 0.0 for v in (cur_eff, base_eff)):
            # (Wh/token at n devices) / (Wh/token at 1) == eff_1 / eff_n;
            # under emulation, n_eff/n cancels the synthetic power model
            # billing each fake device as a full physical chip
            r.metrics["wh_per_token_scaling"] = (
                (base_eff / cur_eff) * (n_eff / n))


def scaling_floor_violations(records: list, floor: float) -> list:
    """Multi-device ok records whose ``scaling_efficiency`` fell below
    ``floor`` — the CI gate that keeps dp scaling from silently
    inverting again. Returns ``(record, efficiency)`` pairs."""
    out = []
    for r in records:
        if not r.ok or r.n_devices <= 1:
            continue
        eff = r.metrics.get("scaling_efficiency")
        try:
            eff = float(eff)
        except (TypeError, ValueError):
            continue
        if math.isfinite(eff) and eff < floor:
            out.append((r, eff))
    return out


def metric_direction(name: str) -> bool:
    """True when higher values of ``name`` are better."""
    return COMPARED_METRICS[name][0]


def metric_tolerance(name: str) -> float:
    """Default relative tolerance for ``name``."""
    return COMPARED_METRICS[name][1]


def result_doc(records: list) -> dict:
    """The on-disk results/baseline document for a record list."""
    workload = records[0].workload if records else ""
    return {"schema_version": SCHEMA_VERSION, "workload": workload,
            "records": [r.to_dict() for r in records]}


def write_result_doc(records: list, path) -> None:
    """Atomically write the schema-versioned JSON document (no CSV)."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    atomic_write_text(path, json.dumps(result_doc(records), indent=1,
                                       default=str))


def save_records(records: list, out_dir, name: str = "results") -> None:
    """Write the schema-versioned JSON + flat CSV pair (atomically)."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    write_result_doc(records, out / f"{name}.json")
    atomic_write_text(out / f"{name}.csv",
                      Frame.from_records([r.flat() for r in records]).to_csv())


def load_records(path) -> list:
    """Read a results.json back into ResultRecords (version-checked).

    Rejects unversioned/foreign documents and unsupported versions with a
    ValueError naming the file — the reader must never degrade into a
    KeyError deep inside rendering or comparison.
    """
    path = pathlib.Path(path)
    doc = json.loads(path.read_text())
    if isinstance(doc, list):   # pre-schema layout (plain record list)
        raise ValueError(f"{path}: unversioned legacy results; re-run the "
                         f"benchmark through `python -m repro.bench run`")
    if not isinstance(doc, dict) or "records" not in doc:
        raise ValueError(f"{path}: not a results document (no 'records')")
    version = doc.get("schema_version")
    if not isinstance(version, int) or not 1 <= version <= SCHEMA_VERSION:
        raise ValueError(
            f"{path}: results schema_version {version!r} not supported "
            f"(this reader understands 1..{SCHEMA_VERSION}); re-run the "
            f"benchmark or upgrade repro.bench")
    try:
        return [ResultRecord.from_dict(d) for d in doc["records"]]
    except ValueError as e:
        raise ValueError(f"{path}: {e}") from None
