"""ResultRecord — the normalized, schema-versioned benchmark result.

Every workload point produces exactly one record: the point, the metrics,
the labeled power source, device count, attempt count, and status. The
on-disk layout under ``artifacts/bench/<workload>/`` is

  results.json   {"schema_version": N, "workload": ..., "records": [...]}
  results.csv    flat rows (point + metrics columns), schema_version column
  manifest.json  host/jax/flags provenance (core.manifest)

written through :mod:`repro.core.results` so the files are atomic and a
partially-interrupted sweep never truncates completed points.

Schema history
--------------
  v1  point/metrics/power_source/n_devices/attempts/status/error
  v2  adds ``git_sha`` (commit of the benchmarked tree) and ``noise``
      (tolerance inputs for cross-run comparison: the relative step-time
      spread the runner's straggler watchdog observed). v1 documents load
      transparently — the new fields default to "unknown provenance" and
      comparison falls back to the per-metric base tolerance.

This module also owns the two helpers the cross-run comparison engine
(:mod:`repro.bench.compare`) joins on: the canonical :func:`point_key`
and :func:`compare_metrics` extraction with per-metric direction.
"""
from __future__ import annotations

import json
import pathlib
from dataclasses import asdict, dataclass, field, fields
from typing import Optional

from repro.core.results import atomic_write_text
from repro.power.frame import Frame

SCHEMA_VERSION = 2

#: metrics the comparison engine understands: name -> (higher_is_better,
#: default relative tolerance). Anything else a workload emits (structural
#: counts, booleans, notes) is carried in the record but not delta-gated.
COMPARED_METRICS: dict[str, tuple[bool, float]] = {
    # throughput — higher is better
    "tokens_per_s": (True, 0.20),
    "images_per_s": (True, 0.20),
    "decode_tok_s": (True, 0.20),
    "speedup_vs_fixed": (True, 0.25),
    "speedup_vs_slotted": (True, 0.25),
    # scheduler health — mean decode-step batch occupancy (active slots /
    # n_slots); a drop means admission/refill regressed even when raw
    # throughput noise hides it
    "occupancy": (True, 0.25),
    # energy efficiency — higher is better
    "tokens_per_wh": (True, 0.20),
    "images_per_wh": (True, 0.20),
    # step/latency time — lower is better
    "seconds": (False, 0.20),
    "ms_per_step": (False, 0.20),
    "ms_per_iter": (False, 0.20),
    "ms": (False, 0.20),
    "us": (False, 0.20),
    "ttft_s": (False, 0.30),
    # energy cost — lower is better
    "wh_per_token": (False, 0.25),
    "wh_per_request": (False, 0.25),
    "energy_wh_per_step": (False, 0.25),
    "energy_wh": (False, 0.25),
}


@dataclass
class ResultRecord:
    """One (workload x point) outcome in the normalized schema."""

    workload: str
    point: dict
    metrics: dict = field(default_factory=dict)
    power_source: str = "none"
    n_devices: int = 1
    attempts: int = 1
    status: str = "ok"                 # "ok" | "error" | "skipped"
    error: Optional[str] = None
    git_sha: Optional[str] = None      # commit of the benchmarked tree (v2)
    noise: dict = field(default_factory=dict)  # tolerance inputs (v2)
    schema_version: int = SCHEMA_VERSION

    @property
    def ok(self) -> bool:
        return self.status == "ok"

    @property
    def rel_std(self) -> float:
        """Recorded relative step-time spread (0.0 when not recorded)."""
        if not isinstance(self.noise, dict):
            return 0.0
        try:
            return max(float(self.noise.get("rel_std", 0.0)), 0.0)
        except (TypeError, ValueError):
            return 0.0

    def flat(self) -> dict:
        """Single-level dict for CSV/result tables: point + metrics merged,
        prefixed by the bookkeeping columns."""
        out = {"schema_version": self.schema_version,
               "workload": self.workload}
        out.update(self.point)
        out.update(self.metrics)
        out.update(power_source=self.power_source, n_devices=self.n_devices,
                   attempts=self.attempts, status=self.status)
        if self.git_sha:
            out["git_sha"] = self.git_sha
        if self.error:
            out["error"] = self.error
        return out

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ResultRecord":
        d = dict(d)
        version = d.get("schema_version", 0)
        if version > SCHEMA_VERSION or version < 1:
            raise ValueError(
                f"ResultRecord schema_version {version} not supported "
                f"(this reader understands 1..{SCHEMA_VERSION})")
        # v1 -> v2: provenance fields did not exist; dataclass defaults
        # (git_sha=None, noise={}) are the correct upconversion. Unknown
        # keys from a same-version writer are rejected loudly rather than
        # surfacing later as an opaque TypeError/KeyError.
        known = {f.name for f in fields(cls)}
        extra = set(d) - known
        if extra:
            raise ValueError(
                f"ResultRecord v{version} has unknown fields {sorted(extra)}"
                f"; known fields: {sorted(known)}")
        if d.get("noise") is None:      # hand-edited/null noise tolerated
            d["noise"] = {}
        for name in ("point", "metrics", "noise"):
            if name in d and not isinstance(d[name], dict):
                raise ValueError(
                    f"ResultRecord field {name!r} must be an object, "
                    f"got {type(d[name]).__name__}")
        try:
            return cls(**d)
        except TypeError as e:   # missing required field etc. — corrupt
            raise ValueError(f"malformed ResultRecord: {e}") from None


def point_key(rec: ResultRecord, *, with_power: bool = True) -> str:
    """Canonical join key for cross-run comparison.

    Two records describe the same measurement point iff their workload,
    Space parameters (order-insensitive), device count — and, unless
    ``with_power=False``, power source — agree. The power source is part
    of the key so RAPL-measured and synthetic-modeled energies are never
    silently diffed against each other; the power-stripped variant lets
    the compare engine *detect* that situation and flag it.
    """
    params = ",".join(f"{k}={rec.point[k]}" for k in sorted(rec.point))
    key = f"{rec.workload}|{params}|ndev={rec.n_devices}"
    if with_power:
        key += f"|power={rec.power_source}"
    return key


def compare_metrics(rec: ResultRecord) -> dict[str, float]:
    """The subset of a record's metrics the comparison engine delta-gates,
    as floats, in ``COMPARED_METRICS`` order."""
    out = {}
    for name in COMPARED_METRICS:
        if name in rec.metrics:
            try:
                out[name] = float(rec.metrics[name])
            except (TypeError, ValueError):
                continue
    return out


def metric_direction(name: str) -> bool:
    """True when higher values of ``name`` are better."""
    return COMPARED_METRICS[name][0]


def metric_tolerance(name: str) -> float:
    """Default relative tolerance for ``name``."""
    return COMPARED_METRICS[name][1]


def result_doc(records: list) -> dict:
    """The on-disk results/baseline document for a record list."""
    workload = records[0].workload if records else ""
    return {"schema_version": SCHEMA_VERSION, "workload": workload,
            "records": [r.to_dict() for r in records]}


def write_result_doc(records: list, path) -> None:
    """Atomically write the schema-versioned JSON document (no CSV)."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    atomic_write_text(path, json.dumps(result_doc(records), indent=1,
                                       default=str))


def save_records(records: list, out_dir, name: str = "results") -> None:
    """Write the schema-versioned JSON + flat CSV pair (atomically)."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    write_result_doc(records, out / f"{name}.json")
    atomic_write_text(out / f"{name}.csv",
                      Frame.from_records([r.flat() for r in records]).to_csv())


def load_records(path) -> list:
    """Read a results.json back into ResultRecords (version-checked).

    Rejects unversioned/foreign documents and unsupported versions with a
    ValueError naming the file — the reader must never degrade into a
    KeyError deep inside rendering or comparison.
    """
    path = pathlib.Path(path)
    doc = json.loads(path.read_text())
    if isinstance(doc, list):   # pre-schema layout (plain record list)
        raise ValueError(f"{path}: unversioned legacy results; re-run the "
                         f"benchmark through `python -m repro.bench run`")
    if not isinstance(doc, dict) or "records" not in doc:
        raise ValueError(f"{path}: not a results document (no 'records')")
    version = doc.get("schema_version")
    if not isinstance(version, int) or not 1 <= version <= SCHEMA_VERSION:
        raise ValueError(
            f"{path}: results schema_version {version!r} not supported "
            f"(this reader understands 1..{SCHEMA_VERSION}); re-run the "
            f"benchmark or upgrade repro.bench")
    try:
        return [ResultRecord.from_dict(d) for d in doc["records"]]
    except ValueError as e:
        raise ValueError(f"{path}: {e}") from None
