"""``python -m repro.bench`` — the single CLI for every benchmark.

  python -m repro.bench list [--tags t1,t2]
  python -m repro.bench run [--suite a,b] [--tags smoke] [--points k=v,...]
                            [--power auto|rapl|tpu_model|synthetic|none]
                            [--warmup N] [--iters N] [--out DIR]
  python -m repro.bench report [--suite a,b] [--out DIR]
  python -m repro.bench compare BASELINE CURRENT [--fail-on-regression]
                            [--fail-on-missing] [--promote]
                            [--rel-tol m=0.1,default=0.3] [--report md|csv]
                            [--report-out FILE] [--suite a,b]

Replaces the old per-benchmark subprocess driver: one process runs every
selected workload, sharing the jax runtime. Multi-device workloads are
satisfied by configuring the host platform device count up front —
sized to the largest mesh any selected point's ``placement`` needs
(capped at ``REPRO_MAX_LOCAL_DEVICES``, default 8) — in-process where
the jax version supports it, otherwise by re-exec'ing once with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set before the
backend initializes. A placement beyond the cap is not an error: the
runner records those points as ``deferred`` with a rendered
``launch.slurm`` job script sized to the mesh.

Each record also prints the classic ``name,us_per_call,derived`` CSV
line, so existing log scrapers keep working.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import subprocess
import sys
from typing import Optional, Sequence

from repro.bench import envtune
from repro.bench import workloads  # noqa: F401 - populates the registry
from repro.bench.compare import (
    MISSING, NOISE_K, POWER_MISMATCH, compare_sets, load_result_set,
    promote,
)
from repro.bench.records import load_records
from repro.bench.runner import WorkloadRunner
from repro.bench.spec import (
    UnknownWorkloadError, get_workload, iter_workloads,
)
from repro.core.results import heatmap, table

_REEXEC_MARKER = "REPRO_BENCH_REEXEC"
_FORCE_FLAG = "--xla_force_host_platform_device_count"
#: ceiling on forced host-platform devices — a dp64 placement point must
#: defer to a rendered Slurm job, not fork 64 CPU "devices"
_LOCAL_DEVICE_CAP_ENV = "REPRO_MAX_LOCAL_DEVICES"
_LOCAL_DEVICE_CAP = 8


def local_device_cap() -> int:
    try:
        return int(os.environ.get(_LOCAL_DEVICE_CAP_ENV,
                                  _LOCAL_DEVICE_CAP))
    except ValueError:
        return _LOCAL_DEVICE_CAP


def _parse_points(s: Optional[str]) -> Optional[dict]:
    """``k=v,k2=v2`` -> axis overrides, values coerced to int/float."""
    if not s:
        return None
    out: dict = {}
    for part in s.split(","):
        if "=" not in part:
            raise SystemExit(f"--points: expected k=v, got {part!r}")
        k, v = part.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        out.setdefault(k.strip(), []).append(v)
    return out


def _parse_list(s: Optional[str]) -> Optional[list[str]]:
    return [x.strip() for x in s.split(",") if x.strip()] if s else None


def _select(args) -> list:
    try:
        return iter_workloads(names=_parse_list(args.suite),
                              tags=_parse_list(args.tags))
    except UnknownWorkloadError as e:
        raise SystemExit(f"error: {e}")


def ensure_devices(needed: int, argv: Sequence[str]) -> Optional[int]:
    """Make >= ``needed`` jax devices available to this run, with any
    opt-in environment tuning (``envtune``: tcmalloc preload, XLA step
    marker) applied.

    Returns None when the current process can proceed; otherwise re-execs
    the CLI once with the host platform device count forced via XLA_FLAGS
    and/or the tuned environment prepared (both must land before the
    dynamic loader / jax backend init in the child) and returns its exit
    code.
    """
    tuning = envtune.pending()
    if needed <= 1 and not tuning:
        return None
    import jax
    if needed > 1:
        try:
            # newer jax: in-process host-platform config (pre-backend-init)
            jax.config.update("jax_num_cpu_devices", needed)
        except Exception:  # noqa: BLE001 - option missing or backend is up
            pass
    if jax.device_count() >= needed and not tuning:
        return None
    if os.environ.get(_REEXEC_MARKER):
        if jax.device_count() >= needed:
            return None   # tuning was applied by the exec that got us here
        raise SystemExit(
            f"error: {needed} devices required but only "
            f"{jax.device_count()} available even after forcing "
            f"the host platform device count")
    env = dict(os.environ)
    if jax.device_count() < needed:
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + f" {_FORCE_FLAG}={needed}").strip()
    env = envtune.apply(env) if tuning else env
    env[_REEXEC_MARKER] = "1"
    # the child must find repro even when the parent got it via sys.path
    src_dir = str(pathlib.Path(__file__).resolve().parents[2])
    env["PYTHONPATH"] = ":".join(
        p for p in (src_dir, env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.bench", *argv], env=env)
    return proc.returncode


def _emit_lines(spec, records) -> None:
    """The classic ``name,us_per_call,derived`` CSV contract."""
    for rec in records:
        if not rec.ok:
            continue
        pt = "/".join(f"{k}={v}" for k, v in rec.point.items())
        us = float(rec.metrics.get("seconds", 0.0)) * 1e6
        derived = ""
        if spec.primary_metric and spec.primary_metric in rec.metrics:
            derived = (f"{spec.primary_metric}="
                       f"{rec.metrics[spec.primary_metric]:.4g}")
        print(f"{spec.name}/{pt},{us:.1f},{derived}")


def _render(spec, records) -> None:
    flat = [r.flat() for r in records]
    print(table(flat, spec.result_columns, floatfmt="{:.4g}"))
    if spec.heatmap_keys:
        row, col, val = spec.heatmap_keys
        ok = [f for f in flat if val in f]
        if ok:
            print(heatmap(ok, row, col, val))


def cmd_list(args) -> int:
    specs = _select(args)
    rows = [{"workload": s.name, "placement": s.placement.label,
             "devices": s.max_devices(),
             "points": len(s.space),
             "tags": ",".join(sorted(s.tags)),
             "paper_analog": s.analog} for s in specs]
    print(table(rows))
    return 0


def cmd_run(args, argv: Sequence[str]) -> int:
    specs = _select(args)
    if not specs:
        print("no workloads selected")
        return 0
    smoke = "smoke" in (_parse_list(args.tags) or [])
    overrides = _parse_points(args.points)

    def devices_for(s) -> int:
        try:
            return s.max_devices(smoke, overrides)
        except KeyError:
            # an override axis foreign to this workload fails later with
            # a precise error; device sizing must not mask it
            return s.max_devices(smoke)

    needed = max(devices_for(s) for s in specs)
    rc = ensure_devices(min(needed, local_device_cap()), argv)
    if rc is not None:
        return rc
    failures = []
    for spec in specs:
        print(f"\n###### {spec.name} — {spec.analog} ######", flush=True)
        runner = WorkloadRunner(
            spec, out_dir=args.out, power=args.power,
            warmup=args.warmup, iters=args.iters, smoke=smoke,
            point_overrides=overrides,
            retries=args.retries)
        records = runner.run(verbose=args.verbose)
        _render(spec, records)
        _emit_lines(spec, records)
        for r in records:
            if r.status == "deferred":
                print(f"DEFERRED: {spec.name} {r.point}: "
                      f"{r.metrics.get('slurm_script', '(no script)')}")
        bad = [r for r in records if r.status == "error"]
        if bad:
            failures.append(spec.name)
            for r in bad:
                print(f"FAILED: {spec.name} {r.point}: {r.error}",
                      file=sys.stderr)
    if failures:
        print(f"\nbenchmark failures: {failures}", file=sys.stderr)
        return 1
    print("\nall benchmarks complete")
    return 0


def cmd_report(args) -> int:
    out = pathlib.Path(args.out)
    names = _parse_list(args.suite) or sorted(
        p.parent.name for p in out.glob("*/results.json"))
    shown, bad = 0, 0
    for name in names:
        path = out / name / "results.json"
        if not path.exists():
            print(f"(no results for {name!r} under {out})")
            continue
        try:
            spec = get_workload(name)
        except UnknownWorkloadError:
            spec = None
        try:
            records = load_records(path)
        except ValueError as e:
            # schema mismatch or foreign document: a clear diagnosis, not
            # a KeyError mid-render — and a nonzero exit for scripts
            print(f"error: {e}", file=sys.stderr)
            bad += 1
            continue
        print(f"\n###### {name} ######")
        if spec is not None:
            _render(spec, records)
        else:
            print(table([r.flat() for r in records], floatfmt="{:.4g}"))
        shown += 1
    if bad:
        return 2
    return 0 if shown or not names else 1


def _parse_tols(s: Optional[str]) -> Optional[dict]:
    """``metric=0.1,default=0.3`` -> per-metric tolerance overrides."""
    if not s:
        return None
    out = {}
    for part in s.split(","):
        if "=" not in part:
            raise SystemExit(f"--rel-tol: expected metric=float, "
                             f"got {part!r}")
        k, v = part.split("=", 1)
        try:
            tol = float(v)
        except ValueError:
            raise SystemExit(f"--rel-tol: {v!r} is not a float") from None
        if tol < 0.0:
            raise SystemExit(f"--rel-tol: {k.strip()}={tol} — tolerances "
                             f"must be >= 0")
        out[k.strip()] = tol
    return out


def cmd_compare(args) -> int:
    try:
        base = load_result_set(args.baseline)
        cur = load_result_set(args.current)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    suites = _parse_list(args.suite)
    if suites:
        base = [r for r in base if r.workload in suites]
        cur = [r for r in cur if r.workload in suites]
    if not cur:
        # a typo'd run dir must not read as "nothing regressed"; only an
        # unpromoted *baseline* store may legitimately be empty
        print(f"error: no results found at {args.current!r} — nothing to "
              f"compare", file=sys.stderr)
        return 2
    if not base:
        print(f"warning: empty baseline set at {args.baseline!r} "
              f"(promote one with `compare ... --promote`)",
              file=sys.stderr)
    cmp = compare_sets(base, cur, tols=_parse_tols(args.rel_tol),
                       noise_k=args.noise_k,
                       baseline_label=str(args.baseline),
                       current_label=str(args.current))
    report = (cmp.to_csv() if args.report == "csv"
              else cmp.to_markdown(all_points=args.all_points))
    if args.report_out:
        out_path = pathlib.Path(args.report_out)
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(report)
        print(f"report written to {args.report_out}")
    print(report)
    print(cmp.summary())
    if args.promote:
        store = pathlib.Path(args.baseline)
        if store.is_file():
            print("error: --promote needs a baseline *directory* to "
                  "write <workload>.json files into", file=sys.stderr)
            return 2
        written = promote(cur, store)
        for p in written:
            print(f"promoted baseline: {p}")
        skipped = {r.workload for r in cur} - \
            {r.workload for r in cur if r.ok}
        for name in sorted(skipped):
            print(f"warning: {name!r} NOT promoted (no ok-status "
                  f"records); its previous baseline, if any, still "
                  f"stands", file=sys.stderr)
        # a renamed/removed workload leaves its old baseline behind, which
        # would fail --fail-on-missing forever; name the file to delete.
        # (Suppressed under --suite: a filtered run legitimately omits
        # every other workload's baseline.)
        if not suites:
            current_wl = {r.workload for r in cur}
            for f in sorted(store.glob("*.json")):
                if f.stem not in current_wl and f.name != "manifest.json":
                    print(f"warning: baseline {f} has no workload in the "
                          f"current run — delete it if the workload was "
                          f"removed or renamed", file=sys.stderr)
    rc = cmp.exit_code(fail_on_regression=args.fail_on_regression,
                       fail_on_missing=args.fail_on_missing)
    if rc:
        # name only the points the active gate flags actually counted —
        # an ungated status in a GATE line sends readers chasing the
        # wrong failure cause
        gated = []
        if args.fail_on_regression:
            gated += cmp.regressions + cmp.by_status(POWER_MISMATCH)
        if args.fail_on_missing:
            gated += cmp.by_status(MISSING)
        for p in gated:
            print(f"GATE: {p.status}: {p.key}"
                  + (f" ({p.note})" if p.note else ""), file=sys.stderr)
    return rc


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench",
        description="CARAML-style benchmark suite: one registry, one "
                    "runner, one CLI for every paper workload.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_list = sub.add_parser("list", help="show registered workloads")
    p_list.add_argument("--suite", help="comma-separated workload names")
    p_list.add_argument("--tags", help="filter by tags (OR)")

    p_run = sub.add_parser("run", help="run selected workloads")
    p_run.add_argument("--suite", help="comma-separated workload names "
                                       "(default: all)")
    p_run.add_argument("--tags", help="select by tags (OR); 'smoke' also "
                                      "switches to the reduced point sets")
    p_run.add_argument("--points", help="axis overrides, k=v,k2=v2 "
                                        "(repeat k for multiple values)")
    p_run.add_argument("--power", default="auto",
                       choices=["auto", "rapl", "tpu_model", "synthetic",
                                "none"],
                       help="power backend (default: auto = RAPL -> "
                            "TPU-model -> synthetic)")
    p_run.add_argument("--warmup", type=int, default=1)
    p_run.add_argument("--iters", type=int, default=3)
    p_run.add_argument("--retries", type=int, default=1)
    p_run.add_argument("--out", default="artifacts/bench")
    p_run.add_argument("--quiet", dest="verbose", action="store_false")

    p_rep = sub.add_parser("report", help="render saved results")
    p_rep.add_argument("--suite", help="comma-separated workload names")
    p_rep.add_argument("--out", default="artifacts/bench")

    p_cmp = sub.add_parser(
        "compare", help="diff two result sets by point key (the JUBE "
                        "`result --compare` analog)")
    p_cmp.add_argument("baseline", help="baseline store dir, run dir, or "
                                        "results.json")
    p_cmp.add_argument("current", help="run dir or results.json to judge")
    p_cmp.add_argument("--suite", help="restrict to these workloads")
    p_cmp.add_argument("--rel-tol",
                       help="tolerance overrides, metric=0.1,...; the key "
                            "'default' replaces every base tolerance")
    p_cmp.add_argument("--noise-k", type=float, default=NOISE_K,
                       help="multiplier on the recorded step-time spread "
                            "when widening tolerances (default %(default)s)")
    p_cmp.add_argument("--fail-on-regression", action="store_true",
                       help="exit nonzero when any point regressed (or "
                            "was measured with a different power source)")
    p_cmp.add_argument("--fail-on-missing", action="store_true",
                       help="exit nonzero when a baseline point is absent "
                            "from the current run")
    p_cmp.add_argument("--promote", action="store_true",
                       help="write the current records into the baseline "
                            "store directory (one <workload>.json each)")
    p_cmp.add_argument("--report", choices=["md", "csv"], default="md")
    p_cmp.add_argument("--report-out", help="also write the report here")
    p_cmp.add_argument("--all-points", action="store_true",
                       help="include unchanged points in the md report")

    args = ap.parse_args(argv)
    if args.cmd == "list":
        return cmd_list(args)
    if args.cmd == "run":
        return cmd_run(args, argv)
    if args.cmd == "compare":
        return cmd_compare(args)
    return cmd_report(args)
