"""repro.bench — the unified WorkloadSpec benchmark subsystem.

One registry, one runner, one CLI for every paper workload:

  python -m repro.bench list
  python -m repro.bench run --tags smoke
  python -m repro.bench run --suite serve --points rate_hz=200
  python -m repro.bench report

Benchmarks declare a :class:`WorkloadSpec` via the :func:`workload`
decorator (see ``repro.bench.workloads``); :class:`WorkloadRunner`
executes them with runner-owned power selection, warmup/iters timing,
retries, and straggler detection, emitting schema-versioned
:class:`ResultRecord`s under ``artifacts/bench/<workload>/``.
"""
from repro.bench.compare import (
    Comparison, MetricDelta, PointComparison, compare_sets,
    load_result_set, promote,
)
from repro.bench.context import Measurement, RunContext
from repro.bench.records import (
    COMPARED_METRICS, SCHEMA_VERSION, ResultRecord, compare_metrics,
    load_records, placement_label, point_key, save_records,
    scaling_floor_violations, stamp_scaling_metrics,
)
from repro.bench.runner import WorkloadRunner
from repro.bench.spec import (
    Placement, UnknownWorkloadError, WorkloadSpec, get_workload,
    iter_workloads, register, unregister, workload, workload_names,
)

__all__ = [
    "Comparison", "MetricDelta", "PointComparison", "compare_sets",
    "load_result_set", "promote",
    "Measurement", "RunContext", "COMPARED_METRICS", "SCHEMA_VERSION",
    "ResultRecord", "compare_metrics", "load_records", "placement_label",
    "point_key", "save_records", "scaling_floor_violations",
    "stamp_scaling_metrics", "WorkloadRunner",
    "Placement", "UnknownWorkloadError", "WorkloadSpec", "get_workload",
    "iter_workloads", "register", "unregister", "workload",
    "workload_names",
]
