"""WorkloadSpec — the benchmark-facing declarative API.

A workload is one paper table/figure: a name, its paper analog, a
parameter ``Space``, the device count it needs, selection tags, and a
``build(point, ctx) -> {step_name: thunk}`` factory. Registration via the
``@workload`` decorator puts it in the global registry that the single
CLI (``python -m repro.bench``) and ``WorkloadRunner`` drive — the suite
half of CARAML's "compact, automated, extensible, reproducible" claim.

``build`` is called once per expanded point with a ``RunContext`` and
returns an ordered mapping of named zero-arg step thunks, each producing
a metrics dict. Cross-point state (configs, params, jitted programs)
lives in ``ctx.memo`` so sweeps compile once; timing/energy plumbing is
``ctx.measure`` — owned by the runner, not the workload.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Optional

from repro.core.params import Space

# step thunk: () -> metrics dict;  build: (point, ctx) -> {name: thunk}
StepFns = Dict[str, Callable[[], dict]]
BuildFn = Callable[[dict, "object"], StepFns]

#: tags with agreed meaning; workloads may add their own on top.
KNOWN_TAGS = ("smoke", "full", "train", "serve", "vision", "kernels",
              "analysis")


class UnknownWorkloadError(KeyError):
    """Raised when a suite name is not in the registry."""

    def __init__(self, name: str, known: Iterable[str]):
        super().__init__(name)
        self.name = name
        self.known = sorted(known)

    def __str__(self) -> str:
        return (f"unknown workload {self.name!r}; registered: "
                f"{', '.join(self.known) or '(none)'}")


@dataclass(frozen=True)
class WorkloadSpec:
    """Declarative description of one benchmark workload."""

    name: str
    analog: str                       # the paper table/figure it reproduces
    space: Space                      # full-run parameter space
    build: BuildFn
    n_devices: int = 1                # jax devices the workload requires
    tags: frozenset = frozenset()
    smoke_axes: Optional[dict] = None  # axis overrides for smoke runs
    result_columns: Optional[list] = None
    primary_metric: Optional[str] = None  # headline column for emit lines
    heatmap_keys: Optional[tuple] = None  # (row, col, val) -> render heatmap
    #: per-metric relative-tolerance overrides for cross-run comparison
    #: ("default" rekeys them all; inf exempts — e.g. a CPU interpret-mode
    #: microbench whose absolute timings are not gateable). The runner
    #: stamps these into each record so `compare` needs no registry.
    compare_tols: Optional[dict] = None
    description: str = ""

    def space_for(self, smoke: bool = False,
                  overrides: Optional[dict] = None) -> Space:
        """The parameter space to run: full axes, narrowed by the smoke
        preset and/or explicit ``--points`` overrides (constraints kept)."""
        axes = dict(self.space.axes)
        if smoke and self.smoke_axes:
            axes.update(self.smoke_axes)
        for k, v in (overrides or {}).items():
            if k not in axes:
                raise KeyError(f"workload {self.name!r} has no axis {k!r}; "
                               f"axes: {sorted(axes)}")
            axes[k] = list(v) if isinstance(v, (list, tuple)) else [v]
        return Space(axes, list(self.space.constraints))

    def matches(self, tags: Optional[Iterable[str]]) -> bool:
        """OR-selection: any requested tag present selects the workload."""
        if not tags:
            return True
        return bool(self.tags & set(tags))


_REGISTRY: dict[str, WorkloadSpec] = {}


def register(spec: WorkloadSpec) -> WorkloadSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"workload {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def workload(name: str, *, analog: str, space: Space, n_devices: int = 1,
             tags: Iterable[str] = (), smoke: Optional[dict] = None,
             result_columns: Optional[list] = None,
             primary_metric: Optional[str] = None,
             heatmap_keys: Optional[tuple] = None,
             compare_tols: Optional[dict] = None):
    """Decorator: register ``build(point, ctx)`` as a WorkloadSpec."""

    def deco(build: BuildFn) -> WorkloadSpec:
        return register(WorkloadSpec(
            name=name, analog=analog, space=space, build=build,
            n_devices=n_devices, tags=frozenset(tags), smoke_axes=smoke,
            result_columns=result_columns, primary_metric=primary_metric,
            heatmap_keys=heatmap_keys, compare_tols=compare_tols,
            description=(build.__doc__ or "").strip().splitlines()[0]
            if build.__doc__ else ""))

    return deco


def get_workload(name: str) -> WorkloadSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownWorkloadError(name, _REGISTRY) from None


def workload_names() -> list[str]:
    return sorted(_REGISTRY)


def iter_workloads(names: Optional[Iterable[str]] = None,
                   tags: Optional[Iterable[str]] = None,
                   ) -> list[WorkloadSpec]:
    """Select workloads by explicit names and/or tags (names validate)."""
    if names:
        specs = [get_workload(n) for n in names]
    else:
        specs = [_REGISTRY[n] for n in sorted(_REGISTRY)]
    return [s for s in specs if s.matches(tags)]


def unregister(name: str) -> None:
    """Testing hook: remove a workload (no-op if absent)."""
    _REGISTRY.pop(name, None)
