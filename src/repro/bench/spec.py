"""WorkloadSpec — the benchmark-facing declarative API.

A workload is one paper table/figure: a name, its paper analog, a
parameter ``Space``, the device mesh it needs (a :class:`Placement`),
selection tags, and a ``build(point, ctx) -> {step_name: thunk}``
factory. Registration via the ``@workload`` decorator puts it in the
global registry that the single CLI (``python -m repro.bench``) and
``WorkloadRunner`` drive — the suite half of CARAML's "compact,
automated, extensible, reproducible" claim.

``build`` is called once per expanded point with a ``RunContext`` and
returns an ordered mapping of named zero-arg step thunks, each producing
a metrics dict. Cross-point state (configs, params, jitted programs)
lives in ``ctx.memo`` so sweeps compile once; timing/energy plumbing is
``ctx.measure`` — owned by the runner, not the workload.

Placement
---------
CARAML's headline measurement is how throughput *and* energy scale as a
workload spreads across more accelerators, so device placement is a
first-class sweep dimension, not a scalar: a :class:`Placement` names a
mesh shape by parallelism axis (``{"dp": 4}``, ``{"dp": 2, "tp": 2}``,
``{"pp": 4}``). A workload declares its default placement on the spec
(scalar ``n_devices`` ints still accepted and upconverted to pure data
parallel) and may additionally expose ``placement`` as an ordinary
``Space`` axis — a scaling sweep is then just another axis of the point
space, and the runner resolves each point's mesh via
:meth:`WorkloadSpec.placement_for`.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Callable, Dict, Iterable, Optional, Union

from repro.core.params import Space

# step thunk: () -> metrics dict;  build: (point, ctx) -> {name: thunk}
StepFns = Dict[str, Callable[[], dict]]
BuildFn = Callable[[dict, "object"], StepFns]

#: tags with agreed meaning; workloads may add their own on top.
KNOWN_TAGS = ("smoke", "full", "train", "serve", "vision", "kernels",
              "analysis")

#: placement axis -> jax mesh axis name (the names the sharding rules in
#: repro.parallel.sharding key on; unknown axes pass through unchanged)
MESH_AXIS_NAMES = {"dp": "data", "tp": "model", "pp": "stage", "pod": "pod"}
#: canonical placement axis order — fixes the mesh's device-major order
#: (lowest-bandwidth/lowest-frequency collective axes first) and makes
#: every spelling of the same mesh produce one canonical label
_AXIS_ORDER = ("pod", "dp", "tp", "pp")

_PLACEMENT_RE = re.compile(r"([a-zA-Z]+)\s*=?\s*(\d+)")


@dataclass(frozen=True)
class Placement:
    """A device mesh shape, named by parallelism axis.

    ``axes`` is a canonically-ordered tuple of ``(axis, size)`` pairs —
    construct through :meth:`of`, which normalizes every accepted
    spelling (int, ``"dp2tp2"``-style label, dict, Placement) to the
    same value, so placements compare/hash by meaning, not by spelling.
    """

    axes: tuple  # ((axis, size), ...) in canonical axis order

    @classmethod
    def of(cls, value: Union[int, str, dict, "Placement", None],
           ) -> "Placement":
        """Normalize any accepted placement spelling.

        int ``n`` -> pure data parallel ``{"dp": n}`` (the scalar
        ``n_devices`` upconversion); str -> parsed label (``"dp4"``,
        ``"dp2tp2"``, ``"dp=2,tp=2"``); dict -> axis sizes.
        """
        if isinstance(value, Placement):
            return value
        if value is None:
            value = 1
        if isinstance(value, int):
            if value < 1:
                raise ValueError(f"placement needs >= 1 device, got {value}")
            value = {"dp": value}
        if isinstance(value, str):
            pairs = _PLACEMENT_RE.findall(value)
            if not pairs or "".join(a + n for a, n in pairs) != re.sub(
                    r"[\s,=]", "", value):
                raise ValueError(
                    f"cannot parse placement {value!r}; expected e.g. "
                    f"'dp4', 'dp2tp2', or 'dp=2,tp=2'")
            value = {}
            for a, n in pairs:
                if a in value:
                    raise ValueError(f"placement {pairs} repeats axis {a!r}")
                value[a] = int(n)
        if not isinstance(value, dict) or not value:
            raise TypeError(f"cannot interpret placement from "
                            f"{type(value).__name__}: {value!r}")
        for a, n in value.items():
            if not isinstance(n, int) or n < 1:
                raise ValueError(f"placement axis {a!r} must be a positive "
                                 f"int, got {n!r}")
        order = {a: i for i, a in enumerate(_AXIS_ORDER)}
        names = sorted(value, key=lambda a: (order.get(a, len(order)), a))
        return cls(axes=tuple((a, int(value[a])) for a in names))

    @property
    def n_devices(self) -> int:
        n = 1
        for _, size in self.axes:
            n *= size
        return n

    @property
    def label(self) -> str:
        """Canonical compact spelling, e.g. ``"dp2tp2"`` — the value a
        ``placement`` Space axis carries and the point-key component."""
        return "".join(f"{a}{n}" for a, n in self.axes)

    def dict(self) -> dict:
        return dict(self.axes)

    def _mesh_entries(self) -> tuple:
        """(jax axis name, size) pairs. The "data" and "model" axes are
        always present (size 1 when the placement doesn't use them) —
        the table-driven sharding rules in ``repro.parallel.sharding``
        name them unconditionally, and a size-1 axis is a free no-op."""
        sizes = self.dict()
        entries = []
        if "pod" in sizes:
            entries.append(("pod", sizes.pop("pod")))
        entries.append(("data", sizes.pop("dp", 1)))
        entries.append(("model", sizes.pop("tp", 1)))
        if "pp" in sizes:
            entries.append(("stage", sizes.pop("pp")))
        for a in sorted(sizes):     # unknown axes pass through by name
            entries.append((MESH_AXIS_NAMES.get(a, a), sizes[a]))
        return tuple(entries)

    @property
    def mesh_shape(self) -> tuple:
        return tuple(n for _, n in self._mesh_entries())

    @property
    def mesh_axes(self) -> tuple:
        """jax mesh axis names (duck-typed by ``launch.mesh.mesh_for``)."""
        return tuple(a for a, _ in self._mesh_entries())

    def __str__(self) -> str:
        return self.label


class UnknownWorkloadError(KeyError):
    """Raised when a suite name is not in the registry."""

    def __init__(self, name: str, known: Iterable[str]):
        super().__init__(name)
        self.name = name
        self.known = sorted(known)

    def __str__(self) -> str:
        return (f"unknown workload {self.name!r}; registered: "
                f"{', '.join(self.known) or '(none)'}")


@dataclass(frozen=True)
class WorkloadSpec:
    """Declarative description of one benchmark workload."""

    name: str
    analog: str                       # the paper table/figure it reproduces
    space: Space                      # full-run parameter space
    build: BuildFn
    #: default device mesh; a ``placement`` Space axis overrides per point
    placement: Placement = Placement.of(1)
    tags: frozenset = frozenset()
    smoke_axes: Optional[dict] = None  # axis overrides for smoke runs
    result_columns: Optional[list] = None
    primary_metric: Optional[str] = None  # headline column for emit lines
    heatmap_keys: Optional[tuple] = None  # (row, col, val) -> render heatmap
    #: per-metric relative-tolerance overrides for cross-run comparison
    #: ("default" rekeys them all; inf exempts — e.g. a CPU interpret-mode
    #: microbench whose absolute timings are not gateable). The runner
    #: stamps these into each record so `compare` needs no registry.
    compare_tols: Optional[dict] = None
    description: str = ""

    @property
    def n_devices(self) -> int:
        """Device floor of the default placement (scalar back-compat)."""
        return self.placement.n_devices

    def placement_for(self, pt: dict) -> Placement:
        """The resolved mesh for one expanded point: the ``placement``
        axis when the Space carries one, else the spec default."""
        return Placement.of(pt.get("placement", self.placement))

    def max_devices(self, smoke: bool = False,
                    overrides: Optional[dict] = None) -> int:
        """Largest device count any point of the selected space needs —
        what the CLI sizes the forced host platform to."""
        points = self.space_for(smoke, overrides).expand()
        if not points:
            return self.placement.n_devices
        return max(self.placement_for(pt).n_devices for pt in points)

    def space_for(self, smoke: bool = False,
                  overrides: Optional[dict] = None) -> Space:
        """The parameter space to run: full axes, narrowed by the smoke
        preset and/or explicit ``--points`` overrides (constraints kept)."""
        axes = dict(self.space.axes)
        if smoke and self.smoke_axes:
            axes.update(self.smoke_axes)
        for k, v in (overrides or {}).items():
            if k not in axes:
                raise KeyError(f"workload {self.name!r} has no axis {k!r}; "
                               f"axes: {sorted(axes)}")
            axes[k] = list(v) if isinstance(v, (list, tuple)) else [v]
        return Space(axes, list(self.space.constraints))

    def matches(self, tags: Optional[Iterable[str]]) -> bool:
        """OR-selection: any requested tag present selects the workload."""
        if not tags:
            return True
        return bool(self.tags & set(tags))


_REGISTRY: dict[str, WorkloadSpec] = {}


def register(spec: WorkloadSpec) -> WorkloadSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"workload {spec.name!r} already registered")
    _REGISTRY[spec.name] = spec
    return spec


def workload(name: str, *, analog: str, space: Space,
             placement: Union[int, str, dict, Placement, None] = None,
             n_devices: Optional[int] = None,
             tags: Iterable[str] = (), smoke: Optional[dict] = None,
             result_columns: Optional[list] = None,
             primary_metric: Optional[str] = None,
             heatmap_keys: Optional[tuple] = None,
             compare_tols: Optional[dict] = None):
    """Decorator: register ``build(point, ctx)`` as a WorkloadSpec.

    ``placement`` names the default device mesh (``{"dp": 2, "tp": 2}``,
    ``"pp4"``, ...); the legacy scalar ``n_devices`` keyword upconverts
    to pure data parallel. Passing both is a contradiction and rejected.
    """
    if placement is not None and n_devices is not None:
        raise ValueError(f"workload {name!r}: pass placement OR n_devices, "
                         f"not both")

    def deco(build: BuildFn) -> WorkloadSpec:
        return register(WorkloadSpec(
            name=name, analog=analog, space=space, build=build,
            placement=Placement.of(n_devices if placement is None
                                   else placement),
            tags=frozenset(tags), smoke_axes=smoke,
            result_columns=result_columns, primary_metric=primary_metric,
            heatmap_keys=heatmap_keys, compare_tols=compare_tols,
            description=(build.__doc__ or "").strip().splitlines()[0]
            if build.__doc__ else ""))

    return deco


def get_workload(name: str) -> WorkloadSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownWorkloadError(name, _REGISTRY) from None


def workload_names() -> list[str]:
    return sorted(_REGISTRY)


def iter_workloads(names: Optional[Iterable[str]] = None,
                   tags: Optional[Iterable[str]] = None,
                   ) -> list[WorkloadSpec]:
    """Select workloads by explicit names and/or tags (names validate)."""
    if names:
        specs = [get_workload(n) for n in names]
    else:
        specs = [_REGISTRY[n] for n in sorted(_REGISTRY)]
    return [s for s in specs if s.matches(tags)]


def unregister(name: str) -> None:
    """Testing hook: remove a workload (no-op if absent)."""
    _REGISTRY.pop(name, None)
