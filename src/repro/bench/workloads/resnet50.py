"""Paper Fig. 3 / Table III analog: ResNet50 training throughput + energy.

images/s and images/Wh across a batch x placement sweep, using the
data-parallel train step (the Horovod-analog path): a ``dp``-axis
placement shards the image batch over the mesh's data axes while the
parameters replicate — the gradient all-reduce GSPMD inserts is exactly
Horovod's — and the AdamW state still ZeRO-1-shards over whatever axes
divide it. The runner derives the scaling metrics (images-per-device
throughput as ``tok_s_per_device``, ``scaling_efficiency``,
``wh_per_token_scaling``) against the dp1 cell of the same sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.spec import workload
from repro.configs.resnet50 import CONFIG
from repro.core.metrics import images_per_s
from repro.core.params import Space
from repro.data.synthetic import synthetic_images
from repro.models import resnet
from repro.parallel import sharding as shd
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import make_resnet_train_step


def _base_state(ctx):
    def make():
        c = CONFIG.reduced(img_size=64, width=16)
        oc = OptConfig(warmup=2, total_steps=1000)
        params = resnet.init(jax.random.key(0), c)
        opt_state = opt_init(oc, params)
        return c, oc, params, opt_state

    return ctx.memo("resnet50", make)


def _placed(ctx):
    """DP-plan-placed train state + jitted step for one placement."""
    placement = ctx.placement

    def make():
        c, oc, params, opt_state = _base_state(ctx)
        plan = shd.make_dp_plan(ctx.mesh())
        params_s, opt_s, psh, osh, _ = shd.shard_train_state(
            plan, params, opt_state)
        # pin output shardings + donate: without the pin the returned
        # params' layout drifts from the placed inputs and every call
        # after the first recompiles (the dp-scaling collapse)
        step = jax.jit(make_resnet_train_step(c, oc),
                       out_shardings=(psh, osh, None),
                       donate_argnums=(0, 1))
        return c, plan, params_s, opt_s, psh, osh, step

    return ctx.memo(("resnet50_placed", placement.label), make)


@workload(
    "resnet50",
    analog="Fig. 3 / Table III (ResNet50 images/s + energy, dp-scaled)",
    space=Space({"global_batch": [16, 32, 64],
                 "placement": ["dp1", "dp2", "dp4"]}),
    smoke={"global_batch": [8], "placement": ["dp1", "dp2"]},
    tags=("vision", "train", "smoke", "full"),
    result_columns=["global_batch", "placement", "images_per_s",
                    "tok_s_per_device", "scaling_efficiency",
                    "ms_per_step", "energy_wh_per_step", "images_per_wh",
                    "wh_per_token_scaling", "power_source"],
    primary_metric="images_per_s",
)
def build(pt, ctx):
    """ResNet50 train-step sweep over global batch x device placement."""
    c, plan, params, opt_state, psh, osh, step = _placed(ctx)
    gb = pt["global_batch"]
    imgs, labels = synthetic_images(gb, c.img_size, c.n_classes)
    batch = {"images": jnp.asarray(imgs), "labels": jnp.asarray(labels)}
    batch = jax.device_put(
        batch, {k: shd.batch_sharding(plan, v.shape)
                for k, v in batch.items()})

    def train():
        # donated buffers: give each thunk its own copies so the
        # memoized state survives retries and later points
        p = jax.device_put(jax.tree.map(jnp.copy, params), psh)
        o = jax.device_put(jax.tree.map(jnp.copy, opt_state), osh)

        def one():
            nonlocal p, o
            p, o, m = step(p, o, batch)
            return m["loss"]

        m = ctx.measure(one)
        return {"images_per_s": images_per_s(gb, m.seconds),
                "ms_per_step": m.ms, "seconds": m.seconds,
                "energy_wh_per_step": m.energy_wh,
                "images_per_wh": (gb / m.energy_wh)
                if m.energy_wh > 0 else 0.0}

    return {"train": train}
