"""Paper Fig. 3 / Table III analog: ResNet50 training throughput + energy.

images/s and images/Wh across a batch sweep (single device), using the
data-parallel train step (the Horovod-analog path).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.spec import workload
from repro.configs.resnet50 import CONFIG
from repro.core.metrics import images_per_s
from repro.core.params import Space
from repro.data.synthetic import synthetic_images
from repro.models import resnet
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import make_resnet_train_step


def _setup():
    c = CONFIG.reduced(img_size=64, width=16)
    oc = OptConfig(warmup=2, total_steps=1000)
    params = resnet.init(jax.random.key(0), c)
    opt_state = opt_init(oc, params)
    step = jax.jit(make_resnet_train_step(c, oc))
    return c, params, opt_state, step


@workload(
    "resnet50",
    analog="Fig. 3 / Table III (ResNet50 images/s + energy)",
    space=Space({"global_batch": [16, 32, 64]}),
    smoke={"global_batch": [8]},
    tags=("vision", "train", "smoke", "full"),
    result_columns=["global_batch", "images_per_s", "ms_per_step",
                    "energy_wh_per_step", "images_per_wh", "power_source"],
    primary_metric="images_per_s",
)
def build(pt, ctx):
    """ResNet50 train-step sweep over global batch size."""
    c, params, opt_state, step = ctx.memo("resnet50", _setup)
    gb = pt["global_batch"]
    imgs, labels = synthetic_images(gb, c.img_size, c.n_classes)
    batch = {"images": jnp.asarray(imgs), "labels": jnp.asarray(labels)}

    def train():
        p, o = params, opt_state

        def one():
            nonlocal p, o
            p, o, m = step(p, o, batch)
            return m["loss"]

        m = ctx.measure(one)
        return {"images_per_s": images_per_s(gb, m.seconds),
                "ms_per_step": m.ms, "seconds": m.seconds,
                "energy_wh_per_step": m.energy_wh,
                "images_per_wh": (gb / m.energy_wh)
                if m.energy_wh > 0 else 0.0}

    return {"train": train}
