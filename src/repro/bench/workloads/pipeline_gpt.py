"""Paper Table II analog: GPT-117M trained with PIPELINE parallelism.

The Graphcore case: the model's layers split over 4 devices (pipeline
parallelism was the only way it fit in per-tile SRAM), throughput in
tokens/s across a batch sweep, plus the pipeline-bubble overhead. The
workload declares a ``{"pp": 4}`` placement — its stages map onto the
mesh's pipeline axis — and the CLI forces a matching host platform
before the backend initializes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.spec import workload
from repro.configs import get_config
from repro.core.metrics import tokens_per_s
from repro.core.params import Space
from repro.data.synthetic import synthetic_tokens
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.models.common import apply_mlp, apply_norm
from repro.parallel.pipeline import (
    bubble_fraction, pipeline_forward, stage_params_split,
)

SEQ = 64
N_STAGES = 4
N_MICROBATCH = 8


def _layer_fn(c):
    def layer_fn(stage_p, x):
        # apply this stage's layers sequentially
        def body(x, lp):
            from repro.models import attention as attn
            sp = lp["slot0"]
            h = apply_norm(c, sp["norm1"], x)
            h = attn.self_attention(c, sp["attn"], h, causal=True)
            x = x + h
            x = x + apply_mlp(c, sp["mlp"], apply_norm(c, sp["norm2"], x))
            return x, None
        x, _ = jax.lax.scan(body, x, stage_p)
        return x
    return layer_fn


def _setup(ctx=None):
    c = get_config("gpt-117m").reduced(n_layers=8, d_model=128, d_ff=512,
                                       n_heads=4, n_kv_heads=4, d_head=32,
                                       vocab=4096)
    # the workload's {"pp": N} placement materializes as the mesh's
    # "stage" axis (spec.MESH_AXIS_NAMES); standalone callers (tests)
    # fall back to building the same mesh directly
    mesh = ctx.mesh() if ctx is not None else make_mesh((N_STAGES,),
                                                        ("stage",))
    stage_axis = "stage" if "stage" in mesh.axis_names \
        else mesh.axis_names[0]
    params = lm.init(jax.random.key(0), c)
    stage_params = stage_params_split(params["layers"], N_STAGES)
    layer_fn = _layer_fn(c)
    fwd = jax.jit(lambda sp, xs: pipeline_forward(
        mesh, stage_axis, layer_fn, sp, xs))
    return c, params, stage_params, fwd


def verify_pipeline_correctness():
    """Pipeline output == sequential execution of the same layers."""
    import numpy as np
    c = get_config("gpt-117m").reduced(n_layers=4, d_model=64, d_ff=128,
                                       n_heads=2, n_kv_heads=2, d_head=32,
                                       vocab=512)
    mesh = make_mesh((N_STAGES,), ("stage",))
    params = lm.init(jax.random.key(0), c)
    stage_params = stage_params_split(params["layers"], N_STAGES)
    layer_fn = _layer_fn(c)
    toks = jnp.asarray(synthetic_tokens(8, 32, c.vocab)[:, :32])
    x = lm._inputs_to_embeds(c, params, toks, None)
    x_mb = x.reshape(4, 2, 32, c.d_model)
    got = pipeline_forward(mesh, "stage", layer_fn, stage_params, x_mb)
    want = layer_fn(jax.tree.map(
        lambda a: a.reshape(-1, *a.shape[2:]), stage_params), x)
    np.testing.assert_allclose(
        np.asarray(got.reshape(x.shape), np.float32),
        np.asarray(want, np.float32), rtol=2e-2, atol=2e-2)
    return {"pipeline_matches_sequential": 1}


@workload(
    "pipeline_gpt",
    analog="Table II (pipeline-parallel GPT-117M tokens/s)",
    space=Space({"global_batch": [16, 32, 64]}),
    smoke={"global_batch": [16]},
    placement={"pp": N_STAGES},
    tags=("train", "smoke", "full"),
    result_columns=["global_batch", "tokens_per_s", "ms_per_iter",
                    "energy_wh", "tokens_per_wh", "bubble_fraction",
                    "power_source"],
    primary_metric="tokens_per_s",
)
def build(pt, ctx):
    """Pipeline-parallel forward sweep over global batch size."""
    c, params, stage_params, fwd = ctx.memo("pipeline_gpt",
                                            lambda: _setup(ctx))
    gb = pt["global_batch"]
    mb = gb // N_MICROBATCH
    toks = jnp.asarray(synthetic_tokens(gb, SEQ, c.vocab)[:, :SEQ])
    x = lm._inputs_to_embeds(c, params, toks, None)
    x_mb = x.reshape(N_MICROBATCH, mb, SEQ, c.d_model)

    def run():
        m = ctx.measure(fwd, stage_params, x_mb)
        return {"tokens_per_s": tokens_per_s(gb, SEQ, m.seconds),
                "ms_per_iter": m.ms, "seconds": m.seconds,
                "energy_wh": m.energy_wh,
                "tokens_per_wh": (gb * SEQ / m.energy_wh)
                if m.energy_wh > 0 else 0.0,
                "bubble_fraction": bubble_fraction(N_STAGES, N_MICROBATCH)}

    steps = {"run": run}
    if not ctx.smoke:   # correctness gate rides along on full runs only
        steps = {"verify": verify_pipeline_correctness, "run": run}
    return steps
