"""Kernel microbenchmarks: Pallas (interpret) vs XLA reference.

Interpret mode executes the kernel body in Python — the timing column is
a correctness-scale signal only; the real figure of merit on TPU is the
roofline delta (flash attention removes the O(S*T) score traffic from
the memory term). Power measurement is off: microsecond kernels are far
below the power sampling interval.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.spec import workload
from repro.core.params import Space
from repro.kernels import ops

FLASH_SHAPES = {
    # case -> (batch, seq, heads, kv_heads, d_head)
    "flash_b1_s256": (1, 256, 4, 2, 64),
    "flash_b2_s512": (2, 512, 8, 8, 64),
}


def _flash_inputs(case: str):
    b, s, h, kh, dh = FLASH_SHAPES[case]
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, dh), jnp.float32)
    return q, k, v


@workload(
    "kernels",
    analog="Pallas kernel microbench (flash attention, rmsnorm)",
    space=Space({"case": ["flash_b1_s256", "flash_b2_s512", "rmsnorm"],
                 "impl": ["xla", "pallas"]}),
    smoke={"case": ["flash_b1_s256", "rmsnorm"]},
    tags=("kernels", "smoke", "full"),
    result_columns=["case", "impl", "us", "interpret"],
    primary_metric="us",
    # interpret-mode microsecond timings on shared CPU hosts swing up to
    # ~10x run-to-run; absolute time is not gateable here (the docstring's
    # correctness-scale caveat). Cross-run compare still gates point
    # presence and error status — just not the timing deltas.
    compare_tols={"default": float("inf")},
)
def build(pt, ctx):
    """Pallas-vs-XLA kernel timing sweep."""
    case, impl = pt["case"], pt["impl"]
    interpret = impl == "pallas"   # no compiled Pallas backend on CPU
    if case == "rmsnorm":
        x, sc = ctx.memo("kernels_rmsnorm", lambda: (
            jax.random.normal(jax.random.key(0), (512, 1024), jnp.float32),
            jnp.ones((1024,))))

        def fn():
            return ops.rmsnorm(x, sc, impl=impl, interpret=interpret)
    else:
        q, k, v = ctx.memo(("kernels_flash", case),
                           lambda: _flash_inputs(case))

        def fn():
            return ops.flash_attention(q, k, v, impl=impl,
                                       interpret=interpret)

    def run():
        m = ctx.measure(fn, iters=2 if interpret else 3, power=False)
        return {"us": m.us, "seconds": m.seconds,
                "interpret": int(interpret)}

    return {"run": run}
