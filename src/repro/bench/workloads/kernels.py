"""Kernel microbenchmarks: Pallas (interpret) vs XLA reference.

Interpret mode executes the kernel body in Python — the timing column is
a correctness-scale signal only; the real figure of merit on TPU is the
roofline delta (flash attention removes the O(S*T) score traffic from
the memory term). Power measurement is off: microsecond kernels are far
below the power sampling interval.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.spec import workload
from repro.core.params import Space
from repro.kernels import ops

FLASH_SHAPES = {
    # case -> (batch, seq, heads, kv_heads, d_head)
    "flash_b1_s256": (1, 256, 4, 2, 64),
    "flash_b2_s512": (2, 512, 8, 8, 64),
}

#: paged prefill case: a 32-token chunk against 3 prefix pool blocks
#: per row (GQA 4q/2kv) — the serve chunked/suffix-prefill hot path
#: shape, scaled for interpret mode
PAGED_PREFILL_SHAPE = {"b": 2, "sq": 32, "h": 4, "kh": 2, "dh": 16,
                       "bs": 16, "npre": 3, "n_blocks": 8}


def _flash_inputs(case: str):
    b, s, h, kh, dh = FLASH_SHAPES[case]
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, dh), jnp.float32)
    return q, k, v


def _paged_prefill_inputs(quantized: bool):
    """(q, k_suffix, v_suffix, k_pool, v_pool, tables, k_scale, v_scale)
    with shuffled non-trivial block tables (block 0 left unused, like
    the serve pool's trash block)."""
    p = PAGED_PREFILL_SHAPE
    b, sq, h, kh, dh = p["b"], p["sq"], p["h"], p["kh"], p["dh"]
    bs, npre, nblk = p["bs"], p["npre"], p["n_blocks"]
    ks = jax.random.split(jax.random.key(1), 6)
    q = jax.random.normal(ks[0], (b, sq, h, dh), jnp.float32)
    k_suf = jax.random.normal(ks[1], (b, sq, kh, dh), jnp.float32)
    v_suf = jax.random.normal(ks[2], (b, sq, kh, dh), jnp.float32)
    k_pool = jax.random.normal(ks[3], (nblk, bs, kh, dh), jnp.float32)
    v_pool = jax.random.normal(ks[4], (nblk, bs, kh, dh), jnp.float32)
    tables = jax.random.permutation(
        ks[5], jnp.arange(1, nblk))[:b * npre].reshape(b, npre)
    k_scale = v_scale = None
    if quantized:
        def quant(pool):
            sc = jnp.max(jnp.abs(pool), axis=(1, 3)) / 127.0
            sc = jnp.where(sc > 0, sc, 1.0)
            codes = jnp.round(pool / sc[:, None, :, None])
            return jnp.clip(codes, -127, 127).astype(jnp.int8), sc
        k_pool, k_scale = quant(k_pool)
        v_pool, v_scale = quant(v_pool)
    return q, k_suf, v_suf, k_pool, v_pool, tables, k_scale, v_scale


@workload(
    "kernels",
    analog="Pallas kernel microbench (flash attention, rmsnorm, "
           "paged prefill)",
    space=Space({"case": ["flash_b1_s256", "flash_b2_s512", "rmsnorm",
                          "paged_prefill", "paged_prefill_int8"],
                 "impl": ["xla", "pallas"]}),
    smoke={"case": ["flash_b1_s256", "rmsnorm", "paged_prefill",
                    "paged_prefill_int8"]},
    tags=("kernels", "smoke", "full"),
    result_columns=["case", "impl", "us", "max_err", "interpret"],
    primary_metric="us",
    # interpret-mode microsecond timings on shared CPU hosts swing up to
    # ~10x run-to-run; absolute time is not gateable here (the docstring's
    # correctness-scale caveat). Cross-run compare still gates point
    # presence and error status — just not the timing deltas.
    compare_tols={"default": float("inf")},
)
def build(pt, ctx):
    """Pallas-vs-XLA kernel timing sweep."""
    case, impl = pt["case"], pt["impl"]
    interpret = impl == "pallas"   # no compiled Pallas backend on CPU
    if case.startswith("paged_prefill"):
        quantized = case.endswith("int8")
        (q, k_suf, v_suf, k_pool, v_pool, tables, k_sc, v_sc) = ctx.memo(
            ("kernels_paged_prefill", quantized),
            lambda: _paged_prefill_inputs(quantized))

        def fn():
            return ops.paged_prefill_attention(
                q, k_suf, v_suf, k_pool, v_pool, tables, impl=impl,
                interpret=interpret, k_scale=k_sc, v_scale=v_sc)

        def run():
            m = ctx.measure(fn, iters=2 if interpret else 3, power=False)
            out = {"us": m.us, "seconds": m.seconds,
                   "interpret": int(interpret)}
            if impl == "pallas":
                # pallas rows carry their oracle delta so the
                # BENCH_kernels table is self-verifying: the xla rows
                # ARE paged_prefill_attention_ref
                oracle = ops.paged_prefill_attention(
                    q, k_suf, v_suf, k_pool, v_pool, tables, impl="xla",
                    k_scale=k_sc, v_scale=v_sc)
                out["max_err"] = float(jnp.max(jnp.abs(fn() - oracle)))
            return out

        return {"run": run}
    if case == "rmsnorm":
        x, sc = ctx.memo("kernels_rmsnorm", lambda: (
            jax.random.normal(jax.random.key(0), (512, 1024), jnp.float32),
            jnp.ones((1024,))))

        def fn():
            return ops.rmsnorm(x, sc, impl=impl, interpret=interpret)
    else:
        q, k, v = ctx.memo(("kernels_flash", case),
                           lambda: _flash_inputs(case))

        def fn():
            return ops.flash_attention(q, k, v, impl=impl,
                                       interpret=interpret)

    def run():
        m = ctx.measure(fn, iters=2 if interpret else 3, power=False)
        return {"us": m.us, "seconds": m.seconds,
                "interpret": int(interpret)}

    return {"run": run}
