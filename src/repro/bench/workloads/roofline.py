"""Roofline table workload: render the dry-run roofline artifacts.

Reads the per-arch dry-run artifacts (``artifacts/dryrun/``), summarizes
the roofline occupancy per mesh, and writes the full row table next to
the workload's results. Analysis-only: no model execution, no power —
an absent artifacts directory yields an empty-but-green record so smoke
runs pass on fresh checkouts.
"""
from __future__ import annotations

import json
import os
import pathlib

from repro.bench.context import Measurement
from repro.bench.spec import workload
from repro.core.params import Space
from repro.core.results import save_results, table


def _dryrun_dir() -> pathlib.Path:
    override = os.environ.get("REPRO_DRYRUN_DIR")
    if override:
        return pathlib.Path(override)
    # anchored to the repo root, not the cwd, so `run --suite roofline`
    # finds the artifacts no matter where it is invoked from
    repo_root = pathlib.Path(__file__).resolve().parents[4]
    return repo_root / "artifacts" / "dryrun"


def load_rows(mesh: str) -> list[dict]:
    rows = []
    for f in sorted(_dryrun_dir().glob(f"{mesh}__*.json")):
        r = json.loads(f.read_text())
        if "roofline" not in r:
            if "skipped" in r:
                rows.append({"arch": r["arch"], "shape": r["shape"],
                             "bottleneck": "SKIP",
                             "note": r["skipped"]})
            continue
        rf = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": rf["compute_s"], "memory_s": rf["memory_s"],
            "collective_s": rf["collective_s"],
            "bottleneck": rf["bottleneck"],
            "roofline_frac": rf["roofline_fraction"],
            "useful_flops": rf["useful_flops_ratio"],
            "hbm_gib": r.get("bytes_per_device_tpu",
                             r.get("bytes_per_device", 0)) / 2**30,
            "fits": r.get("fits_hbm_16g"),
        })
    return rows


@workload(
    "roofline",
    analog="par.Roofline table (per-device seconds/step, from dry-run)",
    space=Space({"mesh": ["single", "multi"]}),
    tags=("analysis", "smoke", "full"),
    result_columns=["mesh", "n_rows", "n_compute_bound", "n_memory_bound",
                    "n_skipped"],
    primary_metric="n_rows",
)
def build(pt, ctx):
    """Summarize dry-run roofline artifacts for one mesh size."""
    mesh = pt["mesh"]

    def run():
        rows = load_rows(mesh)
        if rows:
            print(f"\n== {mesh}-pod roofline (per-device seconds/step) ==")
            print(table(rows, floatfmt="{:.4f}"))
            save_results(rows, ctx.out_dir, f"roofline_{mesh}")
        by = [r.get("bottleneck") for r in rows]
        # analysis-only: nothing here is timed, so the honest same-point
        # noise figure is zero — without this stamp the runner falls back
        # to the straggler watchdog's CROSS-POINT spread (two artifact
        # sets of very different size), which saturated this workload's
        # compare tolerances for no reason
        ctx.last_measurement = Measurement(
            seconds=0.0, energy_wh=0.0, power_source="none",
            iters=1, warmup=0, rel_spread=0.0)
        return {"n_rows": len(rows),
                "n_compute_bound": by.count("compute"),
                "n_memory_bound": by.count("memory"),
                "n_skipped": by.count("SKIP")}

    return {"run": run}
