"""Multi-tenant SLO serving workload: goodput + Wh-per-SLO-met-request.

The MLPerf-Power framing on top of the continuous-batching engine: drive
the ServeEngine with seeded multi-tenant traces (``serve.traffic``
presets — Poisson mixes, MMPP bursts, shared system-prompt populations)
and score every request against its tenant's TTFT/TPOT SLO
(``serve.slo``), per (trace x cache) cell:

  goodput             fraction of requests meeting BOTH targets
  ttft_p99 / tpot_p99 tail latency (nearest-rank, includes queueing)
  wh_per_slo_request  attributed energy / SLO-met requests — energy
                      per *useful* inference, the figure the paper's
                      energy-efficiency story reduces to under SLOs

The ``cache`` axis isolates prefix caching: ``paged`` is the plain
block-table pool, ``paged+prefix`` adds the block-granular shared-prefix
index (``PagedKVCache.enable_prefix_cache``) — prompts whose leading
blocks hit the index adopt the shared KV and prefill only their suffix.
On the ``shared_prefix`` trace (two assistant tenants sharing a 48-token
system prompt) that cuts the prefill bucket from 64 to 16 tokens for
every hit, which shows up directly in ``ttft_p99`` and
``wh_per_slo_request``; the ``*_vs_paged`` ratios make the win a gated
record column. Token streams are bit-identical to the non-cached path
(asserted in tests/test_prefix_cache.py), so the comparison is pure
performance, never quality.

The ``sched`` axis isolates iteration-level scheduling on the same
engine: ``phased`` prefills an admitted prompt whole and reserves every
request's worst-case block footprint at admission; ``chunked``
interleaves block-aligned ``chunk_tokens`` slices with decode steps,
admits optimistically, and backs both admission and decode growth with
block-granular preemption (``ServeEngine`` module docstring). The
``long_prefill`` trace runs against a deliberately TIGHT pool
(``POOL_BY_TRACE``): long-generation requests make phased hold
6-block reservations for whole request lifetimes, so documents (and
everything FIFO-queued behind them) defer for tens of milliseconds,
while chunked evicts the youngest generation and admits immediately —
the ttft_p99 collapse the ``*_vs_phased`` ratios gate per
(trace x cache) cell. ``stream_hash`` (order-independent digest of
every per-request token stream) rides along so any sched- or
cache-induced token divergence is visible in the row — preemption
included: a resumed request replays its emitted tail through the
decode program, keeping streams bit-identical (the property
tests/test_chunked_serve.py pins). ``preemptions`` counts chunked
eviction events (nonzero only on the oversubscribed long_prefill
cells).

The ``kv_dtype`` axis rides the plain-paged phased cells only (the
serve workload owns the full int8 cross): same traces and SLOs on an
int8-quantized pool, with ``pool_bytes``/``max_concurrency`` carrying
the capacity story and ``kv_stream_prefix_agreement`` the stream
quality vs the fp32 twin.

SLO targets are deliberately generous for the reduced-config CPU cell
(~10x steady-state latency): goodput sits at 1.0 and acts as a canary —
only a scheduler stall or admission bug pushes it down — while the
discriminating signal lives in the tail-latency and energy columns.
"""
from __future__ import annotations

import hashlib

import jax

from repro.bench.context import Measurement
from repro.bench.spec import workload
from repro.configs import get_config
from repro.core.params import Space
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.serve.slo import SLO, evaluate_slo
from repro.serve.traffic import TRACE_NAMES, generate_trace, preset_trace

from repro.bench.workloads.serve import _paged_impl, stream_agreement

MAX_LEN = 96            # slot capacity (prompt + budget; see traffic presets)
BLOCK_SIZE = 16         # paged KV block; shared_prefix pins 3 full blocks
N_SLOTS = 4
N_REQUESTS = 96
N_REQUESTS_SMOKE = 48
SEED = 0

#: generous CPU-cell targets (~30x the reduced-config steady-state tail:
#: measured ttft_p99 ~0.06 s, tpot_p99 ~0.003 s). Interactive tenants
#: get the tight budget; batch-flavored tenants (bursty "batch",
#: shared_prefix "misc") tolerate double.
SLO_TIGHT = SLO(ttft_s=2.0, tpot_s=0.2)
SLO_RELAXED = SLO(ttft_s=4.0, tpot_s=0.4)
#: batch-flavored tenants tolerate double; long_prefill's "doc" tenant
#: is offline-flavored AND pays an unavoidable 5-chunk prefill
SLO_BY_TENANT = {"batch": SLO_RELAXED, "misc": SLO_RELAXED,
                 "doc": SLO_RELAXED}

#: per-trace paged-pool override (blocks). long_prefill runs against a
#: TIGHT pool: 17 blocks = trash + 16 usable, so two live worst-case
#: generations (6 blocks each) plus a document prompt (6) oversubscribe
#: it — the regime where phased defers admissions behind gen lifetimes
#: and chunked preempts its way through (see the traffic preset
#: comment). Other traces keep the engine's ample default pool.
POOL_BY_TRACE = {"long_prefill": 17}


def _stream_hash(results) -> str:
    """Order-independent sha1 over {rid: tokens}: completion order (and
    therefore results-list order) differs across scheduler modes, so the
    digest sorts by rid before hashing."""
    blob = repr(sorted((r.rid, tuple(r.tokens)) for r in results))
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


def _engine(ctx, arch: str, cache: str,
            n_blocks=None, kv_dtype: str = "fp32") -> ServeEngine:
    def make():
        c = get_config(arch).reduced()
        params = lm.init(jax.random.key(SEED), c)
        impl, interpret = _paged_impl()
        engine = ServeEngine(c, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                             cache="paged", block_size=BLOCK_SIZE,
                             n_blocks=n_blocks,
                             prefix_cache=cache == "paged+prefix",
                             kv_dtype=kv_dtype,
                             paged_impl=impl, paged_interpret=interpret,
                             power_methods=ctx.power_methods)
        return c, engine

    return ctx.memo(("serve_slo", arch, cache, n_blocks, kv_dtype), make)


@workload(
    "serve_slo",
    analog="multi-tenant SLO serving: goodput + Wh/SLO-met-request "
           "(MLPerf-Power style), prefix-cached prefill",
    space=Space({"arch": ["llama3.2-3b"], "trace": list(TRACE_NAMES),
                 "cache": ["paged", "paged+prefix"],
                 # int8 pools ride only the plain-paged phased cells
                 # here: the SLO grid is already trace x cache x sched,
                 # and the serve workload owns the full kv_dtype cross —
                 # this axis just shows the quantized pool under
                 # multi-tenant SLO scoring (fp32 expands first, so the
                 # int8 cell's twin is cached)
                 "kv_dtype": ["fp32", "int8"],
                 # last axis -> phased expands before chunked for every
                 # cell, so the vs_phased ratio's twin is always cached
                 "sched": ["phased", "chunked"]},
                constraints=[lambda pt: pt["kv_dtype"] == "fp32"
                             or (pt["cache"] == "paged"
                                 and pt["sched"] == "phased")]),
    smoke={"trace": ["poisson", "shared_prefix", "long_prefill"]},
    tags=("serve", "smoke", "full"),
    result_columns=["arch", "trace", "cache", "sched", "kv_dtype",
                    "goodput",
                    "ttft_p99", "tpot_p99", "wh_per_slo_request",
                    "decode_tok_s", "prefix_hit_requests", "preemptions",
                    "ttft_p99_vs_paged", "wh_per_slo_vs_paged",
                    "ttft_p99_vs_phased", "goodput_vs_phased",
                    "speedup_vs_phased", "pool_bytes", "max_concurrency",
                    "speedup_vs_fp_kv", "kv_stream_prefix_agreement",
                    "trace_hash", "power_source"],
    primary_metric="goodput",
    # Tail quantiles from a SINGLE smoke run are scheduling-event-sized
    # (one GC pause or admission stall lands straight in p99): two
    # back-to-back clean runs differ 1.5-4x on ttft_p99/tpot_p99 while
    # throughput and energy hold within percent. They can't carry the
    # CI's blanket --rel-tol (which outranks the registry base — see
    # compare.effective_tolerance), so these stamps keep the columns
    # gated only against order-of-magnitude cliffs; the statistically
    # sound tail gate is scripts/check_ttft_gate.py (median-of-3 per
    # sched on the same host minutes apart). Throughput/energy columns
    # stay on the tight default.
    compare_tols={"ttft_p99": 4.0, "tpot_p99": 1.5,
                  "ttft_p99_vs_phased": 6.0},
)
def build(pt, ctx):
    """Multi-tenant traces x prefix caching, scored against SLOs."""
    c, engine = _engine(ctx, pt["arch"], pt["cache"],
                        n_blocks=POOL_BY_TRACE.get(pt["trace"]),
                        kv_dtype=pt["kv_dtype"])
    n = N_REQUESTS_SMOKE if ctx.smoke else N_REQUESTS
    cfg = preset_trace(pt["trace"], n_requests=n, vocab=c.vocab, seed=SEED)
    requests = generate_trace(cfg)
    drill = _paged_impl()[1]

    # warm once per (engine, trace): compiles the trace's prefill
    # buckets and decode programs; repeat=2 lets a prefix engine
    # register on the first pass and compile every suffix-prefill
    # (bucket, depth) program on the second. The index is cleared
    # afterwards, so measured runs start cold either way.
    warmed = ctx.cache.setdefault("slo_warmed", set())
    wkey = (pt["arch"], pt["cache"], pt["trace"], pt["sched"],
            pt["kv_dtype"])
    if wkey not in warmed:
        engine.warmup(requests=requests,
                      repeat=2 if engine.prefix_cache else 1,
                      sched=pt["sched"])
        warmed.add(wkey)

    def run_cell():
        # same twice-run noise protocol as the serve workload: report
        # the steady-state second run, turn the pair's throughput
        # disagreement into the record's same-point noise figure. Each
        # measured run starts from a cold prefix index so the two runs
        # (and the promoted baseline) see identical hit sequences.
        def one_run():
            engine.reset_prefix_cache()
            return engine.serve(requests, policy="continuous",
                                sched=pt["sched"])

        first = None if drill else one_run().summary
        out = one_run()
        s = out.summary
        if first is not None:
            pair = sorted((first.decode_tok_s, s.decode_tok_s))
            spread = ((pair[1] - pair[0]) / ((pair[0] + pair[1]) / 2)
                      if pair[1] > 0 else 0.0)
            ctx.last_measurement = Measurement(
                seconds=s.wall_s, energy_wh=s.attributed_wh,
                power_source=ctx.power_source, iters=2, warmup=0,
                rel_spread=spread)
        report = evaluate_slo(out.results, SLO_BY_TENANT,
                              default=SLO_TIGHT)
        metrics = {
            "goodput": report.goodput,
            "n_met": report.n_met,
            "n_requests": report.n_requests,
            "ttft_p50": report.ttft_p50_s,
            "ttft_p99": report.ttft_p99_s,
            "tpot_p50": report.tpot_p50_s,
            "tpot_p99": report.tpot_p99_s,
            "wh_per_slo_request": report.wh_per_slo_request,
            "n_tokens": s.n_tokens,
            "decode_tok_s": s.decode_tok_s,
            "wh_per_token": s.wh_per_token,
            "occupancy": s.mean_occupancy,
            "wall_s": s.wall_s,
            "seconds": s.wall_s,
            # full provenance: the trace is reproducible from its row
            "trace_seed": SEED,
            "trace_hash": cfg.config_hash(),
            # order-independent digest of every request's token stream:
            # equal across the sched and cache axes (same greedy argmax
            # path), so a quality-affecting scheduler bug shows up as a
            # hash mismatch in the results table even though the compare
            # gate (floats only) can't diff it
            "stream_hash": _stream_hash(out.results),
            "preemptions": engine.preemptions,
        }
        for name, sub in report.per_tenant.items():
            metrics[f"goodput_{name}"] = sub.goodput
        if engine.prefix_cache:
            for key, val in engine.prefix_stats.items():
                metrics[f"prefix_{key}"] = val
        # headline ratios against the twin cells: plain-paged (same
        # sched) and phased (same cache) — both expand earlier in the
        # Space, so they are already measured except under --points
        # structural pool capacity columns (every cell here is paged)
        metrics["pool_bytes"] = engine._paged.pool_bytes
        metrics["pool_bytes_fp"] = engine._paged.pool_bytes_fp
        metrics["max_concurrency"] = engine._paged.max_concurrency
        cells = ctx.cache.setdefault("serve_slo_cells", {})
        cell_key = (pt["arch"], pt["trace"])
        sub_key = (pt["cache"], pt["kv_dtype"], pt["sched"])
        cells.setdefault(cell_key, {})[sub_key] = metrics
        if pt["cache"] == "paged+prefix":
            base = cells[cell_key].get(
                ("paged", pt["kv_dtype"], pt["sched"]))
            if base is not None:   # absent only under --points filters
                metrics["ttft_p99_vs_paged"] = (
                    metrics["ttft_p99"] / max(base["ttft_p99"], 1e-9))
                metrics["wh_per_slo_vs_paged"] = (
                    metrics["wh_per_slo_request"]
                    / max(base["wh_per_slo_request"], 1e-12))
        if pt["sched"] == "chunked":
            base = cells[cell_key].get(
                (pt["cache"], pt["kv_dtype"], "phased"))
            if base is not None:   # absent only under --points filters
                metrics["ttft_p99_vs_phased"] = (
                    metrics["ttft_p99"] / max(base["ttft_p99"], 1e-9))
                metrics["goodput_vs_phased"] = (
                    metrics["goodput"] / max(base["goodput"], 1e-9))
                metrics["speedup_vs_phased"] = (
                    metrics["decode_tok_s"]
                    / max(base["decode_tok_s"], 1e-9))
        # int8 vs fp32 twin: perf/energy ratios + stream quality (same
        # protocol as the serve workload; streams keyed sans kv_dtype)
        streams = ctx.cache.setdefault("serve_slo_streams", {})
        skey = (pt["arch"], pt["trace"], pt["cache"], pt["sched"])
        my_streams = {r.rid: tuple(r.tokens) for r in out.results}
        if pt["kv_dtype"] == "fp32":
            streams[skey] = my_streams
        else:
            base = cells[cell_key].get((pt["cache"], "fp32", pt["sched"]))
            if base is not None:   # absent only under --points filters
                metrics["speedup_vs_fp_kv"] = (
                    metrics["decode_tok_s"]
                    / max(base["decode_tok_s"], 1e-9))
                metrics["wh_per_slo_vs_fp_kv"] = (
                    metrics["wh_per_slo_request"]
                    / max(base["wh_per_slo_request"], 1e-12))
            ref = streams.get(skey)
            if ref is not None:
                metrics["kv_stream_prefix_agreement"] = stream_agreement(
                    ref, my_streams)
        return metrics

    return {"serve_slo": run_cell}
