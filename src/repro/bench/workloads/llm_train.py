"""Paper Fig. 2 analog: LLM training throughput + energy vs global batch,
swept across device placements (the CARAML scaling measurement).

Trains the paper's GPT decoder (reduced for the host under test) across a
global-batch x placement sweep; reports tokens/s, energy/step, tokens/Wh —
CARAML's LLM figures of merit — plus the cross-placement scaling metrics
the runner derives (tok_s_per_device, scaling_efficiency,
wh_per_token_scaling against the dp1 cell of the same sweep).

The ``placement`` axis is real sharded execution, not bookkeeping: each
cell builds a ``parallel.sharding.Plan`` from its mesh, places
params/optimizer-state with the table-driven TP/FSDP/ZeRO-1 rules,
shards the batch over the data axes, and constrains the micro-batch
gradient accumulator so GSPMD reduce-scatters instead of all-reducing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.spec import workload
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.metrics import tokens_per_s
from repro.core.params import Space
from repro.data.synthetic import synthetic_tokens
from repro.models import lm
from repro.parallel import sharding as shd
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step

MICROBATCHES = 4


def _base_state(ctx, arch: str):
    """Unsharded model/optimizer state, built once per arch; every
    placement cell places a copy of this onto its own mesh."""
    def make():
        c = get_config(arch).reduced(d_model=128, n_layers=4, d_ff=512,
                                     vocab=8192, n_heads=4, n_kv_heads=4,
                                     d_head=32)
        oc = OptConfig(warmup=2, total_steps=1000)
        params = lm.init(jax.random.key(0), c)
        opt_state = opt_init(oc, params)
        return c, oc, params, opt_state

    return ctx.memo(("llm_train", arch), make)


def _placed_state(ctx, arch: str):
    """Mesh-placed train state, once per (arch, placement) — the placed
    params + full AdamW state are ~5x model bytes, so they must not be
    duplicated per batch-size cell."""
    placement = ctx.placement

    def make():
        c, oc, params, opt_state = _base_state(ctx, arch)
        plan = shd.make_plan(c, ctx.mesh(),
                             ShapeConfig("bench", 0, 0, "train"))
        params_s, opt_s, psh, _ = shd.shard_train_state(
            plan, params, opt_state, c)
        return c, oc, plan, params_s, opt_s, psh

    return ctx.memo(("llm_train_placed", arch, placement.label), make)


def _placed(ctx, pt):
    """Placed state + the cell's jitted step (only the step — via its
    batch shardings — depends on the cell's shapes)."""
    arch, gb, seq = pt["arch"], pt["global_batch"], pt["seq"]
    c, oc, plan, params_s, opt_s, psh = _placed_state(ctx, arch)

    def make_step():
        mb = gb // MICROBATCHES
        bsh = {"tokens": shd.batch_sharding(plan, (mb, seq)),
               "labels": shd.batch_sharding(plan, (mb, seq))}
        return jax.jit(make_train_step(
            c, oc, StepConfig(microbatches=MICROBATCHES),
            grad_shardings=psh, batch_shardings=bsh))

    step = ctx.memo(("llm_train_step", arch, ctx.placement.label, gb, seq),
                    make_step)
    return c, plan, params_s, opt_s, step


@workload(
    "llm_train",
    analog="Fig. 2 (LLM tokens/s + energy vs global batch, dp-scaled)",
    space=Space({"arch": ["gpt-800m"], "global_batch": [16, 32, 64],
                 "seq": [128], "placement": ["dp1", "dp2", "dp4"]}),
    smoke={"global_batch": [8], "seq": [64], "placement": ["dp1", "dp2"]},
    tags=("train", "smoke", "full"),
    result_columns=["arch", "global_batch", "seq", "placement",
                    "tokens_per_s", "tok_s_per_device",
                    "scaling_efficiency", "ms_per_step",
                    "energy_wh_per_step", "tokens_per_wh",
                    "wh_per_token_scaling", "power_source"],
    primary_metric="tokens_per_s",
)
def build(pt, ctx):
    """LLM train-step sweep over global batch x device placement."""
    c, plan, params, opt_state, step = _placed(ctx, pt)
    gb, seq = pt["global_batch"], pt["seq"]
    toks = jnp.asarray(synthetic_tokens(gb, seq, c.vocab)[:, :seq])
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    batch = jax.device_put(
        batch, {k: shd.batch_sharding(plan, v.shape)
                for k, v in batch.items()})

    def train():
        p, o = params, opt_state

        def one():
            nonlocal p, o
            p, o, m = step(p, o, batch)
            return m["loss"]

        m = ctx.measure(one)
        tps = tokens_per_s(gb, seq, m.seconds)
        return {"tokens_per_s": tps, "ms_per_step": m.ms,
                "seconds": m.seconds,
                "energy_wh_per_step": m.energy_wh,
                "tokens_per_wh": (gb * seq / m.energy_wh)
                if m.energy_wh > 0 else 0.0}

    return {"train": train}
