"""Paper Fig. 2 analog: LLM training throughput + energy vs global batch,
swept across device placements (the CARAML scaling measurement).

Trains the paper's GPT decoder (reduced for the host under test) across a
global-batch x placement sweep; reports tokens/s, energy/step, tokens/Wh —
CARAML's LLM figures of merit — plus the cross-placement scaling metrics
the runner derives (tok_s_per_device, scaling_efficiency,
wh_per_token_scaling against the dp1 cell of the same sweep).

The ``placement`` axis is real sharded execution, not bookkeeping: each
cell builds a ``parallel.sharding.Plan`` from its mesh and places
params/optimizer-state with the table-driven TP/FSDP/ZeRO-1 rules. Pure
data-parallel cells run the explicit bucketed gradient sync
(``parallel.grad_sync``) with the ``grad_sync`` axis selecting fp32 or
int8-compressed all-reduce; mixed placements keep the GSPMD path with
ZeRO-2 dp-sharded grad accumulators. Both paths pin the jitted step's
output shardings to the input placement and donate params/opt-state —
without the pin the returned params' layout drifts and every call after
the first recompiles (the dp-scaling collapse PR 5 measured as
scaling_efficiency 0.10).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.spec import workload
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.metrics import tokens_per_s
from repro.core.params import Space
from repro.data.synthetic import synthetic_tokens
from repro.models import lm
from repro.parallel import grad_sync as gs
from repro.parallel import sharding as shd
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step

MICROBATCHES = 4


def _microbatches(gb: int, ndev: int) -> int:
    """Largest microbatch count <= MICROBATCHES that divides the
    per-device batch (halving clamp — keeps small smoke batches legal
    on larger dp meshes)."""
    k = MICROBATCHES
    while k > 1 and (gb // max(ndev, 1)) % k:
        k //= 2
    return k


def _base_state(ctx, arch: str):
    """Unsharded model/optimizer state, built once per arch; every
    placement cell places a copy of this onto its own mesh."""
    def make():
        c = get_config(arch).reduced(d_model=128, n_layers=4, d_ff=512,
                                     vocab=8192, n_heads=4, n_kv_heads=4,
                                     d_head=32)
        oc = OptConfig(warmup=2, total_steps=1000)
        params = lm.init(jax.random.key(0), c)
        opt_state = opt_init(oc, params)
        return c, oc, params, opt_state

    return ctx.memo(("llm_train", arch), make)


def _placed_state(ctx, arch: str):
    """Mesh-placed train state, once per (arch, placement) — the placed
    params + full AdamW state are ~5x model bytes, so they must not be
    duplicated per batch-size cell."""
    placement = ctx.placement

    def make():
        c, oc, params, opt_state = _base_state(ctx, arch)
        plan = shd.make_plan(c, ctx.mesh(),
                             ShapeConfig("bench", 0, 0, "train"))
        params_s, opt_s, psh, osh, gsh = shd.shard_train_state(
            plan, params, opt_state, c)
        return c, oc, plan, params_s, opt_s, psh, osh, gsh

    return ctx.memo(("llm_train_placed", arch, placement.label), make)


def _placed(ctx, pt):
    """Placed state + the cell's jitted step (only the step — via its
    batch shardings and grad_sync mode — depends on the cell's shapes)."""
    arch, gb, seq = pt["arch"], pt["global_batch"], pt["seq"]
    mode = pt.get("grad_sync", "fp32")
    c, oc, plan, params_s, opt_s, psh, osh, gsh = _placed_state(ctx, arch)
    ndev = shd.dp_size(plan)
    k = _microbatches(gb, ndev)
    pure_dp = plan.tp_size == 1

    def make_step():
        sc = StepConfig(microbatches=k)
        if pure_dp:
            # explicit bucketed (optionally compressed) gradient sync;
            # backward-overlap on async-collective backends only
            sync = gs.default_sync(mode)
            step = jax.jit(
                gs.make_dp_train_step(c, oc, sc, plan=plan, sync=sync),
                out_shardings=(psh, osh, gs.sync_state_sharding(plan),
                               None),
                donate_argnums=(0, 1, 2))
            return step, sync
        # mixed dp x tp placements: GSPMD step with ZeRO-2 dp-sharded
        # grad accumulators, per-microbatch batch constraints
        mb = gb // k
        mbsh = {"tokens": shd.batch_sharding(plan, (mb, seq)),
                "labels": shd.batch_sharding(plan, (mb, seq))}
        step = jax.jit(
            make_train_step(c, oc, sc, grad_shardings=gsh,
                            batch_shardings=mbsh),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1))
        return step, None

    step, sync = ctx.memo(
        ("llm_train_step", arch, ctx.placement.label, gb, seq, mode),
        make_step)
    return c, plan, params_s, opt_s, psh, osh, step, sync


@workload(
    "llm_train",
    analog="Fig. 2 (LLM tokens/s + energy vs global batch, dp-scaled)",
    space=Space({"arch": ["gpt-800m"], "global_batch": [16, 32, 64],
                 "seq": [128], "placement": ["dp1", "dp2", "dp4"],
                 "grad_sync": ["fp32", "int8"]}),
    smoke={"global_batch": [8], "seq": [64], "placement": ["dp1", "dp2"],
           "grad_sync": ["fp32"]},
    tags=("train", "smoke", "full"),
    result_columns=["arch", "global_batch", "seq", "placement",
                    "grad_sync", "tokens_per_s", "tok_s_per_device",
                    "scaling_efficiency", "ms_per_step",
                    "energy_wh_per_step", "tokens_per_wh",
                    "wh_per_token_scaling", "power_source"],
    primary_metric="tokens_per_s",
)
def build(pt, ctx):
    """LLM train-step sweep over global batch x device placement."""
    c, plan, params, opt_state, psh, osh, step, sync = _placed(ctx, pt)
    gb, seq = pt["global_batch"], pt["seq"]
    toks = jnp.asarray(synthetic_tokens(gb, seq, c.vocab)[:, :seq])
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    batch = jax.device_put(
        batch, {k: shd.batch_sharding(plan, v.shape)
                for k, v in batch.items()})

    def train():
        # the step donates its state buffers, and the placed state is
        # memoized across cells/retries — each thunk works on copies
        p = jax.device_put(jax.tree.map(jnp.copy, params), psh)
        o = jax.device_put(jax.tree.map(jnp.copy, opt_state), osh)
        s = gs.init_sync_state(plan, params, sync) if sync else None

        def one():
            nonlocal p, o, s
            if sync is not None:
                p, o, s, m = step(p, o, s, batch)
            else:
                p, o, m = step(p, o, batch)
            return m["loss"]

        m = ctx.measure(one)
        tps = tokens_per_s(gb, seq, m.seconds)
        return {"tokens_per_s": tps, "ms_per_step": m.ms,
                "seconds": m.seconds,
                "energy_wh_per_step": m.energy_wh,
                "tokens_per_wh": (gb * seq / m.energy_wh)
                if m.energy_wh > 0 else 0.0}

    return {"train": train}
