"""Paper Fig. 2 analog: LLM training throughput + energy vs global batch.

Trains the paper's GPT decoder (reduced for the host under test) across a
global-batch sweep; reports tokens/s, energy/step, tokens/Wh — CARAML's
LLM figures of merit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.spec import workload
from repro.configs import get_config
from repro.core.metrics import tokens_per_s
from repro.core.params import Space
from repro.data.synthetic import synthetic_tokens
from repro.models import lm
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step


def _setup(arch: str):
    c = get_config(arch).reduced(d_model=128, n_layers=4, d_ff=512,
                                 vocab=8192, n_heads=4, n_kv_heads=4,
                                 d_head=32)
    oc = OptConfig(warmup=2, total_steps=1000)
    params = lm.init(jax.random.key(0), c)
    opt_state = opt_init(oc, params)
    step = jax.jit(make_train_step(c, oc, StepConfig(microbatches=4)))
    return c, params, opt_state, step


@workload(
    "llm_train",
    analog="Fig. 2 (LLM tokens/s + energy vs global batch)",
    space=Space({"arch": ["gpt-800m"], "global_batch": [16, 32, 64],
                 "seq": [128]}),
    smoke={"global_batch": [8], "seq": [64]},
    tags=("train", "smoke", "full"),
    result_columns=["arch", "global_batch", "seq", "tokens_per_s",
                    "ms_per_step", "energy_wh_per_step", "tokens_per_wh",
                    "power_source"],
    primary_metric="tokens_per_s",
)
def build(pt, ctx):
    """LLM train-step sweep over global batch size."""
    c, params, opt_state, step = ctx.memo(
        ("llm_train", pt["arch"]), lambda: _setup(pt["arch"]))
    gb, seq = pt["global_batch"], pt["seq"]
    toks = jnp.asarray(synthetic_tokens(gb, seq, c.vocab)[:, :seq])
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}

    def train():
        p, o = params, opt_state

        def one():
            nonlocal p, o
            p, o, m = step(p, o, batch)
            return m["loss"]

        m = ctx.measure(one)
        tps = tokens_per_s(gb, seq, m.seconds)
        return {"tokens_per_s": tps, "ms_per_step": m.ms,
                "seconds": m.seconds,
                "energy_wh_per_step": m.energy_wh,
                "tokens_per_wh": (gb * seq / m.energy_wh)
                if m.energy_wh > 0 else 0.0}

    return {"train": train}
