"""Serving workload: continuous vs fixed batching x slotted vs paged KV.

The MLPerf-Power/CARAML serving point: drive the ServeEngine with a
seeded synthetic Poisson arrival process and a bimodal short/long token
mix, per (slots x rate x cache x policy) cell:

  decode_tok_s    useful generated tokens per wall second
  ttft_s          mean time-to-first-token (includes queueing)
  wh_per_token    energy per generated token (attributed per request)
  wh_per_request  energy per served request
  occupancy       mean decode-step batch occupancy (active/n_slots)
  speedup_vs_fixed    continuous/fixed tokens/s for the same cell
  speedup_vs_slotted  paged/slotted tokens/s for the same cell

Axes isolate the wins separately: ``policy`` flips only admission
(iteration-level refill vs batch-fill barrier) on identical programs, so
``speedup_vs_fixed`` is the pure scheduling gain; ``cache`` flips only
the KV layout (dense ``max_len`` rows vs ``serve.cache.PagedKVCache``
block tables whose decode attention walks just the blocks a slot owns),
so ``speedup_vs_slotted`` is the pure memory-layout gain; ``sched``
flips only the prefill schedule (whole-prompt-at-admission vs
block-aligned ``chunk_tokens`` slices interleaved with decode, with
block-granular preemption backing decode growth), so
``speedup_vs_phased`` is the pure iteration-level-scheduling delta —
~1.0 here, where every prompt fits one chunk; the serve_slo workload's
``long_prefill`` trace is where it separates. Chunked requires the
paged cache (the Space constraint drops chunked x slotted cells
outright, so the grid carries no skip records). ``kv_dtype`` flips the
paged pool to int8 blocks with per-block-per-head scales (continuous
paged cells only): ``pool_bytes``/``max_concurrency`` carry the
capacity win (same block count at ~half the bytes, so ~2x the
worst-case-length requests per fp byte budget), ``speedup_vs_fp_kv``
and ``wh_per_token_vs_fp_kv`` the perf/energy deltas, and
``kv_stream_prefix_agreement`` the token-stream quality vs the fp32
twin's greedy streams (1.0 on the reduced config — quantization noise
below the argmax margin). All cells share the
batched-prefill + fused-decode serve loop. On CPU the paged
cells run the XLA gather path of ``kernels.ops.paged_decode_attention``;
set ``REPRO_PAGED_IMPL=pallas-interpret`` to push every decode step
through the Pallas kernel in interpret mode instead (the CI correctness
drill — orders of magnitude slower, never a timing baseline). Energy
comes from the runner-selected power backend, labeled ``power_source``.
"""
from __future__ import annotations

import os

import jax

from repro.bench.context import Measurement
from repro.bench.spec import workload
from repro.configs import get_config
from repro.core.params import Space
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.serve.requests import poisson_requests

PROMPT_LEN = 8          # fixed: one prefill trace for the whole sweep
MAX_LEN = 96            # slot capacity (multiple of reduced ssm_chunk)
BLOCK_SIZE = 16         # paged KV block (tokens); 6 blocks per full slot
N_REQUESTS = 48
N_REQUESTS_SMOKE = 64   # enough that the drain tail amortizes away
SEED = 0


def _paged_impl() -> tuple[str, bool]:
    """(paged_impl, interpret) from REPRO_PAGED_IMPL: "xla" (default
    CPU measurement path), "pallas" (real TPU), "pallas-interpret"
    (CI correctness drill on CPU)."""
    mode = os.environ.get("REPRO_PAGED_IMPL", "xla")
    if mode == "pallas-interpret":
        return "pallas", True
    if mode == "pallas":
        return "pallas", False
    return "xla", False


def _engine(ctx, arch: str, n_slots: int, cache: str,
            kv_dtype: str = "fp32") -> ServeEngine:
    def make():
        c = get_config(arch).reduced()
        params = lm.init(jax.random.key(SEED), c)
        impl, interpret = _paged_impl()
        engine = ServeEngine(c, params, n_slots=n_slots, max_len=MAX_LEN,
                             cache=cache, block_size=BLOCK_SIZE,
                             kv_dtype=kv_dtype,
                             paged_impl=impl, paged_interpret=interpret,
                             power_methods=ctx.power_methods)
        # warmup: compile every serve program (prompt-bucket prefill,
        # insert, each paged gather bucket) outside any measured cell —
        # the first serve() otherwise charges XLA compilation to the
        # first policy's wall clock and energy
        engine.warmup(prompt_len=PROMPT_LEN)
        return c, engine

    return ctx.memo(("serve", arch, n_slots, cache, kv_dtype), make)


def stream_agreement(ref_streams: dict, cur_streams: dict) -> float:
    """Mean longest-common-prefix fraction of per-request token streams
    against the reference run: 1.0 = bit-identical generation, lower =
    quantization (or a scheduler bug) steered greedy decoding off the
    reference trajectory at 1-lcp/len of the way through an average
    request. Keyed by rid so completion-order differences don't count."""
    fracs = []
    for rid, rt in ref_streams.items():
        ct = cur_streams.get(rid)
        if ct is None:
            continue
        lcp = 0
        for a, b in zip(rt, ct):
            if a != b:
                break
            lcp += 1
        fracs.append(lcp / max(len(rt), len(ct), 1))
    return sum(fracs) / max(len(fracs), 1)


@workload(
    "serve",
    analog="serving: continuous batching + Wh/token (MLPerf-Power style)",
    space=Space({"arch": ["llama3.2-3b"], "slots": [4, 8],
                 "rate_hz": [100.0, 400.0],
                 "cache": ["slotted", "paged"],
                 "policy": ["fixed", "continuous"],
                 # kv_dtype expands before sched, so an int8 cell's fp32
                 # twin (same sched) is always measured first; int8 only
                 # exists for the paged continuous cells (quantized
                 # blocks live in the pool, and the capacity win is a
                 # continuous-batching story)
                 "kv_dtype": ["fp32", "int8"],
                 # last axis -> phased expands before chunked for every
                 # cell, so the vs_phased ratio's twin is always cached
                 "sched": ["phased", "chunked"]},
                constraints=[lambda pt: not (pt["sched"] == "chunked"
                                             and pt["cache"] == "slotted"),
                             lambda pt: pt["kv_dtype"] == "fp32"
                             or (pt["cache"] == "paged"
                                 and pt["policy"] == "continuous")]),
    smoke={"slots": [4], "rate_hz": [300.0]},
    tags=("serve", "smoke", "full"),
    result_columns=["arch", "cache", "policy", "sched", "kv_dtype",
                    "slots", "rate_hz",
                    "n_tokens", "decode_tok_s", "ttft_s", "occupancy",
                    "wh_per_token", "wh_per_request", "speedup_vs_fixed",
                    "speedup_vs_slotted", "speedup_vs_phased",
                    "pool_bytes", "max_concurrency", "speedup_vs_fp_kv",
                    "kv_stream_prefix_agreement", "power_source"],
    primary_metric="decode_tok_s",
    # mean TTFT includes queueing, and at fixed-policy 300 Hz the queue
    # depth is set by host speed during admission — run-to-run swings of
    # ~1.5x on an otherwise-unchanged build. Wide stamp catches only a
    # real cliff; throughput/energy columns stay on the tight default.
    compare_tols={"ttft_s": 2.0},
)
def build(pt, ctx):
    """Continuous vs fixed batching, slotted vs paged KV, Poisson load."""
    c, engine = _engine(ctx, pt["arch"], pt["slots"], pt["cache"],
                        pt["kv_dtype"])
    n = N_REQUESTS_SMOKE if ctx.smoke else N_REQUESTS
    requests = poisson_requests(n, pt["rate_hz"], c.vocab,
                                prompt_len=PROMPT_LEN, seed=SEED)

    # interpret-mode kernel runs are the CI correctness drill: every
    # number is discarded, so skip the noise repetition and the
    # on-demand ratio baselines — one measured serve after warmup is
    # the whole point (and interpret mode is far too slow to repeat)
    drill = _paged_impl()[1]

    def run_cell():
        # two full repetitions of the cell: the second (steady-state) run
        # is reported, and the pair's throughput disagreement becomes the
        # record's same-point noise figure (source=measure_split) — the
        # serve engine orchestrates its own timing, so without this the
        # runner would fall back to the straggler watchdog's cross-point
        # spread, which mixes multi-second fixed cells with sub-second
        # continuous cells and saturates the compare-gate tolerance.
        first = None if drill else engine.serve(
            requests, policy=pt["policy"], sched=pt["sched"]).summary
        out = engine.serve(requests, policy=pt["policy"],
                           sched=pt["sched"])
        s = out.summary
        if first is not None:
            pair = sorted((first.decode_tok_s, s.decode_tok_s))
            spread = ((pair[1] - pair[0]) / ((pair[0] + pair[1]) / 2)
                      if pair[1] > 0 else 0.0)
            ctx.last_measurement = Measurement(
                seconds=s.wall_s, energy_wh=s.attributed_wh,
                power_source=ctx.power_source, iters=2, warmup=0,
                rel_spread=spread)
        metrics = {
            "n_requests": s.n_requests,
            "n_tokens": s.n_tokens,
            "decode_tok_s": s.decode_tok_s,
            "ttft_s": s.mean_ttft_s,
            "p95_ttft_s": s.p95_ttft_s,
            "occupancy": s.mean_occupancy,
            "wh_per_token": s.wh_per_token,
            "wh_per_request": s.wh_per_request,
            "overhead_wh": s.overhead_wh,
            "wall_s": s.wall_s,
            "seconds": s.wall_s,
            # the arrival-process seed rides along so a record is fully
            # reproducible from its own row (same contract as the
            # serve_slo workload's trace_seed/trace_hash stamp)
            "request_seed": SEED,
        }
        # headline ratios. The twin cells are normally already cached
        # (the Space expands cache=slotted before paged and policy=fixed
        # before continuous), but a filtered run (--points ...) still
        # gets speedup_vs_fixed: that baseline is measured on demand.
        cells = ctx.cache.setdefault("serve_cells", {})
        cell_key = (pt["arch"], pt["slots"], pt["rate_hz"], pt["cache"],
                    pt["kv_dtype"], pt["sched"])
        cells.setdefault(cell_key, {})[pt["policy"]] = metrics
        if pt["cache"] == "paged":
            # structural capacity story: actual pool bytes (int8 blocks +
            # scales when quantized), what the same block count costs at
            # the native KV dtype, and how many worst-case-length
            # requests fit the fp byte budget (see PagedKVCache)
            metrics["pool_bytes"] = engine._paged.pool_bytes
            metrics["pool_bytes_fp"] = engine._paged.pool_bytes_fp
            metrics["max_concurrency"] = engine._paged.max_concurrency
        # int8 vs fp32 twin: throughput/energy ratios plus the
        # token-stream quality figure (streams keyed without kv_dtype so
        # the int8 cell finds its fp32 reference run)
        streams = ctx.cache.setdefault("serve_streams", {})
        skey = (pt["arch"], pt["slots"], pt["rate_hz"], pt["cache"],
                pt["policy"], pt["sched"])
        my_streams = {r.rid: tuple(r.tokens) for r in out.results}
        if pt["kv_dtype"] == "fp32":
            streams[skey] = my_streams
        else:
            fp_key = cell_key[:4] + ("fp32",) + cell_key[5:]
            fp = cells.get(fp_key, {}).get(pt["policy"])
            if fp is not None:   # absent only under --points filters
                metrics["speedup_vs_fp_kv"] = (
                    metrics["decode_tok_s"]
                    / max(fp["decode_tok_s"], 1e-9))
                metrics["wh_per_token_vs_fp_kv"] = (
                    metrics["wh_per_token"]
                    / max(fp["wh_per_token"], 1e-12))
            ref = streams.get(skey)
            if ref is not None:
                metrics["kv_stream_prefix_agreement"] = stream_agreement(
                    ref, my_streams)
        if pt["policy"] == "continuous" and not drill:
            fixed = cells[cell_key].get("fixed")
            if fixed is None:
                baseline = engine.serve(requests, policy="fixed",
                                        sched=pt["sched"])
                fixed = {"decode_tok_s": baseline.summary.decode_tok_s}
                cells[cell_key]["fixed"] = fixed
            metrics["speedup_vs_fixed"] = (
                metrics["decode_tok_s"] / max(fixed["decode_tok_s"], 1e-9))
        if pt["cache"] == "paged":
            # slotted twin is always fp32 (no quantized slotted cells)
            slot_key = (pt["arch"], pt["slots"], pt["rate_hz"], "slotted",
                        "fp32", pt["sched"])
            slotted = cells.get(slot_key, {}).get(pt["policy"])
            if slotted is not None:   # absent for chunked (no slotted twin)
                metrics["speedup_vs_slotted"] = (
                    metrics["decode_tok_s"]
                    / max(slotted["decode_tok_s"], 1e-9))
        if pt["sched"] == "chunked":
            phase_key = (pt["arch"], pt["slots"], pt["rate_hz"],
                         pt["cache"], pt["kv_dtype"], "phased")
            phased = cells.get(phase_key, {}).get(pt["policy"])
            if phased is not None:   # absent only under --points filters
                metrics["speedup_vs_phased"] = (
                    metrics["decode_tok_s"]
                    / max(phased["decode_tok_s"], 1e-9))
        return metrics

    return {"serve": run_cell}
