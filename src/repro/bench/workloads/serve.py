"""Serving workload: continuous batching vs fixed batch under Poisson load.

The MLPerf-Power/CARAML serving point: drive the ServeEngine with a
seeded synthetic Poisson arrival process and a bimodal short/long token
mix, per (slots x rate x policy) cell:

  decode_tok_s    useful generated tokens per wall second
  ttft_s          mean time-to-first-token (includes queueing)
  wh_per_token    energy per generated token (attributed per request)
  wh_per_request  energy per served request
  speedup_vs_fixed  continuous/fixed tokens/s for the same cell

Both policies run the SAME jitted programs on the SAME slot pool; the
only difference is admission (iteration-level refill vs batch-fill
barrier), so the speedup column isolates the scheduling win. Energy comes
from the runner-selected power backend, labeled in ``power_source``.
"""
from __future__ import annotations

import jax

from repro.bench.spec import workload
from repro.configs import get_config
from repro.core.params import Space
from repro.models import lm
from repro.serve.engine import ServeEngine
from repro.serve.requests import poisson_requests

PROMPT_LEN = 8          # fixed: one prefill trace for the whole sweep
MAX_LEN = 96            # slot capacity (multiple of reduced ssm_chunk)
N_REQUESTS = 48
N_REQUESTS_SMOKE = 64   # enough that the drain tail amortizes away
SEED = 0


def _engine(ctx, arch: str, n_slots: int) -> ServeEngine:
    def make():
        c = get_config(arch).reduced()
        params = lm.init(jax.random.key(SEED), c)
        engine = ServeEngine(c, params, n_slots=n_slots, max_len=MAX_LEN,
                             power_methods=ctx.power_methods)
        # warmup: compile prefill + slot decode outside any measured cell
        # (the first serve() otherwise charges XLA compilation to the
        # first policy's wall clock and energy)
        engine.serve(poisson_requests(n_slots, 1e6, c.vocab,
                                      prompt_len=PROMPT_LEN, seed=SEED + 1))
        return c, engine

    return ctx.memo(("serve", arch, n_slots), make)


@workload(
    "serve",
    analog="serving: continuous batching + Wh/token (MLPerf-Power style)",
    space=Space({"arch": ["llama3.2-3b"], "slots": [4, 8],
                 "rate_hz": [100.0, 400.0],
                 "policy": ["fixed", "continuous"]}),
    smoke={"slots": [4], "rate_hz": [300.0]},
    tags=("serve", "smoke", "full"),
    result_columns=["arch", "policy", "slots", "rate_hz", "n_tokens",
                    "decode_tok_s", "ttft_s", "wh_per_token",
                    "wh_per_request", "speedup_vs_fixed", "power_source"],
    primary_metric="decode_tok_s",
)
def build(pt, ctx):
    """Continuous vs fixed batching under seeded Poisson arrivals."""
    c, engine = _engine(ctx, pt["arch"], pt["slots"])
    n = N_REQUESTS_SMOKE if ctx.smoke else N_REQUESTS
    requests = poisson_requests(n, pt["rate_hz"], c.vocab,
                                prompt_len=PROMPT_LEN, seed=SEED)

    def run_cell():
        out = engine.serve(requests, policy=pt["policy"])
        s = out.summary
        metrics = {
            "n_requests": s.n_requests,
            "n_tokens": s.n_tokens,
            "decode_tok_s": s.decode_tok_s,
            "ttft_s": s.mean_ttft_s,
            "p95_ttft_s": s.p95_ttft_s,
            "wh_per_token": s.wh_per_token,
            "wh_per_request": s.wh_per_request,
            "overhead_wh": s.overhead_wh,
            "wall_s": s.wall_s,
            "seconds": s.wall_s,
        }
        # every continuous record carries the headline ratio. The fixed
        # twin is normally already cached (the policy axis expands fixed
        # first), but a filtered run (--points policy=continuous) still
        # gets the column: the baseline is measured on demand.
        cells = ctx.cache.setdefault("serve_cells", {})
        cell_key = (pt["arch"], pt["slots"], pt["rate_hz"])
        cells.setdefault(cell_key, {})[pt["policy"]] = metrics
        if pt["policy"] == "continuous":
            fixed = cells[cell_key].get("fixed")
            if fixed is None:
                baseline = engine.serve(requests, policy="fixed")
                fixed = {"decode_tok_s": baseline.summary.decode_tok_s}
                cells[cell_key]["fixed"] = fixed
            metrics["speedup_vs_fixed"] = (
                metrics["decode_tok_s"] / max(fixed["decode_tok_s"], 1e-9))
        return metrics

    return {"serve": run_cell}
