"""The paper workloads, registered on import.

Importing this package populates the WorkloadSpec registry; the modules
must stay side-effect-free beyond registration (no jax device access at
import time) so the CLI can configure the host platform device count
before the backend initializes.
"""
from repro.bench.workloads import (  # noqa: F401 - registration imports
    heatmap,
    kernels,
    llm_train,
    pipeline_gpt,
    resilience,
    resnet50,
    roofline,
    serve,
    serve_slo,
)
