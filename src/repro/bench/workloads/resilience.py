"""Resilience sweep: what fault tolerance costs in time and energy.

The paper measures steady-state throughput and energy; this workload
measures the other axis every production run actually pays for —
recovery. Each cell runs the full crash→backoff→resume machinery
(``faults.schedule`` + ``faults.supervisor`` + the training loop's
auto-resume) end to end under a deterministic, seeded fault schedule,
against the checkpoint cadence axis of the Young/Daly tradeoff:

  ckpt_every small  -> little recompute after a crash, more ckpt I/O
  ckpt_every large  -> cheap steady state, a crash wastes up to a full
                       cadence of steps

Per cell the sweep records compare-gated figures of merit:

  recovery_s               crash -> first completed resumed step
  wasted_tokens            recomputed steps x tokens/step (bounded by
                           ckpt_every x tokens/step when resume found a
                           valid checkpoint)
  goodput_tokens_per_s     delivered tokens / end-to-end wall including
                           crashes, backoff, and recompute
  wh_overhead_resilience   cell energy minus the fault-free, ckpt-free
                           twin of the same arch — the energy premium
                           of resilience itself

plus ``loss_bitmatch``: the resumed run's loss trace must equal the
uninterrupted twin's trace at every overlapping step, element-exact —
the invariant that makes every other number here trustworthy (resume
restored the real state; step-indexed data kept the stream aligned).
``schedule_hash`` stamps the cell's exact fault schedule into the
record the way ``trace_hash`` stamps serve traces.

This is the first benchmark that exercises ``ckpt/`` end to end:
atomic save, digest verification, corrupted-step fallback, restore.
"""
from __future__ import annotations

import shutil
import tempfile
import time

import jax

from repro.bench.spec import workload
from repro.configs import get_config
from repro.core.params import Space
from repro.faults.schedule import FaultSchedule
from repro.faults.supervisor import run_supervised
from repro.launch.train import make_data_fn
from repro.models import lm
from repro.power.ctxmgr import get_power
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step

TOTAL_STEPS = 30
GLOBAL_BATCH = 4
SEQ = 32
FAULT_SEED = 0
MAX_RESTARTS = 5


def _setup(ctx, arch: str):
    """Config, jitted step (warmed), step-indexed data, fresh-state
    factory — shared by every cell and by the fault-free twin, so the
    loss traces being compared ran the identical compiled program."""

    def make():
        c = get_config(arch).reduced(d_model=64, n_layers=2, d_ff=256,
                                     vocab=512, n_heads=4, n_kv_heads=4,
                                     d_head=16)
        oc = OptConfig(warmup=2, total_steps=TOTAL_STEPS)
        step = jax.jit(make_train_step(c, oc, StepConfig(microbatches=1)),
                       donate_argnums=(0, 1))
        data = make_data_fn(c, GLOBAL_BATCH, SEQ, seed=0)

        def init_state():
            p = lm.init(jax.random.key(0), c)
            return p, opt_init(oc, p)

        # warm the jit cache outside any timed window — otherwise the
        # twin (which runs first) eats the compile and the "overhead of
        # resilience" goes negative
        p, o = init_state()
        jax.block_until_ready(step(p, o, data(0))[2]["loss"])
        return c, step, data, init_state

    return ctx.memo(("resilience", arch), make)


def _twin(ctx, arch: str):
    """The fault-free, checkpoint-free twin: same arch, same seed, same
    compiled step, no faults, no ckpt I/O. Its wall/energy is the
    baseline the resilience overhead is measured against; its loss
    trace is the bit-equality reference."""

    def make():
        _, step, data, init_state = _setup(ctx, arch)
        cfg = LoopConfig(total_steps=TOTAL_STEPS, ckpt_every=10 ** 9,
                         ckpt_dir=None, log_every=0,
                         seq_len=SEQ, global_batch=GLOBAL_BATCH)
        p, o = init_state()
        with get_power(ctx.power_methods, ctx.power_interval_ms) as scope:
            t0 = time.perf_counter()
            res = train_loop(step, p, o, data, cfg)
            wall = time.perf_counter() - t0
        return {"wall_s": wall, "energy_wh": scope.total_energy_wh(),
                "losses": list(res.losses)}

    return ctx.memo(("resilience_twin", arch), make)


@workload(
    "resilience",
    analog="fault-tolerance cost: recovery time + energy vs ckpt cadence",
    space=Space({"arch": ["gpt-117m"],
                 "fault_preset": ["none", "crash_mid", "ckpt_corrupt"],
                 "ckpt_every": [5, 10, 20]}),
    smoke={"fault_preset": ["none", "crash_mid"], "ckpt_every": [10]},
    tags=("train", "smoke", "full"),
    result_columns=["arch", "fault_preset", "ckpt_every", "final_step",
                    "restarts", "recovery_s", "wasted_tokens",
                    "goodput_tokens_per_s", "wh_overhead_resilience",
                    "loss_bitmatch", "ckpt_fallbacks", "schedule_hash",
                    "power_source"],
    primary_metric="goodput_tokens_per_s",
    # end-to-end CPU wall differences, not steady-state cells: recovery
    # is ~0.1 s of scheduler wakeups and the Wh overhead is a difference
    # of two integrals over ~1 s windows — both wobble by multiples
    # run-to-run, so the compare gate checks presence/sign, not percent
    compare_tols={"recovery_s": 1.5, "wh_overhead_resilience": 3.0,
                  "goodput_tokens_per_s": 0.4, "final_loss": 0.05},
)
def build(pt, ctx):
    """One supervised crash/resume run per (fault_preset, ckpt_every)."""
    arch, preset = pt["arch"], pt["fault_preset"]
    ckpt_every = int(pt["ckpt_every"])
    _, step, data, init_state = _setup(ctx, arch)
    twin = _twin(ctx, arch)
    tokens_per_step = GLOBAL_BATCH * SEQ

    def run():
        # fresh schedule per attempt-set: `fired` is shared across the
        # supervisor's restarts of ONE run, not across runner retries
        faults = FaultSchedule.from_preset(preset, FAULT_SEED, TOTAL_STEPS)
        ckpt_dir = tempfile.mkdtemp(prefix=f"resil_{preset}_{ckpt_every}_")
        cfg = LoopConfig(total_steps=TOTAL_STEPS, ckpt_every=ckpt_every,
                         ckpt_dir=ckpt_dir, log_every=0,
                         seq_len=SEQ, global_batch=GLOBAL_BATCH)

        def run_once(hook):
            p, o = init_state()   # the jitted step donated the last ones
            return train_loop(step, p, o, data, cfg, hooks=[hook],
                              faults=faults)

        try:
            with get_power(ctx.power_methods,
                           ctx.power_interval_ms) as scope:
                t0 = time.perf_counter()
                sup = run_supervised(run_once, ckpt_dir=ckpt_dir,
                                     max_restarts=MAX_RESTARTS,
                                     seed=FAULT_SEED)
                wall = time.perf_counter() - t0
            energy_wh = scope.total_energy_wh()
        finally:
            shutil.rmtree(ckpt_dir, ignore_errors=True)

        res = sup.result
        # the final attempt's losses cover resumed_from..total; the twin
        # ran the same steps uninterrupted — element-exact or the resume
        # restored the wrong state / desynced the data stream
        tail = twin["losses"][len(twin["losses"]) - len(res.losses):]
        bitmatch = (len(res.losses) > 0 and len(tail) == len(res.losses)
                    and all(a == b for a, b in zip(tail, res.losses)))
        delivered = res.final_step * tokens_per_step
        return {
            "final_step": res.final_step,
            "final_loss": res.losses[-1] if res.losses else float("nan"),
            "loss_bitmatch": 1.0 if bitmatch else 0.0,
            "restarts": sup.restarts,
            "recovery_s": round(sup.recovery_s, 6),
            "backoff_s": round(sup.backoff_s, 6),
            "wasted_tokens": sup.wasted_steps * tokens_per_step,
            "tokens_per_step": tokens_per_step,
            "goodput_tokens_per_s": delivered / max(wall, 1e-9),
            "energy_wh": energy_wh,
            "wh_overhead_resilience": energy_wh - twin["energy_wh"],
            "ckpt_fallbacks": sup.ckpt_fallbacks,
            "rescales": sup.rescales,
            "schedule_hash": faults.schedule_hash,
        }

    return {"run": run}
