"""Paper Fig. 4 analog: throughput heatmap over (data-parallel degree x
global batch size), with infeasible cells excluded by constraints (the
report renders them as OOM, like the paper's figure).

This is the ablation-automation CARAML's JUBE layer provides: the Space
constraints encode the paper's "global batch not divisible by
micro_batch x dp" exclusion. The data-parallel degree is the standard
``placement`` axis (``dp1``..``dp8``), so the CLI sizes the forced host
platform from the sweep itself and the runner derives the scaling
metrics against the dp1 column.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.bench.spec import Placement, workload
from repro.configs import get_config
from repro.core.metrics import tokens_per_s
from repro.core.params import Space
from repro.data.synthetic import synthetic_tokens
from repro.models import lm
from repro.parallel import sharding as shd
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step

SEQ = 64


def _dp(pt) -> int:
    return Placement.of(pt["placement"]).n_devices


def _setup():
    c = get_config("gpt-117m").reduced(n_layers=2, d_model=128, d_ff=256,
                                       n_heads=4, n_kv_heads=4, d_head=32,
                                       vocab=2048)
    oc = OptConfig(warmup=1, total_steps=100)
    params = lm.init(jax.random.key(0), c)
    opt_state = opt_init(oc, params)
    return c, oc, params, opt_state


def _dp_step(ctx):
    placement = ctx.placement

    def make():
        c, oc, _, _ = ctx.memo("heatmap", _setup)
        plan = shd.make_dp_plan(ctx.mesh())
        return jax.jit(make_train_step(c, oc, StepConfig())), plan

    return ctx.memo(("heatmap_dp", placement.label), make)


@workload(
    "heatmap",
    analog="Fig. 4 (dp x global-batch throughput heatmap)",
    space=Space({"placement": ["dp1", "dp2", "dp4", "dp8"],
                 "global_batch": [8, 16, 32],
                 "micro_batch": [1]},
                [lambda pt: pt["global_batch"] % (pt["micro_batch"]
                                                  * _dp(pt)) == 0,
                 lambda pt: pt["global_batch"] >= _dp(pt)]),
    smoke={"placement": ["dp1", "dp2"], "global_batch": [8]},
    tags=("train", "smoke", "full"),
    result_columns=["placement", "global_batch", "tokens_per_s",
                    "tok_s_per_device", "scaling_efficiency", "ms",
                    "power_source"],
    primary_metric="tokens_per_s",
    heatmap_keys=("placement", "global_batch", "tokens_per_s"),
)
def build(pt, ctx):
    """dp x batch train-step sweep (paper Fig. 4)."""
    c, oc, params, opt_state = ctx.memo("heatmap", _setup)
    step, plan = _dp_step(ctx)
    gb = pt["global_batch"]
    toks = jax.device_put(
        jnp.asarray(synthetic_tokens(gb, SEQ, c.vocab)[:, :SEQ]),
        shd.batch_sharding(plan, (gb, SEQ)))
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

    def run():
        def one():
            p, o, m = step(params, opt_state, batch)
            return p

        m = ctx.measure(one)
        return {"tokens_per_s": tokens_per_s(gb, SEQ, m.seconds),
                "ms": m.ms, "seconds": m.seconds,
                "energy_wh": m.energy_wh}

    return {"run": run}
