"""Paper Fig. 4 analog: throughput heatmap over (data-parallel degree x
global batch size), with infeasible cells excluded by constraints (the
report renders them as OOM, like the paper's figure).

This is the ablation-automation CARAML's JUBE layer provides: the Space
constraints encode the paper's "global batch not divisible by
micro_batch x dp" exclusion. The CLI forces a >=8-device host platform
before the backend initializes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.bench.spec import workload
from repro.configs import get_config
from repro.core.metrics import tokens_per_s
from repro.core.params import Space, divisible_batch
from repro.data.synthetic import synthetic_tokens
from repro.launch.mesh import make_mesh
from repro.models import lm
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step

SEQ = 64


def _setup():
    c = get_config("gpt-117m").reduced(n_layers=2, d_model=128, d_ff=256,
                                       n_heads=4, n_kv_heads=4, d_head=32,
                                       vocab=2048)
    oc = OptConfig(warmup=1, total_steps=100)
    params = lm.init(jax.random.key(0), c)
    opt_state = opt_init(oc, params)
    return c, oc, params, opt_state


def _dp_step(ctx, dp: int):
    def make():
        c, oc, _, _ = ctx.memo("heatmap", _setup)
        mesh = make_mesh((dp,), ("data",))
        bsh = NamedSharding(mesh, P("data"))
        return jax.jit(make_train_step(c, oc, StepConfig())), bsh

    return ctx.memo(("heatmap_dp", dp), make)


@workload(
    "heatmap",
    analog="Fig. 4 (dp x global-batch throughput heatmap)",
    space=Space({"dp": [1, 2, 4, 8], "global_batch": [8, 16, 32],
                 "micro_batch": [1]},
                [divisible_batch,
                 lambda pt: pt["global_batch"] >= pt["dp"]]),
    smoke={"dp": [1, 2], "global_batch": [8]},
    n_devices=8,
    tags=("train", "smoke", "full"),
    result_columns=["dp", "global_batch", "tokens_per_s", "ms",
                    "power_source"],
    primary_metric="tokens_per_s",
    heatmap_keys=("dp", "global_batch", "tokens_per_s"),
)
def build(pt, ctx):
    """dp x batch train-step sweep (paper Fig. 4)."""
    c, oc, params, opt_state = ctx.memo("heatmap", _setup)
    step, bsh = _dp_step(ctx, pt["dp"])
    gb = pt["global_batch"]
    toks = jax.device_put(
        jnp.asarray(synthetic_tokens(gb, SEQ, c.vocab)[:, :SEQ]), bsh)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}

    def run():
        def one():
            p, o, m = step(params, opt_state, batch)
            return p

        m = ctx.measure(one)
        return {"tokens_per_s": tokens_per_s(gb, SEQ, m.seconds),
                "ms": m.ms, "seconds": m.seconds,
                "energy_wh": m.energy_wh}

    return {"run": run}
