"""RunContext + Measurement: the runner-owned side of a workload run.

Everything the benchmarks used to hand-roll (``pick_power_methods`` /
``time_step`` / per-file caches) lives here once: the selected power
backend with its label, warmup/iters timing with trapezoid-integrated
energy, and a cross-point memo so sweeps compile jitted programs once.
"""
from __future__ import annotations

import pathlib
import time
from dataclasses import dataclass
from typing import Callable, Optional, Sequence

from repro.power.ctxmgr import get_power
from repro.power.methods import PowerMethod


@dataclass(frozen=True)
class Measurement:
    """One timed region: seconds and energy per iteration, labeled."""

    seconds: float              # wall seconds per iteration
    energy_wh: float            # Wh per iteration (0.0 when power="none")
    power_source: str
    iters: int
    warmup: int
    #: relative spread between the two timed half-windows — a *same-point*
    #: repetition-noise estimate for cross-run comparison tolerances,
    #: unlike the straggler watchdog's cross-point spread which mixes in
    #: sweep heterogeneity. None when the region ran as a single window
    #: (iters=1): one sample cannot estimate spread, and a fabricated 0.0
    #: would give the least-evidence configuration the tightest gate.
    rel_spread: Optional[float] = None

    @property
    def us(self) -> float:
        return self.seconds * 1e6

    @property
    def ms(self) -> float:
        return self.seconds * 1e3


class RunContext:
    """Per-run services handed to ``WorkloadSpec.build``.

    ``measure`` is the single timing/energy path for every workload;
    ``memo`` caches expensive setup (params, jitted steps) across the
    points of a sweep; ``power_methods``/``power_source`` are available
    directly for workloads that orchestrate their own measurement (the
    serve engine samples power synchronously at step boundaries).

    ``placement`` is the resolved device mesh of the point currently
    building (set by the runner before each ``build`` call); ``mesh()``
    materializes the matching ``jax.sharding.Mesh`` via ``launch.mesh``,
    cached per placement so a sweep builds each mesh once.
    """

    def __init__(self, *, out_dir="artifacts/bench",
                 power_methods: Sequence[PowerMethod] = (),
                 power_source: str = "none",
                 power_interval_ms: float = 20.0,
                 warmup: int = 1, iters: int = 3, smoke: bool = False,
                 placement=None):
        self.out_dir = pathlib.Path(out_dir)
        self.power_methods = list(power_methods)
        self.power_source = power_source
        self.power_interval_ms = power_interval_ms
        self.warmup = warmup
        self.iters = iters
        self.smoke = smoke
        self.placement = placement     # repro.bench.spec.Placement | None
        self.cache: dict = {}
        self._meshes: dict = {}
        self.last_measurement: Optional[Measurement] = None

    def memo(self, key, factory: Callable[[], object]):
        """Cross-point cache: build once, reuse for every sweep point."""
        if key not in self.cache:
            self.cache[key] = factory()
        return self.cache[key]

    def mesh(self, placement=None):
        """The ``jax.sharding.Mesh`` for ``placement`` (default: the
        current point's), built once per distinct mesh shape."""
        placement = placement if placement is not None else self.placement
        if placement is None:
            raise RuntimeError("RunContext has no placement — mesh() is "
                               "only available inside a runner-driven "
                               "build")
        key = placement.label
        if key not in self._meshes:
            from repro.launch.mesh import mesh_for
            self._meshes[key] = mesh_for(placement)
        return self._meshes[key]

    def measure(self, fn: Callable, *args, warmup: Optional[int] = None,
                iters: Optional[int] = None, power: bool = True,
                **kw) -> Measurement:
        """Warmup + timed iterations around ``fn(*args, **kw)``.

        Blocks on the last returned value (jax async dispatch) before
        reading the clock; wraps the timed window in the jpwr-style power
        scope when measurement is enabled, charging energy per iteration.

        With ``iters >= 2`` the timed region runs as two blocked
        half-windows; the relative disagreement of their per-iteration
        times is returned as ``rel_spread`` — the same-point noise figure
        the cross-run comparison tolerance model widens by. Iterations
        still dispatch asynchronously within each half, so only one extra
        device sync is added per measurement.
        """
        import jax

        warmup = self.warmup if warmup is None else warmup
        iters = max(self.iters if iters is None else iters, 1)
        out = None
        for _ in range(warmup):
            out = fn(*args, **kw)
        if out is not None:
            jax.block_until_ready(out)
        methods = self.power_methods if power else []
        halves = [iters] if iters < 2 else [iters - iters // 2, iters // 2]

        def timed_window(n: int) -> float:
            t0 = time.perf_counter()
            o = None
            for _ in range(n):
                o = fn(*args, **kw)
            if o is not None:
                jax.block_until_ready(o)
            return time.perf_counter() - t0

        if methods:
            with get_power(methods, self.power_interval_ms) as scope:
                times = [timed_window(n) for n in halves]
            energy = scope.total_energy_wh() / iters
        else:
            times = [timed_window(n) for n in halves]
            energy = 0.0
        dt = sum(times) / iters
        rel_spread = None
        if len(times) == 2 and dt > 0.0:
            per = [t / n for t, n in zip(times, halves)]
            rel_spread = abs(per[0] - per[1]) / dt
        m = Measurement(seconds=dt, energy_wh=energy,
                        power_source=self.power_source if power
                        else "none",
                        iters=iters, warmup=warmup, rel_spread=rel_spread)
        self.last_measurement = m
        return m
