"""WorkloadRunner — executes one WorkloadSpec end to end.

Extends the core suite runner machinery (`repro.core.runner.run_attempts`)
to the declarative WorkloadSpec contract: expand the point space (smoke
preset / ``--points`` overrides applied), select the power backend once
(RAPL -> TPU-model -> synthetic, labeled), call ``spec.build`` per point,
run each returned step thunk with retries and straggler detection, and
persist normalized ``ResultRecord``s incrementally + a manifest.

Placement-aware: each point resolves its device mesh via
``spec.placement_for`` (the ``placement`` Space axis, else the spec
default). The runner hands the resolved :class:`~repro.bench.spec.Placement`
to the build through ``ctx.placement``/``ctx.mesh()``, sizes the power
backend to the point's mesh (per-device attribution: a dp4 cell is
billed four devices' watts, not one), and stamps the cross-placement
scaling metrics (``records.stamp_scaling_metrics``) into every sweep.
A point whose mesh exceeds the local device count is not an error: the
runner renders a ``launch.slurm`` job script sized to the mesh and
records the point as ``deferred`` — the sweep's local cells still
measure, and the script carries the oversized cell to the cluster.
"""
from __future__ import annotations

import math
import os
import pathlib
import re
import time
from typing import Optional, Sequence

from repro.bench import envtune
from repro.bench.context import RunContext
from repro.bench.records import (
    ResultRecord, save_records, stamp_scaling_metrics,
)
from repro.bench.spec import Placement, WorkloadSpec
from repro.core.manifest import git_sha, write_manifest
from repro.core.results import table
from repro.core.runner import StragglerWatchdog, run_attempts
from repro.launch.slurm import render_bench_job
from repro.power.methods import PowerMethod, select_power_methods


def _emulation_device_cap() -> Optional[int]:
    """Physical-core cap for scaling metrics when the "devices" are
    forced host-platform fakes (``--xla_force_host_platform_device_count``
    on a CPU backend): N fake devices share ``cores`` real cores, so
    per-device figures normalize by ``min(n, cores)``. Returns None on
    real accelerators (or single-device CPU) — classic semantics."""
    try:
        import jax
        if jax.default_backend() != "cpu" or jax.device_count() <= 1:
            return None
    except Exception:
        return None
    try:
        return len(os.sched_getaffinity(0)) or None
    except (AttributeError, OSError):
        return os.cpu_count()


class WorkloadRunner:
    def __init__(self, spec: WorkloadSpec, *,
                 out_dir: str = "artifacts/bench",
                 power: str = "auto",
                 power_methods: Optional[Sequence[PowerMethod]] = None,
                 power_source: Optional[str] = None,
                 warmup: int = 1, iters: int = 3,
                 smoke: bool = False,
                 point_overrides: Optional[dict] = None,
                 retries: int = 1,
                 power_interval_ms: float = 20.0):
        self.spec = spec
        self.out = pathlib.Path(out_dir) / spec.name
        self.smoke = smoke
        self.point_overrides = point_overrides
        self._power_arg = power
        self._power_injected = power_methods is not None
        self._power_by_n: dict[int, list] = {}
        if self._power_injected:
            self.power_methods = list(power_methods)
            self.power_source = power_source or (
                self.power_methods[0].name if self.power_methods else "none")
        else:
            n = spec.max_devices(smoke, point_overrides)
            self.power_methods, self.power_source = select_power_methods(
                power, n_devices=n)
            self._power_by_n[n] = self.power_methods
        self.warmup = warmup
        self.iters = iters
        self.retries = retries
        self.power_interval_ms = power_interval_ms
        self.watchdog = StragglerWatchdog()
        self.records: list[ResultRecord] = []

    def _power_for(self, n_devices: int) -> list:
        """Power methods sized to one point's mesh — per-device energy
        attribution for placement sweeps. Injected methods (tests, a
        caller-owned scope) are used as-is."""
        if self._power_injected:
            return self.power_methods
        if n_devices not in self._power_by_n:
            self._power_by_n[n_devices], _ = select_power_methods(
                self._power_arg, n_devices=n_devices)
        return self._power_by_n[n_devices]

    def run(self, verbose: bool = True) -> list[ResultRecord]:
        spec = self.spec
        self.out.mkdir(parents=True, exist_ok=True)
        write_manifest(self.out, {
            "workload": spec.name, "analog": spec.analog,
            "placement": spec.placement.label,
            "max_devices": spec.max_devices(self.smoke,
                                            self.point_overrides),
            "tags": sorted(spec.tags),
            "power_source": self.power_source, "smoke": self.smoke,
        })
        ctx = RunContext(out_dir=self.out,
                         power_methods=self.power_methods,
                         power_source=self.power_source,
                         power_interval_ms=self.power_interval_ms,
                         warmup=self.warmup, iters=self.iters,
                         smoke=self.smoke)
        points = spec.space_for(self.smoke, self.point_overrides).expand()
        for i, pt in enumerate(points):
            rec = self._run_point(pt, ctx)
            self.records.append(rec)
            if verbose:
                print(f"[{spec.name}] {i + 1}/{len(points)} {rec.flat()}",
                      flush=True)
            # scaling metrics join cells ACROSS the sweep (each scaled
            # cell against its 1-device twin), so re-derive over the
            # whole record list before each incremental save
            stamp_scaling_metrics(self.records,
                                  device_cap=_emulation_device_cap())
            save_records(self.records, self.out)
        return self.records

    def _defer_point(self, pt: dict, placement: Placement,
                     rec: ResultRecord, have: int) -> ResultRecord:
        """Render the Slurm script that carries an oversized mesh to the
        cluster; the record keeps the sweep's bookkeeping honest."""
        # one script PER POINT (the placement label alone would let
        # same-mesh cells of a sweep clobber each other), forwarding
        # this run's power/out/warmup/iters so the cluster record joins
        # the local result set by point key
        slug = "_".join(f"{k}{pt[k]}" for k in sorted(pt)
                        if k != "placement")
        slug = re.sub(r"[^A-Za-z0-9._-]+", "-", slug)
        name = f"{self.spec.name}_{placement.label}" + (f"_{slug}" if slug
                                                        else "")
        power = self._power_arg if not self._power_injected \
            else self.power_source
        script = render_bench_job(workload=self.spec.name,
                                  placement=placement, point=pt,
                                  out=str(self.out.parent), power=power,
                                  warmup=self.warmup, iters=self.iters,
                                  job_suffix=f"_{slug}" if slug else "")
        path = self.out / "slurm" / f"{name}.sbatch"
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(script)
        rec.status = "deferred"
        rec.error = (f"mesh {placement.label} needs {placement.n_devices} "
                     f"devices, process has {have}; sbatch script rendered "
                     f"to {path}")
        rec.metrics["slurm_script"] = str(path)
        return rec

    def _run_point(self, pt: dict, ctx: RunContext) -> ResultRecord:
        spec = self.spec
        ctx.last_measurement = None
        placement = spec.placement_for(pt)
        rec = ResultRecord(workload=spec.name, point=dict(pt),
                           power_source=self.power_source,
                           placement=placement.dict(),
                           git_sha=git_sha())
        import jax
        have = jax.device_count()
        if placement.n_devices > have:
            return self._defer_point(pt, placement, rec, have)
        ctx.placement = placement
        ctx.power_methods = self._power_for(placement.n_devices)
        t0 = time.perf_counter()
        backoff_total = 0.0
        ok, step_fns, info = run_attempts(
            "build", lambda: spec.build(pt, ctx), self.retries,
            log_prefix=f"[{spec.name}] ", backoff_base=0.05)
        rec.attempts = info.attempts
        backoff_total += info.backoff_s
        if not ok:
            rec.status, rec.error = "error", step_fns["build_error"]
            return rec
        for name, fn in step_fns.items():
            ok, metrics, info = run_attempts(
                name, fn, self.retries, log_prefix=f"[{spec.name}] ",
                backoff_base=0.05)
            rec.attempts = max(rec.attempts, info.attempts)
            backoff_total += info.backoff_s
            if not ok:
                rec.status, rec.error = "error", metrics[f"{name}_error"]
                break
            rec.metrics.update(metrics or {})
        if backoff_total > 0.0 or rec.attempts > 1:
            rec.metrics["retry_backoff_s"] = round(backoff_total, 6)
        # environment-tuning provenance (tcmalloc preload / XLA step
        # marker): a tuned run must never silently compare against an
        # untuned baseline as if only the code changed
        tuning = envtune.active()
        if tuning:
            rec.metrics["env_tuning"] = tuning
        dt = time.perf_counter() - t0
        if self.watchdog.observe(len(self.records), dt):
            rec.metrics["straggler"] = True
        # tolerance inputs for `repro.bench compare`: prefer the split
        # timed-window spread of this point's own ctx.measure call (pure
        # repetition noise); the watchdog's warmup-seeded spread is the
        # fallback for workloads that orchestrate their own timing, and
        # mixes in cross-point sweep heterogeneity (hence the cap in
        # compare.effective_tolerance)
        m = ctx.last_measurement
        if m is not None and m.rel_spread is not None:
            # two timed half-windows back this estimate, not the
            # watchdog's cross-point count
            rel_std, noise_src, samples = m.rel_spread, "measure_split", 2
        else:
            rel_std, noise_src, samples = (self.watchdog.rel_std(),
                                           "watchdog", self.watchdog.n)
        rec.noise = {"rel_std": round(rel_std, 6), "source": noise_src,
                     "samples": samples,
                     "point_seconds": round(dt, 6)}
        if spec.compare_tols:
            # non-finite floats would serialize as bare `Infinity` — not
            # RFC JSON, and the baseline store is a committed, diffable
            # artifact; "inf" parses back via float() in compare
            rec.noise["tols"] = {
                k: v if isinstance(v, (int, float)) and math.isfinite(v)
                else "inf"
                for k, v in spec.compare_tols.items()}
        return rec

    def result_table(self) -> str:
        flat = [r.flat() for r in self.records]
        return table(flat, self.spec.result_columns)
