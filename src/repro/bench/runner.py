"""WorkloadRunner — executes one WorkloadSpec end to end.

Extends the core suite runner machinery (`repro.core.runner.run_attempts`)
to the declarative WorkloadSpec contract: expand the point space (smoke
preset / ``--points`` overrides applied), select the power backend once
(RAPL -> TPU-model -> synthetic, labeled), call ``spec.build`` per point,
run each returned step thunk with retries and straggler detection, and
persist normalized ``ResultRecord``s incrementally + a manifest.
"""
from __future__ import annotations

import math
import pathlib
import time
from typing import Optional, Sequence

from repro.bench.context import RunContext
from repro.bench.records import ResultRecord, save_records
from repro.bench.spec import WorkloadSpec
from repro.core.manifest import git_sha, write_manifest
from repro.core.results import table
from repro.core.runner import StragglerWatchdog, run_attempts
from repro.power.methods import PowerMethod, select_power_methods


class DeviceCountError(RuntimeError):
    """The workload needs more jax devices than this process has."""

    def __init__(self, spec: WorkloadSpec, have: int):
        super().__init__(
            f"workload {spec.name!r} needs {spec.n_devices} devices, "
            f"process has {have}; run via `python -m repro.bench run` "
            f"(which forces a host platform device count) or set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{spec.n_devices}")
        self.spec = spec
        self.have = have


class WorkloadRunner:
    def __init__(self, spec: WorkloadSpec, *,
                 out_dir: str = "artifacts/bench",
                 power: str = "auto",
                 power_methods: Optional[Sequence[PowerMethod]] = None,
                 power_source: Optional[str] = None,
                 warmup: int = 1, iters: int = 3,
                 smoke: bool = False,
                 point_overrides: Optional[dict] = None,
                 retries: int = 1,
                 power_interval_ms: float = 20.0):
        self.spec = spec
        self.out = pathlib.Path(out_dir) / spec.name
        if power_methods is not None:
            self.power_methods = list(power_methods)
            self.power_source = power_source or (
                self.power_methods[0].name if self.power_methods else "none")
        else:
            self.power_methods, self.power_source = select_power_methods(
                power, n_devices=spec.n_devices)
        self.warmup = warmup
        self.iters = iters
        self.smoke = smoke
        self.point_overrides = point_overrides
        self.retries = retries
        self.power_interval_ms = power_interval_ms
        self.watchdog = StragglerWatchdog()
        self.records: list[ResultRecord] = []

    def _check_devices(self) -> None:
        import jax
        have = jax.device_count()
        if have < self.spec.n_devices:
            raise DeviceCountError(self.spec, have)

    def run(self, verbose: bool = True) -> list[ResultRecord]:
        spec = self.spec
        self._check_devices()
        self.out.mkdir(parents=True, exist_ok=True)
        write_manifest(self.out, {
            "workload": spec.name, "analog": spec.analog,
            "n_devices": spec.n_devices, "tags": sorted(spec.tags),
            "power_source": self.power_source, "smoke": self.smoke,
        })
        ctx = RunContext(out_dir=self.out,
                         power_methods=self.power_methods,
                         power_source=self.power_source,
                         power_interval_ms=self.power_interval_ms,
                         warmup=self.warmup, iters=self.iters,
                         smoke=self.smoke)
        points = spec.space_for(self.smoke, self.point_overrides).expand()
        for i, pt in enumerate(points):
            rec = self._run_point(pt, ctx)
            self.records.append(rec)
            if verbose:
                print(f"[{spec.name}] {i + 1}/{len(points)} {rec.flat()}",
                      flush=True)
            save_records(self.records, self.out)
        return self.records

    def _run_point(self, pt: dict, ctx: RunContext) -> ResultRecord:
        spec = self.spec
        ctx.last_measurement = None
        rec = ResultRecord(workload=spec.name, point=dict(pt),
                           power_source=self.power_source,
                           n_devices=spec.n_devices,
                           git_sha=git_sha())
        t0 = time.perf_counter()
        ok, step_fns, attempts = run_attempts(
            "build", lambda: spec.build(pt, ctx), self.retries,
            log_prefix=f"[{spec.name}] ")
        rec.attempts = attempts
        if not ok:
            rec.status, rec.error = "error", step_fns["build_error"]
            return rec
        for name, fn in step_fns.items():
            ok, metrics, attempts = run_attempts(
                name, fn, self.retries, log_prefix=f"[{spec.name}] ")
            rec.attempts = max(rec.attempts, attempts)
            if not ok:
                rec.status, rec.error = "error", metrics[f"{name}_error"]
                break
            rec.metrics.update(metrics or {})
        dt = time.perf_counter() - t0
        if self.watchdog.observe(len(self.records), dt):
            rec.metrics["straggler"] = True
        # tolerance inputs for `repro.bench compare`: prefer the split
        # timed-window spread of this point's own ctx.measure call (pure
        # repetition noise); the watchdog's warmup-seeded spread is the
        # fallback for workloads that orchestrate their own timing, and
        # mixes in cross-point sweep heterogeneity (hence the cap in
        # compare.effective_tolerance)
        m = ctx.last_measurement
        if m is not None and m.rel_spread is not None:
            # two timed half-windows back this estimate, not the
            # watchdog's cross-point count
            rel_std, noise_src, samples = m.rel_spread, "measure_split", 2
        else:
            rel_std, noise_src, samples = (self.watchdog.rel_std(),
                                           "watchdog", self.watchdog.n)
        rec.noise = {"rel_std": round(rel_std, 6), "source": noise_src,
                     "samples": samples,
                     "point_seconds": round(dt, 6)}
        if spec.compare_tols:
            # non-finite floats would serialize as bare `Infinity` — not
            # RFC JSON, and the baseline store is a committed, diffable
            # artifact; "inf" parses back via float() in compare
            rec.noise["tols"] = {
                k: v if isinstance(v, (int, float)) and math.isfinite(v)
                else "inf"
                for k, v in spec.compare_tols.items()}
        return rec

    def result_table(self) -> str:
        flat = [r.flat() for r in self.records]
        return table(flat, self.spec.result_columns)
