"""Opt-in process-environment tuning for benchmark runs.

Two host-level knobs the CARAML-style sweeps want controlled (and,
above all, RECORDED — an unlabeled allocator swap shifts CPU cell
timings by percent and would read as a code regression in the compare
gate):

``REPRO_TCMALLOC=1``
    LD_PRELOAD a tcmalloc build for the benchmark process. Thread-caching
    malloc removes the glibc arena contention that host-side serve
    orchestration (admission bookkeeping, per-step numpy traffic)
    otherwise serializes on. Preloading must happen before the dynamic
    loader maps the process, so the CLI re-execs itself once with the
    environment prepared; if no tcmalloc library exists on the host the
    request is recorded as unmet and the run proceeds unpreloaded.

``REPRO_XLA_STEP_MARKER=<n>``
    Append ``--xla_step_marker_location=<enum>`` to ``XLA_FLAGS``
    (``0`` = STEP_MARK_AT_ENTRY, ``1`` =
    STEP_MARK_AT_TOP_LEVEL_WHILE_LOOP — where profilers draw step
    boundaries; a full ``STEP_MARK_*`` name passes through verbatim).
    XLA reads the flag at backend init, so this too rides the same
    pre-import re-exec.

Both are strictly opt-in: with neither variable set this module is
inert and the CLI's re-exec logic behaves exactly as before. The child
process carries ``REPRO_ENV_TUNING``, a comma-separated record of what
was actually applied; the runner stamps it into every ResultRecord's
metrics (``env_tuning``) so tuned and untuned runs never silently
compare as equals.
"""
from __future__ import annotations

import os
import pathlib
from typing import Optional

TCMALLOC_ENV = "REPRO_TCMALLOC"
STEP_MARKER_ENV = "REPRO_XLA_STEP_MARKER"
#: set on the re-exec'd child: comma-separated applied-tuning record
APPLIED_ENV = "REPRO_ENV_TUNING"

#: REPRO_XLA_STEP_MARKER shorthand -> DebugOptions::StepMarkerLocation
#: enum name (the XLA flag parser takes the name, not the number)
_STEP_MARKERS = {
    "0": "STEP_MARK_AT_ENTRY",
    "1": "STEP_MARK_AT_TOP_LEVEL_WHILE_LOOP",
    "none": "STEP_MARK_NONE",
}

#: common install locations, most specific first (the plain .so only
#: exists with -dev packages)
_TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/aarch64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/aarch64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
)


def _truthy(val: Optional[str]) -> bool:
    return (val or "").strip().lower() not in ("", "0", "false", "no")


def find_tcmalloc() -> Optional[str]:
    """First existing tcmalloc shared object, or None."""
    override = os.environ.get("REPRO_TCMALLOC_PATH")
    paths = (override,) + _TCMALLOC_CANDIDATES if override \
        else _TCMALLOC_CANDIDATES
    for p in paths:
        if p and pathlib.Path(p).is_file():
            return p
    return None


def requested(env: Optional[dict] = None) -> list[str]:
    """Tuning knobs the environment asks for (unordered request, not
    what was applied — see :func:`active` for that)."""
    env = os.environ if env is None else env
    out = []
    if _truthy(env.get(TCMALLOC_ENV)):
        out.append("tcmalloc")
    if (env.get(STEP_MARKER_ENV) or "").strip():
        out.append("step_marker")
    return out


def pending(env: Optional[dict] = None) -> bool:
    """True when tuning is requested but this process was started
    without it — the CLI must re-exec once with :func:`apply` first."""
    env = os.environ if env is None else env
    return bool(requested(env)) and not env.get(APPLIED_ENV)


def apply(env: dict) -> dict:
    """Prepare a child environment with the requested tuning applied
    and the ``REPRO_ENV_TUNING`` record set (which also makes
    :func:`pending` false in the child, so the re-exec never loops).
    Mutates and returns ``env``.
    """
    applied = []
    if _truthy(env.get(TCMALLOC_ENV)):
        lib = find_tcmalloc()
        if lib is None:
            # record the unmet request rather than failing the run: the
            # env_tuning stamp keeps the provenance honest
            applied.append("tcmalloc-missing")
        else:
            preload = env.get("LD_PRELOAD", "")
            if lib not in preload.split(":"):
                env["LD_PRELOAD"] = ":".join(p for p in (lib, preload) if p)
            applied.append("tcmalloc")
    marker = (env.get(STEP_MARKER_ENV) or "").strip()
    if marker:
        name = marker.upper() if marker.upper().startswith("STEP_MARK") \
            else _STEP_MARKERS.get(marker.lower())
        if name is None:
            applied.append("step_marker-invalid")
        else:
            flag = f"--xla_step_marker_location={name}"
            flags = env.get("XLA_FLAGS", "")
            if flag not in flags.split():
                env["XLA_FLAGS"] = f"{flags} {flag}".strip()
            applied.append(f"step_marker={name}")
    env[APPLIED_ENV] = ",".join(applied) if applied else "none"
    return env


def active() -> str:
    """The applied-tuning record of the current process ("" when the
    run is untuned) — stamped into ResultRecord metrics."""
    return os.environ.get(APPLIED_ENV, "")
