"""Cross-run comparison engine — the JUBE ``result --compare`` analog.

CARAML's value is reproducible *comparison*: the same workload point
re-measured across commits, hosts, or accelerators and diffed. This
module joins two sets of :class:`ResultRecord`s by the canonical point
key (workload + sorted Space params + device count + power source),
computes per-metric relative deltas with a noise-aware tolerance model,
and classifies every point as improved / unchanged / regressed /
missing / new / power_mismatch.

Tolerance model
---------------
Each compared metric carries a direction (higher/lower is better) and a
base relative tolerance (``records.COMPARED_METRICS``, overridable per
metric or wholesale from the CLI). The effective threshold for a point
is widened by the step-time spread both runs recorded::

    tol = base_tol + noise_k * min(max(rel_std_base, rel_std_cur), cap)

so a run whose own step times wobbled 10% cannot support a 5%
regression verdict, while a pair of quiet runs keeps the tight gate.

Baseline store
--------------
``promote()`` writes the current records into a git-trackable store,
one ``<dir>/<workload>.json`` per workload in the same schema-versioned
document format as ``results.json`` (atomic replace). CI re-runs the
smoke suite and gates it against the committed store with
``python -m repro.bench compare artifacts/bench/baselines <run>
--fail-on-regression``.
"""
from __future__ import annotations

import math
import pathlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.bench.records import (
    ResultRecord, compare_metrics, load_records, metric_direction,
    metric_tolerance, point_key, write_result_doc,
)
from repro.core.results import table
from repro.power.frame import Frame

#: default multiplier on the recorded rel_std when widening tolerances
NOISE_K = 2.0
#: rel_std is capped before widening: a wildly noisy sweep (heterogeneous
#: points share one watchdog) must not disable the gate entirely
NOISE_CAP = 0.5

# classification outcomes, in render/severity order
REGRESSED = "regressed"
POWER_MISMATCH = "power_mismatch"
MISSING = "missing"
IMPROVED = "improved"
NEW = "new"
UNCHANGED = "unchanged"
STATUSES = (REGRESSED, POWER_MISMATCH, MISSING, IMPROVED, NEW, UNCHANGED)


@dataclass(frozen=True)
class MetricDelta:
    """One metric diffed at one point."""

    metric: str
    base: float
    current: float
    rel_delta: float        # signed (current - base) / |base|
    tolerance: float        # effective threshold after noise widening
    status: str             # improved | unchanged | regressed

    @property
    def pct(self) -> str:
        if math.isinf(self.rel_delta):
            return "inf"
        return f"{self.rel_delta * 100:+.1f}%"


@dataclass
class PointComparison:
    """One joined point: classification plus its per-metric deltas."""

    key: str
    workload: str
    point: dict
    power_source: str
    status: str
    deltas: List[MetricDelta] = field(default_factory=list)
    note: str = ""

    def flat(self) -> List[dict]:
        """CSV rows: one per metric delta (or one bare row for point-level
        outcomes like missing/new/power_mismatch)."""
        # "/"-joined like the classic emit lines — the CSV writer does not
        # quote fields, so the point column must stay comma-free
        head = {"workload": self.workload,
                "point": "/".join(f"{k}={v}" for k, v in
                                  sorted(self.point.items())),
                "power_source": self.power_source, "status": self.status}
        # reports are unquoted CSV rows and markdown table cells: commas,
        # newlines, and pipes in an error message would corrupt exactly
        # the failing-run report this exists to explain
        note = " ".join(self.note.replace(",", ";")
                        .replace("|", "/").split())
        if not self.deltas:
            return [{**head, "note": note}]
        return [{**head, "metric": d.metric, "baseline": d.base,
                 "current": d.current, "rel_delta": round(d.rel_delta, 6),
                 "tolerance": round(d.tolerance, 6),
                 "metric_status": d.status, "note": note}
                for d in self.deltas]


@dataclass
class Comparison:
    """The full cross-run diff: all joined points plus summary helpers."""

    points: List[PointComparison]
    baseline_label: str = "baseline"
    current_label: str = "current"

    def by_status(self, status: str) -> List[PointComparison]:
        return [p for p in self.points if p.status == status]

    def counts(self) -> Dict[str, int]:
        out = {s: 0 for s in STATUSES}
        for p in self.points:
            out[p.status] = out.get(p.status, 0) + 1
        return out

    @property
    def regressions(self) -> List[PointComparison]:
        return self.by_status(REGRESSED)

    def summary(self) -> str:
        c = self.counts()
        parts = [f"{c[s]} {s}" for s in STATUSES if c[s]]
        return (f"compare {self.baseline_label} -> {self.current_label}: "
                f"{len(self.points)} points; " + (", ".join(parts) or
                                                  "nothing to compare"))

    def exit_code(self, fail_on_regression: bool = False,
                  fail_on_missing: bool = False) -> int:
        """CI gate: regressions (and errored/power-mismatched points)
        fail under --fail-on-regression; vanished points fail only under
        --fail-on-missing so partial re-runs stay usable."""
        c = self.counts()
        if fail_on_regression and (c[REGRESSED] or c[POWER_MISMATCH]):
            return 3
        if fail_on_missing and c[MISSING]:
            return 4
        return 0

    # -- reports ----------------------------------------------------------

    def to_markdown(self, *, all_points: bool = False) -> str:
        """Markdown report: summary + a table of non-unchanged points
        (every metric row with ``all_points=True``)."""
        rows = []
        for p in self.points:
            if not all_points and p.status == UNCHANGED:
                continue
            rows.extend(p.flat())
        lines = [f"## {self.summary()}", ""]
        if rows:
            cols = ["workload", "point", "status", "metric", "baseline",
                    "current", "rel_delta", "tolerance", "metric_status",
                    "note"]
            used = [c for c in cols if any(c in r for r in rows)]
            lines.append(table(rows, used, floatfmt="{:.4g}"))
        else:
            lines.append("(all points unchanged within tolerance)\n")
        return "\n".join(lines)

    def to_csv(self) -> str:
        rows = [r for p in self.points for r in p.flat()]
        return Frame.from_records(rows).to_csv()


def effective_tolerance(metric: str, base: ResultRecord,
                        cur: ResultRecord, *,
                        tols: Optional[dict] = None,
                        noise_k: float = NOISE_K) -> float:
    """Per-metric threshold, widened by the noisier run's recorded
    step-time spread (capped — see NOISE_CAP).

    Base-tolerance precedence (most specific wins): CLI per-metric
    override > per-metric tolerance the *workload declared*
    (``WorkloadSpec.compare_tols``, stamped into each record's noise
    dict — e.g. the CPU interpret-mode kernel microbench exempts its
    un-gateable absolute timings with ``inf``) > workload ``"default"``
    > CLI ``"default"`` > the registry base for the metric. The
    workload's default outranks the CLI's on purpose: a blanket
    ``--rel-tol default=...`` (the CI gate) must not re-arm a gate a
    workload exempted for cause.
    """
    tols = tols or {}
    rec_tols: dict = {}
    for r in (base, cur):     # the current run's declaration wins
        declared = r.noise.get("tols") if isinstance(r.noise, dict) else None
        if isinstance(declared, dict):
            rec_tols.update(declared)
    base_tol = metric_tolerance(metric)
    for candidate in (tols.get("default"), rec_tols.get("default"),
                      rec_tols.get(metric), tols.get(metric)):
        if candidate is not None:
            base_tol = float(candidate)
    spread = min(max(base.rel_std, cur.rel_std), NOISE_CAP)
    return base_tol + noise_k * spread


def diff_metric(metric: str, base_v: float, cur_v: float,
                tolerance: float) -> MetricDelta:
    """Classify one metric against the direction-aware threshold.

    ``rel_delta`` (reported) is the signed relative delta; the
    *classification* runs on the ratio scale: a point regresses when it
    is more than ``(1 + tol)x`` worse than baseline and improves when
    more than ``(1 + tol)x`` better. Ratios are unbounded in both
    directions, so even a saturated tolerance (noisy sweep + CI
    widening pushing tol past 1.0) still catches an order-of-magnitude
    collapse — on the relative scale a throughput drop bottoms out at
    -100% and would slip under any tol >= 1.
    """
    higher = metric_direction(metric)
    if math.isnan(base_v) or math.isnan(cur_v):
        # NaN is a measurement failure, not a delta — it must gate,
        # never slip through as "unchanged" (NaN fails every comparison)
        return MetricDelta(metric=metric, base=base_v, current=cur_v,
                           rel_delta=math.nan, tolerance=tolerance,
                           status=REGRESSED)
    if math.isinf(base_v) or math.isinf(cur_v):
        # inf can be an honest value, not a failure: wh_per_slo_request
        # is inf whenever energy was spent but nothing met the SLO. A
        # stress cell that is inf on BOTH sides (same sign) is therefore
        # unchanged — gating it would flag the baseline's own saturation
        # forever. Any finite<->inf transition still gates as a
        # regression: degenerating to inf is the metric collapsing, and
        # escaping it (a genuine recovery) changes regime enough that a
        # human must look and re-promote rather than let it slide by.
        if base_v == cur_v:
            return MetricDelta(metric=metric, base=base_v, current=cur_v,
                               rel_delta=0.0, tolerance=tolerance,
                               status=UNCHANGED)
        return MetricDelta(metric=metric, base=base_v, current=cur_v,
                           rel_delta=math.copysign(math.inf, cur_v - base_v),
                           tolerance=tolerance, status=REGRESSED)
    if not higher and cur_v == 0.0 and base_v > 0.0:
        # a time/energy metric degenerating to exactly zero is a broken
        # measurement path (e.g. a dead power scope), not a best-ever run
        return MetricDelta(metric=metric, base=base_v, current=cur_v,
                           rel_delta=-1.0, tolerance=tolerance,
                           status=REGRESSED)
    if base_v == 0.0:
        rel = 0.0 if cur_v == 0.0 else math.copysign(math.inf, cur_v)
    else:
        rel = (cur_v - base_v) / abs(base_v)
    if base_v > 0.0 and cur_v >= 0.0:
        if higher:
            worse = math.inf if cur_v == 0.0 else base_v / cur_v
        else:
            worse = cur_v / base_v
        if worse > 1.0 + tolerance:
            status = REGRESSED
        elif worse < 1.0 / (1.0 + tolerance):
            status = IMPROVED
        else:
            status = UNCHANGED
    else:
        # zero/negative baselines have no ratio; fall back to the signed
        # relative delta (inf when appearing from exactly zero)
        goodness = rel if higher else -rel
        if goodness < -tolerance:
            status = REGRESSED
        elif goodness > tolerance:
            status = IMPROVED
        else:
            status = UNCHANGED
    return MetricDelta(metric=metric, base=base_v, current=cur_v,
                       rel_delta=rel, tolerance=tolerance, status=status)


def _classify(deltas: List[MetricDelta]) -> str:
    statuses = {d.status for d in deltas}
    if REGRESSED in statuses:
        return REGRESSED
    if IMPROVED in statuses:
        return IMPROVED
    return UNCHANGED


def compare_sets(baseline: List[ResultRecord], current: List[ResultRecord],
                 *, tols: Optional[dict] = None,
                 noise_k: float = NOISE_K,
                 baseline_label: str = "baseline",
                 current_label: str = "current") -> Comparison:
    """Join two record sets by point key and classify every point.

    ``tols`` overrides relative tolerances per metric name; the special
    key ``"default"`` replaces the base tolerance for every metric.
    Error-status baseline records are ignored (a broken baseline point
    gates nothing); an error-status current record at an ok baseline
    point is itself a regression.
    """
    base_by = {point_key(r): r for r in baseline if r.ok}
    cur_by = {point_key(r): r for r in current}
    # power-stripped indexes, for mismatch detection on both sides
    base_nopower = {point_key(r, with_power=False): r
                    for r in baseline if r.ok}
    cur_nopower = {point_key(r, with_power=False): r for r in current}

    points: List[PointComparison] = []
    for key in sorted(set(base_by) | set(cur_by)):
        base, cur = base_by.get(key), cur_by.get(key)
        rec = cur or base
        pc = PointComparison(key=key, workload=rec.workload,
                             point=dict(rec.point),
                             power_source=rec.power_source, status=UNCHANGED)
        if base is None:
            twin = base_nopower.get(point_key(cur, with_power=False))
            if cur.status == "error":
                # a point that errors must not hide behind `new` (it is
                # never promoted, so it would stay green forever) nor
                # behind the power-mismatch dedup — the crash message
                # must surface, whatever power source the attempt used
                pc.status = REGRESSED
                pc.note = f"new point errored: {cur.error}"
            elif twin is not None and point_key(twin) not in cur_by:
                # the baseline side of this pair reports POWER_MISMATCH;
                # a second `new` row for the same point is just noise
                continue
            elif twin is not None:
                # the baseline matched its own-power record at full key;
                # this extra power source is genuinely additional data
                pc.status = NEW
                pc.note = "additional power source not in baseline"
            else:
                pc.status, pc.note = NEW, "point not in baseline"
        elif cur is None:
            other = cur_nopower.get(point_key(base, with_power=False))
            if other is not None and point_key(other) not in base_by:
                # the current run re-measured this point under a power
                # source the baseline does not have — a genuine mismatch.
                # (If `other` has its own full-key baseline match the
                # pair compared cleanly and this row is merely absent.)
                pc.status = POWER_MISMATCH
                pc.note = (f"baseline measured with "
                           f"power={base.power_source!r} but current run "
                           f"used power={other.power_source!r}; refusing "
                           f"to diff across power sources")
            else:
                pc.status, pc.note = MISSING, "point absent from current run"
        elif cur.status in ("skipped", "deferred"):
            # a deliberately skipped point (missing hardware, gated
            # feature) — or one deferred to a rendered Slurm job because
            # its mesh exceeds local devices — is absence, not failure;
            # --fail-on-missing governs
            pc.status = MISSING
            pc.note = (f"current run {cur.status} this point"
                       + (f": {cur.error}" if cur.error else ""))
        elif not cur.ok:
            pc.status = REGRESSED
            pc.note = f"current run errored: {cur.error}"
        else:
            base_m, cur_m = compare_metrics(base), compare_metrics(cur)
            for m in base_m:
                if m not in cur_m:
                    continue
                tol = effective_tolerance(m, base, cur, tols=tols,
                                          noise_k=noise_k)
                pc.deltas.append(diff_metric(m, base_m[m], cur_m[m], tol))
            pc.status = _classify(pc.deltas)
            lost = sorted(set(base_m) - set(cur_m))
            if lost:
                # a compared metric that vanished is a gated outcome, not
                # a footnote — otherwise breaking energy accounting would
                # silently disarm the Wh gate this engine exists for
                pc.status = REGRESSED
                pc.note = f"metrics no longer reported: {' '.join(lost)}"
        points.append(pc)
    return Comparison(points=points, baseline_label=baseline_label,
                      current_label=current_label)


# ---------------------------------------------------------------------------
# result-set loading + the baseline store
# ---------------------------------------------------------------------------


def load_result_set(path) -> List[ResultRecord]:
    """Load records from any of the three layouts compare accepts:

      * an explicit ``results.json`` (or baseline ``<workload>.json``) file
      * a run directory — ``<dir>/results.json`` or the runner's
        ``<dir>/<workload>/results.json`` tree
      * a baseline store directory of per-workload ``*.json`` documents

    A nonexistent directory yields an empty set (the bootstrap case:
    comparing against a baseline store that has not been promoted yet).
    """
    p = pathlib.Path(path)
    if p.is_file():
        return load_records(p)
    if not p.is_dir():
        if p.exists():
            raise ValueError(f"{p}: not a results file or directory")
        return []
    if (p / "results.json").exists():
        return load_records(p / "results.json")
    files = sorted(p.glob("*/results.json"))
    if not files:
        files = sorted(f for f in p.glob("*.json")
                       if f.name != "manifest.json")
    recs: List[ResultRecord] = []
    for f in files:
        recs.extend(load_records(f))
    return recs


def promote(records: List[ResultRecord], store_dir) -> List[pathlib.Path]:
    """Write ok-status records into the baseline store, one atomic
    ``<store_dir>/<workload>.json`` per workload (replacing that
    workload's previous baseline; other workloads are untouched)."""
    store = pathlib.Path(store_dir)
    by_workload: Dict[str, List[ResultRecord]] = {}
    for r in records:
        if r.ok:
            by_workload.setdefault(r.workload, []).append(r)
    written = []
    for name in sorted(by_workload):
        path = store / f"{name}.json"
        write_result_doc(by_workload[name], path)
        written.append(path)
    return written
