"""CARAML-JAX: TPU-native reproduction of the CARAML benchmark suite
(John et al., 2024) as a production multi-pod JAX framework."""
__version__ = "1.0.0"
