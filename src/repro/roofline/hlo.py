"""Parse compiled HLO text for collective ops and their byte volumes.

``cost_analysis()`` does not report collective bytes, so we scan the
post-SPMD (compiled) HLO for all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute ops and sum operand/result sizes.

Byte accounting is per-device "wire bytes" (what crosses links), using ring
estimates with the parsed replica-group size g:
  all-reduce       2 * B * (g-1)/g      (B = result bytes = operand bytes)
  all-gather       B_result * (g-1)/g   (received shards)
  reduce-scatter   B_operand * (g-1)/g  = B_result * (g-1)
  all-to-all       B * (g-1)/g
  collective-permute  B                 (point-to-point)
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,4096,512]{2,1,0} all-gather(...) or
#       ... = (f32[128]{0}, f32[128]{0}) all-reduce-start(...)
_OP_RE = re.compile(
    r"=\s*(?P<type>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_GROUPS_LIST_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]<=[N]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        ids = [x for x in m.group(1).strip("{}").split(",") if x.strip()]
        return max(len(ids), 1)
    return default


@dataclass
class CollectiveStats:
    counts: dict = field(default_factory=lambda: defaultdict(int))
    result_bytes: dict = field(default_factory=lambda: defaultdict(int))
    wire_bytes: dict = field(default_factory=lambda: defaultdict(float))

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    @property
    def total_result_bytes(self) -> int:
        return int(sum(self.result_bytes.values()))

    def to_dict(self) -> dict:
        return {
            "counts": dict(self.counts),
            "result_bytes": dict(self.result_bytes),
            "wire_bytes": dict(self.wire_bytes),
            "total_wire_bytes": self.total_wire_bytes,
        }


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    seen_done: set[str] = set()
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async pair: count the -start only
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        b = shape_bytes(m.group("type"))
        g = _group_size(line, n_devices)
        frac = (g - 1) / g if g > 1 else 0.0
        if op == "all-reduce":
            wire = 2.0 * b * frac
        elif op == "all-gather":
            wire = b * frac
        elif op == "reduce-scatter":
            wire = b * (g - 1)  # operand = result * g
        elif op == "all-to-all":
            wire = b * frac
        else:  # collective-permute
            wire = float(b)
        stats.counts[op] += 1
        stats.result_bytes[op] += b
        stats.wire_bytes[op] += wire
    return stats
