"""Inspect the largest tensors in a compiled HLO module (debug/perf tool).

Used in the par.Perf hillclimbs to find which buffers dominate the memory
term — the dry-run "profile" in lieu of a real-TPU trace.
"""
from __future__ import annotations

import re
from collections import Counter

_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s8": 1, "u8": 1,
          "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
          "pred": 1}

_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred)"
                       r"\[([0-9,]+)\]")


def largest_shapes(hlo_text: str, top: int = 20) -> list[tuple[float, int, str]]:
    """Returns [(bytes, count, shape_str)] sorted by bytes desc."""
    sizes: dict[str, int] = {}
    counts: Counter = Counter()
    for m in _SHAPE_RE.finditer(hlo_text):
        dt, dims = m.group(1), m.group(2)
        key = f"{dt}[{dims}]"
        counts[key] += 1
        if key not in sizes:
            n = 1
            for d in dims.split(","):
                n *= int(d)
            sizes[key] = n * _BYTES[dt]
    out = [(float(sizes[k]), counts[k], k) for k in sizes]
    out.sort(reverse=True)
    return out[:top]


def print_largest(compiled, top: int = 15):
    for b, cnt, shape in largest_shapes(compiled.as_text(), top):
        print(f"{b / 2**30:8.2f} GiB  x{cnt:4d}  {shape}")
