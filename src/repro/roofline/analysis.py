"""Three-term roofline model from the compiled dry-run artifact.

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
  memory term     = HLO_bytes_per_device / HBM_BW_per_chip
  collective term = wire_bytes_per_device / ICI_BW_per_chip

``cost_analysis()`` on the SPMD-partitioned executable reports *per-device*
flops/bytes (verified empirically in tests), so dividing by per-chip peaks
directly matches the spec's "HLO_FLOPs / (chips x peak)" formula.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Optional

from repro.configs.base import ModelConfig, ShapeConfig

# TPU v5e per-chip constants (given)
PEAK_FLOPS_BF16 = 197e12         # FLOP/s
HBM_BW = 819e9                   # B/s
ICI_BW = 50e9                    # B/s per link (conservative: 1 link)


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float           # 6*N*D (dense) or 6*N_active*D
    useful_flops_ratio: float    # MODEL_FLOPS/chips / HLO_FLOPs
    step_time_s: float           # max of the three terms
    roofline_fraction: float     # compute_s / step_time_s (MFU-like bound)

    def to_dict(self):
        return asdict(self)


def model_flops(c: ModelConfig, shape: ShapeConfig) -> float:
    """6*N*D for training; 2*N*D for inference (fwd only)."""
    n = c.active_param_count()
    d = shape.tokens_per_step
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * d


def analyze(c: ModelConfig, shape: ShapeConfig, *, mesh_name: str,
            n_devices: int, flops_per_device: float,
            hbm_bytes_per_device: float,
            wire_bytes_per_device: float) -> Roofline:
    compute_s = flops_per_device / PEAK_FLOPS_BF16
    memory_s = hbm_bytes_per_device / HBM_BW
    coll_s = wire_bytes_per_device / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(c, shape)
    useful = (mf / n_devices) / max(flops_per_device, 1.0)
    step = max(terms.values())
    return Roofline(
        arch=c.name, shape=shape.name, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=flops_per_device,
        hbm_bytes_per_device=hbm_bytes_per_device,
        wire_bytes_per_device=wire_bytes_per_device,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bottleneck=bottleneck, model_flops=mf, useful_flops_ratio=useful,
        step_time_s=step,
        roofline_fraction=compute_s / step if step > 0 else 0.0)
