"""Gradient compression: int8 quantized all-reduce with error feedback.

Cross-pod (DCN) gradient all-reduce is the scaling bottleneck for the
multi-pod mesh; int8 quantization cuts wire bytes 4x vs fp32. Error
feedback (Seide et al.) keeps SGD convergence: the quantization residual
is added back into the next step's gradient. Property-tested for
convergence in tests/test_compress.py.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

Params = Any


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8. Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str,
                    error: Optional[jax.Array] = None):
    """int8 all-reduce with error feedback (use inside shard_map).

    Returns (mean-reduced x, new_error). Each participant quantizes its
    local gradient; the int8 payloads are summed (psum in int32 to avoid
    overflow) and rescaled by the max scale (psum-max).
    """
    xf = x.astype(jnp.float32)
    if error is not None:
        xf = xf + error
    scale = jnp.maximum(jax.lax.pmax(jnp.max(jnp.abs(xf)), axis_name),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127)
    new_error = xf - q * scale          # residual kept locally
    summed = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    out = summed.astype(jnp.float32) * scale / n.astype(jnp.float32)
    return out, new_error


def make_compressed_grad_sync(mesh, axis_name: str):
    """Tree-level compressed gradient mean over ``axis_name``.

    Returns sync(grads, errors) -> (synced_grads, new_errors), to be used
    under shard_map with the model's param specs.
    """
    def sync(grads, errors):
        flat_g, tree = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(errors)
        out_g, out_e = [], []
        for g, e in zip(flat_g, flat_e):
            og, oe = compressed_psum(g, axis_name, e)
            out_g.append(og.astype(g.dtype))
            out_e.append(oe)
        return (jax.tree.unflatten(tree, out_g),
                jax.tree.unflatten(tree, out_e))
    return sync
