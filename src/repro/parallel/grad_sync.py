"""Bucketed data-parallel gradient synchronization, optionally overlapped
with the backward pass and optionally int8-compressed.

The dp-scaling collapse fix (ISSUE 6): instead of leaving gradient
synchronization to GSPMD's per-leaf all-reduces, flatten the grad tree
into size-capped fp32 buckets and reduce each bucket explicitly inside a
``shard_map``. Two levers on top:

- **overlap**: hook the bucketed reduce into the microbatch-accumulation
  scan (``train.step.scan_microbatch_grads``'s ``grad_hook``) so bucket
  reduces for microbatch *i* are issued while microbatch *i+1*'s backward
  is still running (async collectives hide the wire time on TPU; psum is
  linear, so syncing per-microbatch means ≡ syncing the sum).
- **mode="int8"**: route each bucket through
  ``repro.parallel.compress.compressed_psum`` (4x fewer wire bytes,
  error feedback carried across steps in a per-device sync state).

Only the grad computation + sync live inside the shard_map; the
optimizer update stays in GSPMD land so the ZeRO-1-sharded optimizer
state (``sharding.zero1_sharding``) is consumed in place, without an
all-gather.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.parallel.compat import shard_map
from repro.parallel.compress import compressed_psum
from repro.parallel.sharding import Plan, dp_size
from repro.train.optimizer import OptConfig, opt_update
from repro.train.step import (StepConfig, make_loss_fn,
                              scan_microbatch_grads)

Params = Any

#: accepted values of the llm_train ``grad_sync`` Space axis
GRAD_SYNC_MODES = ("fp32", "int8")


@dataclass(frozen=True)
class GradSyncConfig:
    """How the dp gradient all-reduce is performed."""

    mode: str = "fp32"        # "fp32" | "int8" (compressed + error feedback)
    bucket_mb: float = 4.0    # bucket size cap, MiB of fp32
    overlap: bool = True      # reduce bucket k while bucket k+1's bwd runs

    def __post_init__(self):
        if self.mode not in GRAD_SYNC_MODES:
            raise ValueError(f"grad_sync mode {self.mode!r} not in "
                             f"{GRAD_SYNC_MODES}")

    @property
    def bucket_elems(self) -> int:
        return max(1, int(self.bucket_mb * (1 << 20) / 4))


def default_sync(mode: str = "fp32") -> GradSyncConfig:
    """Backend-appropriate sync config: overlapping the reduce with the
    backward pays only where collectives run async (the TPU
    latency-hiding scheduler); on CPU the scan-carried sync is pure
    overhead, so overlap stays off there."""
    return GradSyncConfig(mode=mode,
                          overlap=jax.default_backend() != "cpu")


# ---------------------------------------------------------------------------
# Bucketing
# ---------------------------------------------------------------------------


def flatten_buckets(tree, bucket_elems: int):
    """Flatten a pytree into equal-size fp32 buckets (last one padded).

    Returns ``(buckets, meta)``; ``meta`` round-trips through
    :func:`unflatten_buckets`. Bucket count is static (derived from leaf
    shapes), so this traces cleanly under jit/scan.
    """
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [l.size for l in leaves]
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    flat = jnp.concatenate(
        [l.astype(jnp.float32).reshape(-1) for l in leaves])
    n = flat.size
    nb = max(1, math.ceil(n / bucket_elems))
    pad = nb * bucket_elems - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    buckets = [flat[i * bucket_elems:(i + 1) * bucket_elems]
               for i in range(nb)]
    return buckets, (treedef, sizes, shapes, dtypes, n)


def unflatten_buckets(buckets, meta):
    treedef, sizes, shapes, dtypes, n = meta
    flat = buckets[0] if len(buckets) == 1 else jnp.concatenate(buckets)
    flat = flat[:n]
    out, off = [], 0
    for size, shape, dt in zip(sizes, shapes, dtypes):
        out.append(flat[off:off + size].reshape(shape).astype(dt))
        off += size
    return jax.tree.unflatten(treedef, out)


def n_buckets(params, bucket_elems: int) -> int:
    total = sum(l.size for l in jax.tree.leaves(params))
    return max(1, math.ceil(total / bucket_elems))


# ---------------------------------------------------------------------------
# Reduction (inside shard_map)
# ---------------------------------------------------------------------------


def reduce_buckets(buckets, axis, ndev: int, mode: str, errors=None):
    """Mean-reduce each bucket over ``axis``. Returns
    ``(reduced, new_errors)`` — errors only meaningful for int8."""
    out, new_e = [], []
    for i, b in enumerate(buckets):
        if mode == "int8":
            r, e = compressed_psum(b, axis,
                                   errors[i] if errors is not None else None)
            out.append(r)
            new_e.append(e)
        else:
            out.append(jax.lax.psum(b, axis) / ndev)
    return out, (tuple(new_e) if mode == "int8" else errors)


def sync_grads(grads, axis, ndev: int, sync: GradSyncConfig, errors=None):
    """Tree-level bucketed gradient mean over ``axis`` (use inside
    shard_map). Returns ``(synced_grads, new_errors)``."""
    buckets, meta = flatten_buckets(grads, sync.bucket_elems)
    red, new_e = reduce_buckets(buckets, axis, ndev, sync.mode, errors)
    return unflatten_buckets(red, meta), new_e


def naive_psum_sync(grads, axis, ndev: int):
    """Reference: per-leaf fp32 psum mean (what GSPMD would insert) —
    the numeric-equivalence target for the bucketed path in tests."""
    return jax.tree.map(
        lambda g: jax.lax.psum(g.astype(jnp.float32), axis) / ndev, grads)


# ---------------------------------------------------------------------------
# The dp train step (shard_map grads + sync, GSPMD optimizer update)
# ---------------------------------------------------------------------------


def init_sync_state(plan: Plan, params: Params,
                    sync: GradSyncConfig) -> jax.Array:
    """Per-device sync state, dp-sharded on its leading axis.

    int8 mode carries the error-feedback residual per (device, bucket);
    fp32 mode carries an empty placeholder so the jitted step keeps one
    signature across modes."""
    ndev = dp_size(plan)
    if sync.mode == "int8":
        nb = n_buckets(params, sync.bucket_elems)
        z = jnp.zeros((ndev, nb, sync.bucket_elems), jnp.float32)
    else:
        z = jnp.zeros((ndev, 1, 0), jnp.float32)
    return jax.device_put(z, sync_state_sharding(plan))


def sync_state_sharding(plan: Plan) -> NamedSharding:
    return NamedSharding(plan.mesh, P(plan.dp))


def make_dp_train_step(c: ModelConfig, oc: OptConfig,
                       sc: StepConfig = StepConfig(), *, plan: Plan,
                       sync: GradSyncConfig = GradSyncConfig()):
    """Data-parallel train step with explicit bucketed gradient sync.

    ``train_step(params, opt_state, sync_state, batch) ->
    (params, opt_state, sync_state, metrics)``. Gradients (and the
    bucketed reduce) run under shard_map over the plan's dp axes; the
    optimizer update runs outside it so GSPMD consumes the
    ZeRO-1-sharded optimizer state in place.
    """
    loss_fn = make_loss_fn(c, sc)
    vg = jax.value_and_grad(loss_fn, has_aux=True)
    axis = plan.dp if len(plan.dp) > 1 else plan.dp[0]
    ndev = dp_size(plan)
    k = max(sc.microbatches, 1)

    def local_step(params, batch, err):
        gdt = jnp.dtype(sc.grad_dtype)
        errs = None
        if sync.mode == "int8":
            errs = tuple(err[0, i] for i in range(err.shape[1]))

        if sync.overlap and k > 1:
            def hook(g, hs):
                return sync_grads(g, axis, ndev, sync, hs)

            grads, errs, loss, ce, aux = scan_microbatch_grads(
                vg, params, batch, k, gdt, grad_hook=hook, hook_state=errs)
        else:
            if k > 1:
                grads, _, loss, ce, aux = scan_microbatch_grads(
                    vg, params, batch, k, gdt)
            else:
                (loss, (ce, aux)), grads = vg(params, batch)
                grads = jax.tree.map(lambda g: g.astype(gdt), grads)
            grads, errs = sync_grads(grads, axis, ndev, sync, errs)

        grads = jax.tree.map(lambda g: (g / k).astype(jnp.float32), grads)
        loss = jax.lax.pmean(loss / k, axis)
        ce = jax.lax.pmean(ce / k, axis)
        aux = jax.lax.pmean(aux / k, axis)
        new_err = jnp.stack(errs)[None] if sync.mode == "int8" else err
        return grads, new_err, loss, ce, aux

    smapped = shard_map(
        local_step, mesh=plan.mesh,
        in_specs=(P(), P(plan.dp), P(plan.dp)),
        out_specs=(P(), P(plan.dp), P(), P(), P()),
        check_vma=False)

    def train_step(params, opt_state, sync_state, batch):
        grads, new_err, loss, ce, aux = smapped(params, batch, sync_state)
        new_p, new_o, info = opt_update(oc, grads, opt_state, params)
        metrics = {"loss": loss, "ce": ce, "aux": aux, **info}
        return new_p, new_o, new_err, metrics

    return train_step
