"""Pipeline parallelism via shard_map + collective_permute (1F1B-style).

The paper's Graphcore case trains GPT-117M with its layers pipelined over
4 IPUs (the only way it fits in per-core SRAM); the 13B/175B JUBE configs
pipeline over nodes. On TPU we map the pattern onto a mesh "stage" axis —
for multi-pod, the natural choice is pod = stage (the DCN link carries
only the (B, S, D) activation handoff once per microbatch, the cheapest
possible cross-pod pattern).

Implementation: GPipe/1F1B microbatch schedule expressed as a rotation
loop. Each device holds n_layers/n_stages contiguous layers; microbatch i
enters stage 0, activations are collective_permuted to the next stage
each tick. Forward schedule shown; the backward runs through jax.grad of
the whole rotated loop (activations rematerialized per microbatch).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.compat import shard_map

Params = Any


def stage_params_split(params_stacked, n_stages: int):
    """Split scan-stacked layer params (L, ...) into (n_stages, L/S, ...)."""
    def split(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])
    return jax.tree.map(split, params_stacked)


def pipeline_forward(mesh: Mesh, stage_axis: str, layer_fn: Callable,
                     stage_params, x_microbatches: jax.Array):
    """Run microbatches through pipeline stages.

    layer_fn(params_for_stage, x) -> x, applied per stage.
    stage_params: pytree with leading dim = n_stages (sharded over
    ``stage_axis``); x_microbatches: (n_mb, mb, S, D) — each microbatch is
    replicated (or data-sharded on its own axes) across stages.

    Returns (n_mb, mb, S, D) outputs. Uses the rotation schedule: at tick
    t, stage s processes microbatch (t - s); a collective_permute hands
    activations to stage s+1. Total ticks = n_mb + n_stages - 1 (the
    pipeline bubble the paper observes on the IPU is exactly the
    (n_stages-1)/(n_mb+n_stages-1) idle fraction).
    """
    n_stages = mesh.shape[stage_axis]
    n_mb = x_microbatches.shape[0]
    mb_shape = x_microbatches.shape[1:]

    def per_stage(params, xs):
        # params: (1, L/S, ...) local stage slice; xs: (n_mb, ...) local
        params = jax.tree.map(lambda p: p[0], params)
        stage_id = jax.lax.axis_index(stage_axis)
        n_ticks = n_mb + n_stages - 1
        buf = jnp.zeros(mb_shape, xs.dtype)  # current activation
        outs = jnp.zeros((n_mb, *mb_shape), xs.dtype)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if in range)
            mb_idx = jnp.clip(t, 0, n_mb - 1)
            incoming = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0,
                                                    keepdims=False)
            buf = jnp.where(stage_id == 0,
                            jnp.where(t < n_mb, incoming, buf), buf)
            # every stage runs its layers on its current buffer
            y = layer_fn(params, buf)
            # emit from the last stage: microbatch (t - n_stages + 1)
            out_idx = jnp.clip(t - n_stages + 1, 0, n_mb - 1)
            emit = jnp.logical_and(stage_id == n_stages - 1,
                                   t >= n_stages - 1)
            outs = jnp.where(
                emit,
                jax.lax.dynamic_update_index_in_dim(
                    outs, y, out_idx, 0),
                outs)
            # rotate activations forward one stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, stage_axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # outputs live on the last stage; broadcast to all stages
        outs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outs, jnp.zeros_like(outs)),
            stage_axis)
        return outs

    pspec = jax.tree.map(lambda _: P(stage_axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(pspec, P()), out_specs=P(),
                   check_vma=False)
    return fn(stage_params, x_microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    """The pipeline-bubble overhead the paper cites for the IPU case."""
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
