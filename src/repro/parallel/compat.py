"""jax API compatibility shims for the parallel layer.

The repo targets the modern jax surface (``jax.shard_map`` with
``check_vma``), but must also run on jax 0.4.x where shard_map lives in
``jax.experimental.shard_map`` and the replication-check kwarg is named
``check_rep``. Route every shard_map call through here.
"""
from __future__ import annotations

import jax


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` on new jax, experimental fallback on 0.4.x.

    ``check_vma`` maps onto the older ``check_rep`` flag (both disable the
    same replication/varying-manual-axes validation).
    """
    if hasattr(jax, "shard_map"):
        kw = {} if check_vma is None else {"check_vma": check_vma}
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {} if check_vma is None else {"check_rep": check_vma}
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)
