"""Table-driven sharding rules: DP / TP / FSDP / EP / sequence sharding.

Every (arch x shape x mesh) combination must compile — rules use a
divisible-or-replicate fallback so no assignment can fail, and the roofline
report then grades the quality of what was chosen.

Layout summary (see DESIGN.md par.5):
  - "model" axis: tensor parallel (attention heads / d_ff / experts / vocab)
  - "data" axis:  batch DP + FSDP weight sharding for large archs +
                  ZeRO-1 optimizer-state sharding (Megatron's
                  "distributed optimizer", which the paper's benchmark uses)
  - "pod" axis:   extra DP (gradient all-reduce only — the cross-pod DCN
                  link carries the lowest-frequency collective)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import axis_size, dp_axes

Params = Any

# FSDP threshold: params whose bf16 bytes / TP shard would crowd a 16 GiB
# v5e chip once grads + ZeRO-1 states are added (see DESIGN.md).
FSDP_PARAM_THRESHOLD = 32e9


@dataclass(frozen=True)
class Plan:
    """Resolved parallel layout for one (arch, mesh, shape) cell."""

    mesh: Mesh
    dp: tuple[str, ...]          # batch axes
    tp: str                      # tensor-parallel axis name
    tp_size: int
    fsdp: bool                   # shard weights over "data" as well
    tp_heads: bool               # Megatron head-TP possible
    ep: bool                     # experts sharded over tp axis
    seq_axis: Optional[str]      # shard cache sequence dim (long-context)
    attn_impl: str               # "repeat" | "grouped"
    use_tp: bool = True          # False: model axis becomes extra DP
    seq_parallel: bool = False   # Megatron SP: shard resid seq over tp
    moe_dshard: bool = False     # constrain MoE dispatch buffer d over tp

    @property
    def fsdp_axis(self) -> Optional[str]:
        return "data" if self.fsdp else None


def make_plan(c: ModelConfig, mesh: Mesh, shape: ShapeConfig,
              *, force_fsdp: Optional[bool] = None) -> Plan:
    tp = "model"
    tp_size = axis_size(mesh, tp)
    dp = dp_axes(mesh)
    tp_heads = c.n_heads > 0 and c.n_heads % tp_size == 0
    fsdp = (c.param_count() > FSDP_PARAM_THRESHOLD
            if force_fsdp is None else force_fsdp)
    ep = c.n_experts > 0 and c.n_experts % tp_size == 0
    seq_axis = "data" if (shape.kind == "decode"
                          and shape.global_batch < axis_size(mesh, "data")) else None
    return Plan(mesh=mesh, dp=dp, tp=tp, tp_size=tp_size, fsdp=fsdp,
                tp_heads=tp_heads, ep=ep, seq_axis=seq_axis,
                attn_impl="repeat" if tp_heads else "grouped")


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _div(n: int, mesh: Mesh, axis: Optional[str]) -> bool:
    return axis is not None and n % axis_size(mesh, axis) == 0


def _spec(plan: Plan, shape: tuple[int, ...], wants: list[tuple[int, Optional[str]]],
          stacked: bool) -> P:
    """Build a PartitionSpec from (dim, axis) requests; skip non-divisible.

    ``wants`` dims are indices into the UNSTACKED shape; ``stacked`` shifts
    them by one for the scan-stacked leading layer dim.
    """
    off = 1 if stacked else 0
    parts: list[Optional[str]] = [None] * len(shape)
    used: set[str] = set()
    for dim, axis in wants:
        d = dim + off
        if axis == plan.tp and not plan.use_tp:
            axis = None  # dp-only layout: model axis carries batch instead
        if axis is None or axis in used or d >= len(shape):
            continue
        if shape[d] % axis_size(plan.mesh, axis) == 0:
            parts[d] = axis
            used.add(axis)
    return P(*parts)


# ---------------------------------------------------------------------------
# Parameter sharding
# ---------------------------------------------------------------------------


def _param_rule(c: ModelConfig, plan: Plan, path: tuple[str, ...],
                shape: tuple[int, ...]) -> P:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    parent = names[-2] if len(names) > 1 else ""
    stacked = "layers" in names  # scan-stacked leading dim
    fa = plan.fsdp_axis
    if not plan.use_tp:
        import dataclasses as _dc
        plan = _dc.replace(plan, tp_heads=False, ep=False)

    # --- embeddings -----------------------------------------------------
    if parent == "embed" or (parent == "encoder" and leaf == "pos"):
        if leaf in ("tok", "head"):
            return _spec(plan, shape, [(0, plan.tp), (1, fa)], False)
        if leaf == "pos":
            return _spec(plan, shape, [(0, plan.tp)], False)

    # --- attention ------------------------------------------------------
    # Head-TP (Megatron column/row) when n_heads divides the tp axis.
    # Fallback: attention weights REPLICATED over tp (FSDP over data only).
    # Contracting-dim TP was measured to make GSPMD all-reduce the O(S*T)
    # score tensors (EXPERIMENTS.md par.Perf) — strictly worse than
    # replicating the (small) attention compute for these archs.
    if parent in ("attn", "cross"):
        # FSDP archs additionally shard the head_dim over tp (2D weight
        # sharding; GSPMD all-gathers just-in-time) so nothing stays
        # 16x-replicated on the model axis.
        dh_tp = plan.tp if plan.fsdp else None
        if leaf == "wq":
            if plan.tp_heads:
                return _spec(plan, shape, [(1, fa), (2, plan.tp)], stacked)
            return _spec(plan, shape, [(1, fa), (3, dh_tp)], stacked)
        if leaf in ("wk", "wv"):
            kvh = c.n_kv_heads
            if plan.tp_heads and kvh % plan.tp_size == 0:
                return _spec(plan, shape, [(1, fa), (2, plan.tp)], stacked)
            return _spec(plan, shape, [(1, fa), (3, dh_tp)], stacked)
        if leaf == "wo":
            if plan.tp_heads:
                return _spec(plan, shape, [(0, plan.tp), (2, fa)], stacked)
            return _spec(plan, shape, [(2, fa), (1, dh_tp)], stacked)
        return P()  # biases

    # --- dense mlp / shared expert --------------------------------------
    if parent in ("mlp", "shared"):
        if leaf in ("wi", "wi_gate", "wi_up"):
            return _spec(plan, shape, [(1, plan.tp), (0, fa)], stacked)
        if leaf == "wo":
            return _spec(plan, shape, [(0, plan.tp), (1, fa)], stacked)
        return P()

    # --- moe experts -----------------------------------------------------
    if parent == "experts":
        # unstacked leaf shape: (E, D, F) or (E, F, D)
        if plan.ep:
            if leaf in ("wi", "wi_gate", "wi_up"):
                return _spec(plan, shape, [(0, plan.tp), (2, fa)], stacked)
            if leaf == "wo":
                return _spec(plan, shape, [(0, plan.tp), (1, fa)], stacked)
            return _spec(plan, shape, [(0, plan.tp)], stacked)
        # E not divisible: TP inside the expert FFN dim
        if leaf in ("wi", "wi_gate", "wi_up"):
            return _spec(plan, shape, [(2, plan.tp), (1, fa)], stacked)
        if leaf == "wo":
            return _spec(plan, shape, [(1, plan.tp), (2, fa)], stacked)
        return P()
    if leaf == "router":
        return P()

    # --- mamba ------------------------------------------------------------
    if parent == "mamba":
        if leaf == "in_proj":
            return _spec(plan, shape, [(0, fa)], stacked)
        if leaf == "out_proj":
            return _spec(plan, shape, [(0, fa)], stacked)
        return P()

    # --- norms, scalars ----------------------------------------------------
    return P()


def param_shardings(c: ModelConfig, plan: Plan, abstract_params: Params):
    """Map an (abstract) param pytree to NamedShardings."""

    def rule(path, leaf):
        spec = _param_rule(c, plan, path, tuple(leaf.shape))
        return NamedSharding(plan.mesh, spec)

    return jax.tree_util.tree_map_with_path(rule, abstract_params)


def shard_abstract(tree, shardings):
    """Attach shardings to a ShapeDtypeStruct pytree (dry-run inputs)."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        tree, shardings)


# ---------------------------------------------------------------------------
# Optimizer-state sharding (ZeRO-1 / Megatron distributed optimizer)
# ---------------------------------------------------------------------------


def zero1_sharding(plan: Plan, param_sharding: NamedSharding,
                   shape: tuple[int, ...]) -> NamedSharding:
    """Extra-shard optimizer state over every unused mesh axis.

    ZeRO-1 classically shards over DP only; we extend to any axis the
    parameter itself doesn't use (e.g. non-head-TP archs leave "model"
    free on their attention weights — fp32 m/v/master would otherwise be
    replicated 16x there)."""
    spec = list(param_sharding.spec)
    spec += [None] * (len(shape) - len(spec))
    if not shape:
        return NamedSharding(plan.mesh, P(*spec))
    used: set = set()
    for part in spec:
        for a in (part if isinstance(part, tuple) else (part,)):
            if a:
                used.add(a)
    for axis in ("data", "model", "pod"):
        if axis in used or axis not in plan.mesh.axis_names:
            continue
        asz = axis_size(plan.mesh, axis)
        candidates = [i for i in range(len(shape))
                      if spec[i] is None and shape[i] % asz == 0]
        if candidates:
            i = max(candidates, key=lambda i: shape[i])
            spec[i] = axis
            used.add(axis)
    return NamedSharding(plan.mesh, P(*spec))


def opt_state_shardings(plan: Plan, param_shardings_tree, abstract_params):
    def rule(sh, leaf):
        return zero1_sharding(plan, sh, tuple(leaf.shape))
    return jax.tree.map(rule, param_shardings_tree, abstract_params)


# ---------------------------------------------------------------------------
# Batch / activation / cache sharding
# ---------------------------------------------------------------------------


def batch_sharding(plan: Plan, shape: tuple[int, ...],
                   batch_dim: int = 0) -> NamedSharding:
    parts: list = [None] * len(shape)
    b = shape[batch_dim]
    if b % _dp_size(plan) == 0:
        parts[batch_dim] = plan.dp
    elif b % axis_size(plan.mesh, "data") == 0:
        parts[batch_dim] = "data"
    return NamedSharding(plan.mesh, P(*parts))


def cache_sharding(c: ModelConfig, plan: Plan, path: tuple, shape) -> NamedSharding:
    """KV/SSM cache sharding. Stacked leading layer dim, then batch.

    attn k/v: (L, B, T, Kh, Dh); mamba conv: (L, B, K-1, CH); ssm:
    (L, B, nh, hp, ns). Batch over dp when divisible; long-context decode
    (batch < data axis) shards the sequence dim instead.
    """
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    mesh = plan.mesh
    parts: list = [None] * len(shape)
    bdim = 1
    if shape[bdim] % _dp_size(plan) == 0:
        parts[bdim] = plan.dp
    elif shape[bdim] % axis_size(mesh, "data") == 0:
        parts[bdim] = "data"
    if leaf in ("k", "v"):
        if parts[bdim] is None and plan.seq_axis and shape[2] % axis_size(mesh, plan.seq_axis) == 0:
            parts[2] = plan.seq_axis      # sequence-sharded KV
        if shape[3] % plan.tp_size == 0:
            parts[3] = plan.tp            # kv heads
        elif shape[4] % plan.tp_size == 0:
            parts[4] = plan.tp            # head dim
    elif leaf == "ssm":
        if shape[2] % plan.tp_size == 0:
            parts[2] = plan.tp            # ssm heads
    return NamedSharding(mesh, P(*parts))


def _dp_size(plan: Plan) -> int:
    n = 1
    for a in plan.dp:
        n *= axis_size(plan.mesh, a)
    return n


def dp_size(plan: Plan) -> int:
    """Total data-parallel degree of a plan (product of its dp axes)."""
    return _dp_size(plan)


def make_attn_hints(c: ModelConfig, plan: Plan, batch: int,
                    cache_seq: int = 0, decode: bool = False,
                    seq_len: int = 0):
    """Attention sharding hints (see repro.models.attention): explicit
    q/k/v/out constraints so remat-recomputed backward keeps the forward
    layout instead of replicating score tensors. Decode keeps heads
    unsharded (grouped einsum against the Kh/Dh-sharded cache)."""
    from repro.models.attention import AttnShardingHints
    mesh, tp = plan.mesh, plan.tp

    def bspec(b):
        if b % _dp_size(plan) == 0:
            return plan.dp
        if b % axis_size(mesh, "data") == 0:
            return "data"
        return None

    bs = bspec(batch)
    h_ax = tp if (plan.tp_heads and not decode) else None
    kv_ax = tp if (plan.tp_heads and not decode
                   and c.n_kv_heads % plan.tp_size == 0) else None
    q_spec = P(bs, None, h_ax, None)
    kv_spec = P(bs, None, kv_ax, None)
    cache_spec = None
    if cache_seq:
        parts = [bs, None, None, None]
        if bs is None and plan.seq_axis and cache_seq % axis_size(
                mesh, plan.seq_axis) == 0:
            parts[1] = plan.seq_axis
        if c.n_kv_heads and c.n_kv_heads % plan.tp_size == 0:
            parts[2] = tp
        elif c.d_head and c.d_head % plan.tp_size == 0:
            parts[3] = tp
        cache_spec = P(*parts)
    # Megatron sequence parallelism: shard the residual stream's sequence
    # dim over tp between blocks (AR becomes RS+AG: half the wire bytes)
    sp_ax = (plan.tp if (plan.seq_parallel and seq_len
                         and seq_len % plan.tp_size == 0) else None)
    return AttnShardingHints(q_spec=q_spec, kv_spec=kv_spec,
                             out_spec=q_spec, cache_spec=cache_spec,
                             resid_spec=P(bs, sp_ax, None))


def logits_sharding(plan: Plan, shape: tuple[int, ...]) -> NamedSharding:
    parts: list = [None] * len(shape)
    if shape[0] % _dp_size(plan) == 0:
        parts[0] = plan.dp
    elif shape[0] % axis_size(plan.mesh, "data") == 0:
        parts[0] = "data"
    if shape[-1] % plan.tp_size == 0:
        parts[-1] = plan.tp
    return NamedSharding(plan.mesh, P(*parts))


def replicated(plan: Plan) -> NamedSharding:
    return NamedSharding(plan.mesh, P())


# ---------------------------------------------------------------------------
# Train-state placement (the bench layer / launch CLI entry point)
# ---------------------------------------------------------------------------


def make_dp_plan(mesh: Mesh) -> Plan:
    """Pure data-parallel Plan for models the table-driven LM rules do
    not describe (ResNet): params replicate, batch shards over every
    non-"model" axis, optimizer state still ZeRO-1-shards over whatever
    axes divide it."""
    return Plan(mesh=mesh, dp=dp_axes(mesh), tp="model",
                tp_size=axis_size(mesh, "model"), fsdp=False,
                tp_heads=False, ep=False, seq_axis=None,
                attn_impl="repeat", use_tp=False)


def train_state_shardings(plan: Plan, params: Params, opt_state: Params,
                          c: Optional[ModelConfig] = None):
    """(param, optimizer-state) NamedSharding trees for one Plan.

    With an LM ``ModelConfig`` the table-driven parameter rules apply
    (TP/FSDP per plan); without one, parameters replicate (classic DP).
    AdamW's ``m``/``v``/``master`` trees mirror the parameter tree and
    get the ZeRO-1 extra-sharding; scalars and factored Adafactor
    states replicate (their shapes do not mirror params).
    """
    if c is None:
        psh = jax.tree.map(lambda _: replicated(plan), params)
    else:
        psh = param_shardings(c, plan, params)
    mirrored = opt_state_shardings(plan, psh, params)
    rep = replicated(plan)
    osh = {k: (mirrored if k in ("m", "v", "master")
               else jax.tree.map(lambda _: rep, v))
           for k, v in opt_state.items()}
    return psh, osh


def grad_shardings(plan: Plan, param_shardings_tree, params: Params):
    """ZeRO-2 gradient-accumulator shardings: the zero1 extra-sharding
    applied to the grad buffer itself, so each dp rank owns a slice of
    the accumulated gradients (GSPMD then reduce-scatters each
    microbatch's contribution instead of all-reducing the full buffer,
    and the fp32 accumulator stops being replicated over dp)."""
    return opt_state_shardings(plan, param_shardings_tree, params)


def shard_train_state(plan: Plan, params: Params, opt_state: Params,
                      c: Optional[ModelConfig] = None):
    """Place a concrete (params, opt_state) onto the plan's mesh.

    Returns ``(params, opt_state, param_shardings, opt_shardings,
    grad_shardings)`` — param shardings double as checkpoint-restore
    targets, grad shardings are the ZeRO-2 dp-sharded accumulator specs
    for ``make_train_step``. This is the one device-placement path
    shared by the bench workloads and ``repro.launch.train``.
    """
    psh, osh = train_state_shardings(plan, params, opt_state, c)
    gsh = grad_shardings(plan, psh, params)
    return (jax.device_put(params, psh), jax.device_put(opt_state, osh),
            psh, osh, gsh)
