"""ResNet50 — the paper's computer-vision benchmark case (Fig. 3/4, Table III).

Not an LM; described by its own small config record.
"""
from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ResNetConfig:
    name: str = "resnet50"
    stage_sizes: tuple = (3, 4, 6, 3)      # ResNet50 bottleneck stages
    width: int = 64
    n_classes: int = 1000
    img_size: int = 224
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    def reduced(self, **overrides) -> "ResNetConfig":
        small = dict(stage_sizes=(1, 1, 1, 1), width=8, n_classes=16,
                     img_size=32, name=self.name + "-reduced")
        small.update(overrides)
        return replace(self, **small)


CONFIG = ResNetConfig()
RESNET18 = ResNetConfig(name="resnet18", stage_sizes=(2, 2, 2, 2))
RESNET34 = ResNetConfig(name="resnet34", stage_sizes=(3, 4, 6, 3))
