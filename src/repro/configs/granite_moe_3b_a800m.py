"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,             # per-expert FFN width (fine-grained experts)
    vocab=49155,
    n_experts=40,
    top_k=8,
    expert_d_ff=512,
    moe_layer_step=1,     # every layer is MoE
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
