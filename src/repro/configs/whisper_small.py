"""whisper-small — enc-dec audio backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,          # decoder layers
    n_enc_layers=12,
    enc_seq=1500,         # 30 s of audio at 50 frames/s (post-conv)
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=3072,
    vocab=51865,
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    mlp_bias=True,
    use_rope=False,       # learned absolute positions
    max_position=40960,   # covers decode_32k; long_500k is skipped (quad.)
    tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)
