"""The paper's own benchmark models.

CARAML trains GPT decoder models from scratch with Megatron-LM:
  - 117M (GPT-2 small layout)  — the Graphcore IPU case (Table II)
  - 800M                       — the main NVIDIA/AMD case (Fig. 2)
  - 13B / 175B                 — provided configs for larger systems
All use rotary positional embeddings, as the paper's Megatron-LM setup does.
"""
from repro.configs.base import ModelConfig

_COMMON = dict(
    family="dense",
    vocab=50257,          # GPT-2 tokenizer (OSCAR preprocessed with GPT-2 BPE)
    norm="layernorm",
    act="gelu",
    qkv_bias=True,
    mlp_bias=True,
    use_rope=True,        # paper: "rotary positional embeddings"
    tie_embeddings=True,
)

GPT_117M = ModelConfig(
    name="gpt-117m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, source="CARAML paper (Graphcore case, Table II)", **_COMMON)

GPT_800M = ModelConfig(
    name="gpt-800m", n_layers=24, d_model=1536, n_heads=16, n_kv_heads=16,
    d_ff=6144, source="CARAML paper (NVIDIA/AMD case, Fig. 2)", **_COMMON)

GPT_13B = ModelConfig(
    name="gpt-13b", n_layers=40, d_model=5120, n_heads=40, n_kv_heads=40,
    d_ff=20480, source="CARAML paper (13B JUBE config)", **_COMMON)

GPT_175B = ModelConfig(
    name="gpt-175b", n_layers=96, d_model=12288, n_heads=96, n_kv_heads=96,
    d_ff=49152, source="CARAML paper (175B JUBE config)", **_COMMON)
