"""llama4-maverick-400b-a17b — MoE 128 experts top-1, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

Interpretation (DESIGN.md par.4): MoE on every 2nd layer (the published
Maverick `interleave_moe_layer_step=2`), dense SwiGLU on the others — this
matches the "400b total / a17b active" naming; MoE-on-every-layer would be
~780 B params. long_500k is runnable via the published chunked/local
attention (iRoPE) window of 8192.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=16384,           # dense (non-MoE) layers; experts use 8192 below
    vocab=202048,
    n_experts=128,
    top_k=1,
    expert_d_ff=8192,
    moe_layer_step=2,
    moe_shared=True,      # shared expert in parallel with the routed one
    attn_window=8192,     # chunked attention (iRoPE) -> sub-quadratic
    rope_theta=500_000.0,
    long_context_ok=True,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)
