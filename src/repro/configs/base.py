"""Model/shape configuration system.

Every architecture is a pure function of a frozen :class:`ModelConfig`.
Input shapes are frozen :class:`ShapeConfig` records; the cross product of
(arch x shape) defines the benchmark/dry-run cells.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field, replace
from typing import Optional

# ---------------------------------------------------------------------------
# Shape configs (assigned input-shape set; identical for all LM-family archs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    """One benchmark cell's input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def vocab_pad(vocab: int, multiple: int = 256) -> int:
    """Megatron-style vocab padding (make_vocab_size_divisible_by)."""
    return int(math.ceil(vocab / multiple) * multiple)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. All models are pure functions of this.

    family:
      dense   — decoder-only transformer (GQA)
      moe     — decoder-only with MoE FFN layers
      ssm     — attention-free Mamba2 (SSD)
      hybrid  — Mamba2 + periodic attention (+ optional MoE) (Jamba)
      encdec  — encoder-decoder transformer (Whisper backbone)
      vlm     — decoder-only with prepended patch embeddings (LLaVA backbone)
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    # attention
    d_head: int = 0                 # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    mlp_bias: bool = False
    attn_window: Optional[int] = None   # chunked/local attention (tokens)
    use_rope: bool = True               # False -> learned absolute positions
    max_position: int = 1 << 20         # for learned positions only
    logits_softcap: float = 0.0

    # norms / activations
    norm: str = "rmsnorm"           # "rmsnorm" | "layernorm"
    act: str = "swiglu"             # "swiglu" | "gelu"
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    moe_layer_step: int = 1         # every k-th layer is MoE (1 = all)
    moe_shared: bool = False        # shared expert in parallel with routed
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    attn_layer_period: int = 0      # hybrid: one attn layer per period
    attn_layer_offset: int = 0      # index of the attn layer inside period

    # encoder-decoder (Whisper backbone)
    n_enc_layers: int = 0
    enc_seq: int = 0                # precomputed frame embeddings length

    # VLM
    n_patches: int = 0              # precomputed patch embeddings length

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # bookkeeping
    source: str = ""
    long_context_ok: bool = False   # may run long_500k (sub-quadratic path)

    # ---------------------------------------------------------------
    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // max(self.n_heads, 1))

    # Derived quantities -------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        return vocab_pad(self.vocab)

    @property
    def group_size(self) -> int:
        """GQA group size (query heads per KV head)."""
        return self.n_heads // max(self.n_kv_heads, 1)

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        # layers i with (i % step == step-1) are MoE (e.g. step=2 -> 1,3,5..)
        return (i % self.moe_layer_step) == (self.moe_layer_step - 1)

    def is_attn_layer(self, i: int) -> bool:
        """For hybrid archs: whether layer i is attention (else Mamba)."""
        if self.family != "hybrid":
            return self.family != "ssm"
        return (i % self.attn_layer_period) == self.attn_layer_offset

    # Parameter counting (analytic; used by roofline + metrics) ----------
    def param_count(self) -> int:
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        small: dict = dict(
            n_layers=max(2, self.moe_layer_step * max(1, self.attn_layer_period or 1)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 2,
            d_ff=128,
            vocab=512,
            d_head=16,
        )
        if self.n_experts:
            small.update(n_experts=min(self.n_experts, 4), expert_d_ff=64,
                         top_k=min(self.top_k, 2))
        if self.ssm_state:
            small.update(ssm_state=16, ssm_headdim=16, ssm_chunk=32)
        if self.attn_layer_period:
            small.update(attn_layer_period=min(self.attn_layer_period, 4),
                         attn_layer_offset=min(self.attn_layer_offset, 3),
                         n_layers=2 * min(self.attn_layer_period, 4))
        if self.n_enc_layers:
            small.update(n_enc_layers=2, enc_seq=32)
        if self.n_patches:
            small.update(n_patches=16)
        if self.family == "ssm":
            small.update(n_heads=0, n_kv_heads=0, d_ff=0, d_head=0)
        small["name"] = self.name + "-reduced"
        small.update(overrides)
        return replace(self, **small)


def _attn_params(c: ModelConfig) -> int:
    qo = 2 * c.d_model * c.n_heads * c.d_head
    kv = 2 * c.d_model * c.n_kv_heads * c.d_head
    bias = (c.n_heads + 2 * c.n_kv_heads) * c.d_head if c.qkv_bias else 0
    return qo + kv + bias


def _mlp_params(c: ModelConfig, d_ff: int) -> int:
    n_mats = 3 if c.act == "swiglu" else 2
    return n_mats * c.d_model * d_ff + (c.mlp_bias and (n_mats - 1) * d_ff + c.d_model or 0)


def _mamba_params(c: ModelConfig) -> int:
    di, ns, nh = c.d_inner, c.ssm_state, c.ssm_nheads
    in_proj = c.d_model * (2 * di + 2 * ns + nh)  # z, x, B, C, dt
    conv = (di + 2 * ns) * c.ssm_conv
    out = di * c.d_model
    extras = 2 * nh + di  # A_log, D, norm
    return in_proj + conv + out + extras


def _param_count(c: ModelConfig, active_only: bool) -> int:
    total = c.padded_vocab * c.d_model  # embedding
    if not c.tie_embeddings:
        total += c.padded_vocab * c.d_model  # lm head
    if c.n_patches:
        total += 0  # patch frontend is a stub (precomputed embeddings)
    per_norm = c.d_model * (2 if c.norm == "layernorm" else 1)

    def layer_params(i: int, cross: bool = False) -> int:
        p = 0
        if c.is_attn_layer(i):
            p += _attn_params(c) + per_norm
            if cross:
                p += _attn_params(c) + per_norm
        else:
            p += _mamba_params(c) + per_norm
        if c.family in ("ssm",):
            return p
        if c.family == "hybrid" and not c.is_attn_layer(i):
            # mamba layer still followed by FFN in Jamba
            pass
        if c.is_moe_layer(i):
            eff = c.expert_d_ff or c.d_ff
            n_used = c.top_k if active_only else c.n_experts
            p += n_used * _mlp_params(c, eff) + per_norm
            if c.moe_shared:
                p += _mlp_params(c, eff)
            p += c.d_model * c.n_experts  # router
        elif c.d_ff:
            p += _mlp_params(c, c.d_ff) + per_norm
        return p

    for i in range(c.n_layers):
        total += layer_params(i, cross=c.family == "encdec")
    for i in range(c.n_enc_layers):
        total += _attn_params(c) + _mlp_params(c, c.d_ff) + 2 * per_norm
    total += per_norm  # final norm
    return total
