"""command-r-35b — dense GQA, no-bias, 256k vocab
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab=256000,
    norm="layernorm",
    act="swiglu",
    tie_embeddings=True,
    rope_theta=8_000_000.0,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)
