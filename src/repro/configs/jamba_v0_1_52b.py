"""jamba-v0.1-52b — hybrid Mamba+attention (1:7 interleave) with MoE
(16 experts, top-2, every 2nd layer) [arXiv:2403.19887; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    # hybrid pattern: one attention layer per 8 (1:7 mamba:attn interleave)
    attn_layer_period=8,
    attn_layer_offset=4,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=256,
    # MoE on every 2nd layer
    n_experts=16,
    top_k=2,
    expert_d_ff=14336,
    moe_layer_step=2,
    use_rope=False,       # Jamba uses no positional encoding in attn layers
    long_context_ok=True,  # only 4 attention layers; KV seq-sharded
    source="arXiv:2403.19887; hf",
)
