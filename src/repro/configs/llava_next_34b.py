"""llava-next-34b — VLM backbone; anyres tiling frontend is a STUB
(input_specs provides precomputed patch embeddings prepended to the text
sequence) [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    n_patches=2880,       # anyres: 5 tiles x 576 patches
    rope_theta=5_000_000.0,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
