"""Config registry: every selectable ``--arch`` id maps to a ModelConfig."""
from __future__ import annotations

from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    vocab_pad,
)
from repro.configs import resnet50 as _resnet50
from repro.configs.gpt_models import GPT_117M, GPT_800M, GPT_13B, GPT_175B

from repro.configs.granite_8b import CONFIG as _granite_8b
from repro.configs.qwen2_0_5b import CONFIG as _qwen2_0_5b
from repro.configs.command_r_35b import CONFIG as _command_r_35b
from repro.configs.llama3_2_3b import CONFIG as _llama3_2_3b
from repro.configs.whisper_small import CONFIG as _whisper_small
from repro.configs.llava_next_34b import CONFIG as _llava_next_34b
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba
from repro.configs.mamba2_1_3b import CONFIG as _mamba2
from repro.configs.granite_moe_3b_a800m import CONFIG as _granite_moe
from repro.configs.llama4_maverick_400b_a17b import CONFIG as _llama4

# The 10 assigned architectures (dry-run + roofline cells).
ASSIGNED: dict[str, ModelConfig] = {
    c.name: c
    for c in (
        _granite_8b,
        _qwen2_0_5b,
        _command_r_35b,
        _llama3_2_3b,
        _whisper_small,
        _llava_next_34b,
        _jamba,
        _mamba2,
        _granite_moe,
        _llama4,
    )
}

# The paper's own models.
PAPER_MODELS: dict[str, ModelConfig] = {
    c.name: c for c in (GPT_117M, GPT_800M, GPT_13B, GPT_175B)
}

REGISTRY: dict[str, ModelConfig] = {**ASSIGNED, **PAPER_MODELS}

RESNET_REGISTRY = {
    "resnet50": _resnet50.CONFIG,
    "resnet18": _resnet50.RESNET18,
    "resnet34": _resnet50.RESNET34,
}


def get_config(arch: str) -> ModelConfig:
    try:
        return REGISTRY[arch]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch!r}; available: {sorted(REGISTRY)}"
        ) from None


def cells(archs=None, shapes=None):
    """All (arch, shape) benchmark cells, honoring long_500k applicability."""
    out = []
    for a in archs or ASSIGNED:
        cfg = get_config(a)
        for s in shapes or SHAPES:
            shp = SHAPES[s]
            if shp.name == "long_500k" and not cfg.long_context_ok:
                continue  # quadratic full-attention arch: documented skip
            out.append((cfg, shp))
    return out


def skipped_cells(archs=None):
    out = []
    for a in archs or ASSIGNED:
        cfg = get_config(a)
        if not cfg.long_context_ok:
            out.append((cfg.name, "long_500k", "full quadratic attention"))
    return out


__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "ASSIGNED", "PAPER_MODELS", "REGISTRY",
    "RESNET_REGISTRY", "get_config", "cells", "skipped_cells", "vocab_pad",
]
