"""Utilization sources for the analytic TPU power model.

``TPUModelPower`` converts a utilization fraction into watts
(``P = idle + (TDP - idle) * u``); this module supplies that fraction
from the roofline occupancy of the compiled steps (the dry-run
artifacts under ``artifacts/dryrun/``) instead of the old constant 1.0
— which billed every modeled run at full TDP regardless of occupancy
and overreported energy for memory-/collective-bound cells.
"""
from __future__ import annotations

import json
import logging
import os
import pathlib
from typing import Callable, Optional

log = logging.getLogger(__name__)


def _dryrun_dir(override: Optional[str] = None) -> pathlib.Path:
    d = override or os.environ.get("REPRO_DRYRUN_DIR")
    if d:
        return pathlib.Path(d)
    # anchored to the repo root, not the cwd (same convention as the
    # roofline workload)
    repo_root = pathlib.Path(__file__).resolve().parents[3]
    return repo_root / "artifacts" / "dryrun"


def roofline_fractions(dryrun_dir=None) -> list[float]:
    """All finite ``roofline_fraction`` values in the dry-run artifacts
    (empty when the directory or the field is absent)."""
    out = []
    d = _dryrun_dir(dryrun_dir)
    if not d.is_dir():
        return out
    for f in sorted(d.glob("*.json")):
        try:
            r = json.loads(f.read_text())
            frac = float(r["roofline"]["roofline_fraction"])
        except (OSError, ValueError, TypeError, KeyError,
                json.JSONDecodeError):
            continue
        if 0.0 <= frac:
            out.append(min(frac, 1.0))
    return out


def roofline_utilization_fn(dryrun_dir=None, default: float = 1.0,
                            ) -> Callable[[], float]:
    """A ``TPUModelPower.utilization_fn`` backed by roofline occupancy.

    Averages the ``roofline_fraction`` across the dry-run artifacts —
    the occupancy of the compiled steps this host would run. Falls back
    to ``default`` (with a logged warning) when no roofline data exists,
    so modeled power stays populated on fresh checkouts.
    """
    fracs = roofline_fractions(dryrun_dir)
    if not fracs:
        log.warning(
            "tpu_model power: no roofline dry-run artifacts under %s; "
            "utilization falls back to %.2f (full-TDP billing) — run "
            "`python -m repro.launch.dryrun` to ground it in occupancy",
            _dryrun_dir(dryrun_dir), default)
        u = default
    else:
        u = sum(fracs) / len(fracs)
    return lambda: u
