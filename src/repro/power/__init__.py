from repro.power.ctxmgr import MeasuredScope, get_power
from repro.power.frame import Frame
from repro.power.methods import (
    METHODS, PowerMethod, RaplPower, SyntheticPower, TPUModelPower, get_method,
)

__all__ = [
    "MeasuredScope", "get_power", "Frame", "METHODS", "PowerMethod",
    "RaplPower", "SyntheticPower", "TPUModelPower", "get_method",
]
