"""jpwr-style power measurement context manager.

Usage (mirrors the paper's jpwr API):

    from repro.power.ctxmgr import get_power
    from repro.power.methods import get_method

    met_list = [get_method("tpu_model", n_devices=4, utilization_fn=u)]
    with get_power(met_list, interval_ms=100) as measured_scope:
        application_call()
    print(measured_scope.df)
    energy_df, additional = measured_scope.energy()

A background thread samples every method periodically; at exit, samples are
trapezoid-integrated to energy (Wh). ``df_suffix`` supports ``%q{VAR}``
environment interpolation for per-rank files, as in jpwr.
"""
from __future__ import annotations

import os
import re
import threading
import time
from typing import Optional, Sequence

from repro.power.frame import Frame
from repro.power.methods import PowerMethod


def expand_suffix(suffix: str, env: Optional[dict] = None) -> str:
    """Interpolate %q{VARIABLE} from the environment (jpwr --df-suffix)."""
    env = env if env is not None else os.environ

    def rep(m):
        return str(env.get(m.group(1), ""))

    return re.sub(r"%q\{([^}]+)\}", rep, suffix)


class MeasuredScope:
    def __init__(self, methods: Sequence[PowerMethod], interval_ms: float,
                 clock=time.monotonic):
        self.methods = list(methods)
        self.interval = interval_ms / 1000.0
        self.clock = clock
        cols = ["t"]
        for m in self.methods:
            cols += [f"{m.name}:{d}" for d in m.devices()]
        self.df = Frame(cols)
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.t0 = 0.0
        self.t1 = 0.0

    # -- sampling ---------------------------------------------------------
    def _sample(self):
        row = {"t": self.clock()}
        for m in self.methods:
            try:
                for d, w in m.read().items():
                    row[f"{m.name}:{d}"] = w
            except Exception:
                pass  # a failing backend must not kill the measurement loop
        self.df.append(row)

    def _loop(self):
        while not self._stop.is_set():
            self._sample()
            self._stop.wait(self.interval)

    def start(self):
        self.t0 = self.clock()
        self._sample()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
        self._sample()
        self.t1 = self.clock()

    # -- integration --------------------------------------------------------
    def energy(self):
        """Returns (energy_df, additional_data) like jpwr.

        energy_df rows: device, energy_wh, avg_power_w, duration_s.
        """
        ts = self.df.col("t")
        records = []
        additional = {"samples": self.df}
        for col in self.df.columns[1:]:
            ws = self.df.col(col)
            joules = 0.0
            for i in range(1, len(ts)):
                if ws[i] is None or ws[i - 1] is None:
                    continue
                joules += 0.5 * (ws[i] + ws[i - 1]) * (ts[i] - ts[i - 1])
            dur = ts[-1] - ts[0] if len(ts) > 1 else 0.0
            records.append({
                "device": col,
                "energy_wh": joules / 3600.0,
                "avg_power_w": (joules / dur) if dur > 0 else 0.0,
                "duration_s": dur,
            })
        return Frame.from_records(records), additional

    def total_energy_wh(self) -> float:
        edf, _ = self.energy()
        return float(sum(edf.col("energy_wh")))

    def export(self, out_dir: str, filetype: str = "csv", suffix: str = ""):
        os.makedirs(out_dir, exist_ok=True)
        sfx = expand_suffix(suffix)
        edf, _ = self.energy()
        if filetype == "csv":
            self.df.to_csv(os.path.join(out_dir, f"power{sfx}.csv"))
            edf.to_csv(os.path.join(out_dir, f"energy{sfx}.csv"))
        else:
            self.df.to_json(os.path.join(out_dir, f"power{sfx}.json"))
            edf.to_json(os.path.join(out_dir, f"energy{sfx}.json"))


class get_power:
    """Context manager mirroring jpwr.ctxmgr.get_power."""

    def __init__(self, methods: Sequence[PowerMethod], interval_ms: float = 100,
                 clock=time.monotonic):
        self.scope = MeasuredScope(methods, interval_ms, clock=clock)

    def __enter__(self) -> MeasuredScope:
        self.scope.start()
        return self.scope

    def __exit__(self, *exc):
        self.scope.stop()
        return False
