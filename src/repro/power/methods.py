"""Power-measurement methods (jpwr's pluggable backend architecture).

Each method exposes ``read() -> dict[device_name, watts]`` plus metadata.
Methods mirror jpwr's: where jpwr has pynvml / rocm-smi / gcipuinfo /
GH200-sysfs, we provide:

  rapl       — Linux powercap sysfs (real counters where the host has them;
               the direct analog of jpwr's `gh` hwmon-sysfs method)
  tpu_model  — analytic TPU v5e power model driven by a utilization source
               (roofline occupancy of the compiled step); TPUs expose no
               user-space power counter, see DESIGN.md par.2.1
  synthetic  — deterministic waveform, for tests and CI
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class PowerMethod:
    name = "base"

    def devices(self) -> list[str]:
        raise NotImplementedError

    def read(self) -> dict[str, float]:
        """Instantaneous power per device, in watts."""
        raise NotImplementedError

    def available(self) -> bool:
        return True


class SyntheticPower(PowerMethod):
    """Deterministic device power: P(t) = base + amp * tri(t/period)."""

    name = "synthetic"

    def __init__(self, n_devices: int = 1, base: float = 100.0,
                 amp: float = 0.0, period: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        self.n, self.base, self.amp, self.period = n_devices, base, amp, period
        self.clock = clock
        self._t0 = clock()

    def devices(self):
        return [f"synthetic:{i}" for i in range(self.n)]

    def read(self):
        t = (self.clock() - self._t0) / self.period
        tri = abs(2 * (t - int(t)) - 1)  # triangle wave in [0, 1]
        p = self.base + self.amp * tri
        return {d: p for d in self.devices()}


class RaplPower(PowerMethod):
    """Linux powercap (intel-rapl) sysfs energy counters -> watts."""

    name = "rapl"
    ROOT = "/sys/class/powercap"

    def __init__(self, root: Optional[str] = None):
        self.root = root or self.ROOT
        self._zones = []
        if os.path.isdir(self.root):
            for z in sorted(os.listdir(self.root)):
                p = os.path.join(self.root, z, "energy_uj")
                if os.path.exists(p):
                    self._zones.append((z, p))
        self._last: dict[str, tuple[float, float]] = {}

    def available(self) -> bool:
        if not self._zones:
            return False
        try:
            with open(self._zones[0][1]) as f:
                f.read()
            return True
        except OSError:
            return False

    def devices(self):
        return [z for z, _ in self._zones]

    def read(self):
        out = {}
        now = time.monotonic()
        for z, p in self._zones:
            try:
                with open(p) as f:
                    uj = float(f.read().strip())
            except OSError:
                continue
            if z in self._last:
                uj0, t0 = self._last[z]
                dt = max(now - t0, 1e-6)
                d_uj = uj - uj0
                if d_uj < 0:  # counter wrap
                    d_uj = uj
                out[z] = d_uj / dt / 1e6
            else:
                out[z] = 0.0
            self._last[z] = (uj, now)
        return out


# TPU v5e power envelope (per chip). Idle fraction per public v5e studies.
TPU_V5E_TDP_W = 220.0
TPU_V5E_IDLE_W = 60.0


class TPUModelPower(PowerMethod):
    """Analytic TPU power: P = P_idle + (P_TDP - P_idle) * utilization.

    ``utilization_fn`` is supplied by the benchmark runner: typically the
    roofline occupancy of the running step (compute_term / step_time from
    the dry-run artifact), or a live duty-cycle estimate.
    """

    name = "tpu_model"

    def __init__(self, n_devices: int = 1,
                 utilization_fn: Optional[Callable[[], float]] = None,
                 tdp_w: float = TPU_V5E_TDP_W, idle_w: float = TPU_V5E_IDLE_W):
        self.n = n_devices
        self.utilization_fn = utilization_fn or (lambda: 0.0)
        self.tdp_w, self.idle_w = tdp_w, idle_w

    def devices(self):
        return [f"tpu_v5e:{i}" for i in range(self.n)]

    def read(self):
        u = min(max(float(self.utilization_fn()), 0.0), 1.0)
        p = self.idle_w + (self.tdp_w - self.idle_w) * u
        return {d: p for d in self.devices()}


class FallbackPower(PowerMethod):
    """Resilience wrapper: a primary backend whose ``read()`` failures
    fall back to a second method instead of crashing (or silently
    zeroing) the measurement.

    Column stability: ``name``/``devices()`` are the PRIMARY's —
    ``MeasuredScope`` builds its frame columns once at entry, so the
    wrapper must look like the primary forever. Fallback readings are
    remapped onto the primary's device names (total watts split evenly).
    After ``max_failures`` consecutive primary failures the wrapper
    stops poking the dead backend (``degraded``). ``label`` reports
    ``"<primary>+fallback:<name>"`` once any fallback reading was used,
    so records never pass modeled power off as measured.
    """

    def __init__(self, primary: PowerMethod, fallback: PowerMethod,
                 max_failures: int = 3):
        self.primary, self.fallback = primary, fallback
        self.name = primary.name
        self.max_failures = max(1, int(max_failures))
        self.failures = 0           # consecutive primary read failures
        self.fallback_reads = 0
        self.degraded = False

    @property
    def label(self) -> str:
        if self.fallback_reads:
            return f"{self.primary.name}+fallback:{self.fallback.name}"
        return self.primary.name

    def devices(self):
        return self.primary.devices()

    def available(self) -> bool:
        return self.primary.available() or self.fallback.available()

    def _read_fallback(self) -> dict:
        self.fallback_reads += 1
        vals = self.fallback.read()
        devs = self.primary.devices()
        per = sum(vals.values()) / max(len(devs), 1)
        return {d: per for d in devs}

    def read(self) -> dict:
        if self.degraded:
            return self._read_fallback()
        try:
            out = self.primary.read()
            self.failures = 0
            return out
        except Exception:  # noqa: BLE001 - a dead backend must not crash
            self.failures += 1
            if self.failures >= self.max_failures:
                self.degraded = True
            return self._read_fallback()


METHODS = {"synthetic": SyntheticPower, "rapl": RaplPower,
           "tpu_model": TPUModelPower}


def get_method(name: str, **kw) -> PowerMethod:
    return METHODS[name](**kw)


def select_power_methods(prefer: str = "auto", *, n_devices: int = 1,
                         utilization_fn: Optional[Callable[[], float]] = None,
                         ) -> tuple[list[PowerMethod], str]:
    """Pick the measurement backend for this host: RAPL -> TPU-model ->
    synthetic, returning ``(methods, source_label)``.

    The label is stamped into every result record as ``power_source`` so a
    reader can always tell measured counters from modeled or synthetic
    power. ``prefer`` forces a specific backend (or ``"none"`` to disable
    measurement); ``"auto"`` walks the preference order:

      rapl       — real powercap counters, when the host exposes them
      tpu_model  — analytic model, when running on an actual TPU backend
                   (TPUs expose no user-space counter) or REPRO_TPU is set
      synthetic  — deterministic waveform everywhere else (CPU CI hosts),
                   so energy columns stay populated but clearly labeled
    """
    if prefer == "none":
        return [], "none"
    if prefer not in ("auto", None):
        if prefer not in METHODS:
            raise KeyError(f"unknown power method {prefer!r}; "
                           f"known: {sorted(METHODS)} + ['auto', 'none']")
        kw: dict = {}
        if prefer in ("synthetic", "tpu_model"):
            kw["n_devices"] = n_devices
        if prefer == "tpu_model":
            kw["utilization_fn"] = utilization_fn or _roofline_utilization()
        return [METHODS[prefer](**kw)], prefer
    rapl = RaplPower()
    if rapl.available():
        return [rapl], "rapl"
    on_tpu = bool(os.environ.get("REPRO_TPU"))
    if not on_tpu:
        try:
            import jax
            on_tpu = jax.default_backend() == "tpu"
        except Exception:  # noqa: BLE001 - no jax, no TPU
            on_tpu = False
    if on_tpu:
        return [TPUModelPower(
            n_devices=n_devices,
            utilization_fn=utilization_fn or _roofline_utilization(),
        )], "tpu_model"
    return [SyntheticPower(n_devices=n_devices)], "synthetic"


def _roofline_utilization() -> Callable[[], float]:
    """Default tpu_model utilization: roofline occupancy of the dry-run
    artifacts, not the old constant 1.0 (which billed memory-bound steps
    at full TDP). Falls back to 1.0 — with a logged warning — when no
    roofline data exists."""
    from repro.power.utilization import roofline_utilization_fn
    return roofline_utilization_fn(default=1.0)
