"""Minimal column-oriented DataFrame (pandas is not available offline).

Supports what jpwr needs: append rows, column access, CSV/JSON export,
simple reductions — keeping the jpwr API shape (``measured_scope.df``,
``energy_df``) without the pandas dependency.
"""
from __future__ import annotations

import json
from typing import Any, Iterable


class Frame:
    def __init__(self, columns: Iterable[str]):
        self.columns = list(columns)
        self._rows: list[list[Any]] = []

    # -- construction -----------------------------------------------------
    def append(self, row: dict[str, Any] | Iterable[Any]):
        if isinstance(row, dict):
            self._rows.append([row.get(c) for c in self.columns])
        else:
            vals = list(row)
            assert len(vals) == len(self.columns)
            self._rows.append(vals)

    @classmethod
    def from_records(cls, records: list[dict]) -> "Frame":
        cols: list[str] = []
        for r in records:
            for k in r:
                if k not in cols:
                    cols.append(k)
        f = cls(cols)
        for r in records:
            f.append(r)
        return f

    # -- access -----------------------------------------------------------
    def __len__(self) -> int:
        return len(self._rows)

    def col(self, name: str) -> list:
        i = self.columns.index(name)
        return [r[i] for r in self._rows]

    def row(self, i: int) -> dict:
        return dict(zip(self.columns, self._rows[i]))

    def records(self) -> list[dict]:
        return [self.row(i) for i in range(len(self))]

    # -- export -----------------------------------------------------------
    def to_csv(self, path=None) -> str:
        lines = [",".join(self.columns)]
        for r in self._rows:
            lines.append(",".join("" if v is None else str(v) for v in r))
        text = "\n".join(lines) + "\n"
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_json(self, path=None) -> str:
        text = json.dumps(self.records(), indent=1, default=str)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def __repr__(self) -> str:
        head = " | ".join(f"{c:>14s}" for c in self.columns)
        body = "\n".join(
            " | ".join(f"{str(v):>14s}" for v in r) for r in self._rows[:20])
        more = f"\n... ({len(self)} rows)" if len(self) > 20 else ""
        return f"{head}\n{body}{more}"
