import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

For every cell this driver:
  1. builds the parallel Plan and abstract (ShapeDtypeStruct) inputs,
  2. ``jax.jit(step).lower(...).compile()`` on the production mesh,
  3. records memory_analysis / cost_analysis / parsed collective bytes,
  4. appends one JSON artifact per cell under artifacts/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh single --arch granite-8b
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both --all
"""
import argparse
import json
import pathlib
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, PAPER_MODELS, SHAPES, get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import specs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.models import attention as attn_mod
from repro.models import lm
from repro.parallel import sharding as sh
from repro.roofline import analysis as roof
from repro.roofline.hlo import parse_collectives
from repro.serve.engine import make_decode_fn
from repro.train.optimizer import OptConfig, opt_init, opt_update
from repro.train.step import StepConfig, make_train_step

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _cpu_f32_param_dupe_bytes(hlo_text: str) -> int:
    """Bytes of top-level f32 copies of bf16 parameters.

    XLA:CPU's float normalization rewrites bf16 dots to f32 dots (no native
    bf16 matmul on CPU) and then hoists the weight-side converts out of the
    layer while-loop, materializing full f32 twins of the stacked bf16
    weights/caches. TPU executes bf16 dots natively on the MXU, so these
    buffers do not exist there; we report memory both raw and corrected.
    Only direct convert-of-parameter fusions are counted (fp32 gradient
    accumulators etc. are real and kept).
    """
    import re as _re
    total = 0
    pat = _re.compile(r"= f32\[([0-9,]+)\]\S* fusion\(%param[^)]*\), kind=kLoop,"
                      r" calls=%wrapped_convert")
    for m in pat.finditer(hlo_text):
        n = 1
        for d in m.group(1).split(","):
            n *= int(d)
        total += n * 4
    return total


def _mem_dict(ma) -> dict:
    if ma is None:
        return {}
    fields = ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes")
    return {f: int(getattr(ma, f, 0)) for f in fields}


def _pick_opt(c, n_dev: int) -> OptConfig:
    # fp32 Adam + master = 12 B/param; when that alone would exceed half a
    # v5e's HBM even fully sharded, fall back to Adafactor (factored second
    # moment) — the standard very-large-model choice. Recorded per cell.
    if c.param_count() * 12 / n_dev > 8 * 2**30:
        return OptConfig(name="adafactor")
    return OptConfig()


def _opt_shardings(c, plan, aps, param_sh, oc=None):
    oc = oc or OptConfig()
    abstract_opt = jax.eval_shape(lambda p: opt_init(oc, p), aps)
    zs = lambda: sh.opt_state_shardings(plan, param_sh, aps)
    if oc.name == "adamw":
        opt_sh = {"step": sh.replicated(plan), "m": zs(), "v": zs(),
                  "master": zs()}
    else:
        from jax.sharding import NamedSharding, PartitionSpec as P

        def factored(drop_last: bool):
            def rule(psh, leaf):
                spec = list(psh.spec) + [None] * (leaf.ndim - len(psh.spec))
                if leaf.ndim < 2:
                    sub = [None] * max(leaf.ndim, 0)
                elif drop_last:
                    sub = spec[:-1]          # vr: reduced over last dim
                else:
                    sub = spec[:-2] + [spec[-1]]  # vc: reduced 2nd-to-last
                return NamedSharding(plan.mesh, P(*sub))
            return jax.tree.map(rule, param_sh, aps)

        opt_sh = {"step": sh.replicated(plan), "vr": factored(True),
                  "vc": factored(False)}
    return sh.shard_abstract(abstract_opt, opt_sh), opt_sh


def _analyze_compiled(compiled, n_dev: int):
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per computation
        ca = ca[0] if ca else {}
    colls = parse_collectives(compiled.as_text(), n_dev)
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)), colls)


def _lin_metrics(weighted):
    """Linear combination of (flops, bytes, colls) metric triples.

    Used for layer-count extrapolation: programs with 1 and 2 layer periods
    are compiled (tiny, fast) and metric(n) = (2-n)*m1 + (n-1)*m2, exact
    because periods are structurally identical (validated in tests against
    full unrolls). Avoids unrolling 36-96 layers through XLA:CPU.
    """
    f = sum(w * m[0] for m, w in weighted)
    b = sum(w * m[1] for m, w in weighted)
    colls = _combine_colls([(m[2], w) for m, w in weighted])
    for op in list(colls.counts):
        colls.counts[op] = max(int(round(colls.counts[op])), 0)
        colls.result_bytes[op] = max(int(round(colls.result_bytes[op])), 0)
        colls.wire_bytes[op] = max(colls.wire_bytes[op], 0.0)
    return f, b, colls


def _reduced_depth_config(c: ModelConfig, n_periods: int,
                          n_enc: int | None = None) -> ModelConfig:
    import dataclasses
    from repro.models.blocks import period_of
    kw = {"n_layers": period_of(c) * n_periods}
    if c.n_enc_layers:
        kw["n_enc_layers"] = n_enc if n_enc is not None else c.n_enc_layers
    return dataclasses.replace(c, **kw)


def lower_cell(c: ModelConfig, shape: ShapeConfig, mesh,
               mesh_name: str, *, microbatch_size: int = 4,
               plan_overrides: dict | None = None,
               step_overrides: dict | None = None,
               metrics_pass: bool = True):
    """Lower + compile one cell; return (record_dict, compiled).

    Two compiles per cell:
      A) the REAL step (layer scan, microbatch accumulation) -> proves the
         cell compiles and gives the true memory_analysis (scan/while
         buffers are allocated once, so memory is accurate);
      B) a metrics pass with UNROLLED layer scans (single microbatch for
         train) -> accurate FLOPs + collective bytes, since XLA's
         cost_analysis counts a while-loop body only once (verified in
         tests). Train totals = k * grad_microbatch + optimizer program C.
    """
    plan = sh.make_plan(c, mesh, shape)
    if plan_overrides:
        import dataclasses
        plan = dataclasses.replace(plan, **plan_overrides)
    n_dev = mesh.size
    # Micro-batch-size: the paper uses 4 (800M model on 40 GB A100); on
    # 16 GiB v5e we scale it down with model size so activations fit.
    params_b = c.param_count()
    if params_b > 16e9 or plan.fsdp:
        microbatch_size = 1
    elif params_b > 4e9:
        microbatch_size = min(microbatch_size, 2)
    t0 = time.time()
    with mesh:
        aps_sharded, param_sh = specs_mod.abstract_params(c, plan)
        k = 1
        if shape.kind == "train":
            per_dev_batch = max(shape.global_batch // max(
                sh._dp_size(plan), 1), 1)
            k = max(per_dev_batch // microbatch_size, 1)
            sc = StepConfig(microbatches=k, impl=plan.attn_impl,
                            remat="full", **(step_overrides or {}))
            abstract_p = lm.init_abstract(c)
            grad_sh = sh.opt_state_shardings(plan, param_sh, abstract_p)
            batch = specs_mod.train_batch_specs(c, plan, shape)
            batch_sh = jax.tree.map(lambda s: s.sharding, batch)
            oc = _pick_opt(c, n_dev)
            step = make_train_step(c, oc, sc, grad_shardings=grad_sh,
                                   batch_shardings=batch_sh)
            opt_sharded, opt_sh = _opt_shardings(c, plan, abstract_p,
                                                 param_sh, oc)
            jitted = jax.jit(step, out_shardings=(param_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            with _lower_ctx(c, plan, shape, shape.global_batch // k):
                lowered = jitted.lower(aps_sharded, opt_sharded, batch)
        elif shape.kind == "prefill":
            tokens, extras = specs_mod.prefill_specs(c, plan, shape)

            def prefill_step(params, tokens, extras, unroll=False):
                return lm.prefill(
                    c, params, tokens,
                    patch_embeds=extras.get("patch_embeds"),
                    enc_frames=extras.get("enc_frames"),
                    impl=plan.attn_impl, unroll=unroll)

            # pin output cache shardings (batch over dp, heads/Dh over tp)
            _, caches_sds, pos_sds, enckv_sds = specs_mod.decode_specs(
                c, plan, shape, lm.init_abstract(c))
            cache_out_sh = jax.tree.map(lambda s: s.sharding, caches_sds)
            enckv_out_sh = (None if enckv_sds is None else
                            jax.tree.map(lambda s: s.sharding, enckv_sds))
            with _lower_ctx(c, plan, shape, shape.global_batch):
                lowered = jax.jit(
                    prefill_step,
                    out_shardings=(None, cache_out_sh, enckv_out_sh)).lower(
                        aps_sharded, tokens, extras)
        else:  # decode
            token, caches, pos, enc_kv = specs_mod.decode_specs(
                c, plan, shape, lm.init_abstract(c))
            serve_step = make_decode_fn(c, impl="grouped")
            cache_out_sh = jax.tree.map(lambda x: x.sharding, caches)
            jitted = jax.jit(serve_step, donate_argnums=(2,),
                             out_shardings=(None, cache_out_sh))
            with _lower_ctx(c, plan, shape, shape.global_batch):
                lowered = jitted.lower(aps_sharded, token, caches, pos, enc_kv)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        # ---- metrics pass (layer-count extrapolation) ------------------
        flops = hbm_bytes = 0.0
        colls = None
        t_metrics = 0.0
        if metrics_pass:
            tm = time.time()
            flops, hbm_bytes, colls = _metrics_extrapolated(
                c, plan, shape, mesh, k, step_overrides=step_overrides)
            if shape.kind == "train":
                # C: optimizer-only program (full-depth param tree)
                oc_c = _pick_opt(c, n_dev)

                def opt_only(grads, state, params):
                    return opt_update(oc_c, grads, state, params)

                grads_spec = sh.shard_abstract(
                    jax.tree.map(lambda l: jax.ShapeDtypeStruct(
                        l.shape, jnp.float32), lm.init_abstract(c)),
                    param_sh)
                comp_c = jax.jit(opt_only).lower(
                    grads_spec, opt_sharded, aps_sharded).compile()
                fc, bc, cc = _analyze_compiled(comp_c, n_dev)
                flops = k * flops + fc
                hbm_bytes = k * hbm_bytes + bc
                colls = _combine_colls([(colls, k), (cc, 1)])
            t_metrics = time.time() - tm

    ma = _mem_dict(compiled.memory_analysis())
    f32_dupes = _cpu_f32_param_dupe_bytes(compiled.as_text())
    if colls is None:
        flops, hbm_bytes, colls = _analyze_compiled(compiled, n_dev)
    r = roof.analyze(c, shape, mesh_name=mesh_name, n_devices=n_dev,
                     flops_per_device=flops, hbm_bytes_per_device=hbm_bytes,
                     wire_bytes_per_device=colls.total_wire_bytes)
    per_dev_hbm = (ma.get("argument_size_in_bytes", 0)
                   + ma.get("temp_size_in_bytes", 0)
                   + ma.get("output_size_in_bytes", 0)
                   - ma.get("alias_size_in_bytes", 0))
    per_dev_hbm_tpu = per_dev_hbm - f32_dupes
    rec = {
        "arch": c.name, "shape": shape.name, "mesh": mesh_name,
        "n_devices": n_dev, "kind": shape.kind, "microbatches": k,
        "optimizer": _pick_opt(c, n_dev).name if shape.kind == "train"
        else None,
        "plan": {"tp_heads": plan.tp_heads, "fsdp": plan.fsdp, "ep": plan.ep,
                 "attn_impl": plan.attn_impl, "seq_axis": plan.seq_axis,
                 **(plan_overrides or {})},
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "metrics_s": round(t_metrics, 2),
        "memory_analysis": ma,
        "bytes_per_device": per_dev_hbm,
        "cpu_f32_param_dupe_bytes": f32_dupes,
        "bytes_per_device_tpu": per_dev_hbm_tpu,
        "fits_hbm_16g": per_dev_hbm_tpu < 16 * 1024**3,
        "fits_hbm_16g_raw_cpu": per_dev_hbm < 16 * 1024**3,
        "cost_analysis": {"flops": flops, "bytes_accessed": hbm_bytes},
        "collectives": colls.to_dict(),
        "roofline": r.to_dict(),
    }
    return rec, compiled


def dataclasses_replace_shape(shape: ShapeConfig, new_batch: int) -> ShapeConfig:
    import dataclasses
    return dataclasses.replace(shape, global_batch=new_batch)


def _hints_for(c: ModelConfig, plan, shape: ShapeConfig, batch: int):
    cache_seq = shape.seq_len if shape.kind == "decode" else 0
    return sh.make_attn_hints(c, plan, batch, cache_seq=cache_seq,
                              decode=shape.kind == "decode",
                              seq_len=shape.seq_len)


class _lower_ctx:
    """Sharding hints + MoE dispatch impl for one lowering."""

    def __init__(self, c, plan, shape, batch):
        import contextlib
        self.stack = contextlib.ExitStack()
        self.c, self.plan, self.shape, self.batch = c, plan, shape, batch

    def __enter__(self):
        from jax.sharding import PartitionSpec as P
        from repro.models import moe as moe_mod2
        self.stack.enter_context(attn_mod.sharding_hints(
            _hints_for(self.c, self.plan, self.shape, self.batch)))
        if self.c.n_experts and not self.plan.ep:
            self.stack.enter_context(moe_mod2.moe_impl("dense"))
        elif self.c.n_experts and getattr(self.plan, "moe_dshard", False):
            self.stack.enter_context(moe_mod2.moe_impl(
                "scatter", buf_spec=P(None, self.plan.tp)))
        return self

    def __exit__(self, *exc):
        self.stack.close()
        return False


def _lower_metrics_program(cfg: ModelConfig, plan, shape: ShapeConfig,
                           mb_batch: int, step_overrides: dict | None = None):
    """Lower one reduced-depth metrics program (unroll=True, trip<=2)."""
    import dataclasses as dc
    plan_r = dc.replace(plan)  # same layout flags, reduced-depth model
    aps_sharded, param_sh = specs_mod.abstract_params(cfg, plan_r)
    if shape.kind == "train":
        from repro.train.step import make_loss_fn
        sc_u = StepConfig(microbatches=1, impl=plan.attn_impl,
                          remat="full", unroll=True,
                          **(step_overrides or {}))
        loss_fn = make_loss_fn(cfg, sc_u)
        vg = jax.value_and_grad(loss_fn, has_aux=True)
        mb_shape = dataclasses_replace_shape(shape, mb_batch)
        batch = specs_mod.train_batch_specs(cfg, plan_r, mb_shape)
        # pin grad shardings like the real step (ZeRO grad buffer)
        grad_sh = sh.opt_state_shardings(
            plan_r, param_sh, lm.init_abstract(cfg))
        with _lower_ctx(cfg, plan, shape, mb_batch):
            return jax.jit(vg, out_shardings=(None, grad_sh)).lower(
                aps_sharded, batch)
    if shape.kind == "prefill":
        tokens, extras = specs_mod.prefill_specs(cfg, plan_r, shape)

        def prefill_step(params, tokens, extras):
            return lm.prefill(cfg, params, tokens,
                              patch_embeds=extras.get("patch_embeds"),
                              enc_frames=extras.get("enc_frames"),
                              impl=plan.attn_impl, unroll=True)

        with _lower_ctx(cfg, plan, shape, shape.global_batch):
            return jax.jit(prefill_step).lower(aps_sharded, tokens, extras)
    token, caches, pos, enc_kv = specs_mod.decode_specs(
        cfg, plan_r, shape, lm.init_abstract(cfg))

    def serve_step(params, token, caches, pos, enc_kv):
        return lm.decode_step(cfg, params, token, caches, pos,
                              enc_kv=enc_kv, impl="grouped", unroll=True)

    cache_out_sh = jax.tree.map(lambda x: x.sharding, caches)
    with _lower_ctx(cfg, plan, shape, shape.global_batch):
        return jax.jit(serve_step, donate_argnums=(2,),
                       out_shardings=(None, cache_out_sh)).lower(
            aps_sharded, token, caches, pos, enc_kv)


def _metrics_extrapolated(c: ModelConfig, plan, shape: ShapeConfig, mesh,
                          k: int, step_overrides: dict | None = None):
    """FLOPs/bytes/collectives via 1-vs-2-period extrapolation."""
    from repro.models.blocks import period_of
    n_dev = mesh.size
    n = c.n_layers // period_of(c)
    mb_batch = shape.global_batch // k if shape.kind == "train" else 0

    def run(np_, ne_=None):
        cfg = _reduced_depth_config(c, np_, ne_)
        comp = _lower_metrics_program(cfg, plan, shape, mb_batch,
                                      step_overrides).compile()
        return _analyze_compiled(comp, n_dev)

    if c.n_enc_layers:  # separate encoder/decoder slopes (3-point)
        ne = c.n_enc_layers
        m11, m21, m12 = run(1, 1), run(2, 1), run(1, 2)
        return _lin_metrics([(m11, float(3 - n - ne)),
                             (m21, float(n - 1)), (m12, float(ne - 1))])
    if n == 1:
        return run(1)
    m1, m2 = run(1), run(2)
    return _lin_metrics([(m1, float(2 - n)), (m2, float(n - 1))])


def _combine_colls(weighted):
    """Sum CollectiveStats with multipliers."""
    from repro.roofline.hlo import CollectiveStats
    out = CollectiveStats()
    for st, w in weighted:
        for op, n in st.counts.items():
            out.counts[op] += n * w
        for op, b in st.result_bytes.items():
            out.result_bytes[op] += b * w
        for op, b in st.wire_bytes.items():
            out.wire_bytes[op] += b * w
    return out


def run_cells(archs, shapes, meshes, out_dir: pathlib.Path,
              microbatch_size: int = 4, tag: str = "",
              metrics_pass: bool = True) -> list[dict]:
    out_dir.mkdir(parents=True, exist_ok=True)
    results = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        for arch in archs:
            c = get_config(arch)
            for sname in shapes:
                shape = SHAPES[sname]
                if sname == "long_500k" and not c.long_context_ok:
                    rec = {"arch": arch, "shape": sname, "mesh": mesh_name,
                           "skipped": "full quadratic attention (DESIGN.md)"}
                    results.append(rec)
                    continue
                fn = out_dir / f"{mesh_name}__{arch}__{sname}{tag}.json"
                print(f"[dryrun] {mesh_name:6s} {arch:28s} {sname:12s} ... ",
                      end="", flush=True)
                try:
                    rec, _ = lower_cell(c, shape, mesh, mesh_name,
                                        microbatch_size=microbatch_size,
                                        metrics_pass=metrics_pass)
                    rf = rec["roofline"]
                    print(f"ok compile={rec['compile_s']:.1f}s "
                          f"bottleneck={rf['bottleneck']:10s} "
                          f"frac={rf['roofline_fraction']:.3f} "
                          f"fits={rec['fits_hbm_16g']}")
                except Exception as e:  # record failures as bugs to fix
                    rec = {"arch": arch, "shape": sname, "mesh": mesh_name,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"FAIL {type(e).__name__}: {str(e)[:120]}")
                fn.write_text(json.dumps(rec, indent=1))
                results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--paper-models", action="store_true")
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default=str(ART))
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-metrics", action="store_true",
                    help="compile+memory proof only (multi-pod pass; the "
                         "roofline table is single-pod)")
    args = ap.parse_args()

    archs = args.arch or list(ASSIGNED)
    if args.paper_models:
        archs += list(PAPER_MODELS)
    shapes = args.shape or list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    results = run_cells(archs, shapes, meshes, pathlib.Path(args.out),
                        tag=args.tag, metrics_pass=not args.no_metrics)
    n_ok = sum(1 for r in results if "roofline" in r)
    n_skip = sum(1 for r in results if "skipped" in r)
    n_fail = sum(1 for r in results if "error" in r)
    print(f"\n[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_fail} FAILED")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
