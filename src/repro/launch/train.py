"""Training CLI: the end-to-end driver.

  PYTHONPATH=src python -m repro.launch.train --arch gpt-117m --preset tiny \
      --steps 50 --ckpt-dir /tmp/ckpt

On a real TPU cluster each process runs this under the Slurm scripts from
repro.launch.slurm with jax.distributed auto-init; on this CPU container
it runs reduced configs end-to-end (the quickstart/benchmark path).

XLA flags: latency-hiding scheduler + async collectives are enabled for
TPU so FSDP all-gathers and gradient reduce-scatters overlap with compute
(no-ops on CPU).
"""
import os

TPU_PERF_FLAGS = (
    " --xla_tpu_enable_latency_hiding_scheduler=true"
    " --xla_tpu_megacore_fusion_allow_ags=true"
    " --xla_enable_async_collective_permute=true"
    " --xla_tpu_enable_async_collective_fusion=true"
)
if os.environ.get("REPRO_TPU"):
    os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + TPU_PERF_FLAGS

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.spec import Placement
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.core.manifest import write_manifest
from repro.data.loader import ShardedLoader, lm_sample_fn
from repro.data.synthetic import synthetic_tokens
from repro.faults.schedule import FaultSchedule, TRAIN_PRESETS
from repro.faults.supervisor import run_supervised
from repro.launch.mesh import mesh_for
from repro.models import lm
from repro.parallel import sharding as shd
from repro.train.loop import LoopConfig, train_loop
from repro.train.optimizer import OptConfig, opt_init
from repro.train.step import StepConfig, make_train_step


def make_data_iter(c, global_batch: int, seq_len: int, seed: int = 0,
                   batch_put=None):
    """``batch_put`` places each batch onto the active mesh (identity
    when training single-device)."""
    toks = synthetic_tokens(4096, seq_len, c.vocab, seed=seed)

    def sample(idx: int):
        row = toks[idx % toks.shape[0]]
        return {"tokens": row[:-1], "labels": row[1:]}

    loader = ShardedLoader(sample, global_batch)

    def gen():
        for batch in loader:
            out = {"tokens": jnp.asarray(batch["tokens"]),
                   "labels": jnp.asarray(batch["labels"])}
            if c.family == "vlm":
                out["patch_embeds"] = jnp.zeros(
                    (global_batch, c.n_patches, c.d_model), jnp.bfloat16)
            if c.family == "encdec":
                out["enc_frames"] = jnp.zeros(
                    (global_batch, c.enc_seq, c.d_model), jnp.bfloat16)
            yield batch_put(out) if batch_put is not None else out

    return gen()


def make_data_fn(c, global_batch: int, seq_len: int, seed: int = 0,
                 batch_put=None):
    """Step-indexed data: ``data(step) -> batch``, the resume-safe form.

    A fresh iterator restarts at sample 0 after a crash, silently
    desyncing the data stream from the checkpointed step counter —
    indexing by step keeps batch ``N`` identical whether the run reached
    step ``N`` directly or through three crash/resume cycles, which is
    what makes the resumed loss trace bit-equal to the uninterrupted
    one. Same sample indexing as :func:`make_data_iter` (rank 0 of 1),
    so the two forms produce identical batches at every step."""
    toks = synthetic_tokens(4096, seq_len, c.vocab, seed=seed)

    def sample(idx: int):
        row = toks[idx % toks.shape[0]]
        return {"tokens": row[:-1], "labels": row[1:]}

    def data(step: int):
        base = step * global_batch
        samples = [sample(base + j) for j in range(global_batch)]
        out = {"tokens": jnp.asarray(np.stack([s["tokens"]
                                               for s in samples])),
               "labels": jnp.asarray(np.stack([s["labels"]
                                               for s in samples]))}
        if c.family == "vlm":
            out["patch_embeds"] = jnp.zeros(
                (global_batch, c.n_patches, c.d_model), jnp.bfloat16)
        if c.family == "encdec":
            out["enc_frames"] = jnp.zeros(
                (global_batch, c.enc_seq, c.d_model), jnp.bfloat16)
        return batch_put(out) if batch_put is not None else out

    return data


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-117m")
    ap.add_argument("--preset", choices=["full", "tiny"], default="tiny",
                    help="tiny = reduced config for CPU end-to-end runs")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a failure (fault-tolerance demo)")
    ap.add_argument("--fault-preset", default=None, choices=TRAIN_PRESETS,
                    help="seeded fault schedule; the run goes through the "
                         "bounded-restart supervisor (faults.supervisor)")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--max-restarts", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--placement", default="dp1",
                    help="device mesh, e.g. dp4 or dp2tp2 — the same "
                         "Placement spelling the bench sweeps use")
    args = ap.parse_args(argv)

    c = get_config(args.arch)
    if args.preset == "tiny":
        c = c.reduced()
    placement = Placement.of(args.placement)
    if placement.n_devices > jax.device_count():
        raise SystemExit(
            f"error: placement {placement.label} needs "
            f"{placement.n_devices} devices, process has "
            f"{jax.device_count()}; launch under the rendered Slurm "
            f"scripts (repro.launch.slurm) or set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={placement.n_devices}")
    print(f"[train] arch={c.name} params={c.param_count()/1e6:.1f}M "
          f"batch={args.global_batch} seq={args.seq_len} "
          f"placement={placement.label}")

    oc = OptConfig(lr=args.lr, warmup=max(args.steps // 20, 5),
                   total_steps=args.steps)
    sc = StepConfig(microbatches=args.microbatches)
    key = jax.random.key(args.seed)
    # state is rebuilt per supervisor attempt (the jitted step donates
    # its input buffers, so crashed state cannot be reused), then the
    # loop's auto-resume overwrites it from the checkpoint
    def init_state():
        p = lm.init(key, c)
        return p, opt_init(oc, p)

    params, opt_state = init_state()
    batch_put = None
    build_state = init_state
    if placement.n_devices > 1:
        # same placement path as the bench workloads: Plan from the mesh,
        # table-driven param/ZeRO-1 shardings, batch over the data axes —
        # including per-microbatch constraints (the (gb,)->(k, mb)
        # reshape loses the batch-axis sharding through GSPMD otherwise)
        plan = shd.make_plan(c, mesh_for(placement), ShapeConfig(
            "train_cli", args.seq_len, args.global_batch, "train"))
        params, opt_state, psh, osh, gsh = shd.shard_train_state(
            plan, params, opt_state, c)
        mbs = args.global_batch // max(args.microbatches, 1)
        bkeys = {"tokens": (mbs, args.seq_len),
                 "labels": (mbs, args.seq_len)}
        if c.family == "vlm":
            bkeys["patch_embeds"] = (mbs, c.n_patches, c.d_model)
        if c.family == "encdec":
            bkeys["enc_frames"] = (mbs, c.enc_seq, c.d_model)
        bsh = {k: shd.batch_sharding(plan, s) for k, s in bkeys.items()}
        # pin output shardings to the input placement — without this the
        # returned params' layout drifts from the placed inputs and every
        # call after the first recompiles (the dp-scaling collapse)
        step = jax.jit(make_train_step(c, oc, sc, grad_shardings=gsh,
                                       batch_shardings=bsh),
                       out_shardings=(psh, osh, None),
                       donate_argnums=(0, 1))

        def batch_put(batch):
            return jax.device_put(
                batch, {k: shd.batch_sharding(plan, v.shape)
                        for k, v in batch.items()})

        def build_state(p=psh, o=osh):
            fresh_p, fresh_o = init_state()
            return jax.device_put(fresh_p, p), jax.device_put(fresh_o, o)
    else:
        step = jax.jit(make_train_step(c, oc, sc), donate_argnums=(0, 1))

    data = make_data_fn(c, args.global_batch, args.seq_len, args.seed,
                        batch_put=batch_put)
    cfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir, log_every=10,
                     seq_len=args.seq_len, global_batch=args.global_batch)
    if args.fault_preset and args.fault_preset != "none":
        faults = FaultSchedule.from_preset(args.fault_preset,
                                           args.fault_seed, args.steps)
        print(f"[train] fault schedule {faults!r}")

        def run_once(hook):
            p, o = build_state()
            return train_loop(step, p, o, data, cfg, hooks=[hook],
                              faults=faults)

        sup = run_supervised(run_once, ckpt_dir=args.ckpt_dir,
                             max_restarts=args.max_restarts,
                             seed=args.fault_seed)
        res = sup.result
        print(f"[train] supervised: restarts={sup.restarts} "
              f"wasted_steps={sup.wasted_steps} "
              f"recovery_s={sup.recovery_s:.3f} "
              f"backoff_s={sup.backoff_s:.3f} "
              f"ckpt_fallbacks={sup.ckpt_fallbacks}")
    else:
        res = train_loop(step, params, opt_state, data, cfg,
                         fail_at_step=args.fail_at_step)
    print(f"[train] done: steps={res.steps_run} "
          f"first_loss={res.losses[0]:.4f} last_loss={res.losses[-1]:.4f} "
          f"tokens/s={res.tokens_per_s:,.0f} resumed_from={res.resumed_from}")
    return res


if __name__ == "__main__":
    main()
