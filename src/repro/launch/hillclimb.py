import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512")
"""Perf hillclimbing driver (EXPERIMENTS.md par.Perf).

Runs named optimization variants over chosen (arch x shape) cells:
re-lowers, re-analyzes the roofline terms, and records
hypothesis -> change -> before -> after per variant. Variants:

  baseline      the paper-faithful layout from repro.parallel.sharding
  dp_only       model axis re-purposed as extra data parallelism (for
                small archs whose TP is replicated/latency-bound)
  bf16_grads    Megatron's bf16 gradient buffer (halves grad RS wire)
  seq_parallel  Megatron SP: residual-stream AR -> RS+AG (halves wire)
  mbs{N}        micro-batch-size sweep
  flash_attn    measured attention-core traffic replaced by the Pallas
                flash kernel's streaming traffic (kernel validated in
                interpret mode; its HBM cost modeled as q/k/v/o IO)

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb \
      --cell qwen2-0.5b:train_4k --variant baseline --variant dp_only
"""
import argparse
import dataclasses
import json
import pathlib

import jax

from repro.configs import SHAPES, get_config
from repro.launch.dryrun import (
    _analyze_compiled, _lower_metrics_program, _metrics_extrapolated,
    lower_cell,
)
from repro.launch.mesh import make_production_mesh
from repro.models import attention as attn_mod
from repro.parallel import sharding as sh
from repro.roofline import analysis as roof

ART = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "hillclimb"

VARIANTS: dict[str, dict] = {
    "baseline": {},
    "dp_only": {"plan": {"use_tp": False, "tp_heads": False, "ep": False,
                         "attn_impl": "grouped"}},
    "bf16_grads": {"step": {"grad_dtype": "bfloat16"}},
    "seq_parallel": {"plan": {"seq_parallel": True}},
    "flash_attn": {},
    "moe_dshard": {"plan": {"moe_dshard": True}},
    "mbs1": {"mbs": 1}, "mbs2": {"mbs": 2}, "mbs8": {"mbs": 8},
}


def run_variant(arch: str, shape_name: str, variant: str, mesh=None):
    c = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = mesh or make_production_mesh(multi_pod=False)
    plan_over, kw = {}, {}
    flash = False
    for part in variant.split("+"):
        spec = VARIANTS.get(part, {})
        plan_over.update(spec.get("plan", {}))
        if "mbs" in spec:
            kw["microbatch_size"] = spec["mbs"]
        if "step" in spec:
            kw.setdefault("step_overrides", {}).update(spec["step"])
        if part == "flash_attn":
            flash = True
    if plan_over.get("use_tp") is False:
        plan_over["dp"] = tuple(a for a in mesh.axis_names)  # all axes = DP
    rec, compiled = lower_cell(c, shape, mesh, "single",
                               plan_overrides=plan_over or None, **kw)

    if flash:
        rec = _apply_flash_model(c, shape, mesh, rec,
                                 plan_over=plan_over or None)
    rec["variant"] = variant
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{arch}__{shape_name}__{variant}.json").write_text(
        json.dumps(rec, indent=1))
    return rec


def _apply_flash_model(c, shape, mesh, rec, plan_over=None):
    """Measure the attention core's share of flops/bytes by compiling the
    metrics program with the core stubbed out, then substitute the Pallas
    kernel's streaming model (q/k/v/o IO only) for the score traffic."""
    plan = sh.make_plan(c, mesh, shape)
    if plan_over:
        plan = dataclasses.replace(plan, **plan_over)
    with mesh:
        with attn_mod.skip_attention_core():
            f_no, b_no, c_no = _metrics_extrapolated(
                c, plan, shape, mesh,
                k=rec.get("microbatches", 1))
    full = rec["cost_analysis"]
    attn_bytes = max(full["bytes_accessed"] - b_no, 0.0)
    attn_flops = max(full["flops"] - f_no, 0.0)
    # Pallas flash streaming model: q,o read+write once; k,v re-read per
    # q-block pass (nq blocks of 512 on TPU); fp32 accum stays in VMEM.
    b_loc = max(shape.global_batch // 16, 1)
    s = shape.seq_len
    heads_loc = c.n_heads / (16 if c.n_heads % 16 == 0 else 1)
    nq = max(s // 512, 1)
    n_attn = sum(c.is_attn_layer(i) for i in range(c.n_layers))
    qo = 2 * b_loc * s * heads_loc * c.d_head * 2
    kv = 2 * b_loc * s * (c.n_kv_heads or 1) * c.d_head * 2 * nq
    flash_bytes = n_attn * (qo + kv) * 3  # fwd + bwd recompute + dgrads
    new_bytes = b_no + flash_bytes
    r = roof.analyze(
        c, shape, mesh_name=rec["mesh"], n_devices=rec["n_devices"],
        flops_per_device=full["flops"],
        hbm_bytes_per_device=new_bytes,
        wire_bytes_per_device=rec["collectives"]["total_wire_bytes"])
    rec["flash_model"] = {
        "attn_core_bytes_measured": attn_bytes,
        "attn_core_flops_measured": attn_flops,
        "flash_streaming_bytes": flash_bytes,
    }
    rec["cost_analysis"]["bytes_accessed"] = new_bytes
    rec["roofline"] = r.to_dict()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", action="append", required=True,
                    help="arch:shape, e.g. qwen2-0.5b:train_4k")
    ap.add_argument("--variant", action="append", default=None)
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=False)
    variants = args.variant or ["baseline"]
    for cell in args.cell:
        arch, shape_name = cell.split(":")
        for v in variants:
            try:
                rec = run_variant(arch, shape_name, v, mesh)
                rf = rec["roofline"]
                print(f"[hillclimb] {arch} {shape_name} {v:14s} "
                      f"comp={rf['compute_s']:.3f} mem={rf['memory_s']:.3f} "
                      f"coll={rf['collective_s']:.3f} "
                      f"bottleneck={rf['bottleneck']:10s} "
                      f"frac={rf['roofline_fraction']:.3f}")
            except Exception as e:
                print(f"[hillclimb] {arch} {shape_name} {v}: "
                      f"FAIL {type(e).__name__}: {str(e)[:200]}")


if __name__ == "__main__":
    main()
