"""Mesh construction for the production pod configurations.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches see the real single device.
"""
from __future__ import annotations

import jax


def _mesh(shape, axes) -> jax.sharding.Mesh:
    # jax.sharding.AxisType landed after 0.4.x; Auto is the default there,
    # and on older jax every axis is implicitly auto — same semantics.
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """Single pod: (16,16) ("data","model") = 256 chips.
    Multi-pod:   (2,16,16) ("pod","data","model") = 512 chips; the "pod"
    axis is data-parallel by default (lowest-bandwidth axis gets the
    lowest-frequency collective: one gradient all-reduce per step).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """Arbitrary mesh helper (tests, pipeline-parallel experiments)."""
    return _mesh(shape, axes)


def mesh_for(placement) -> jax.sharding.Mesh:
    """Mesh for a ``repro.bench.spec.Placement`` (duck-typed: anything
    with ``mesh_shape``/``mesh_axes``) — the bench runner's bridge from
    a declarative placement to a live device mesh."""
    return _mesh(placement.mesh_shape, placement.mesh_axes)


def dp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The data-parallel axes of a mesh (everything except "model")."""
    return tuple(a for a in mesh.axis_names if a != "model")


def axis_size(mesh: jax.sharding.Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
