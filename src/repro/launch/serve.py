"""Serving CLI: fixed-batch generation or continuous-batching service.

Legacy fixed-batch run (one batched prefill + n decode steps):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b \
      --preset tiny --batch 4 --prompt-len 64 --gen 32

Continuous-batching service under synthetic Poisson load, with
energy-per-token accounting (see benchmarks/README.md):

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \
      --mode continuous --slots 4 --requests 32 --rate 200
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synthetic import synthetic_tokens
from repro.models import lm
from repro.power.methods import select_power_methods
from repro.serve.engine import BatchedServer, ServeEngine
from repro.serve.requests import poisson_requests


def _run_batch(args, c, params):
    server = BatchedServer(c, params, max_len=args.gen + 1)
    prompts = jnp.asarray(synthetic_tokens(
        args.batch, args.prompt_len, c.vocab, args.seed)[:, :args.prompt_len])
    extras = {}
    if c.family == "vlm":
        extras["patch_embeds"] = jnp.zeros(
            (args.batch, c.n_patches, c.d_model), jnp.bfloat16)
    if c.family == "encdec":
        extras["enc_frames"] = jnp.zeros(
            (args.batch, c.enc_seq, c.d_model), jnp.bfloat16)

    res = server.generate(prompts, args.gen, extras)
    print(f"[serve] arch={c.name} batch={args.batch} "
          f"prefill={res.prefill_s * 1e3:.1f} ms "
          f"decode={res.decode_s * 1e3:.1f} ms "
          f"({res.decode_tokens_per_s:,.0f} tok/s decode)")
    return res


def _run_scheduled(args, c, params):
    methods, source = select_power_methods("auto")
    max_len = args.prompt_len + args.gen + 1
    if args.cache == "paged":   # paged pools allocate whole blocks
        max_len = -(-max_len // args.block_size) * args.block_size
    engine = ServeEngine(c, params, n_slots=args.slots, max_len=max_len,
                         cache=args.cache, block_size=args.block_size,
                         power_methods=methods)
    engine.warmup(prompt_len=args.prompt_len)
    reqs = poisson_requests(args.requests, args.rate, c.vocab,
                            prompt_len=args.prompt_len, seed=args.seed,
                            short=(max(args.gen // 4, 1), args.gen),
                            long=(max(args.gen // 4, 1), args.gen))
    out = engine.serve(reqs, policy=args.mode)
    s = out.summary
    print(f"[serve] arch={c.name} mode={args.mode} slots={args.slots} "
          f"rate={args.rate:g}/s power={source}")
    print(f"  {s.n_requests} requests, {s.n_tokens} tokens in "
          f"{s.wall_s:.2f} s -> {s.decode_tok_s:,.0f} tok/s "
          f"(cache={args.cache}, occupancy {s.mean_occupancy:.2f})")
    print(f"  ttft mean {s.mean_ttft_s * 1e3:.1f} ms / p95 "
          f"{s.p95_ttft_s * 1e3:.1f} ms")
    print(f"  energy {s.attributed_wh:.4f} Wh attributed "
          f"(+{s.overhead_wh:.4f} Wh overhead) -> "
          f"{s.wh_per_token * 1e3:.4f} mWh/token, "
          f"{s.wh_per_request * 1e3:.4f} mWh/request")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-117m")
    ap.add_argument("--preset", choices=["full", "tiny"], default="tiny")
    ap.add_argument("--mode", choices=["batch", "continuous", "fixed"],
                    default="batch",
                    help="batch = legacy one-shot generate; continuous/"
                         "fixed = scheduled serving under Poisson load")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache", choices=["slotted", "paged"],
                    default="slotted",
                    help="KV layout: dense per-slot rows or the paged "
                         "block-table pool (serve.cache.PagedKVCache)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV block size (tokens) for --cache paged")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.rate <= 0:
        ap.error("--rate must be > 0 (Poisson arrival rate in req/s)")

    c = get_config(args.arch)
    if args.preset == "tiny":
        c = c.reduced()
    params = lm.init(jax.random.key(args.seed), c)
    if args.mode == "batch":
        return _run_batch(args, c, params)
    return _run_scheduled(args, c, params)


if __name__ == "__main__":
    main()
