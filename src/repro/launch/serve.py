"""Serving CLI: batched prefill + decode driver.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --preset tiny \
      --batch 4 --prompt-len 64 --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.synthetic import synthetic_tokens
from repro.models import lm
from repro.serve.engine import BatchedServer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt-117m")
    ap.add_argument("--preset", choices=["full", "tiny"], default="tiny")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    c = get_config(args.arch)
    if args.preset == "tiny":
        c = c.reduced()
    params = lm.init(jax.random.key(args.seed), c)
    server = BatchedServer(c, params, max_len=args.gen + 1)

    prompts = jnp.asarray(synthetic_tokens(
        args.batch, args.prompt_len, c.vocab, args.seed)[:, :args.prompt_len])
    extras = {}
    if c.family == "vlm":
        extras["patch_embeds"] = jnp.zeros(
            (args.batch, c.n_patches, c.d_model), jnp.bfloat16)
    if c.family == "encdec":
        extras["enc_frames"] = jnp.zeros(
            (args.batch, c.enc_seq, c.d_model), jnp.bfloat16)

    res = server.generate(prompts, args.gen, extras)
    print(f"[serve] arch={c.name} batch={args.batch} "
          f"prefill={res.prefill_s * 1e3:.1f} ms "
          f"decode={res.decode_s * 1e3:.1f} ms "
          f"({res.decode_tokens_per_s:,.0f} tok/s decode)")
    return res


if __name__ == "__main__":
    main()
