"""Slurm job-script generation (the JUBE platform.xml analog).

CARAML populates job templates from a system config and submits to Slurm;
this module renders equivalent sbatch scripts for TPU pod slices, with the
affinity/binding lessons from the paper's Section V baked in (one task per
host, open CPU masks for collective helper threads, explicit coordinator
address for multi-pod jobs).
"""
from __future__ import annotations

import pathlib
from dataclasses import dataclass, field


@dataclass
class SystemConfig:
    """Per-system template values (the platform.xml analog)."""
    name: str = "v5e-pod"
    hosts_per_pod: int = 64          # v5e-256: 64 hosts x 4 chips
    chips_per_host: int = 4
    partition: str = "tpu"
    account: str = "repro"
    container: str = ""              # optional container image
    env: dict = field(default_factory=dict)


TEMPLATE = """#!/bin/bash
#SBATCH --job-name={job_name}
#SBATCH --partition={partition}
#SBATCH --account={account}
#SBATCH --nodes={n_hosts}
#SBATCH --ntasks-per-node=1
#SBATCH --cpus-per-task={cpus}
#SBATCH --exclusive
#SBATCH --time={time_limit}
#SBATCH --output={log_dir}/%x_%j.out

# one task per host; open CPU mask so collective helper threads can float
# (CARAML Sec. V: over-tight masks starve NCCL/ICI helper threads)
export SLURM_CPU_BIND=none
{env_exports}
export REPRO_TPU=1
# multi-pod rendezvous: first host of the allocation coordinates
export JAX_COORDINATOR_ADDRESS=$(scontrol show hostnames $SLURM_JOB_NODELIST | head -n1):8476
export JAX_NUM_PROCESSES=$SLURM_NTASKS
export JAX_PROCESS_ID=$SLURM_PROCID

srun {container_prefix}python -m {module} {args}
"""


def render_job(*, job_name: str, module: str, args: str,
               system: SystemConfig, n_pods: int = 1,
               n_hosts: int | None = None,
               time_limit: str = "02:00:00", log_dir: str = "logs") -> str:
    """Render one sbatch script. ``n_hosts`` sizes the allocation
    directly (a bench mesh that needs 4 hosts should not reserve a full
    pod); default is whole pods (``hosts_per_pod * n_pods``)."""
    env_exports = "\n".join(f"export {k}={v}" for k, v in system.env.items())
    container_prefix = (f"apptainer exec {system.container} "
                        if system.container else "")
    return TEMPLATE.format(
        job_name=job_name, partition=system.partition, account=system.account,
        n_hosts=system.hosts_per_pod * n_pods if n_hosts is None else n_hosts,
        cpus=112,
        time_limit=time_limit, log_dir=log_dir, env_exports=env_exports,
        container_prefix=container_prefix, module=module, args=args)


def render_bench_job(*, workload: str, placement, point: dict,
                     system: SystemConfig | None = None,
                     out: str = "artifacts/bench",
                     power: str = "auto",
                     warmup: int | None = None,
                     iters: int | None = None,
                     job_suffix: str = "") -> str:
    """The deferred-record script: re-run ONE bench point on a Slurm
    allocation sized to its mesh (``placement`` is a
    ``repro.bench.spec.Placement``). The bench runner renders this when
    a point's mesh exceeds the local device count instead of erroring —
    the sweep's local cells still measure, and the rendered script
    carries the oversized cell to the cluster. ``out``/``power``/
    ``warmup``/``iters`` forward the invoking run's settings so the
    cluster record lands in the same results tree with a point key that
    joins the local sweep (power_source is part of the key)."""
    system = system or SystemConfig()
    n_hosts = max(1, -(-placement.n_devices // system.chips_per_host))
    points = ",".join(f"{k}={point[k]}" for k in sorted(point))
    args = f"run --suite {workload} --out {out} --power {power}"
    if warmup is not None:
        args += f" --warmup {warmup}"
    if iters is not None:
        args += f" --iters {iters}"
    if points:
        args += f" --points {points}"
    return render_job(
        job_name=f"bench_{workload}_{placement.label}{job_suffix}",
        module="repro.bench", args=args, system=system, n_hosts=n_hosts)


def write_launch_scripts(out_dir, archs, system: SystemConfig | None = None):
    """Render train + dry-run scripts for every arch (single & multi pod)."""
    system = system or SystemConfig()
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    written = []
    for arch in archs:
        for pods, tag in ((1, "pod1"), (2, "pod2")):
            script = render_job(
                job_name=f"train_{arch}_{tag}",
                module="repro.launch.train",
                args=f"--arch {arch} --preset full",
                system=system, n_pods=pods)
            p = out / f"train_{arch}_{tag}.sbatch"
            p.write_text(script)
            written.append(str(p))
    dry = render_job(job_name="dryrun", module="repro.launch.dryrun",
                     args="--mesh both", system=system, n_pods=2)
    (out / "dryrun.sbatch").write_text(dry)
    written.append(str(out / "dryrun.sbatch"))
    return written
