"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — the dry-run lowers and
compiles against these. Modality frontends are stubs: whisper gets
precomputed frame embeddings, llava gets precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm
from repro.parallel import sharding as sh
from repro.serve.cache import abstract_caches

Params = Any


def abstract_params(c: ModelConfig, plan: sh.Plan):
    """Abstract (no-alloc) params with production shardings attached."""
    aps = lm.init_abstract(c)
    shards = sh.param_shardings(c, plan, aps)
    return sh.shard_abstract(aps, shards), shards


def train_batch_specs(c: ModelConfig, plan: sh.Plan, shape: ShapeConfig) -> dict:
    b, s = shape.global_batch, shape.seq_len
    s_text = s - (c.n_patches if c.family == "vlm" else 0)
    mk = lambda shp, dt: jax.ShapeDtypeStruct(
        shp, dt, sharding=sh.batch_sharding(plan, shp))
    batch = {
        "tokens": mk((b, s_text), jnp.int32),
        "labels": mk((b, s_text), jnp.int32),
    }
    if c.family == "vlm":
        batch["patch_embeds"] = mk((b, c.n_patches, c.d_model), jnp.dtype(c.dtype))
    if c.family == "encdec":
        batch["enc_frames"] = mk((b, c.enc_seq, c.d_model), jnp.dtype(c.dtype))
    return batch


def prefill_specs(c: ModelConfig, plan: sh.Plan, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    s_text = s - (c.n_patches if c.family == "vlm" else 0)
    mk = lambda shp, dt: jax.ShapeDtypeStruct(
        shp, dt, sharding=sh.batch_sharding(plan, shp))
    tokens = mk((b, s_text), jnp.int32)
    extras = {}
    if c.family == "vlm":
        extras["patch_embeds"] = mk((b, c.n_patches, c.d_model), jnp.dtype(c.dtype))
    if c.family == "encdec":
        extras["enc_frames"] = mk((b, c.enc_seq, c.d_model), jnp.dtype(c.dtype))
    return tokens, extras


def decode_specs(c: ModelConfig, plan: sh.Plan, shape: ShapeConfig,
                 aps_sharded):
    """(token, caches, pos, enc_kv) specs for serve_step."""
    b, s = shape.global_batch, shape.seq_len
    (caches, enc_kv), _ = abstract_caches(c, b, s, aps_sharded)

    def shard_cache(path, leaf):
        ns = sh.cache_sharding(c, plan, path, leaf.shape)
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=ns)

    caches = jax.tree_util.tree_map_with_path(shard_cache, caches)
    if enc_kv is not None:
        enc_kv = jax.tree_util.tree_map_with_path(shard_cache, enc_kv)
    token = jax.ShapeDtypeStruct(
        (b, 1), jnp.int32, sharding=sh.batch_sharding(plan, (b, 1)))
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=sh.replicated(plan))
    return token, caches, pos, enc_kv
