"""Shard-aware, prefetching data loader.

Each data-parallel rank reads its own disjoint slice of the sample index
space (rank-strided, like Megatron's data sampler); a background thread
prefetches the next batches while the step runs.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import numpy as np


class ShardedLoader:
    def __init__(self, sample_fn: Callable[[int], dict], global_batch: int,
                 *, rank: int = 0, world: int = 1, prefetch: int = 2,
                 start_step: int = 0):
        """sample_fn(global_sample_idx) -> dict of arrays (one sample)."""
        assert global_batch % world == 0, (global_batch, world)
        self.sample_fn = sample_fn
        self.global_batch = global_batch
        self.local_batch = global_batch // world
        self.rank, self.world = rank, world
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _build(self, step: int) -> dict:
        base = step * self.global_batch
        samples = [self.sample_fn(base + self.rank * self.local_batch + j)
                   for j in range(self.local_batch)]
        return {k: np.stack([s[k] for s in samples]) for k in samples[0]}

    def _worker(self):
        step = self.step
        while not self._stop.is_set():
            batch = self._build(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        step, batch = self._q.get()
        self.step = step + 1
        return batch

    def close(self):
        self._stop.set()


def lm_sample_fn(reader, seq_len: int):
    """Adapter: IndexedDatasetReader -> (tokens, labels) samples."""
    def fn(idx: int) -> dict:
        chunk = reader.sample(idx, seq_len)
        return {"tokens": chunk[:-1].astype(np.int32),
                "labels": chunk[1:].astype(np.int32)}
    return fn
