"""Byte-fallback word tokenizer with a GPT-2-like interface.

The paper preprocesses OSCAR with the GPT-2 BPE tokenizer; offline we
provide a deterministic tokenizer with the same API surface (encode /
decode / vocab_size) built from a learned word vocabulary + byte fallback,
so the data pipeline (tokenize -> indexed dataset -> loader) is exercised
end to end.
"""
from __future__ import annotations

import collections
import json
import pathlib
from typing import Iterable

BYTE_OFFSET = 3  # 0=pad, 1=bos, 2=eos; bytes occupy [3, 259)
FIRST_WORD_ID = 259


class ByteFallbackTokenizer:
    def __init__(self, vocab: dict[str, int] | None = None,
                 max_vocab: int = 50257):
        self.word_to_id = vocab or {}
        self.id_to_word = {i: w for w, i in self.word_to_id.items()}
        self.max_vocab = max_vocab

    # -- training ----------------------------------------------------------
    @classmethod
    def train(cls, docs: Iterable[str], max_vocab: int = 50257
              ) -> "ByteFallbackTokenizer":
        counts = collections.Counter()
        for d in docs:
            counts.update(d.split())
        n_words = max_vocab - FIRST_WORD_ID
        vocab = {w: FIRST_WORD_ID + i
                 for i, (w, _) in enumerate(counts.most_common(n_words))}
        return cls(vocab, max_vocab)

    # -- core API ------------------------------------------------------------
    @property
    def vocab_size(self) -> int:
        return self.max_vocab

    @property
    def bos(self) -> int:
        return 1

    @property
    def eos(self) -> int:
        return 2

    def encode(self, text: str, add_special: bool = True) -> list[int]:
        ids = [self.bos] if add_special else []
        for i, word in enumerate(text.split()):
            if word in self.word_to_id:
                ids.append(self.word_to_id[word])
            else:  # byte fallback
                ids.extend(BYTE_OFFSET + b for b in word.encode("utf-8"))
            ids.append(BYTE_OFFSET + ord(" "))
        if ids and ids[-1] == BYTE_OFFSET + ord(" "):
            ids.pop()
        if add_special:
            ids.append(self.eos)
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        out: list[str] = []
        byte_buf: list[int] = []

        def flush():
            if byte_buf:
                out.append(bytes(byte_buf).decode("utf-8", errors="replace"))
                byte_buf.clear()

        for t in ids:
            if t in (0, 1, 2):
                continue
            if BYTE_OFFSET <= t < FIRST_WORD_ID:
                byte_buf.append(t - BYTE_OFFSET)
            else:
                flush()
                out.append(self.id_to_word.get(t, "<unk>"))
        flush()
        return "".join(
            w if (i == 0 or w == " " or out[i - 1] == " ") else " " + w
            for i, w in enumerate(out)).replace("  ", " ")

    # -- persistence ----------------------------------------------------------
    def save(self, path):
        pathlib.Path(path).write_text(json.dumps(
            {"max_vocab": self.max_vocab, "vocab": self.word_to_id}))

    @classmethod
    def load(cls, path) -> "ByteFallbackTokenizer":
        d = json.loads(pathlib.Path(path).read_text())
        return cls(d["vocab"], d["max_vocab"])
