"""Megatron-style indexed dataset: .bin token stream + .idx offsets.

The paper's LLM benchmark consumes OSCAR preprocessed into exactly this
format. Writer appends documents; reader memory-maps and serves fixed-length
training samples (with cross-document packing, as Megatron does).
"""
from __future__ import annotations

import json
import pathlib
import struct

import numpy as np

MAGIC = b"REPRIDX1"


class IndexedDatasetWriter:
    def __init__(self, prefix):
        self.prefix = pathlib.Path(prefix)
        self.prefix.parent.mkdir(parents=True, exist_ok=True)
        self._bin = open(self.prefix.with_suffix(".bin"), "wb")
        self._offsets = [0]
        self._n_tokens = 0

    def add_document(self, tokens):
        arr = np.asarray(tokens, dtype=np.int32)
        self._bin.write(arr.tobytes())
        self._n_tokens += arr.size
        self._offsets.append(self._n_tokens)

    def finalize(self, meta: dict | None = None):
        self._bin.close()
        off = np.asarray(self._offsets, dtype=np.int64)
        with open(self.prefix.with_suffix(".idx"), "wb") as f:
            f.write(MAGIC)
            f.write(struct.pack("<q", len(off)))
            f.write(off.tobytes())
        if meta is not None:
            self.prefix.with_suffix(".json").write_text(json.dumps(meta))


class IndexedDatasetReader:
    def __init__(self, prefix):
        self.prefix = pathlib.Path(prefix)
        with open(self.prefix.with_suffix(".idx"), "rb") as f:
            assert f.read(8) == MAGIC, "bad index magic"
            (n,) = struct.unpack("<q", f.read(8))
            self.offsets = np.frombuffer(f.read(8 * n), dtype=np.int64)
        self.tokens = np.memmap(self.prefix.with_suffix(".bin"),
                                dtype=np.int32, mode="r")
        mp = self.prefix.with_suffix(".json")
        self.meta = json.loads(mp.read_text()) if mp.exists() else {}

    @property
    def n_documents(self) -> int:
        return len(self.offsets) - 1

    @property
    def n_tokens(self) -> int:
        return int(self.offsets[-1])

    def document(self, i: int) -> np.ndarray:
        return np.asarray(self.tokens[self.offsets[i]:self.offsets[i + 1]])

    def sample(self, idx: int, seq_len: int) -> np.ndarray:
        """Packed fixed-length sample idx (wraps around the stream)."""
        start = (idx * seq_len) % max(self.n_tokens - seq_len - 1, 1)
        return np.asarray(self.tokens[start:start + seq_len + 1])
