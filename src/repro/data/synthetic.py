"""Deterministic synthetic data (the paper's `synthetic` tag).

Both CARAML benchmarks support synthetic data when the real corpus
(OSCAR / ImageNet) is not mounted; generation is seeded and reproducible.
"""
from __future__ import annotations

import numpy as np

_WORDS = (
    "the of and to in is was for on that with as by at from benchmark "
    "accelerator energy power throughput token image training model "
    "hardware system performance measurement efficiency cluster node "
    "gpu ipu tpu memory bandwidth compute parallel data tensor pipeline"
).split()


def synthetic_tokens(n_seqs: int, seq_len: int, vocab: int,
                     seed: int = 0) -> np.ndarray:
    """Zipf-ish token stream — more realistic rank-frequency than uniform."""
    rng = np.random.default_rng(seed)
    z = rng.zipf(1.3, size=(n_seqs, seq_len + 1)).astype(np.int64)
    return (z % vocab).astype(np.int32)


def synthetic_oscar_text(n_docs: int, seed: int = 0,
                         words_per_doc: int = 200) -> list[str]:
    """OSCAR-like text documents for the tokenizer -> indexed-dataset path."""
    rng = np.random.default_rng(seed)
    docs = []
    for _ in range(n_docs):
        n = int(rng.integers(words_per_doc // 2, words_per_doc * 2))
        idx = rng.zipf(1.4, size=n) % len(_WORDS)
        docs.append(" ".join(_WORDS[i] for i in idx))
    return docs


def synthetic_images(n: int, img_size: int, n_classes: int,
                     seed: int = 0):
    """(images NHWC float32 in [0,1), labels int32)."""
    rng = np.random.default_rng(seed)
    imgs = rng.random((n, img_size, img_size, 3), dtype=np.float32)
    labels = rng.integers(0, n_classes, size=(n,), dtype=np.int32)
    return imgs, labels
