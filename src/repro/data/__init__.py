from repro.data.indexed import IndexedDatasetReader, IndexedDatasetWriter
from repro.data.loader import ShardedLoader
from repro.data.synthetic import (
    synthetic_images, synthetic_oscar_text, synthetic_tokens,
)
from repro.data.tokenizer import ByteFallbackTokenizer

__all__ = [
    "IndexedDatasetReader", "IndexedDatasetWriter", "ShardedLoader",
    "synthetic_images", "synthetic_oscar_text", "synthetic_tokens",
    "ByteFallbackTokenizer",
]
