"""SLO evaluation: goodput, latency quantiles, Wh-per-SLO-met-request.

The MLPerf-Power framing (PAPERS.md, arXiv:2410.12032): at scale, the
figure of merit is energy per *useful* unit of work — and "useful" for
a serving stack means the request met its latency SLO. This module turns
the engine's per-request latency record (``RequestResult``: TTFT from
arrival, TPOT over the decode phase) plus per-tenant SLO targets into

  goodput              fraction of requests meeting BOTH targets
  ttft_p50 / ttft_p99  TTFT quantiles (includes queueing delay)
  tpot_p50 / tpot_p99  TPOT quantiles (steady-state decode latency)
  wh_per_slo_request   attributed energy / SLO-met requests — the
                       energy-per-useful-inference metric; ``inf`` when
                       nothing met (all energy, zero useful work)

A request meets its SLO when ``ttft_s <= slo.ttft_s`` AND
``tpot_s <= slo.tpot_s`` — boundary equality counts as met (a target is
a budget, and landing exactly on budget is within it). Per-tenant
targets come from a ``{tenant: SLO}`` map with a default fallback;
per-tenant sub-reports ride along for the workload's result columns.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence, Union

from repro.core.metrics import percentile


@dataclass(frozen=True)
class SLO:
    """Latency targets for one tenant (seconds). Requests meet the SLO
    when TTFT and TPOT are both at-or-under target."""

    ttft_s: float
    tpot_s: float

    def met_by(self, result) -> bool:
        # a shed (or otherwise tokenless) request delivered nothing —
        # it can never meet the SLO, whatever its timestamps say
        if result.finish_reason == "shed" or result.n_tokens == 0:
            return False
        return (result.ttft_s <= self.ttft_s
                and result.tpot_s <= self.tpot_s)


@dataclass
class SLOReport:
    """Aggregate (or per-tenant) SLO outcome over one serve run."""

    n_requests: int
    n_met: int
    ttft_p50_s: float
    ttft_p99_s: float
    tpot_p50_s: float
    tpot_p99_s: float
    energy_wh: float
    per_tenant: dict = field(default_factory=dict)   # name -> SLOReport

    @property
    def goodput(self) -> float:
        """Fraction of requests meeting their SLO (0.0 for an empty
        run: no requests served means no useful work delivered)."""
        return self.n_met / self.n_requests if self.n_requests else 0.0

    @property
    def wh_per_slo_request(self) -> float:
        """Energy per SLO-met request. ``inf`` when energy was spent
        but nothing met the SLO — the honest 'all cost, no useful work'
        signal; 0.0 only when there was no energy either."""
        if self.n_met:
            return self.energy_wh / self.n_met
        return float("inf") if self.energy_wh > 0 else 0.0


SLOTargets = Union[SLO, Mapping[str, SLO]]


def _slo_for(targets: SLOTargets, tenant: str, default: Optional[SLO]) -> SLO:
    if isinstance(targets, SLO):
        return targets
    slo = targets.get(tenant, default)
    assert slo is not None, (
        f"no SLO for tenant {tenant!r} and no default given")
    return slo


def _report(results, met_flags, energy_wh: float) -> SLOReport:
    # latency quantiles cover SERVED requests only: a shed request has
    # no first token, so its "TTFT" is a meaningless negative number
    # that would drag the percentiles. It still counts in n_requests
    # (and therefore against goodput) — shedding is not free.
    served = [r for r in results if r.n_tokens > 0]
    ttfts = [r.ttft_s for r in served]
    tpots = [r.tpot_s for r in served]
    return SLOReport(
        n_requests=len(results),
        n_met=sum(met_flags),
        ttft_p50_s=percentile(ttfts, 50.0),
        ttft_p99_s=percentile(ttfts, 99.0),
        tpot_p50_s=percentile(tpots, 50.0),
        tpot_p99_s=percentile(tpots, 99.0),
        energy_wh=energy_wh,
    )


def evaluate_slo(results: Sequence, targets: SLOTargets, *,
                 default: Optional[SLO] = None,
                 total_energy_wh: Optional[float] = None) -> SLOReport:
    """Score a serve run's results against (per-tenant) SLO targets.

    ``targets`` is either one :class:`SLO` for every request or a
    ``{tenant: SLO}`` map (``default`` catches unmapped tenants).
    ``total_energy_wh`` overrides the energy numerator (e.g. run-total
    including idle overhead); the default is the sum of per-request
    attributed energies — the marginal-cost view matching
    ``ServeSummary.wh_per_request``. Per-tenant energy always uses each
    tenant's own attributed sum.
    """
    results = list(results)
    met = [_slo_for(targets, r.tenant, default).met_by(r) for r in results]
    energy = (sum(r.energy_wh for r in results)
              if total_energy_wh is None else float(total_energy_wh))
    report = _report(results, met, energy)
    tenants = sorted({r.tenant for r in results})
    for name in tenants:
        sub = [(r, m) for r, m in zip(results, met) if r.tenant == name]
        report.per_tenant[name] = _report(
            [r for r, _ in sub], [m for _, m in sub],
            sum(r.energy_wh for r, _ in sub))
    return report
