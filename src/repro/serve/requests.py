"""Serving request/result records with per-request latency accounting.

The MLPerf-Power observation (PAPERS.md, arXiv:2410.12032) is that the
metric that matters at scale is energy per *served inference* under a
realistic arrival process — not fixed-batch peak throughput. These records
carry everything needed to compute it: arrival/admission/first-token/
finish timestamps per request, and the energy attributed to the request
by :func:`repro.core.metrics.attribute_energy`.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

J_PER_WH = 3600.0


@dataclass
class Request:
    """One generation request entering the serve queue.

    ``arrival_s`` is relative to the engine run start (the engine offsets
    it by its clock at run begin); requests with ``arrival_s`` in the
    future stay queued until the (possibly fake) clock reaches them —
    this is how the benchmark injects Poisson arrivals.
    """

    rid: int
    prompt: np.ndarray                  # (prompt_len,) int32 token ids
    max_new_tokens: int
    arrival_s: float = 0.0
    eos_id: Optional[int] = None        # None -> run to max_new_tokens
    tenant: str = ""                    # multi-tenant traces (serve.traffic)
    #: admission deadline, seconds after arrival: a request still queued
    #: (never admitted) past it is SHED by the engine rather than served
    #: hopelessly late — it finishes with reason "shed", zero tokens,
    #: and counts against goodput. None disables the timeout.
    deadline_s: Optional[float] = None
    #: True for a preemption-resume request (``Scheduler.preempt``): the
    #: prompt already contains previously-emitted tokens, so the engine
    #: must append its prefill token to the existing result stream
    #: without resetting the admission/first-token timestamps.
    resumed: bool = False
    #: how many trailing prompt tokens are previously-EMITTED tokens
    #: (0 for fresh requests). The engine prefills only the original
    #: prompt (``prompt_len - n_replay`` tokens) and REPLAYS the tail
    #: through the decode program — the emitted tokens were produced by
    #: decode steps, and prefill's attention numerics are not bit-equal
    #: to decode's, so recomputing them via prefill would let low-bit KV
    #: drift flip a downstream argmax. Replay keeps the resumed stream
    #: bit-identical to the never-preempted one by construction.
    n_replay: int = 0

    @property
    def prompt_len(self) -> int:
        return int(np.asarray(self.prompt).shape[-1])


@dataclass
class RequestResult:
    """Per-request serving outcome: tokens + latency + energy."""

    rid: int
    prompt_len: int
    tokens: list = field(default_factory=list)   # generated token ids
    arrival_s: float = 0.0
    admitted_s: float = 0.0             # slot admission (prefill start)
    first_token_s: float = 0.0          # end of prefill = first token
    finish_s: float = 0.0
    finish_reason: str = ""             # "eos" | "length" | "shed"
    slot: int = -1
    energy_wh: float = 0.0              # attributed by core.metrics
    tenant: str = ""                    # copied from the request

    # -- latency figures of merit ---------------------------------------
    @property
    def n_tokens(self) -> int:
        return len(self.tokens)

    @property
    def ttft_s(self) -> float:
        """Time to first token, from arrival (includes queueing delay)."""
        return self.first_token_s - self.arrival_s

    @property
    def queue_s(self) -> float:
        return self.admitted_s - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.finish_s - self.arrival_s

    @property
    def tpot_s(self) -> float:
        """Time per output token over the decode phase (TPOT): the
        steady-state inter-token latency after the first token. 0.0 for
        single-token results (no decode phase to time)."""
        if self.n_tokens <= 1:
            return 0.0
        return (self.finish_s - self.first_token_s) / (self.n_tokens - 1)

    @property
    def decode_tok_s(self) -> float:
        """Steady-state decode rate (excludes queueing and prefill)."""
        gen_window = self.finish_s - self.first_token_s
        if self.n_tokens <= 1 or gen_window <= 0:
            return 0.0
        return (self.n_tokens - 1) / gen_window

    # -- energy figures of merit ----------------------------------------
    @property
    def wh_per_token(self) -> float:
        return self.energy_wh / self.n_tokens if self.n_tokens else 0.0

    @property
    def tokens_per_wh(self) -> float:
        return self.n_tokens / self.energy_wh if self.energy_wh > 0 else 0.0


def exponential_arrivals(rng: np.random.Generator, n: int,
                         rate_hz: float) -> np.ndarray:
    """Seeded Poisson arrival times: exponential inter-arrival gaps at
    ``rate_hz``, shifted so the first request arrives at t=0. The single
    arrival-process primitive shared by :func:`poisson_requests` and the
    multi-tenant trace generator (``serve.traffic``) — it consumes
    exactly ``n`` exponential draws from ``rng``, so the legacy
    ``poisson_requests`` stream is bit-identical to before the split."""
    gaps = rng.exponential(1.0 / rate_hz, size=n)
    return np.cumsum(gaps) - gaps[0]


def poisson_requests(n: int, rate_hz: float, vocab: int, *,
                     prompt_len: int = 8, seed: int = 0,
                     short: tuple[int, int] = (2, 8),
                     long: tuple[int, int] = (64, 88),
                     p_long: float = 0.25) -> list[Request]:
    """Seeded synthetic request stream shared by the serve benchmark and
    the serving CLI: exponential inter-arrival gaps (Poisson process) and
    a bimodal short/long token-budget mix — the realistic serving load
    (mostly short answers, a tail of long generations) that iteration-level
    refill monetizes against a batch-fill barrier.
    """
    from repro.data.synthetic import synthetic_tokens

    rng = np.random.default_rng(seed)
    prompts = synthetic_tokens(n, prompt_len, vocab, seed)[:, :prompt_len]
    arrivals = exponential_arrivals(rng, n, rate_hz)
    is_long = rng.random(n) < p_long
    budgets = np.where(is_long,
                       rng.integers(long[0], long[1] + 1, size=n),
                       rng.integers(short[0], short[1] + 1, size=n))
    return [Request(rid=i, prompt=prompts[i], max_new_tokens=int(budgets[i]),
                    arrival_s=float(arrivals[i])) for i in range(n)]
