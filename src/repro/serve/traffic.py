"""Multi-tenant traffic generator: seeded request traces for serving.

CARAML's serve benchmark drove the engine with a single Poisson knob;
production serving is judged under *multi-tenant* load — several request
populations with their own arrival processes (steady Poisson, bursty
MMPP, diurnal envelopes), their own prompt/output length distributions,
and — crucially for the KV cache — tenant populations that share a
common system-prompt prefix (the forcing function for block-granular
prefix caching, ``serve.cache.PagedKVCache``).

A trace is a plain ``list[Request]`` (``serve.requests``), each stamped
with its tenant name, fully determined by a :class:`TraceConfig` and its
seed: per-tenant RNG streams derive from ``SeedSequence([seed, i])`` so
adding a tenant never perturbs the others' streams, and the config's
canonical hash (:meth:`TraceConfig.config_hash`) is stamped into bench
``ResultRecord``s so two runs are comparable iff they served the same
trace.

Arrival processes:

  * ``poisson`` — exponential inter-arrival gaps at ``rate_hz``
    (``serve.requests.exponential_arrivals``, the same helper the legacy
    ``poisson_requests`` stream uses);
  * ``bursty``  — a two-state Markov-modulated Poisson process: a burst
    state emitting at ``burst_factor`` x the base rate, occupied
    ``burst_fraction`` of the time, with sticky state transitions; the
    base rate is normalized so the *mean* rate stays ``rate_hz``.

An optional diurnal envelope thins either process: candidate arrivals
are kept with probability ``diurnal_envelope(t)`` in
``[1 - depth, 1]``, producing the peak/trough cycles a
millions-of-users service sees (period compressed to benchmark scale).
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import asdict, dataclass
from typing import Sequence

import numpy as np

from repro.serve.requests import Request, exponential_arrivals


@dataclass(frozen=True)
class TenantSpec:
    """One tenant population: arrival process + length distributions.

    ``weight`` sets this tenant's share of the trace's ``n_requests``
    (largest-remainder allocation — deterministic, sums exactly).
    ``prompt_len`` / ``output_len`` are inclusive uniform ranges; the
    *total* prompt is ``prefix_len + prompt_len`` tokens when the tenant
    belongs to a ``prefix_group`` (every tenant in a group shares the
    same ``prefix_len`` system-prompt tokens, derived from the group
    name — the shared-prefix population prefix caching monetizes).
    """

    name: str
    weight: float = 1.0
    arrival: str = "poisson"            # "poisson" | "bursty"
    rate_hz: float = 100.0
    burst_factor: float = 8.0           # burst-state rate multiplier
    burst_fraction: float = 0.2         # stationary burst-state share
    prompt_len: tuple[int, int] = (8, 16)
    output_len: tuple[int, int] = (4, 12)
    prefix_group: str = ""              # "" -> no shared prefix
    prefix_len: int = 0

    def __post_init__(self):
        assert self.arrival in ("poisson", "bursty"), self.arrival
        assert self.weight > 0, self.weight
        assert self.prompt_len[0] >= 1 and self.output_len[0] >= 1
        assert (self.prefix_len == 0) == (self.prefix_group == ""), (
            "prefix_group and prefix_len must be set together")


@dataclass(frozen=True)
class TraceConfig:
    """A full multi-tenant trace specification (hashable provenance)."""

    tenants: tuple
    n_requests: int
    vocab: int
    seed: int = 0
    diurnal_period_s: float = 0.0       # 0 -> no diurnal envelope
    diurnal_depth: float = 0.0          # trough rate = (1 - depth) * peak

    def __post_init__(self):
        assert self.tenants, "a trace needs at least one tenant"
        assert 0.0 <= self.diurnal_depth < 1.0, self.diurnal_depth
        names = [t.name for t in self.tenants]
        assert len(names) == len(set(names)), f"duplicate tenants: {names}"

    def config_hash(self) -> str:
        """Canonical short hash of the full config (seed included): two
        records carry the same hash iff they served the same trace."""
        blob = json.dumps(asdict(self), sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()[:12]


def diurnal_envelope(t, period_s: float, depth: float):
    """Thinning probability at time ``t``: 1.0 at the peak (t=0 mod
    period), ``1 - depth`` at the trough, cosine in between. Bounded in
    ``[1 - depth, 1]`` for every t (the property the tests pin)."""
    if period_s <= 0.0 or depth <= 0.0:
        return np.ones_like(np.asarray(t, np.float64))
    phase = 2.0 * np.pi * np.asarray(t, np.float64) / period_s
    return 1.0 - depth * 0.5 * (1.0 - np.cos(phase))


def _bursty_arrivals(rng: np.random.Generator, n: int, rate_hz: float,
                     burst_factor: float, burst_fraction: float,
                     p_stay: float = 0.9) -> np.ndarray:
    """Two-state MMPP arrival times with mean rate ``rate_hz``.

    The burst state emits at ``burst_factor * lam_base``, the calm state
    at ``lam_base``, with ``lam_base`` chosen so the stationary mean
    inter-arrival time is exactly ``1 / rate_hz``:

        E[gap] = f / (B * lam) + (1 - f) / lam  =>  lam = rate * (f/B + 1-f)

    State transitions are sticky (``p_stay``) and land on the stationary
    distribution when they switch, so ``burst_fraction`` is honoured.
    """
    f, bf = burst_fraction, burst_factor
    lam_base = rate_hz * (f / bf + (1.0 - f))
    gaps = np.empty(n)
    in_burst = bool(rng.random() < f)
    for i in range(n):
        lam = lam_base * (bf if in_burst else 1.0)
        gaps[i] = rng.exponential(1.0 / lam)
        if rng.random() >= p_stay:
            in_burst = bool(rng.random() < f)
    return np.cumsum(gaps) - gaps[0]


def _thin_diurnal(rng: np.random.Generator, arrivals: np.ndarray,
                  period_s: float, depth: float) -> np.ndarray:
    """Keep each candidate arrival with probability ``envelope(t)`` —
    the standard thinning construction for an inhomogeneous process."""
    keep = rng.random(arrivals.shape) < diurnal_envelope(
        arrivals, period_s, depth)
    return arrivals[keep]


def _tenant_counts(tenants: Sequence[TenantSpec], n: int) -> list[int]:
    """Largest-remainder allocation of ``n`` requests by tenant weight —
    deterministic, exact-sum, and every tenant with positive weight gets
    its proportional share (the tenant-mix property test)."""
    total_w = sum(t.weight for t in tenants)
    raw = [n * t.weight / total_w for t in tenants]
    counts = [int(r) for r in raw]
    rem = n - sum(counts)
    order = sorted(range(len(tenants)), key=lambda i: raw[i] - counts[i],
                   reverse=True)
    for i in order[:rem]:
        counts[i] += 1
    return counts


def _group_prefix(group: str, prefix_len: int, vocab: int,
                  seed: int) -> np.ndarray:
    """The shared system-prompt tokens for a prefix group — a function
    of (seed, group name) only, so every tenant in the group, and every
    regeneration of the trace, sees the identical token string."""
    digest = hashlib.sha1(group.encode()).digest()[:8]
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, int.from_bytes(digest, "big")]))
    return rng.integers(1, vocab, size=prefix_len, dtype=np.int64).astype(
        np.int32)


def generate_trace(cfg: TraceConfig) -> list[Request]:
    """Expand a :class:`TraceConfig` into a deterministic request list.

    Per-tenant RNG streams come from ``SeedSequence([seed, tenant_i])``;
    requests merge across tenants in arrival order, the first arrival is
    shifted to t=0, and rids are assigned in arrival order. Each request
    carries its tenant name (``Request.tenant``) for per-tenant SLO
    evaluation downstream.
    """
    counts = _tenant_counts(cfg.tenants, cfg.n_requests)
    prefixes = {
        t.prefix_group: _group_prefix(t.prefix_group, t.prefix_len,
                                      cfg.vocab, cfg.seed)
        for t in cfg.tenants if t.prefix_group}
    merged: list[tuple[float, int, TenantSpec, np.ndarray, int]] = []
    for ti, (tenant, n) in enumerate(zip(cfg.tenants, counts)):
        if n == 0:
            continue
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, ti]))
        if tenant.arrival == "bursty":
            arrivals = _bursty_arrivals(rng, n, tenant.rate_hz,
                                        tenant.burst_factor,
                                        tenant.burst_fraction)
        else:
            arrivals = exponential_arrivals(rng, n, tenant.rate_hz)
        if cfg.diurnal_period_s > 0.0 and cfg.diurnal_depth > 0.0:
            kept = _thin_diurnal(rng, arrivals, cfg.diurnal_period_s,
                                 cfg.diurnal_depth)
            # thinning drops candidates; extend at the mean gap until the
            # tenant's allocation is met (still fully rng-deterministic)
            while len(kept) < n:
                t0 = arrivals[-1] if len(arrivals) else 0.0
                more = t0 + np.cumsum(rng.exponential(1.0 / tenant.rate_hz,
                                                      size=n))
                arrivals = more
                kept = np.concatenate([
                    kept, _thin_diurnal(rng, more, cfg.diurnal_period_s,
                                        cfg.diurnal_depth)])
            arrivals = kept[:n]
        plens = rng.integers(tenant.prompt_len[0], tenant.prompt_len[1] + 1,
                             size=n)
        budgets = rng.integers(tenant.output_len[0], tenant.output_len[1] + 1,
                               size=n)
        pre = prefixes.get(tenant.prefix_group)
        for j in range(n):
            body = rng.integers(1, cfg.vocab, size=int(plens[j]),
                                dtype=np.int64).astype(np.int32)
            prompt = body if pre is None else np.concatenate([pre, body])
            merged.append((float(arrivals[j]), ti, tenant, prompt,
                           int(budgets[j])))
    merged.sort(key=lambda item: (item[0], item[1]))
    t0 = merged[0][0] if merged else 0.0
    return [Request(rid=i, prompt=[int(t) for t in prompt],
                    max_new_tokens=budget,
                    arrival_s=arrival - t0, tenant=tenant.name)
            for i, (arrival, _ti, tenant, prompt, budget) in
            enumerate(merged)]


# ---------------------------------------------------------------------------
# Presets — the serve_slo workload's trace axis
# ---------------------------------------------------------------------------

#: serve_slo trace presets: name -> tenant tuple builder. Lengths are
#: sized for the workload's MAX_LEN=96 slot capacity (prompt + budget
#: must fit; the scheduler asserts so).
_PRESETS = {
    "poisson": (
        TenantSpec("chat", weight=0.5, rate_hz=150.0,
                   prompt_len=(8, 24), output_len=(4, 16)),
        TenantSpec("search", weight=0.3, rate_hz=90.0,
                   prompt_len=(4, 12), output_len=(2, 8)),
        TenantSpec("code", weight=0.2, rate_hz=60.0,
                   prompt_len=(16, 32), output_len=(8, 24)),
    ),
    "bursty": (
        TenantSpec("chat", weight=0.5, rate_hz=150.0,
                   prompt_len=(8, 24), output_len=(4, 16)),
        TenantSpec("batch", weight=0.5, rate_hz=150.0, arrival="bursty",
                   burst_factor=8.0, burst_fraction=0.2,
                   prompt_len=(8, 16), output_len=(4, 12)),
    ),
    # the chunked-scheduler stress trace, non-saturated on average so
    # the TTFT tail reflects SCHEDULING events rather than backlog:
    # bursty document-length prompts (~10x the chat median) and a
    # long-GENERATION tenant ride on a steady chat stream. The gens are
    # what separates the schedulers on a tight pool (serve_slo runs
    # this trace against 17 blocks): phased reserves each gen's
    # worst-case footprint (6 blocks) for its whole multi-hundred-ms
    # lifetime, so a doc arriving while two gens live DEFERS until one
    # finishes — and every later arrival queues behind it (FIFO).
    # Chunked admits the same doc immediately by preempting the
    # youngest gen (blocks reclaimed, gen resumes by recompute+replay),
    # so its ttft_p99 is a prefill, not a deferral — the cliff the
    # sched axis (and the ci.sh ttft_p99 gate) measures.
    "long_prefill": (
        TenantSpec("chat", weight=0.55, rate_hz=40.0,
                   prompt_len=(4, 8), output_len=(4, 10)),
        TenantSpec("doc", weight=0.15, rate_hz=8.0, arrival="bursty",
                   burst_factor=5.0, burst_fraction=0.3,
                   prompt_len=(64, 80), output_len=(2, 6)),
        TenantSpec("gen", weight=0.3, rate_hz=18.0,
                   prompt_len=(4, 8), output_len=(72, 88)),
    ),
    "shared_prefix": (
        TenantSpec("assist-a", weight=0.4, rate_hz=120.0,
                   prompt_len=(4, 12), output_len=(4, 12),
                   prefix_group="sys", prefix_len=48),
        TenantSpec("assist-b", weight=0.4, rate_hz=120.0,
                   prompt_len=(4, 12), output_len=(4, 12),
                   prefix_group="sys", prefix_len=48),
        TenantSpec("misc", weight=0.2, rate_hz=60.0,
                   prompt_len=(8, 16), output_len=(4, 12)),
    ),
}

TRACE_NAMES = tuple(_PRESETS)


def preset_trace(name: str, *, n_requests: int, vocab: int,
                 seed: int = 0, diurnal_period_s: float = 0.0,
                 diurnal_depth: float = 0.0) -> TraceConfig:
    """A named multi-tenant TraceConfig (the workload's ``trace`` axis)."""
    assert name in _PRESETS, (name, TRACE_NAMES)
    return TraceConfig(tenants=_PRESETS[name], n_requests=n_requests,
                       vocab=vocab, seed=seed,
                       diurnal_period_s=diurnal_period_s,
                       diurnal_depth=diurnal_depth)
