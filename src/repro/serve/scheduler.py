"""Continuous-batching scheduler: queue, slot admission, refill, early exit.

Pure host-side bookkeeping — no jax. The engine owns the device work; the
scheduler owns WHICH request sits in WHICH batch slot at every decode
step. The model of operation (Orca/vLLM-style iteration-level scheduling,
reduced to fixed slots):

  * a fixed pool of ``n_slots`` batch slots, each backed by one KV-cache
    row of capacity ``max_len`` tokens (prompt + generated);
  * arriving requests queue FIFO; ``refill(now)`` admits arrived requests
    into free slots *between* decode steps (admission = one prefill);
  * every decode step advances all active slots by one token;
  * a slot frees as soon as its request hits EOS or its token budget
    ("early exit"), and is refilled from the queue before the next step —
    finished requests never occupy batch rows.

Two policies share this class:

  ``continuous`` — refill whenever a slot is free (the tentpole);
  ``fixed``      — admit only when ALL slots are idle, i.e. classic
                   fixed-batch serving with a batch-fill barrier; used as
                   the benchmark baseline.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.serve.requests import Request


@dataclass
class Slot:
    """One batch row: its request and per-slot position/length state."""

    index: int
    request: Optional[Request] = None
    pos: int = 0          # next KV write position == tokens in the row
    generated: int = 0
    last_token: int = 0   # input token for the next decode step

    @property
    def active(self) -> bool:
        return self.request is not None


@dataclass
class StepRecord:
    """One engine step window (prefill or decode) for energy attribution.

    ``rids`` are the request ids credited with tokens in this window,
    one entry per token: a decode window covering ``n_steps`` fused
    micro-steps lists every active slot's rid ``n_steps`` times, a
    (batched) prefill window lists each admitted request once. Energy
    integrated over the window splits equally across the entries
    (``core.metrics.attribute_energy``), so per-request attribution
    stays exact under both batched prefill and fused decode runs.

    ``n_steps`` is the number of decode micro-steps the window fused
    (1 for prefill and legacy single-step decode) — the denominator for
    per-step occupancy: ``n_tokens / (n_steps * n_slots)``.
    """

    kind: str             # "prefill" | "decode"
    t0: float
    t1: float
    rids: tuple
    n_tokens: int
    n_steps: int = 1

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


class Scheduler:
    """Slot admission / refill / early-exit state machine."""

    def __init__(self, n_slots: int, max_len: int, *,
                 policy: str = "continuous"):
        assert policy in ("continuous", "fixed"), policy
        self.n_slots = n_slots
        self.max_len = max_len
        self.policy = policy
        self.slots = [Slot(i) for i in range(n_slots)]
        self.queue: deque[Request] = deque()
        self._arrivals: list[Request] = []   # not yet arrived (future)

    # -- submission ------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Register a request; it becomes admissible once now >= arrival."""
        cap = req.prompt_len + req.max_new_tokens
        assert cap <= self.max_len, (
            f"request {req.rid} needs {cap} cache rows > max_len "
            f"{self.max_len}")
        self._arrivals.append(req)
        self._arrivals.sort(key=lambda r: r.arrival_s)

    def _absorb_arrivals(self, now: float) -> None:
        while self._arrivals and self._arrivals[0].arrival_s <= now:
            self.queue.append(self._arrivals.pop(0))

    def next_arrival_s(self) -> Optional[float]:
        return self._arrivals[0].arrival_s if self._arrivals else None

    # -- admission -------------------------------------------------------
    def refill(self, now: float) -> list[Slot]:
        """Admit arrived+queued requests into free slots (FIFO).

        Returns the newly-filled slots; the engine prefills each. Under
        the ``fixed`` policy admission waits for the batch to fully drain
        (the classic fixed-batch barrier the benchmark measures against).
        """
        self._absorb_arrivals(now)
        if self.policy == "fixed":
            if any(s.active for s in self.slots):
                return []
            # batch-fill barrier: when more requests are still arriving,
            # wait until a FULL batch is queued (the strongest fixed-batch
            # baseline — admitting partial batches would only flatter the
            # continuous policy in the benchmark comparison)
            if self._arrivals and len(self.queue) < self.n_slots:
                return []
        admitted = []
        for slot in self.slots:
            if not self.queue:
                break
            if slot.active:
                continue
            req = self.queue.popleft()
            slot.request = req
            slot.pos = req.prompt_len     # prefill fills rows [0, len)
            slot.generated = 0
            slot.last_token = 0
            admitted.append(slot)
        return admitted

    # -- step bookkeeping ------------------------------------------------
    def active_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.active]

    def record_token(self, slot: Slot, token: int) -> Optional[str]:
        """Account one generated token for ``slot``.

        Returns a finish reason ("eos" | "length") and frees the slot if
        the request completed, else None. EOS counts as a generated
        token (it is the model's output) but stops the request early.

        Position invariant: token ``g`` (1-indexed, g=1 from prefill) is
        the *input* of decode step ``g`` and gets written to cache row
        ``prompt_len + g - 1``; so after recording token g the slot's
        next write position is ``prompt_len + g - 1``.
        """
        req = slot.request
        assert req is not None
        slot.generated += 1
        slot.last_token = int(token)
        slot.pos = req.prompt_len + slot.generated - 1
        if req.eos_id is not None and int(token) == req.eos_id:
            self._free(slot)
            return "eos"
        if slot.generated >= req.max_new_tokens:
            self._free(slot)
            return "length"
        if slot.pos >= self.max_len:   # cache row exhausted (defensive)
            self._free(slot)
            return "length"
        return None

    def _free(self, slot: Slot) -> None:
        slot.request = None
        slot.generated = 0

    def unadmit(self, slot: Slot) -> Request:
        """Return a just-admitted (not yet prefilled) request to the
        FRONT of the queue and free its slot — the engine's admission-
        control hook for a cache pool that cannot reserve the request's
        worst-case footprint yet. Unadmit in reverse admission order to
        preserve FIFO."""
        req = slot.request
        assert req is not None and slot.generated == 0, (
            "unadmit is only valid before the first token")
        self._free(slot)
        self.queue.appendleft(req)
        return req

    # -- batched views for the decode step -------------------------------
    def input_tokens(self) -> np.ndarray:
        """(n_slots,) int32 — each slot's next input token (0 if idle)."""
        return np.asarray([s.last_token if s.active else 0
                           for s in self.slots], np.int32)

    def positions(self) -> np.ndarray:
        """(n_slots,) int32 — per-slot KV write position.

        Idle slots report ``max_len - 1``: a valid in-bounds row whose
        write is harmless (the row is dead until the next prefill
        overwrites it) — keeps the jitted decode free of masking.
        """
        return np.asarray([s.pos if s.active else self.max_len - 1
                           for s in self.slots], np.int32)

    def active_mask(self) -> np.ndarray:
        return np.asarray([s.active for s in self.slots], bool)

    # -- run state -------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return (bool(self.queue) or bool(self._arrivals)
                or any(s.active for s in self.slots))

    @property
    def n_pending(self) -> int:
        return len(self.queue) + len(self._arrivals)
