"""Continuous-batching scheduler: queue, slot admission, refill, early exit.

Pure host-side bookkeeping — no jax. The engine owns the device work; the
scheduler owns WHICH request sits in WHICH batch slot at every decode
step. The model of operation (Orca/vLLM-style iteration-level scheduling,
reduced to fixed slots):

  * a fixed pool of ``n_slots`` batch slots, each backed by one KV-cache
    row of capacity ``max_len`` tokens (prompt + generated);
  * arriving requests queue FIFO; ``refill(now)`` admits arrived requests
    into free slots *between* decode steps (admission = one prefill);
  * every decode step advances all active slots by one token;
  * a slot frees as soon as its request hits EOS or its token budget
    ("early exit"), and is refilled from the queue before the next step —
    finished requests never occupy batch rows.

Two policies share this class:

  ``continuous`` — refill whenever a slot is free (the tentpole);
  ``fixed``      — admit only when ALL slots are idle, i.e. classic
                   fixed-batch serving with a batch-fill barrier; used as
                   the benchmark baseline.
"""
from __future__ import annotations

import bisect
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.serve.requests import Request


@dataclass
class Slot:
    """One batch row: its request and per-slot position/length state."""

    index: int
    request: Optional[Request] = None
    pos: int = 0          # next KV write position == tokens in the row
    generated: int = 0
    last_token: int = 0   # input token for the next decode step
    #: prompt tokens prefilled so far. The phased scheduler prefills the
    #: whole prompt at admission (``refill`` sets this to ``prompt_len``
    #: immediately); the chunked engine resets it to the prefix-match
    #: depth and advances it one ``chunk_tokens`` slice per iteration —
    #: a slot decodes only once the prompt is fully prefilled.
    prefill_pos: int = 0
    #: preemption-resume replay: previously-emitted prompt-tail tokens
    #: still to feed through the DECODE program as forced inputs (their
    #: logits are discarded except the last, which continues the
    #: stream). Prefill numerics are not bit-equal to decode's, so the
    #: emitted tail must be rebuilt by the same program that built it.
    replay: int = 0

    @property
    def active(self) -> bool:
        return self.request is not None

    @property
    def prefill_target(self) -> int:
        """Where chunked prefill stops: the ORIGINAL prompt. A resume
        request's trailing ``n_replay`` emitted tokens rebuild their KV
        via decode replay instead."""
        return self.request.prompt_len - self.request.n_replay

    @property
    def prefilling(self) -> bool:
        """Mid-chunked-prefill: admitted but the prefillable prompt
        region isn't fully in cache yet — the slot must not take decode
        steps."""
        return (self.request is not None
                and self.prefill_pos < self.prefill_target)

    @property
    def decoding(self) -> bool:
        return self.request is not None and not self.prefilling


@dataclass
class StepRecord:
    """One engine step window (prefill or decode) for energy attribution.

    ``rids`` are the request ids credited with tokens in this window,
    one entry per token: a decode window covering ``n_steps`` fused
    micro-steps lists every active slot's rid ``n_steps`` times, a
    (batched) prefill window lists each admitted request once. Energy
    integrated over the window splits equally across the entries
    (``core.metrics.attribute_energy``), so per-request attribution
    stays exact under both batched prefill and fused decode runs.

    ``n_steps`` is the number of decode micro-steps the window fused
    (1 for prefill and legacy single-step decode) — the denominator for
    per-step occupancy: ``n_tokens / (n_steps * n_slots)``.
    """

    kind: str             # "prefill" | "decode"
    t0: float
    t1: float
    rids: tuple
    n_tokens: int
    n_steps: int = 1

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


class Scheduler:
    """Slot admission / refill / early-exit state machine."""

    def __init__(self, n_slots: int, max_len: int, *,
                 policy: str = "continuous"):
        assert policy in ("continuous", "fixed"), policy
        self.n_slots = n_slots
        self.max_len = max_len
        self.policy = policy
        self.slots = [Slot(i) for i in range(n_slots)]
        self.queue: deque[Request] = deque()
        self._arrivals: list[Request] = []   # not yet arrived (future)

    # -- submission ------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Register a request; it becomes admissible once now >= arrival.

        ``_arrivals`` is kept sorted by insertion point (``insort_right``
        keyed on ``arrival_s``) — re-sorting the whole list per submit
        was O(n^2 log n) over an n-request trace. Right-insertion keeps
        submission order among equal-arrival ties, so FIFO service is
        stable however the trace was built.
        """
        cap = req.prompt_len + req.max_new_tokens
        assert cap <= self.max_len, (
            f"request {req.rid} needs {cap} cache rows > max_len "
            f"{self.max_len}")
        bisect.insort_right(self._arrivals, req,
                            key=lambda r: r.arrival_s)

    def _absorb_arrivals(self, now: float) -> None:
        idx = 0
        while idx < len(self._arrivals) \
                and self._arrivals[idx].arrival_s <= now:
            idx += 1
        if idx:
            self.queue.extend(self._arrivals[:idx])
            del self._arrivals[:idx]

    def next_arrival_s(self) -> Optional[float]:
        return self._arrivals[0].arrival_s if self._arrivals else None

    # -- admission -------------------------------------------------------
    def refill(self, now: float) -> list[Slot]:
        """Admit arrived+queued requests into free slots (FIFO).

        Returns the newly-filled slots; the engine prefills each. Under
        the ``fixed`` policy admission waits for the batch to fully drain
        (the classic fixed-batch barrier the benchmark measures against).
        """
        self._absorb_arrivals(now)
        if self.policy == "fixed":
            if any(s.active for s in self.slots):
                return []
            # batch-fill barrier: when more requests are still arriving,
            # wait until a FULL batch is queued (the strongest fixed-batch
            # baseline — admitting partial batches would only flatter the
            # continuous policy in the benchmark comparison)
            if self._arrivals and len(self.queue) < self.n_slots:
                return []
        admitted = []
        for slot in self.slots:
            if not self.queue:
                break
            if slot.active:
                continue
            req = self.queue.popleft()
            slot.request = req
            slot.pos = req.prompt_len     # prefill fills rows [0, len)
            slot.generated = 0
            slot.last_token = 0
            # phased default: the whole prompt prefills at admission.
            # The chunked engine rewinds this to the prefix-match depth
            # and advances chunk by chunk.
            slot.prefill_pos = req.prompt_len
            admitted.append(slot)
        return admitted

    # -- step bookkeeping ------------------------------------------------
    def active_slots(self) -> list[Slot]:
        return [s for s in self.slots if s.active]

    def decode_slots(self) -> list[Slot]:
        """Slots eligible for a decode step: active AND fully prefilled
        (mid-chunk slots ride along idle until their prompt lands)."""
        return [s for s in self.slots if s.decoding]

    def record_token(self, slot: Slot, token: int) -> Optional[str]:
        """Account one generated token for ``slot``.

        Returns a finish reason ("eos" | "length") and frees the slot if
        the request completed, else None. EOS counts as a generated
        token (it is the model's output) but stops the request early.

        Position invariant: token ``g`` (1-indexed, g=1 from prefill) is
        the *input* of decode step ``g`` and gets written to cache row
        ``prompt_len + g - 1``; so after recording token g the slot's
        next write position is ``prompt_len + g - 1``.
        """
        req = slot.request
        assert req is not None
        slot.generated += 1
        slot.last_token = int(token)
        slot.pos = req.prompt_len + slot.generated - 1
        if req.eos_id is not None and int(token) == req.eos_id:
            self._free(slot)
            return "eos"
        if slot.generated >= req.max_new_tokens:
            self._free(slot)
            return "length"
        if slot.pos >= self.max_len:   # cache row exhausted (defensive)
            self._free(slot)
            return "length"
        return None

    def _free(self, slot: Slot) -> None:
        slot.request = None
        slot.generated = 0
        slot.prefill_pos = 0
        slot.replay = 0

    def preempt(self, slot: Slot, tokens) -> Request:
        """Evict a RUNNING request from its slot, to be resumed later by
        recompute-from-prompt: the resume request's prompt is the
        original prompt plus every token already emitted (``tokens`` is
        the engine's result stream for this rid), its budget is the
        remaining budget, and it re-enters the queue FRONT so eviction
        never reorders service. The original-prompt region prefills
        again; the emitted tail (``n_replay``) is instead REPLAYED
        through the decode program (the program that first produced its
        KV — see ``Request.n_replay``), whose last replay logits ARE
        the next token: the resumed stream continues bit-identically
        and already-emitted tokens are never re-emitted.

        The caller (the engine) reclaims the slot's cache blocks; this
        method owns only the scheduler state. Works mid-chunked-prefill
        too: nothing was emitted yet, so the resume request is simply
        the original one.
        """
        req = slot.request
        assert req is not None, f"preempting idle slot {slot.index}"
        remaining = req.max_new_tokens - slot.generated
        assert remaining >= 1, (
            "a slot with exhausted budget frees, never preempts")
        if slot.generated:
            emitted = [int(t) for t in tokens[-slot.generated:]]
            prompt = np.concatenate([
                np.asarray(req.prompt, np.int32),
                np.asarray(emitted, np.int32)])
        else:
            prompt = req.prompt
        resume = Request(rid=req.rid, prompt=prompt,
                         max_new_tokens=remaining,
                         arrival_s=req.arrival_s, eos_id=req.eos_id,
                         tenant=req.tenant, resumed=True,
                         # a re-preempted resume replays its WHOLE
                         # emitted history, not just this admission's
                         n_replay=req.n_replay + slot.generated)
        self._free(slot)
        self.queue.appendleft(resume)
        return resume

    # -- graceful degradation (shedding) --------------------------------
    def shed_expired(self, now: float) -> list[Request]:
        """Remove queued requests whose admission deadline has passed
        (``now - arrival_s > deadline_s``). Only never-admitted, fresh
        requests are sheddable: a preemption-resume already received
        service and must complete (FIFO-degradation invariant). Returns
        the shed requests, queue order."""
        if not self.queue:
            return []
        shed: list[Request] = []
        kept: deque[Request] = deque()
        for r in self.queue:
            if (not r.resumed and r.deadline_s is not None
                    and now - r.arrival_s > r.deadline_s):
                shed.append(r)
            else:
                kept.append(r)
        self.queue = kept
        return shed

    def shed_newest(self, cap: int) -> list[Request]:
        """Overload response: pop queued requests from the BACK (newest
        arrivals) until the queue fits ``cap``. The front of the queue —
        the oldest request, and any preemption-resumes parked there — is
        never shed, so under overload service degrades newest-first and
        the oldest request always completes (PR 8's FIFO-degradation
        invariant, extended to admission control). Returns the shed
        requests, newest first."""
        shed: list[Request] = []
        floor = max(1, int(cap))
        while len(self.queue) > floor:
            if self.queue[-1].resumed:
                break   # resumed work is never shed
            shed.append(self.queue.pop())
        return shed

    def unadmit(self, slot: Slot) -> Request:
        """Return a just-admitted (not yet prefilled) request to the
        FRONT of the queue and free its slot — the engine's admission-
        control hook for a cache pool that cannot reserve the request's
        worst-case footprint yet. Unadmit in reverse admission order to
        preserve FIFO."""
        req = slot.request
        assert req is not None and slot.generated == 0, (
            "unadmit is only valid before the first token")
        self._free(slot)
        self.queue.appendleft(req)
        return req

    # -- batched views for the decode step -------------------------------
    def input_tokens(self) -> np.ndarray:
        """(n_slots,) int32 — each slot's next input token (0 if idle).

        All three step views key on ``decoding``, not ``active``: a slot
        mid-chunked-prefill has no last token yet and must ride through
        the decode step as an idle row (its dead write lands at the
        parked position / the paged trash block).
        """
        return np.asarray([s.last_token if s.decoding else 0
                           for s in self.slots], np.int32)

    def positions(self) -> np.ndarray:
        """(n_slots,) int32 — per-slot KV write position.

        Idle (and mid-prefill) slots report ``max_len - 1``: a valid
        in-bounds row whose write is harmless (the row is dead until the
        next prefill overwrites it; a mid-prefill paged slot's unowned
        table columns point at the trash block) — keeps the jitted
        decode free of masking.
        """
        return np.asarray([s.pos if s.decoding else self.max_len - 1
                           for s in self.slots], np.int32)

    def active_mask(self) -> np.ndarray:
        return np.asarray([s.decoding for s in self.slots], bool)

    # -- run state -------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return (bool(self.queue) or bool(self._arrivals)
                or any(s.active for s in self.slots))

    @property
    def n_pending(self) -> int:
        return len(self.queue) + len(self._arrivals)
