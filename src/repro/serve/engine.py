"""Serving engine: continuous batching + fixed-batch policies, with
energy-per-token accounting.

``ServeEngine`` owns the jitted prefill/decode programs and the slotted
KV cache (``serve.cache``); on top of that single engine sit two
admission policies (``serve.scheduler``):

  * ``continuous`` — Orca/vLLM-style iteration-level scheduling: slots
    refill from the queue between decode steps, requests early-exit on
    EOS and free their cache row immediately;
  * ``fixed``      — classic fixed-batch serving (admit a full batch,
    drain it, admit the next) — the baseline the serve benchmark
    measures continuous batching against.

Energy: the engine reads its ``PowerMethod`` list synchronously at every
step boundary, so each prefill/decode window is bracketed by samples and
``repro.core.metrics.attribute_energy`` integrates exactly over it —
yielding Wh/token and Wh/request per served request (the MLPerf-Power
figure of merit).

``serve_step`` (single-token decode against a full KV cache) is what the
``decode_*`` / ``long_*`` dry-run shapes lower. ``BatchedServer`` remains
as the thin fixed-batch wrapper the examples/tests drive.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.metrics import (
    ServeSummary, attribute_energy, serve_summary,
)
from repro.core.runner import StragglerWatchdog
from repro.models import lm
from repro.serve.cache import grow_caches, insert_slot, slotted_cache
from repro.serve.requests import Request, RequestResult
from repro.serve.scheduler import Scheduler, StepRecord

Params = Any


def make_prefill_fn(c: ModelConfig, impl: str = "repeat"):
    def prefill_step(params, tokens, extras):
        logits, caches, enc_kv = lm.prefill(
            c, params, tokens,
            patch_embeds=extras.get("patch_embeds"),
            enc_frames=extras.get("enc_frames"), impl=impl)
        return logits, caches, enc_kv
    return prefill_step


def make_decode_fn(c: ModelConfig, impl: str = "grouped"):
    def serve_step(params, token, caches, pos, enc_kv=None):
        return lm.decode_step(c, params, token, caches, pos,
                              enc_kv=enc_kv, impl=impl)
    return serve_step


@dataclass
class GenerationResult:
    tokens: Any
    steps: int
    prefill_s: float
    decode_s: float

    @property
    def decode_tokens_per_s(self) -> float:
        n = self.tokens.shape[0] * self.steps
        return n / max(self.decode_s, 1e-9)


@dataclass
class ServeRunResult:
    """Outcome of one ``ServeEngine.serve`` run."""

    results: list                 # RequestResult, completion order
    steps: list                   # StepRecord log (energy attribution)
    sample_ts: list               # synchronous power sample times
    sample_ws: list               # total watts at each sample
    summary: ServeSummary
    straggler_events: list = field(default_factory=list)

    def by_rid(self) -> dict:
        return {r.rid: r for r in self.results}


class ServeEngine:
    """Shared serving engine: jitted prefill/decode + slotted KV cache.

    Model mode (the default): pass ``(c, params)`` — the engine jits
    prefill/decode once and allocates an ``(n_slots, max_len)`` cache
    pool on first use. ``max_len`` is the TOTAL per-slot capacity
    (prompt + generated tokens).

    Scripted mode (unit tests): pass ``prefill_fn``/``decode_fn`` —
    host-side callables with no device work:

      prefill_fn(slot: int, prompt: np.ndarray) -> int   first token
      decode_fn(tokens (S,), positions (S,), active (S,) bool) -> (S,)

    plus an optional fake ``clock``/``sleep_fn`` pair, which makes the
    energy accounting exactly computable in tests.
    """

    def __init__(self, c: Optional[ModelConfig] = None,
                 params: Params = None, *,
                 n_slots: int = 4, max_len: int = 256,
                 impl_prefill: str = "repeat", impl_decode: str = "grouped",
                 donate: bool = True,
                 prefill_fn: Optional[Callable] = None,
                 decode_fn: Optional[Callable] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep_fn: Optional[Callable[[float], None]] = None,
                 power_methods: Sequence = (),
                 watchdog: Optional[StragglerWatchdog] = None):
        self.c, self.params = c, params
        self.n_slots, self.max_len = n_slots, max_len
        self.clock = clock
        self.sleep_fn = sleep_fn or time.sleep
        self.power_methods = list(power_methods)
        self.watchdog = watchdog
        self._scripted = prefill_fn is not None
        if self._scripted:
            self._slot_prefill = prefill_fn
            self._slot_decode = decode_fn
        else:
            assert c is not None and params is not None
            self._prefill = jax.jit(make_prefill_fn(c, impl_prefill))
            decode = make_decode_fn(c, impl_decode)
            self._decode = jax.jit(decode,
                                   donate_argnums=(2,) if donate else ())
            self._grow = jax.jit(grow_caches, static_argnums=(1,))
            self.caches: Params = None   # allocated on first serve()

    # ------------------------------------------------------------------
    # Model-backed slot operations (continuous policy)
    # ------------------------------------------------------------------

    def _ensure_slotted(self):
        if self.caches is None:
            assert self.c.family not in ("encdec", "vlm"), (
                "continuous batching currently covers decoder-only "
                "families (dense/moe/ssm/hybrid); encdec/vlm need "
                "per-request side inputs — use the fixed-batch policy")
            self.caches = slotted_cache(self.c, self.n_slots, self.max_len,
                                        self.params)

    def _model_slot_prefill(self, slot: int, prompt: np.ndarray) -> int:
        """Prefill one request (batch=1) and insert its KV row at slot.

        Distinct prompt lengths compile distinct prefill programs (pad
        prompts to shared buckets upstream to avoid that); slot index and
        cache contents are traced, so refill itself never retraces.
        """
        tokens = jnp.asarray(np.asarray(prompt, np.int32))[None, :]
        logits, row, _enc_kv = self._prefill(self.params, tokens, {})
        row = self._grow(row, self.max_len)
        self.caches = insert_slot(self.caches, row, jnp.int32(slot))
        return int(jnp.argmax(logits[0, -1], -1))

    def _model_slot_decode(self, tokens: np.ndarray, positions: np.ndarray,
                           active: np.ndarray) -> np.ndarray:
        """One decode step over the whole slot pool (inactive rows ride
        along at a dead position; fixed shapes keep it a single trace)."""
        tok = jnp.asarray(tokens, jnp.int32)[:, None]
        logits, self.caches = self._decode(
            self.params, tok, self.caches,
            jnp.asarray(positions, jnp.int32), None)
        return np.asarray(jnp.argmax(logits[:, -1], -1), np.int32)

    # ------------------------------------------------------------------
    # Continuous-batching run loop
    # ------------------------------------------------------------------

    def _sample_power(self, ts: list, ws: list):
        if not self.power_methods:
            return
        w = 0.0
        for m in self.power_methods:
            try:
                w += sum(m.read().values())
            except Exception:
                pass  # a failing backend must not kill serving
        ts.append(self.clock())
        ws.append(w)

    def serve(self, requests: Sequence[Request], *,
              policy: str = "continuous",
              poll_s: float = 0.002) -> ServeRunResult:
        """Run a request set to completion under the given policy.

        Request ``arrival_s`` values are relative to run start; the
        engine sleeps (``sleep_fn``) while the queue is empty and slots
        are idle, so wall time includes genuine arrival gaps.
        """
        if not self._scripted:
            self._ensure_slotted()
        sched = Scheduler(self.n_slots, self.max_len, policy=policy)
        slot_prefill = (self._slot_prefill if self._scripted
                        else self._model_slot_prefill)
        slot_decode = (self._slot_decode if self._scripted
                       else self._model_slot_decode)
        watchdog = self.watchdog

        t_start = self.clock()
        results: dict[int, RequestResult] = {}
        for r in requests:
            sched.submit(r)
            results[r.rid] = RequestResult(
                rid=r.rid, prompt_len=r.prompt_len,
                arrival_s=t_start + r.arrival_s)
        steps: list[StepRecord] = []
        ts: list[float] = []
        ws: list[float] = []
        self._sample_power(ts, ws)
        decode_idx = 0

        while sched.has_work:
            now_rel = self.clock() - t_start
            # -- admission: prefill newly admitted requests ---------------
            for slot in sched.refill(now_rel):
                req = slot.request
                res = results[req.rid]
                res.slot = slot.index
                res.admitted_s = self.clock()
                self._sample_power(ts, ws)   # bracket the prefill window
                first = slot_prefill(slot.index, req.prompt)
                t1 = self.clock()
                self._sample_power(ts, ws)
                res.first_token_s = t1
                res.tokens.append(int(first))
                steps.append(StepRecord("prefill", res.admitted_s, t1,
                                        (req.rid,), 1))
                reason = sched.record_token(slot, int(first))
                if reason is not None:
                    res.finish_s, res.finish_reason = t1, reason
            # -- one decode step over all active slots --------------------
            active = sched.active_slots()
            if active:
                rids = tuple(s.request.rid for s in active)
                t0 = self.clock()
                self._sample_power(ts, ws)   # bracket the decode window
                out = slot_decode(sched.input_tokens(), sched.positions(),
                                  sched.active_mask())
                t1 = self.clock()
                self._sample_power(ts, ws)
                if watchdog is not None:
                    watchdog.observe(decode_idx, t1 - t0)
                decode_idx += 1
                steps.append(StepRecord("decode", t0, t1, rids, len(rids)))
                for s in active:
                    res = results[s.request.rid]
                    tok = int(out[s.index])
                    res.tokens.append(tok)
                    reason = sched.record_token(s, tok)
                    if reason is not None:
                        res.finish_s, res.finish_reason = t1, reason
            elif sched.n_pending:
                # idle: nothing admitted yet — wait for the next arrival
                nxt = sched.next_arrival_s()
                wait = (t_start + nxt) - self.clock() if nxt is not None \
                    else poll_s
                if wait > 0:
                    self.sleep_fn(min(wait, 0.05))

        self._sample_power(ts, ws)
        out_results = sorted(results.values(), key=lambda r: r.finish_s)
        for rid, wh in attribute_energy(steps, ts, ws).items():
            results[rid].energy_wh = wh
        return ServeRunResult(
            results=out_results, steps=steps, sample_ts=ts, sample_ws=ws,
            summary=serve_summary(out_results, steps, ts, ws),
            straggler_events=list(watchdog.events) if watchdog else [])

    # ------------------------------------------------------------------
    # Fixed-batch generation (legacy BatchedServer path)
    # ------------------------------------------------------------------

    def generate(self, tokens: jax.Array, n_steps: int,
                 extras: Optional[dict] = None,
                 gen_budget: Optional[int] = None) -> GenerationResult:
        """Fixed-batch greedy decode: prefill a full batch, decode
        ``n_steps`` with a shared scalar position. ``gen_budget`` sets
        the KV growth beyond the prompt (defaults to n_steps + 1)."""
        assert not self._scripted
        extras = extras or {}
        budget = gen_budget if gen_budget is not None else n_steps + 1
        b, s = tokens.shape
        t0 = time.perf_counter()
        logits, caches, enc_kv = self._prefill(self.params, tokens, extras)
        logits.block_until_ready()
        t1 = time.perf_counter()
        # grow KV caches so decode can append (SSM states pass through)
        caches = self._grow(caches, s + budget)
        out = [jnp.argmax(logits[:, -1], -1).astype(jnp.int32)]
        pos = s
        for _ in range(n_steps - 1):
            tok = out[-1][:, None]
            logits, caches = self._decode(self.params, tok, caches,
                                          jnp.int32(pos), enc_kv)
            out.append(jnp.argmax(logits[:, -1], -1).astype(jnp.int32))
            pos += 1
        out[-1].block_until_ready()
        t2 = time.perf_counter()
        return GenerationResult(jnp.stack(out, 1), n_steps, t1 - t0, t2 - t1)


class BatchedServer:
    """Fixed-batch greedy decoding driver — one policy over ServeEngine.

    Back-compat shim: ``max_len`` keeps its historical meaning here (KV
    growth budget beyond the prompt), while ``ServeEngine.max_len`` is
    the total slot capacity.
    """

    def __init__(self, c: ModelConfig, params: Params, *,
                 max_len: int = 256, impl_prefill: str = "repeat",
                 impl_decode: str = "grouped", donate: bool = True):
        self.c, self.params, self.max_len = c, params, max_len
        self.engine = ServeEngine(
            c, params, n_slots=1, max_len=max_len,
            impl_prefill=impl_prefill, impl_decode=impl_decode,
            donate=donate)

    def generate(self, tokens: jax.Array, n_steps: int,
                 extras: Optional[dict] = None) -> GenerationResult:
        return self.engine.generate(tokens, n_steps, extras,
                                    gen_budget=self.max_len)
