"""Serving engine: continuous batching + fixed-batch policies, with
energy-per-token accounting and a paged/slotted KV cache choice.

``ServeEngine`` owns the jitted prefill/decode programs and the KV cache
(``serve.cache``); on top of that single engine sit two admission
policies (``serve.scheduler``):

  * ``continuous`` — Orca/vLLM-style iteration-level scheduling: slots
    refill from the queue between decode steps, requests early-exit on
    EOS and free their cache row immediately;
  * ``fixed``      — classic fixed-batch serving (admit a full batch,
    drain it, admit the next) — the baseline the serve benchmark
    measures continuous batching against.

The decode hot path (model mode) is built around three mechanisms:

  * **cache layouts** — ``cache="slotted"`` keeps the dense
    ``(n_slots, max_len)`` row pool (the reference path);
    ``cache="paged"`` switches to ``serve.cache.PagedKVCache``:
    fixed-size KV blocks in a shared pool addressed by per-slot block
    tables, with decode attention walking only the blocks a slot owns
    (``models.attention.decode_attention`` paged path →
    ``kernels.ops.paged_decode_attention``; the gathered table width is
    bucketed to the longest live slot, so short batches never pay
    ``max_len``).
  * **batched prefill** — newly admitted requests prefill as one padded
    batch per prompt-length bucket (one jitted program per bucket,
    batch padded to ``n_slots`` so admission count never retraces);
    first tokens arrive in a single host fetch instead of one
    ``.item()`` per request.
  * **fused decode runs** — when the scheduler can prove no slot can
    finish for the next ``k`` steps (length budgets are known; EOS makes
    ``k=1``), the engine dispatches ``k`` decode steps back-to-back with
    the token stream chained **on device** and drains all ``k`` outputs
    in one batched ``np.asarray`` fetch afterwards — scheduler
    bookkeeping overlaps device compute instead of blocking every token.

Two scheduler modes share the loop (``sched=``):

  * ``phased``  — a newly admitted request's WHOLE prompt prefills at
    admission; long prompts stall every decoding slot for the full
    prefill (the TTFT/TPOT cliff the chunked mode removes);
  * ``chunked`` — iteration-level scheduling proper: each loop
    iteration runs at most one ``chunk_tokens`` prefill slice per
    mid-prefill slot (batched across slots), then one decode step for
    every fully-prefilled slot. Chunk boundaries are block-aligned, so
    every chunk after the first reuses the suffix-prefill program
    (``prefix_kv`` gathered from the slot's own blocks). Admission
    reserves only prompt+1 blocks (optimistic); decode-time growth that
    hits ``CacheOOM`` preempts the youngest other request — its blocks
    free, it re-queues at the front, and it resumes later by
    recompute-from-prompt (``Scheduler.preempt``): the original prompt
    prefills again chunk by chunk, then the already-emitted tail
    REPLAYS through the decode program as forced inputs
    (``Request.n_replay`` / ``Slot.replay``) — decode built that KV the
    first time, and prefill's attention numerics are not bit-equal to
    decode's, so replay is what keeps a resumed stream bit-identical.
    Requires the paged cache and an attention-only family. Greedy
    argmax streams are bit-identical to phased: chunked prefill
    computes the same causal attention in block-aligned slices, and
    every KV row is built by the same program phased used for it.

Energy: the engine reads its ``PowerMethod`` list synchronously at every
step-window boundary, so each prefill/decode window is bracketed by
samples and ``repro.core.metrics.attribute_energy`` integrates exactly
over it — yielding Wh/token and Wh/request per served request (the
MLPerf-Power figure of merit). Fused windows credit each active rid once
per micro-step, keeping the attribution exact.

``serve_step`` (single-token decode against a full KV cache) is what the
``decode_*`` / ``long_*`` dry-run shapes lower. ``BatchedServer`` remains
as the thin fixed-batch wrapper the examples/tests drive.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.metrics import (
    ServeSummary, attribute_energy, serve_summary,
)
from repro.core.runner import StragglerWatchdog
from repro.models import lm
from repro.serve.cache import (
    CacheOOM, PagedKVCache, copy_blocks, grow_caches,
    insert_paged_prefill, insert_rows, slotted_cache,
)
from repro.serve.requests import Request, RequestResult
from repro.serve.scheduler import Scheduler, Slot, StepRecord

Params = Any


def make_prefill_fn(c: ModelConfig, impl: str = "repeat"):
    def prefill_step(params, tokens, extras):
        logits, caches, enc_kv = lm.prefill(
            c, params, tokens,
            patch_embeds=extras.get("patch_embeds"),
            enc_frames=extras.get("enc_frames"), impl=impl)
        return logits, caches, enc_kv
    return prefill_step


def make_decode_fn(c: ModelConfig, impl: str = "grouped"):
    def serve_step(params, token, caches, pos, enc_kv=None):
        return lm.decode_step(c, params, token, caches, pos,
                              enc_kv=enc_kv, impl=impl)
    return serve_step


@dataclass
class GenerationResult:
    tokens: Any
    steps: int
    prefill_s: float
    decode_s: float

    @property
    def decode_tokens_per_s(self) -> float:
        n = self.tokens.shape[0] * self.steps
        return n / max(self.decode_s, 1e-9)


@dataclass
class ServeRunResult:
    """Outcome of one ``ServeEngine.serve`` run."""

    results: list                 # RequestResult, completion order
    steps: list                   # StepRecord log (energy attribution)
    sample_ts: list               # synchronous power sample times
    sample_ws: list               # total watts at each sample
    summary: ServeSummary
    straggler_events: list = field(default_factory=list)

    def by_rid(self) -> dict:
        return {r.rid: r for r in self.results}


class ServeEngine:
    """Shared serving engine: jitted prefill/decode + slotted/paged KV.

    Model mode (the default): pass ``(c, params)`` — the engine jits
    prefill/decode once and allocates the cache pool on first use.
    ``max_len`` is the TOTAL per-slot capacity (prompt + generated
    tokens). ``cache`` selects the KV layout (``"slotted"`` dense rows /
    ``"paged"`` block tables, see module docstring); ``decode_window``
    caps how many decode steps a fused run may keep in flight (1
    restores the legacy sync-every-token loop).

    Scripted mode (unit tests): pass ``prefill_fn``/``decode_fn`` —
    host-side callables with no device work:

      prefill_fn(slot: int, prompt: np.ndarray) -> int   first token
      decode_fn(tokens (S,), positions (S,), active (S,) bool) -> (S,)

    plus an optional fake ``clock``/``sleep_fn`` pair, which makes the
    energy accounting exactly computable in tests. Scripted mode keeps
    the legacy one-request-prefill / one-step-decode loop so the exact
    step windows the energy tests assert against are unchanged.
    """

    def __init__(self, c: Optional[ModelConfig] = None,
                 params: Params = None, *,
                 n_slots: int = 4, max_len: int = 256,
                 impl_prefill: str = "repeat", impl_decode: str = "grouped",
                 donate: bool = True,
                 cache: str = "slotted", block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 prefix_cache: bool = False,
                 kv_dtype: str = "fp32",
                 decode_window: int = 8,
                 sched: str = "phased", chunk_tokens: int = 32,
                 paged_impl: str = "xla", paged_interpret: bool = False,
                 prefill_fn: Optional[Callable] = None,
                 decode_fn: Optional[Callable] = None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep_fn: Optional[Callable[[float], None]] = None,
                 power_methods: Sequence = (),
                 watchdog: Optional[StragglerWatchdog] = None):
        assert cache in ("slotted", "paged"), cache
        assert sched in ("phased", "chunked"), sched
        assert not prefix_cache or cache == "paged", (
            "prefix caching shares KV blocks — requires the paged cache")
        assert kv_dtype in ("fp32", "int8"), kv_dtype
        assert kv_dtype == "fp32" or cache == "paged", (
            "int8 KV quantizes pool blocks — requires the paged cache")
        self.c, self.params = c, params
        self.n_slots, self.max_len = n_slots, max_len
        self.cache_kind = cache
        self.block_size = block_size
        self._n_blocks = n_blocks
        self.prefix_cache = prefix_cache
        #: "fp32" = unquantized pool at the model's native cache dtype;
        #: "int8" = quantized blocks + per-(block, head) scales
        self.kv_dtype = kv_dtype
        self.decode_window = max(int(decode_window), 1)
        #: default scheduler mode for serve(): "phased" keeps the
        #: admission-wave prefill; "chunked" interleaves chunk_tokens
        #: prefill slices with decode steps (iteration-level scheduling)
        self.sched = sched
        self.chunk_tokens = int(chunk_tokens)
        self.preemptions = 0          # preempt events in the last serve()
        self.shed = 0                 # requests shed in the last serve()
        self.injected_faults = 0      # slot faults fired in the last serve()
        self.paged_impl, self.paged_interpret = paged_impl, paged_interpret
        self.impl_prefill = impl_prefill
        self.impl_decode, self.donate = impl_decode, donate
        self.clock = clock
        self.sleep_fn = sleep_fn or time.sleep
        self.power_methods = list(power_methods)
        self.watchdog = watchdog
        self._decode_idx = 0
        self._scripted = prefill_fn is not None
        if self._scripted:
            self._slot_prefill = prefill_fn
            self._slot_decode = decode_fn
        else:
            assert c is not None and params is not None
            # legacy fixed-batch generate() programs
            self._prefill = jax.jit(make_prefill_fn(c, impl_prefill))
            decode = make_decode_fn(c, impl_decode)
            self._decode = jax.jit(decode,
                                   donate_argnums=(2,) if donate else ())
            self._grow = jax.jit(grow_caches, static_argnums=(1,))
            # serve programs: batched prefill returning per-row argmax
            # first tokens; single-step decode returning next tokens so
            # fused runs chain the token stream on device
            def serve_prefill(params, tokens, last_pos):
                logits, caches, _ = lm.prefill(c, params, tokens,
                                               impl=impl_prefill,
                                               last_pos=last_pos)
                first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                return first, caches
            self._serve_prefill = jax.jit(serve_prefill)

            def serve_step(params, tok, caches, pos):
                logits, caches = lm.decode_step(c, params, tok[:, None],
                                                caches, pos, impl=impl_decode)
                return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), caches
            self._serve_step = jax.jit(
                serve_step, donate_argnums=(2,) if donate else ())
            self._paged_steps: dict = {}
            self._prefix_prefills: dict = {}
            self.prefix_stats: dict = self._blank_prefix_stats()
            self._paged: Optional[PagedKVCache] = None
            #: admission-control ledger: slot -> worst-case block demand
            #: (prompt + full token budget). Admission only proceeds when
            #: the pool can cover every active slot's remaining demand,
            #: so decode-time ``ensure`` growth can never hit CacheOOM.
            self._slot_cap: dict[int, int] = {}
            #: free-block count snapshotted when admission last deferred
            #: the queue head; admission only retries once it changes
            self._defer_free_blocks: Optional[int] = None
            self.caches: Params = None   # allocated on first serve()

    # ------------------------------------------------------------------
    # Model-backed cache + program construction
    # ------------------------------------------------------------------

    def _ensure_cache(self):
        if self.caches is not None:
            return
        assert self.c.family not in ("encdec", "vlm"), (
            "continuous batching currently covers decoder-only "
            "families (dense/moe/ssm/hybrid); encdec/vlm need "
            "per-request side inputs — use the fixed-batch policy")
        if self.cache_kind == "paged":
            self._paged = PagedKVCache(self.c, self.n_slots, self.max_len,
                                       self.params,
                                       block_size=self.block_size,
                                       n_blocks=self._n_blocks,
                                       kv_dtype=self.kv_dtype)
            if self.prefix_cache:
                assert self.c.family not in ("ssm", "hybrid"), (
                    "prefix caching skips prefix recompute — impossible "
                    "for mamba recurrences, which must run through the "
                    "whole sequence (attention-only families)")
                self._paged.enable_prefix_cache()
            # the engine takes ownership of the device tree: the jitted
            # serve programs donate it in place, which would leave the
            # PagedKVCache attribute pointing at deleted buffers — clear
            # it so a stale read fails loudly instead
            self.caches = self._paged.caches
            self._paged.caches = None
        else:
            self.caches = slotted_cache(self.c, self.n_slots, self.max_len,
                                        self.params)

    def _paged_step_fn(self, nb: int):
        """Decode program gathering ``nb`` block-table columns (static —
        one compiled program per bucket, reused across steps)."""
        fn = self._paged_steps.get(nb)
        if fn is None:
            c = self.c

            def step(params, tok, caches, pos, tables):
                logits, caches = lm.decode_step(
                    c, params, tok[:, None], caches, pos,
                    impl=self.impl_decode, block_tables=tables,
                    n_kv_blocks=nb, paged_impl=self.paged_impl,
                    paged_interpret=self.paged_interpret)
                return (jnp.argmax(logits[:, -1], -1).astype(jnp.int32),
                        caches)

            fn = jax.jit(step, donate_argnums=(2,) if self.donate else ())
            self._paged_steps[nb] = fn
        return fn

    def _prefix_prefill_fn(self, bucket: int, npre: int):
        """Suffix-prefill program for prompts whose first ``npre`` blocks
        hit the prefix index: the ``bucket``-padded suffix attends
        against the slot's prefix blocks IN the pool via the paged
        prefill kernel (``kernels.ops.paged_prefill_attention`` — the
        per-row block table rides into the program; no dense prefix-KV
        gather is ever materialized), and suffix cache rows come back.
        One compiled program per (suffix bucket, prefix depth) pair. The
        pool is read, never donated — the suffix rows scatter in via
        ``insert_paged_prefill`` afterwards, exactly like a cold
        prefill."""
        key = (bucket, npre)
        fn = self._prefix_prefills.get(key)
        if fn is None:
            c, bs = self.c, self.block_size
            impl = self.impl_prefill

            def prefill_hit(params, caches, tokens, last, pre_blocks):
                logits, rows, _ = lm.prefill(
                    c, params, tokens, impl=impl, last_pos=last,
                    paged_prefix=caches, paged_tables=pre_blocks,
                    pos_offset=npre * bs, paged_impl=self.paged_impl,
                    paged_interpret=self.paged_interpret)
                first = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
                return first, rows

            fn = jax.jit(prefill_hit)
            self._prefix_prefills[key] = fn
        return fn

    @staticmethod
    def _blank_prefix_stats() -> dict:
        return {"hit_requests": 0, "miss_requests": 0,
                "reused_blocks": 0, "registered_blocks": 0}

    def _nb_bucket(self, n: int) -> int:
        """Static gather width for ``n`` live blocks: the next power of
        two, capped at ``max_blocks`` — a handful of compiled programs
        covers every live-length mix."""
        cap = self._paged.max_blocks
        b = 1
        while b < n and b < cap:
            b *= 2
        return min(b, cap)

    def _prompt_bucket(self, n: int) -> int:
        """Prompt-length bucket for batched prefill.

        Attention-only stacks round up to the next ``block_size``
        multiple — causal masking hides the pad tokens' KV until decode
        overwrites it, so coarse buckets are free and cut trace count.
        Stacks with mamba layers (ssm/hybrid) must prefill at the EXACT
        prompt length: the SSD recurrence and conv tail run *through*
        trailing pad tokens and would carry corrupted state into decode
        (masking protects attention KV only), so each distinct length
        is its own group (the pre-batching behaviour, still batched
        across same-length requests). Partial paged blocks are
        zero-padded by ``insert_paged_rows``.
        """
        if self.c.family in ("ssm", "hybrid"):
            return n
        b = -(-n // self.block_size) * self.block_size
        return min(max(b, self.block_size), self.max_len)

    # ------------------------------------------------------------------
    # Paged admission control (CacheOOM -> deferral, not a crash)
    # ------------------------------------------------------------------

    def _free_paged_slot(self, slot_index: int) -> None:
        self._paged.free(slot_index)
        self._slot_cap.pop(slot_index, None)

    def _paged_headroom(self) -> int:
        """Free blocks not yet spoken for by active slots' worst-case
        growth (their cap minus what they already own). Blocks pinned
        only by the prefix index count as available: ``ensure`` reclaims
        them LRU-first when the free list runs dry, so a warm index can
        never starve admission."""
        reserved = sum(max(0, cap - self._paged.owned(s))
                       for s, cap in self._slot_cap.items())
        return self._paged.available_blocks - reserved

    def _admit_paged(self, sched: Scheduler, admitted: list,
                     results=None, chunked: bool = False) -> list:
        """Reserve pool blocks for new admissions; defer (phased) or
        preempt (chunked) when the pool cannot cover them.

        Each admitted request reserves its worst-case block demand
        (prompt + full ``max_new_tokens`` budget); when the pool's
        unreserved headroom cannot cover the next request, that request
        — and everything behind it, preserving FIFO — goes back to the
        queue front and waits for active slots to finish and free
        blocks. Because an empty pool always covers one full slot
        (PagedKVCache asserts so), the head request always admits
        eventually: deferral, never deadlock, never ``CacheOOM``.

        Under the chunked scheduler the reservation is OPTIMISTIC —
        prompt + first token only — and a shortfall PREEMPTS strictly
        younger running slots instead of only deferring: the queue head
        is older than they are, so it reclaims their blocks and they
        resume later (recompute + replay). This is what turns phased's
        multi-hundred-millisecond admission stalls behind long-lived
        generations into a bounded eviction cost, and what lets an
        oversubscribed pool run cells the phased scheduler can only
        defer. Requeue order keeps FIFO: the not-yet-prefilled
        admission tail unadmits first, then victims (older than the
        tail) land ahead of it at the queue front.

        A deferral snapshots ``free_blocks``; the serve loop skips the
        refill/unadmit churn — and stops treating the head as pending
        for the decode fusion check — until that count changes (blocks
        only move at window edges, so no retry can succeed earlier).
        """
        ok = []
        for i, slot in enumerate(admitted):
            req = slot.request
            if chunked:
                cap = -(-(req.prompt_len + 1) // self.block_size)
            else:
                cap = -(-(req.prompt_len + req.max_new_tokens)
                        // self.block_size)
            if cap > self._paged_headroom():
                if not chunked:
                    for later in reversed(admitted[i:]):
                        sched.unadmit(later)
                    self._defer_free_blocks = self._paged.available_blocks
                    break
                # chunked: free the unprefillled tail's reservations,
                # then evict strictly younger running slots until the
                # head fits ( _pick_victim's strict-younger rule also
                # keeps ``slot`` itself off the victim list)
                for later in reversed(admitted[i + 1:]):
                    sched.unadmit(later)
                me = (req.arrival_s, req.rid)
                while cap > self._paged_headroom():
                    victim = self._pick_victim(sched, me)
                    if victim is None:
                        break
                    self._preempt_slot(sched, victim, results)
                if cap > self._paged_headroom():
                    sched.unadmit(slot)
                    self._defer_free_blocks = self._paged.available_blocks
                    break
                self._slot_cap[slot.index] = cap
                ok.append(slot)
                # the tail re-admits on the next loop iteration, behind
                # any just-preempted (older) victims
                break
            self._slot_cap[slot.index] = cap
            ok.append(slot)
        return ok

    def _admission_blocked(self) -> bool:
        """True while a deferred queue head cannot possibly admit: the
        pool's free-block count hasn't moved since the deferral."""
        snap = getattr(self, "_defer_free_blocks", None)
        return (snap is not None and self._paged is not None
                and self._paged.available_blocks == snap)

    # ------------------------------------------------------------------
    # Block-granular preemption (chunked scheduler)
    # ------------------------------------------------------------------

    def _ensure_with_preempt(self, sched: Scheduler, slot: Slot,
                             n_tokens: int, results) -> bool:
        """Grow ``slot``'s pool to ``n_tokens`` rows, preempting younger
        requests on ``CacheOOM``. Returns True once the growth lands;
        False when the slot itself was preempted instead.

        Victims are STRICTLY YOUNGER than the beneficiary (arrival, then
        rid): when every other active request is older, the beneficiary
        defers ITSELF back to the queue front rather than evict an older
        request — the oldest active request can therefore always preempt
        its way to completion, so an oversubscribed pool degrades to
        FIFO-ordered service instead of livelocking on mutual eviction.
        """
        req = slot.request
        me = (req.arrival_s, req.rid)
        while True:
            try:
                self._paged.ensure(slot.index, n_tokens)
                return True
            except CacheOOM:
                victim = self._pick_victim(sched, me)
                if victim is None:
                    self._preempt_slot(sched, slot, results)
                    return False
                self._preempt_slot(sched, victim, results)

    def _pick_victim(self, sched: Scheduler,
                     me: tuple) -> Optional[Slot]:
        """Youngest active request strictly younger than ``me``; ties
        (same arrival) evict the fewest-blocks slot — the cheapest
        recompute-from-prompt."""
        cands = [s for s in sched.slots if s.active
                 and (s.request.arrival_s, s.request.rid) > me]
        if not cands:
            return None
        return max(cands, key=lambda s: (s.request.arrival_s,
                                         -self._paged.owned(s.index),
                                         s.request.rid))

    def _preempt_slot(self, sched: Scheduler, victim: Slot,
                      results) -> int:
        """Evict ``victim``: scheduler state first (the resume request
        captures the emitted stream), then reclaim its blocks. Returns
        the number of blocks actually returned to the free list (shared
        blocks a prefix pin or another slot still references stay)."""
        idx = victim.index
        rid = victim.request.rid
        sched.preempt(victim, results[rid].tokens)
        freed = self._paged.free(idx)
        self._slot_cap.pop(idx, None)
        # blocks moved — a headroom-deferred queue head may now retry
        self._defer_free_blocks = None
        self.preemptions += 1
        return freed

    # ------------------------------------------------------------------
    # Model-backed serve phases
    # ------------------------------------------------------------------

    def _model_prefill_admitted(self, sched: Scheduler, admitted, results,
                                steps, ts, ws):
        """Prefill newly admitted slots as one padded batch per
        (suffix-bucket, prefix-depth) group; one host fetch returns
        every first token.

        With prefix caching on, each prompt is first matched against the
        prefix index: a hit of ``npre`` full blocks adopts those shared
        pool blocks (refcounted, never copied) and prefills ONLY the
        remaining suffix — the jitted program gathers the prefix K/V out
        of the pool and attends across [prefix ++ suffix]. Every prompt
        then registers its own full blocks so later requests can hit
        them. Misses (npre=0) take the exact cold path."""
        use_prefix = (self.prefix_cache and self.cache_kind == "paged")
        groups: dict[tuple, list] = {}
        for slot in admitted:
            pre: list = []
            if use_prefix:
                pre = self._paged.prefix_match(
                    [int(t) for t in slot.request.prompt])
            npre = len(pre)
            suffix = slot.request.prompt_len - npre * self.block_size
            bucket = self._prompt_bucket(suffix)
            groups.setdefault((bucket, npre), []).append((slot, pre))
        for (bucket, npre), entries in sorted(groups.items()):
            kp = self.n_slots       # fixed batch: admission never retraces
            pre_len = npre * self.block_size
            t0 = self.clock()
            self._sample_power(ts, ws)   # bracket the prefill window
            tokens = np.zeros((kp, bucket), np.int32)
            last = np.zeros((kp,), np.int32)
            slot_ids = np.full((kp,), self.n_slots, np.int32)  # pad: dropped
            # pad rows gather the trash block — harmless, never read back
            pre_blocks = np.zeros((kp, npre), np.int32)
            for i, (slot, pre) in enumerate(entries):
                plen = slot.request.prompt_len
                prompt = np.asarray(slot.request.prompt, np.int32)
                tokens[i, :plen - pre_len] = prompt[pre_len:]
                last[i] = plen - pre_len - 1
                slot_ids[i] = slot.index
                if npre:
                    pre_blocks[i] = pre
            if npre:
                first, rows = self._prefix_prefill_fn(bucket, npre)(
                    self.params, self.caches, jnp.asarray(tokens),
                    jnp.asarray(last), jnp.asarray(pre_blocks))
            else:
                first, rows = self._serve_prefill(self.params,
                                                  jnp.asarray(tokens),
                                                  jnp.asarray(last))
            if self.cache_kind == "paged":
                nbk = -(-bucket // self.block_size)
                blocks = np.full((kp, nbk), self._paged.n_blocks, np.int32)
                for i, (slot, pre) in enumerate(entries):
                    plen = slot.request.prompt_len
                    if npre:
                        self._paged.adopt(slot.index, pre)
                    self._paged.ensure(slot.index, plen)
                    own = self._paged.block_ids(slot.index, plen)[npre:]
                    blocks[i, :len(own)] = own
                self.caches = insert_paged_prefill(
                    self.caches, rows, jnp.asarray(blocks),
                    jnp.asarray(slot_ids), block_size=self.block_size)
                if use_prefix:
                    st = self.prefix_stats
                    st["hit_requests" if npre else
                       "miss_requests"] += len(entries)
                    st["reused_blocks"] += npre * len(entries)
                    for slot, _pre in entries:
                        st["registered_blocks"] += self._paged.prefix_register(
                            slot.index,
                            [int(t) for t in slot.request.prompt])
            else:
                self.caches = insert_rows(self.caches, rows,
                                          jnp.asarray(slot_ids))
            first_np = np.asarray(first)      # single batched device fetch
            t1 = self.clock()
            self._sample_power(ts, ws)
            rids = tuple(s.request.rid for s, _pre in entries)
            slots = [s for s, _pre in entries]
            steps.append(StepRecord("prefill", t0, t1, rids, len(rids)))
            for i, slot in enumerate(slots):
                res = results[slot.request.rid]
                res.slot = slot.index
                res.admitted_s, res.first_token_s = t0, t1
                tok = int(first_np[i])
                res.tokens.append(tok)
                slot_index = slot.index
                reason = sched.record_token(slot, tok)
                if reason is not None:
                    res.finish_s, res.finish_reason = t1, reason
                    if self._paged is not None:
                        self._free_paged_slot(slot_index)

    def _start_chunked(self, admitted, results) -> None:
        """Begin chunked prefill for newly admitted slots: rewind
        ``prefill_pos`` (refill set it to ``prompt_len``, the phased
        default) to the prefix-match depth, adopting shared prefix
        blocks when the index hits. The chunk executor advances from
        there, one block-aligned slice per loop iteration."""
        t_admit = self.clock()
        for slot in admitted:
            req = slot.request
            res = results[req.rid]
            res.slot = slot.index
            # a preemption-resume keeps its original admission stamp:
            # TTFT measures first service, not re-service
            if res.admitted_s == 0.0:
                res.admitted_s = t_admit
            pre: list = []
            if self.prefix_cache:
                pre = self._paged.prefix_match(
                    [int(t) for t in req.prompt])
                if req.n_replay:
                    # a resume rebuilds its emitted tail via decode
                    # replay — adoption must stop short of the replay
                    # region, leaving >= 1 original-prompt token so the
                    # last prefill chunk is never empty
                    pre = pre[:max(slot.prefill_target - 1, 0)
                              // self.block_size]
                st = self.prefix_stats
                st["hit_requests" if pre else "miss_requests"] += 1
                st["reused_blocks"] += len(pre)
            if pre:
                self._paged.adopt(slot.index, pre)
            slot.prefill_pos = len(pre) * self.block_size
            slot.pos = slot.prefill_pos   # KV rows landed so far

    def _model_prefill_chunks(self, sched: Scheduler, results, steps,
                              ts, ws, chunk_tokens: int) -> None:
        """Run ONE ``chunk_tokens`` prefill slice for every mid-prefill
        slot, batched per (suffix-bucket, prefix-depth) group — the
        chunked scheduler's per-iteration prefill quantum.

        Chunk ``j`` is just a suffix prefill against the slot's own
        first ``prefill_pos / block_size`` blocks, so it reuses the
        prefix-cache program verbatim (``_prefix_prefill_fn``). A
        non-final chunk writes KV only — its argmax is discarded (the
        slice's last token is not the prompt's last). The final chunk
        emits the first token exactly like phased prefill and registers
        the full prompt with the prefix index. Pool growth for a chunk
        may preempt a younger slot — possibly one in this very wave,
        which then drops out before grouping."""
        slots = [s for s in sched.slots if s.prefilling]
        # grow pools oldest-first so preemption flows old -> young
        for slot in sorted(slots,
                           key=lambda s: (s.request.arrival_s
                                          if s.request else 0.0,
                                          s.index)):
            if not slot.prefilling:
                continue   # preempted by an older slot's growth
            end = min(slot.prefill_pos + chunk_tokens,
                      slot.prefill_target)
            self._ensure_with_preempt(sched, slot, end, results)
        groups: dict[tuple, list] = {}
        for slot in slots:
            if not slot.prefilling:
                continue
            start = slot.prefill_pos
            end = min(start + chunk_tokens, slot.prefill_target)
            npre = start // self.block_size
            bucket = self._prompt_bucket(end - start)
            groups.setdefault((bucket, npre), []).append(
                (slot, start, end))
        for (bucket, npre), entries in sorted(groups.items()):
            kp = self.n_slots
            pre_len = npre * self.block_size
            t0 = self.clock()
            self._sample_power(ts, ws)   # bracket the chunk window
            tokens = np.zeros((kp, bucket), np.int32)
            last = np.zeros((kp,), np.int32)
            slot_ids = np.full((kp,), self.n_slots, np.int32)
            pre_blocks = np.zeros((kp, npre), np.int32)
            for i, (slot, start, end) in enumerate(entries):
                prompt = np.asarray(slot.request.prompt, np.int32)
                tokens[i, :end - start] = prompt[start:end]
                last[i] = end - start - 1
                slot_ids[i] = slot.index
                if npre:
                    pre_blocks[i] = self._paged.block_ids(slot.index,
                                                          pre_len)
            if npre:
                first, rows = self._prefix_prefill_fn(bucket, npre)(
                    self.params, self.caches, jnp.asarray(tokens),
                    jnp.asarray(last), jnp.asarray(pre_blocks))
            else:
                first, rows = self._serve_prefill(self.params,
                                                  jnp.asarray(tokens),
                                                  jnp.asarray(last))
            nbk = -(-bucket // self.block_size)
            blocks = np.full((kp, nbk), self._paged.n_blocks, np.int32)
            for i, (slot, start, end) in enumerate(entries):
                own = self._paged.block_ids(slot.index, end)[npre:]
                blocks[i, :len(own)] = own
            self.caches = insert_paged_prefill(
                self.caches, rows, jnp.asarray(blocks),
                jnp.asarray(slot_ids), block_size=self.block_size)
            finals = [(i, slot)
                      for i, (slot, _s, end) in enumerate(entries)
                      if end == slot.prefill_target]
            emitting = [(i, s) for i, s in finals
                        if not s.request.n_replay]
            first_np = np.asarray(first) if emitting else None
            t1 = self.clock()
            self._sample_power(ts, ws)
            rids = tuple(s.request.rid for s, _s, _e in entries)
            # window energy splits across every chunking request;
            # n_tokens counts only the first tokens actually emitted,
            # keeping the credited-token accounting exact
            steps.append(StepRecord("prefill", t0, t1, rids,
                                    len(emitting)))
            for slot, _start, end in entries:
                slot.prefill_pos = end
                slot.pos = end
            for i, slot in finals:
                req = slot.request
                if req.n_replay:
                    # resume: the original prompt is back in cache; the
                    # emitted tail now replays through the decode program
                    # as forced inputs (this chunk's argmax is a prefill
                    # recompute of an already-emitted token — discard it,
                    # decode's version is the stream's ground truth). No
                    # prefix registration either: the tail blocks would
                    # hold decode-built KV, and an adopter's phased twin
                    # would prefill them — bit-divergence by adoption.
                    slot.replay = req.n_replay
                    slot.last_token = int(req.prompt[slot.prefill_pos])
                    continue
                if self.prefix_cache:
                    self.prefix_stats["registered_blocks"] += \
                        self._paged.prefix_register(
                            slot.index, [int(t) for t in req.prompt])
                res = results[req.rid]
                # a resume that had already emitted keeps its stamp
                if res.first_token_s == 0.0:
                    res.first_token_s = t1
                tok = int(first_np[i])
                res.tokens.append(tok)
                slot_index = slot.index
                reason = sched.record_token(slot, tok)
                if reason is not None:
                    res.finish_s, res.finish_reason = t1, reason
                    self._free_paged_slot(slot_index)

    def _decode_plan(self, sched: Scheduler, active,
                     admission_blocked: bool = False,
                     prefilling: bool = False) -> int:
        """How many decode steps can run before the host must look.

        Fused runs are only taken when the scheduler can PROVE no
        bookkeeping decision is pending inside the window: no active
        request can hit EOS (host can't predict it), every active
        request has at least ``k`` budget left (length finishes land
        exactly on the window edge), and no admission could happen
        meanwhile (a free slot plus pending work keeps the legacy
        per-token cadence so TTFT never pays for throughput).
        ``admission_blocked`` marks a headroom-deferred queue head: it
        cannot admit until a slot finishes and frees blocks, and
        finishes only land on window edges — so the pending head must
        not hold the whole pool at per-token cadence.
        """
        if self.decode_window <= 1:
            return 1
        if prefilling:
            # a mid-chunked-prefill slot needs its next chunk between
            # every decode step — a fused window would starve its TTFT
            return 1
        if any(s.replay for s in active):
            # replay inputs are FORCED host-side tokens; a fused window
            # chains argmax outputs on device and would feed the wrong
            # token at the second micro-step
            return 1
        if (len(active) < self.n_slots and sched.n_pending
                and not admission_blocked and sched.policy != "fixed"):
            # a free slot could refill mid-window — stay per-token so
            # TTFT never pays for throughput. Under the fixed policy
            # admission waits for ALL slots to drain, so no window can
            # overlap an admission and the drain tail fuses too (both
            # policies run identical programs at identical cadence:
            # speedup_vs_fixed stays a pure scheduling measurement).
            return 1
        if any(s.request.eos_id is not None for s in active):
            return 1
        k = min(s.request.max_new_tokens - s.generated for s in active)
        k = min(k, min(self.max_len - s.pos for s in active))
        return max(1, min(k, self.decode_window))

    def _model_decode_run(self, sched: Scheduler, active, k: int, results,
                          steps, ts, ws, allow_preempt: bool = False):
        """Dispatch ``k`` decode steps with the token stream chained on
        device, then drain all outputs in one batched fetch."""
        if self.cache_kind == "paged":
            if allow_preempt:
                # chunked mode: growth past the optimistic reservation
                # evicts younger slots on CacheOOM; grow oldest-first so
                # eviction flows old -> young, then drop evicted slots
                for s in sorted(active,
                                key=lambda s: (s.request.arrival_s
                                               if s.request else 0.0,
                                               s.index)):
                    if not s.decoding:
                        continue   # preempted by an older slot's growth
                    self._ensure_with_preempt(sched, s, s.pos + k,
                                              results)
                active = [s for s in active if s.decoding]
                if not active:
                    return
            else:
                for s in active:
                    self._paged.ensure(s.index, s.pos + k)
            if self.prefix_cache:
                # copy-on-write net: decode writes land at pos >=
                # prompt_len, past every registered (full, block-aligned)
                # prefix block, so this is structurally a no-op today —
                # but if a shared block ever ends up under a write
                # cursor, it is copied out here instead of corrupting
                # every other reader of that block.
                srcs: list = []
                dsts: list = []
                for s in active:
                    sc, dc = self._paged.make_writable(s.index, s.pos, k)
                    srcs += sc
                    dsts += dc
                if srcs:
                    self.caches = copy_blocks(
                        self.caches, jnp.asarray(srcs, jnp.int32),
                        jnp.asarray(dsts, jnp.int32))
            tables = self._paged.device_tables()
            step = self._paged_step_fn(self._nb_bucket(self._paged.max_owned()))
            extra = (tables,)
        else:
            step = self._serve_step
            extra = ()
        tok = jnp.asarray(sched.input_tokens())
        pos0 = sched.positions()
        adv = sched.active_mask().astype(np.int32)  # idle rows stay parked
        rids = tuple(s.request.rid for s in active)
        t0 = self.clock()
        self._sample_power(ts, ws)   # bracket the decode window
        outs = []
        caches = self.caches
        for i in range(k):
            tok, caches = step(self.params, tok, caches,
                               jnp.asarray(pos0 + i * adv), *extra)
            try:
                tok.copy_to_host_async()
            except AttributeError:   # older jax array types
                pass
            outs.append(tok)
        self.caches = caches
        outs_np = [np.asarray(o) for o in outs]   # pipeline drain: one sync
        t1 = self.clock()
        self._sample_power(ts, ws)
        if self.watchdog is not None:
            self.watchdog.observe(self._decode_idx, (t1 - t0) / k)
        self._decode_idx += 1
        emitted = 0
        for out in outs_np:
            for s in active:
                if s.request is None:     # finished at an earlier micro-step
                    continue
                if s.replay:
                    # preemption-resume replay: this step consumed a
                    # forced emitted-tail token. Mid-replay outputs are
                    # discarded (the stream already has them); the LAST
                    # replay step's argmax is the next NEW token and
                    # falls through to the normal emission path.
                    s.replay -= 1
                    if s.replay:
                        s.pos += 1
                        s.last_token = int(s.request.prompt[s.pos])
                        continue
                res = results[s.request.rid]
                tok_i = int(out[s.index])
                emitted += 1
                res.tokens.append(tok_i)
                slot_index = s.index
                reason = sched.record_token(s, tok_i)
                if reason is not None:
                    res.finish_s, res.finish_reason = t1, reason
                    if self._paged is not None:
                        self._free_paged_slot(slot_index)
        # rids credit every stepped slot with the window's energy
        # (replay steps burn compute too); n_tokens counts only tokens
        # actually appended to a stream, so token accounting stays exact
        steps.append(StepRecord("decode", t0, t1, rids * k, emitted,
                                n_steps=k))

    # ------------------------------------------------------------------
    # Warmup (compile outside any measured window)
    # ------------------------------------------------------------------

    def warmup(self, prompt_len: int = 8,
               requests: Optional[Sequence[Request]] = None,
               repeat: int = 1, sched: Optional[str] = None):
        """Compile every serve program this engine can reach: the
        prompt-bucket prefill, the insert, and each decode program
        (every paged gather bucket gets crossed as the warmup requests
        grow to full slot capacity). Power sampling and the straggler
        watchdog are detached so warmup never pollutes measurement.

        Pass the measured ``requests`` (and ``repeat=2``) to warm a
        prefix-cached engine: the first pass registers prefixes, the
        second takes the hit path, so every suffix-prefill program
        compiles before measurement. The prefix index is cleared
        afterwards — measured runs start from a cold index either way.
        """
        if self._scripted:
            return
        if requests is None:
            budget = max(self.max_len - prompt_len, 1)
            requests = [Request(rid=-(i + 1),
                                prompt=np.zeros(prompt_len, np.int32),
                                max_new_tokens=budget, arrival_s=0.0)
                        for i in range(self.n_slots)]
        saved = self.power_methods, self.watchdog
        self.power_methods, self.watchdog = [], None
        try:
            for _ in range(max(int(repeat), 1)):
                self.serve(requests, policy="continuous", sched=sched)
        finally:
            self.power_methods, self.watchdog = saved
            self.reset_prefix_cache()

    def reset_prefix_cache(self):
        """Drop every prefix-index entry (freeing index-only blocks) and
        zero the hit counters — each measured run starts cold."""
        if not self._scripted and self._paged is not None \
                and self.prefix_cache:
            self._paged.clear_prefix()
        if not self._scripted:
            self.prefix_stats = self._blank_prefix_stats()

    # ------------------------------------------------------------------
    # Continuous-batching run loop
    # ------------------------------------------------------------------

    def _sample_power(self, ts: list, ws: list):
        if not self.power_methods:
            return
        w = 0.0
        for m in self.power_methods:
            try:
                w += sum(m.read().values())
            except Exception:
                pass  # a failing backend must not kill serving
        ts.append(self.clock())
        ws.append(w)

    def serve(self, requests: Sequence[Request], *,
              policy: str = "continuous",
              poll_s: float = 0.002,
              sched: Optional[str] = None,
              chunk_tokens: Optional[int] = None,
              faults=None) -> ServeRunResult:
        """Run a request set to completion under the given policy.

        Request ``arrival_s`` values are relative to run start; the
        engine sleeps (``sleep_fn``) while the queue is empty and slots
        are idle, so wall time includes genuine arrival gaps.

        ``sched``/``chunk_tokens`` override the engine defaults for
        this run: ``"chunked"`` interleaves block-aligned prefill
        slices with decode steps and backs decode growth with
        preemption (see module docstring) — paged cache, model mode,
        attention-only families.

        Degradation: requests carrying a ``deadline_s`` are SHED (zero
        tokens, reason "shed") if still queued past their admission
        deadline — the engine never hangs on hopeless work, and shed
        requests count against goodput in ``serve.slo``. ``faults`` is
        an optional seeded :class:`~repro.faults.schedule.FaultSchedule`:
        its overload windows cap the admission queue (shedding newest
        arrivals first — the oldest queued request is never shed), and
        its slot faults kill the youngest decoding slot mid-run
        (chunked mode only: the victim resumes via preemption replay,
        so the faulted stream stays bit-identical to a fault-free one).
        """
        mode = sched or self.sched
        assert mode in ("phased", "chunked"), mode
        ct = int(chunk_tokens if chunk_tokens is not None
                 else self.chunk_tokens)
        chunked = mode == "chunked"
        if chunked:
            assert not self._scripted, (
                "chunked prefill drives the jitted model programs — "
                "scripted engines serve phased only")
            assert self.cache_kind == "paged", (
                "chunked prefill + preemption need block-granular "
                "reclaim (cache='paged')")
            assert ct > 0 and ct % self.block_size == 0, (
                f"chunk_tokens {ct} must be a positive multiple of "
                f"block_size {self.block_size}: chunk boundaries must "
                f"land on block edges so suffix chunks can gather the "
                f"already-prefilled prefix KV block-wise")
        if faults is not None and any(
                e.kind == "slot_fault" for e in faults.events):
            assert chunked and not self._scripted, (
                "slot faults recover via preemption replay, which only "
                "the chunked+paged scheduler implements — phased prefill "
                "cannot rebuild an emitted tail bit-identically")
        if not self._scripted:
            self._ensure_cache()
            if chunked:
                assert self.c.family not in ("ssm", "hybrid"), (
                    "chunked prefill re-enters the prompt mid-sequence "
                    "via prefix_kv — attention-only families (a mamba "
                    "recurrence cannot restart at a block boundary)")
            self.preemptions = 0
        self.shed = 0
        self.injected_faults = 0
        sched = Scheduler(self.n_slots, self.max_len, policy=policy)
        watchdog = self.watchdog
        has_deadlines = any(getattr(r, "deadline_s", None) is not None
                            for r in requests)

        t_start = self.clock()
        results: dict[int, RequestResult] = {}
        for r in requests:
            sched.submit(r)
            results[r.rid] = RequestResult(
                rid=r.rid, prompt_len=r.prompt_len,
                arrival_s=t_start + r.arrival_s,
                tenant=getattr(r, "tenant", ""))
        steps: list[StepRecord] = []
        ts: list[float] = []
        ws: list[float] = []
        if not self._scripted:
            self._defer_free_blocks = None
            self.prefix_stats = self._blank_prefix_stats()
        self._sample_power(ts, ws)

        def _mark_shed(req: Request):
            res = results[req.rid]
            res.finish_s = self.clock()
            res.finish_reason = "shed"
            self.shed += 1

        poll = 0
        while sched.has_work:
            now_rel = self.clock() - t_start
            # -- graceful degradation: deadline expiry + overload caps ----
            if has_deadlines or faults is not None:
                sched._absorb_arrivals(now_rel)
                if has_deadlines:
                    for req in sched.shed_expired(now_rel):
                        _mark_shed(req)
                cap = faults.queue_cap_at(poll) if faults is not None \
                    else None
                if cap is not None:
                    for req in sched.shed_newest(cap):
                        _mark_shed(req)
            poll += 1
            # -- admission: prefill newly admitted requests ---------------
            # a headroom-deferred head retries only once free_blocks has
            # moved — not every loop iteration (re-admit/unadmit churn)
            if self._admission_blocked():
                admitted = []
            else:
                if not self._scripted:
                    self._defer_free_blocks = None
                admitted = sched.refill(now_rel)
                if admitted and not self._scripted \
                        and self.cache_kind == "paged":
                    admitted = self._admit_paged(sched, admitted, results,
                                                 chunked=chunked)
            if chunked:
                if admitted:
                    self._start_chunked(admitted, results)
                if any(s.prefilling for s in sched.slots):
                    self._model_prefill_chunks(sched, results, steps,
                                               ts, ws, ct)
            elif admitted and not self._scripted:
                self._model_prefill_admitted(sched, admitted, results,
                                             steps, ts, ws)
            elif admitted:
                for slot in admitted:
                    req = slot.request
                    res = results[req.rid]
                    res.slot = slot.index
                    res.admitted_s = self.clock()
                    self._sample_power(ts, ws)   # bracket the prefill window
                    first = self._slot_prefill(slot.index, req.prompt)
                    t1 = self.clock()
                    self._sample_power(ts, ws)
                    res.first_token_s = t1
                    res.tokens.append(int(first))
                    steps.append(StepRecord("prefill", res.admitted_s, t1,
                                            (req.rid,), 1))
                    reason = sched.record_token(slot, int(first))
                    if reason is not None:
                        res.finish_s, res.finish_reason = t1, reason
            # -- decode over all fully-prefilled slots --------------------
            active = sched.decode_slots()
            if (faults is not None and active
                    and faults.slot_fault_at(self._decode_idx)):
                # injected slot failure: evict the YOUNGEST decoding slot
                # (never the oldest — FIFO degradation). The victim
                # re-queues at the front and resumes via decode replay,
                # so its stream stays bit-identical to a fault-free run.
                victim = max(active, key=lambda s: (s.request.arrival_s,
                                                    s.request.rid))
                self._preempt_slot(sched, victim, results)
                self.injected_faults += 1
                active = sched.decode_slots()
            prefilling = any(s.prefilling for s in sched.slots)
            if active and not self._scripted:
                k = self._decode_plan(
                    sched, active,
                    admission_blocked=self._admission_blocked(),
                    prefilling=prefilling)
                self._model_decode_run(sched, active, k, results,
                                       steps, ts, ws,
                                       allow_preempt=chunked)
            elif active:
                rids = tuple(s.request.rid for s in active)
                t0 = self.clock()
                self._sample_power(ts, ws)   # bracket the decode window
                out = self._slot_decode(sched.input_tokens(),
                                        sched.positions(),
                                        sched.active_mask())
                t1 = self.clock()
                self._sample_power(ts, ws)
                if watchdog is not None:
                    watchdog.observe(self._decode_idx, t1 - t0)
                self._decode_idx += 1
                steps.append(StepRecord("decode", t0, t1, rids, len(rids)))
                out = np.asarray(out)
                for s in active:
                    res = results[s.request.rid]
                    tok = int(out[s.index])
                    res.tokens.append(tok)
                    reason = sched.record_token(s, tok)
                    if reason is not None:
                        res.finish_s, res.finish_reason = t1, reason
            elif sched.n_pending and not prefilling:
                # idle: nothing admitted yet — wait for the next arrival
                nxt = sched.next_arrival_s()
                wait = (t_start + nxt) - self.clock() if nxt is not None \
                    else poll_s
                if wait > 0:
                    self.sleep_fn(min(wait, 0.05))

        self._sample_power(ts, ws)
        out_results = sorted(results.values(), key=lambda r: r.finish_s)
        for rid, wh in attribute_energy(steps, ts, ws).items():
            results[rid].energy_wh = wh
        return ServeRunResult(
            results=out_results, steps=steps, sample_ts=ts, sample_ws=ws,
            summary=serve_summary(out_results, steps, ts, ws,
                                  n_slots=self.n_slots),
            straggler_events=list(watchdog.events) if watchdog else [])

    # ------------------------------------------------------------------
    # Fixed-batch generation (legacy BatchedServer path)
    # ------------------------------------------------------------------

    def generate(self, tokens: jax.Array, n_steps: int,
                 extras: Optional[dict] = None,
                 gen_budget: Optional[int] = None) -> GenerationResult:
        """Fixed-batch greedy decode: prefill a full batch, decode
        ``n_steps`` with a shared scalar position. ``gen_budget`` sets
        the KV growth beyond the prompt (defaults to n_steps + 1)."""
        assert not self._scripted
        extras = extras or {}
        budget = gen_budget if gen_budget is not None else n_steps + 1
        b, s = tokens.shape
        t0 = time.perf_counter()
        logits, caches, enc_kv = self._prefill(self.params, tokens, extras)
        logits.block_until_ready()
        t1 = time.perf_counter()
        # grow KV caches so decode can append (SSM states pass through)
        caches = self._grow(caches, s + budget)
        out = [jnp.argmax(logits[:, -1], -1).astype(jnp.int32)]
        pos = s
        for _ in range(n_steps - 1):
            tok = out[-1][:, None]
            logits, caches = self._decode(self.params, tok, caches,
                                          jnp.int32(pos), enc_kv)
            out.append(jnp.argmax(logits[:, -1], -1).astype(jnp.int32))
            pos += 1
        out[-1].block_until_ready()
        t2 = time.perf_counter()
        return GenerationResult(jnp.stack(out, 1), n_steps, t1 - t0, t2 - t1)


class BatchedServer:
    """Fixed-batch greedy decoding driver — one policy over ServeEngine.

    Back-compat shim: ``max_len`` keeps its historical meaning here (KV
    growth budget beyond the prompt), while ``ServeEngine.max_len`` is
    the total slot capacity.
    """

    def __init__(self, c: ModelConfig, params: Params, *,
                 max_len: int = 256, impl_prefill: str = "repeat",
                 impl_decode: str = "grouped", donate: bool = True):
        self.c, self.params, self.max_len = c, params, max_len
        self.engine = ServeEngine(
            c, params, n_slots=1, max_len=max_len,
            impl_prefill=impl_prefill, impl_decode=impl_decode,
            donate=donate)

    def generate(self, tokens: jax.Array, n_steps: int,
                 extras: Optional[dict] = None) -> GenerationResult:
        return self.engine.generate(tokens, n_steps, extras,
                                    gen_budget=self.max_len)
