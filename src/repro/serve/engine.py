"""Serving engine: batched prefill + decode with donated caches.

``serve_step`` (single-token decode against a full KV cache) is what the
``decode_*`` / ``long_*`` dry-run shapes lower. The BatchedServer is the
runnable driver used by the serving example/benchmark: fixed-batch
continuous decoding with greedy or temperature sampling.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm

Params = Any


def make_prefill_fn(c: ModelConfig, impl: str = "repeat"):
    def prefill_step(params, tokens, extras):
        logits, caches, enc_kv = lm.prefill(
            c, params, tokens,
            patch_embeds=extras.get("patch_embeds"),
            enc_frames=extras.get("enc_frames"), impl=impl)
        return logits, caches, enc_kv
    return prefill_step


def make_decode_fn(c: ModelConfig, impl: str = "grouped"):
    def serve_step(params, token, caches, pos, enc_kv=None):
        return lm.decode_step(c, params, token, caches, pos,
                              enc_kv=enc_kv, impl=impl)
    return serve_step


@dataclass
class GenerationResult:
    tokens: Any
    steps: int
    prefill_s: float
    decode_s: float

    @property
    def decode_tokens_per_s(self) -> float:
        n = self.tokens.shape[0] * self.steps
        return n / max(self.decode_s, 1e-9)


class BatchedServer:
    """Fixed-batch greedy decoding driver (benchmark/serving example)."""

    def __init__(self, c: ModelConfig, params: Params, *,
                 max_len: int = 256, impl_prefill: str = "repeat",
                 impl_decode: str = "grouped", donate: bool = True):
        self.c, self.params, self.max_len = c, params, max_len
        self._prefill = jax.jit(make_prefill_fn(c, impl_prefill))
        decode = make_decode_fn(c, impl_decode)
        self._decode = jax.jit(decode, donate_argnums=(2,) if donate else ())

    def generate(self, tokens: jax.Array, n_steps: int,
                 extras: Optional[dict] = None) -> GenerationResult:
        extras = extras or {}
        b, s = tokens.shape
        t0 = time.perf_counter()
        logits, caches, enc_kv = self._prefill(self.params, tokens, extras)
        logits.block_until_ready()
        t1 = time.perf_counter()
        # grow KV caches to max_len so decode can append
        caches = jax.tree_util.tree_map_with_path(self._grow, caches)
        out = [jnp.argmax(logits[:, -1], -1).astype(jnp.int32)]
        pos = s
        for _ in range(n_steps - 1):
            tok = out[-1][:, None]
            logits, caches = self._decode(self.params, tok, caches,
                                          jnp.int32(pos), enc_kv)
            out.append(jnp.argmax(logits[:, -1], -1).astype(jnp.int32))
            pos += 1
        out[-1].block_until_ready()
        t2 = time.perf_counter()
        return GenerationResult(jnp.stack(out, 1), n_steps, t1 - t0, t2 - t1)

    def _grow(self, path, leaf: jax.Array) -> jax.Array:
        # KV caches have layout (L, B, T, ...); pad T up to prompt+max_len.
        # SSM/conv states are fixed-size and pass through untouched.
        name = getattr(path[-1], "key", None)
        if name in ("k", "v"):
            widths = [(0, 0)] * leaf.ndim
            widths[2] = (0, self.max_len)
            return jnp.pad(leaf, widths)
        return leaf
