"""KV / SSM cache construction (abstract + concrete) + slot operations.

Two layers:

  * ``abstract_caches`` — ShapeDtypeStructs via eval_shape (dry-run path);
  * slotted-cache ops — the continuous-batching engine's KV store. The
    cache batch axis is a pool of ``n_slots`` rows of capacity
    ``max_len``; finished requests free their row via ``insert_slot``
    (overwrite on refill) or ``reset_slot`` without retracing: the slot
    index is a *traced* argument, so one jitted program serves every
    slot, and donation makes the update in-place.

Cache tree layout (from ``blocks.stack_prefill`` under scan):
  attention slots:  {"k","v"}      leaves (L, B, T, Kh, Dh)
  mamba slots:      {"ssm","conv"} leaves (L, B, ...) — T-independent
The batch axis is axis 1 for every leaf, which is what the slot ops rely
on; only "k"/"v" leaves carry the T axis (axis 2) and need growing.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, lm

Params = Any


def abstract_caches(c: ModelConfig, batch: int, seq_len: int,
                    abstract_params: Params):
    """Cache/enc_kv ShapeDtypeStructs via eval_shape on prefill (no alloc)."""
    kw = {}
    s_text = seq_len - (c.n_patches if c.family == "vlm" else 0)
    tokens = jax.ShapeDtypeStruct((batch, s_text), jnp.int32)
    if c.family == "vlm":
        kw["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, c.n_patches, c.d_model), jnp.dtype(c.dtype))
    if c.family == "encdec":
        kw["enc_frames"] = jax.ShapeDtypeStruct(
            (batch, c.enc_seq, c.d_model), jnp.dtype(c.dtype))

    def run(p, t, kwargs):
        logits, caches, enc_kv = lm.prefill(c, p, t, **kwargs)
        return caches, enc_kv

    return jax.eval_shape(run, abstract_params, tokens, kw), kw


# ---------------------------------------------------------------------------
# Slotted cache (continuous batching)
# ---------------------------------------------------------------------------


def _is_kv(path) -> bool:
    return getattr(path[-1], "key", None) in ("k", "v")


def grow_caches(caches: Params, max_len: int) -> Params:
    """Pad every k/v leaf's T axis (axis 2) up to ``max_len`` rows.

    SSM/conv state leaves are fixed-size and pass through untouched.
    Used both by the fixed-batch policy (grow prompt caches for decode)
    and by slot insertion (grow a batch-1 prefill row to slot capacity).
    """

    def grow(path, leaf):
        if _is_kv(path):
            pad = max_len - leaf.shape[2]
            assert pad >= 0, (leaf.shape, max_len)
            widths = [(0, 0)] * leaf.ndim
            widths[2] = (0, pad)
            return jnp.pad(leaf, widths)
        return leaf

    return jax.tree_util.tree_map_with_path(grow, caches)


def slotted_cache(c: ModelConfig, n_slots: int, max_len: int,
                  params: Params) -> Params:
    """Zero-initialized cache pool: n_slots rows of max_len capacity.

    Shapes come from ``eval_shape`` on prefill (no tracing of the real
    model weights); the concrete zeros are allocated once and then only
    ever updated in place (donation) by decode/insert/reset.
    """
    abstract = lm.init_abstract(c) if params is None else params
    (caches, _enc_kv), _ = abstract_caches(c, n_slots, max_len, abstract)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), caches)


@partial(jax.jit, donate_argnums=(0,))
def insert_slot(caches: Params, row: Params, slot: jax.Array) -> Params:
    """Write a batch-1 cache tree into batch row ``slot`` of the pool.

    ``slot`` is traced — one compiled program covers every slot index, so
    admitting a request into any slot never retraces. The old row content
    (a finished request's KV) is simply overwritten: freeing is O(0).
    """

    def put(big, small):
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, axis=1)

    return jax.tree.map(put, caches, row)


@partial(jax.jit, donate_argnums=(0,))
def reset_slot(caches: Params, slot: jax.Array) -> Params:
    """Zero batch row ``slot`` (defensive scrub; insert_slot overwrites
    anyway, but an explicit reset keeps cancelled requests from leaking
    stale KV into debugging dumps)."""

    def zero(leaf):
        row = jnp.zeros((leaf.shape[0], 1) + leaf.shape[2:], leaf.dtype)
        return jax.lax.dynamic_update_slice_in_dim(leaf, row, slot, axis=1)

    return jax.tree.map(zero, caches)


@partial(jax.jit, donate_argnums=(0,))
def compact_slots(caches: Params, perm: jax.Array) -> Params:
    """Gather batch rows by ``perm`` (n_slots,) — packs active slots to
    the front. Not needed by the fixed-pool engine (slots are
    position-independent) but the building block for shrinking the live
    batch under paged/variable-slot serving."""
    return jax.tree.map(lambda leaf: jnp.take(leaf, perm, axis=1), caches)
