"""KV / SSM cache construction (abstract + concrete)."""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, lm

Params = Any


def abstract_caches(c: ModelConfig, batch: int, seq_len: int,
                    abstract_params: Params):
    """Cache/enc_kv ShapeDtypeStructs via eval_shape on prefill (no alloc)."""
    kw = {}
    s_text = seq_len - (c.n_patches if c.family == "vlm" else 0)
    tokens = jax.ShapeDtypeStruct((batch, s_text), jnp.int32)
    if c.family == "vlm":
        kw["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, c.n_patches, c.d_model), jnp.dtype(c.dtype))
    if c.family == "encdec":
        kw["enc_frames"] = jax.ShapeDtypeStruct(
            (batch, c.enc_seq, c.d_model), jnp.dtype(c.dtype))

    def run(p, t, kwargs):
        logits, caches, enc_kv = lm.prefill(c, p, t, **kwargs)
        return caches, enc_kv

    return jax.eval_shape(run, abstract_params, tokens, kw), kw
