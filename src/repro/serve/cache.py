"""KV / SSM cache construction (abstract + concrete) + slot operations.

Three layers:

  * ``abstract_caches`` — ShapeDtypeStructs via eval_shape (dry-run path);
  * slotted-cache ops — the reference KV store for continuous batching.
    The cache batch axis is a pool of ``n_slots`` rows of capacity
    ``max_len``; finished requests free their row via ``insert_slot``
    (overwrite on refill) or ``reset_slot`` without retracing: the slot
    index is a *traced* argument, so one jitted program serves every
    slot, and donation makes the update in-place.
  * ``PagedKVCache`` — the production layout: attention K/V lives in
    fixed-size blocks inside one shared pool, addressed through
    per-slot block tables. A slot holding ``t`` tokens owns
    ``ceil(t / block_size)`` blocks instead of reserving a dense
    ``max_len`` row, so short requests stop paying for long-request
    capacity and the same HBM holds more live requests. Alloc/free is
    host-side free-list bookkeeping (no retracing, no device work);
    only the small ``(n_slots, max_blocks)`` int32 table is re-uploaded
    when it changes. Block 0 is the *trash block*: every unowned table
    column points at it, so idle slots riding along in the fused decode
    step scatter their dead writes there instead of corrupting a
    neighbour.

Cache tree layout (from ``blocks.stack_prefill`` under scan):
  attention slots:  {"k","v"}      leaves (L, B, T, Kh, Dh)  [slotted]
                                   leaves (L, n_blocks, bs, Kh, Dh) [paged]
  mamba slots:      {"ssm","conv"} leaves (L, B, ...) — T-independent,
                                   per-slot rows in either layout.
The batch/pool axis is axis 1 for every leaf, which is what the slot ops
rely on; only "k"/"v" leaves carry the T axis (axis 2) and need growing.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks, lm

Params = Any


class CacheOOM(RuntimeError):
    """The paged pool ran out of free blocks (admission control signal)."""


def abstract_caches(c: ModelConfig, batch: int, seq_len: int,
                    abstract_params: Params):
    """Cache/enc_kv ShapeDtypeStructs via eval_shape on prefill (no alloc)."""
    kw = {}
    s_text = seq_len - (c.n_patches if c.family == "vlm" else 0)
    tokens = jax.ShapeDtypeStruct((batch, s_text), jnp.int32)
    if c.family == "vlm":
        kw["patch_embeds"] = jax.ShapeDtypeStruct(
            (batch, c.n_patches, c.d_model), jnp.dtype(c.dtype))
    if c.family == "encdec":
        kw["enc_frames"] = jax.ShapeDtypeStruct(
            (batch, c.enc_seq, c.d_model), jnp.dtype(c.dtype))

    def run(p, t, kwargs):
        logits, caches, enc_kv = lm.prefill(c, p, t, **kwargs)
        return caches, enc_kv

    return jax.eval_shape(run, abstract_params, tokens, kw), kw


# ---------------------------------------------------------------------------
# Slotted cache (continuous batching)
# ---------------------------------------------------------------------------


def _is_kv(path) -> bool:
    return getattr(path[-1], "key", None) in ("k", "v")


def grow_caches(caches: Params, max_len: int) -> Params:
    """Pad every k/v leaf's T axis (axis 2) up to ``max_len`` rows.

    SSM/conv state leaves are fixed-size and pass through untouched.
    Used both by the fixed-batch policy (grow prompt caches for decode)
    and by slot insertion (grow a batch-1 prefill row to slot capacity).
    """

    def grow(path, leaf):
        if _is_kv(path):
            pad = max_len - leaf.shape[2]
            assert pad >= 0, (leaf.shape, max_len)
            widths = [(0, 0)] * leaf.ndim
            widths[2] = (0, pad)
            return jnp.pad(leaf, widths)
        return leaf

    return jax.tree_util.tree_map_with_path(grow, caches)


def slotted_cache(c: ModelConfig, n_slots: int, max_len: int,
                  params: Params) -> Params:
    """Zero-initialized cache pool: n_slots rows of max_len capacity.

    Shapes come from ``eval_shape`` on prefill (no tracing of the real
    model weights); the concrete zeros are allocated once and then only
    ever updated in place (donation) by decode/insert/reset.
    """
    abstract = lm.init_abstract(c) if params is None else params
    (caches, _enc_kv), _ = abstract_caches(c, n_slots, max_len, abstract)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), caches)


@partial(jax.jit, donate_argnums=(0,))
def insert_slot(caches: Params, row: Params, slot: jax.Array) -> Params:
    """Write a batch-1 cache tree into batch row ``slot`` of the pool.

    ``slot`` is traced — one compiled program covers every slot index, so
    admitting a request into any slot never retraces. The old row content
    (a finished request's KV) is simply overwritten: freeing is O(0).
    """

    def put(big, small):
        return jax.lax.dynamic_update_slice_in_dim(
            big, small.astype(big.dtype), slot, axis=1)

    return jax.tree.map(put, caches, row)


@partial(jax.jit, donate_argnums=(0,))
def reset_slot(caches: Params, slot: jax.Array) -> Params:
    """Zero batch row ``slot`` (defensive scrub; insert_slot overwrites
    anyway, but an explicit reset keeps cancelled requests from leaking
    stale KV into debugging dumps)."""

    def zero(leaf):
        row = jnp.zeros((leaf.shape[0], 1) + leaf.shape[2:], leaf.dtype)
        return jax.lax.dynamic_update_slice_in_dim(leaf, row, slot, axis=1)

    return jax.tree.map(zero, caches)


@partial(jax.jit, donate_argnums=(0,))
def insert_rows(caches: Params, rows: Params, slots: jax.Array) -> Params:
    """Batched ``insert_slot``: write ``Kp`` prefill results at once.

    ``rows`` is a cache tree whose batch axis holds Kp requests and
    whose k/v T axis is the (static) prompt bucket ``S <= max_len``;
    ``slots`` (Kp,) int32 names the target pool rows. Rows [S, max_len)
    of the target keep whatever they held — a previous tenant's KV is
    masked by position until decode overwrites it. Out-of-range slot ids
    (>= n_slots) are *dropped*: the batch-bucketing pad rows of the
    batched prefill vanish here instead of needing a mask.
    """

    def put(path, big, small):
        if _is_kv(path):
            s = small.shape[2]
            return big.at[:, slots, :s].set(small.astype(big.dtype),
                                            mode="drop")
        return big.at[:, slots].set(small.astype(big.dtype), mode="drop")

    return jax.tree_util.tree_map_with_path(put, caches, rows)


@partial(jax.jit, donate_argnums=(0,))
def compact_slots(caches: Params, perm: jax.Array) -> Params:
    """Gather batch rows by ``perm`` (n_slots,) — packs active slots to
    the front. Not needed by the fixed-pool engine (slots are
    position-independent) but the building block for shrinking the live
    batch under paged/variable-slot serving."""
    return jax.tree.map(lambda leaf: jnp.take(leaf, perm, axis=1), caches)


# ---------------------------------------------------------------------------
# Paged cache (block-table KV pool)
# ---------------------------------------------------------------------------


@partial(jax.jit, donate_argnums=(0,), static_argnames=("block_size",))
def insert_paged_rows(caches: Params, rows: Params, blocks: jax.Array,
                      slots: jax.Array, *, block_size: int) -> Params:
    """Scatter a batched prefill result into the paged pool.

    ``rows``: cache tree with k/v leaves (L, Kp, S, Kh, Dh) — S need not
    be a block multiple: the k/v tail of a partial block is zero-padded
    here (those rows are position-masked until decode overwrites them);
    ``blocks``: (Kp, ceil(S / block_size)) int32 physical block ids per
    request, in position order; ``slots``: (Kp,) int32 batch rows for
    the T-independent state leaves. Out-of-range ids in either index
    array are dropped (the batch-bucketing pad rows and the unowned
    tail columns of short prompts).
    """
    flat_blocks = blocks.reshape(-1)

    def put(path, big, small):
        if _is_kv(path):
            l, kp, s = small.shape[:3]
            pad = -s % block_size
            if pad:
                widths = [(0, 0)] * small.ndim
                widths[2] = (0, pad)
                small = jnp.pad(small, widths)
                s += pad
            small = small.reshape((l, kp * (s // block_size), block_size)
                                  + small.shape[3:])
            return big.at[:, flat_blocks].set(small.astype(big.dtype),
                                              mode="drop")
        return big.at[:, slots].set(small.astype(big.dtype), mode="drop")

    return jax.tree_util.tree_map_with_path(put, caches, rows)


def _quantize_block(blk: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization of pool-shaped KV ``(L, n, bs, Kh, Dh)``
    per (block, KV head): scale = maxabs / 127 over the block's (bs, Dh)
    slab. Returns (int8 values, f32 scales (L, n, Kh)). Zero slabs (pad
    rows, untouched blocks) get scale 0 — the dequant guard maps that to
    exact zeros."""
    blk = blk.astype(jnp.float32)
    sc = jnp.max(jnp.abs(blk), axis=(2, 4)) / 127.0
    q = jnp.round(blk / jnp.where(sc > 0.0, sc, 1.0)[:, :, None, :, None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), sc


@partial(jax.jit, donate_argnums=(0,), static_argnames=("block_size",))
def insert_paged_prefill(caches: Params, rows: Params, blocks: jax.Array,
                         slots: jax.Array, *, block_size: int) -> Params:
    """:func:`insert_paged_rows` that also understands int8 pools.

    When a ``"k"``/``"v"`` leaf has a sibling ``"k_scale"``/``"v_scale"``
    leaf (the :class:`PagedKVCache` ``kv_dtype="int8"`` layout), the
    prefill KV is quantized per (block, KV head) on the way in and both
    the int8 pool blocks and their scales are scattered at
    ``flat_blocks``. Walked as a dict tree (not ``tree_map``) because
    ``rows`` — a model prefill result — has no scale leaves.
    """
    flat_blocks = blocks.reshape(-1)

    def prep(small):
        l, kp, s = small.shape[:3]
        pad = -s % block_size
        if pad:
            widths = [(0, 0)] * small.ndim
            widths[2] = (0, pad)
            small = jnp.pad(small, widths)
            s += pad
        return small.reshape((l, kp * (s // block_size), block_size)
                             + small.shape[3:])

    def walk(big, small):
        if not isinstance(big, dict):
            return big.at[:, slots].set(small.astype(big.dtype), mode="drop")
        out = {}
        for key, leaf in big.items():
            if key in ("k_scale", "v_scale"):
                continue                    # written with the kv leaf below
            if key in ("k", "v") and key + "_scale" in big:
                q, sc = _quantize_block(prep(small[key]))
                out[key] = leaf.at[:, flat_blocks].set(q, mode="drop")
                sleaf = big[key + "_scale"]
                out[key + "_scale"] = sleaf.at[:, flat_blocks].set(
                    sc.astype(sleaf.dtype), mode="drop")
            elif key in ("k", "v"):
                out[key] = leaf.at[:, flat_blocks].set(
                    prep(small[key]).astype(leaf.dtype), mode="drop")
            else:
                out[key] = walk(leaf, small[key])
        return out

    return walk(caches, rows)


def _is_pool(path) -> bool:
    return getattr(path[-1], "key", None) in ("k", "v", "k_scale", "v_scale")


def _add_scale_leaves(tree):
    """Add a zero ``k_scale``/``v_scale`` leaf ``(L, n_blocks, Kh)`` f32
    beside every pool-shaped k/v leaf — the int8 pool layout. Scales live
    in the same per-slot dicts as the blocks they describe, so every
    existing tree walk (insert, CoW, scan xs) carries them for free."""
    if not isinstance(tree, dict):
        return tree
    out = {k: _add_scale_leaves(v) for k, v in tree.items()}
    for key in ("k", "v"):
        leaf = tree.get(key)
        if leaf is not None and not isinstance(leaf, dict):
            out[key + "_scale"] = jnp.zeros(
                (leaf.shape[0], leaf.shape[1], leaf.shape[3]), jnp.float32)
    return out


@partial(jax.jit, donate_argnums=(0,))
def copy_blocks(caches: Params, src: jax.Array, dst: jax.Array) -> Params:
    """Copy pool blocks ``src[i] -> dst[i]`` on every attention k/v leaf
    — and, on int8 pools, the matching scale leaves, so a CoW'd block
    dequantizes identically to its parent. SSM/conv state leaves are
    per-slot and pass through. Traced per (len(src),) shape — CoW events
    are rare (a write into a still-shared block), so the handful of
    compiled variants is cheap."""

    def cp(path, leaf):
        if _is_pool(path):
            return leaf.at[:, dst].set(leaf[:, src])
        return leaf

    return jax.tree_util.tree_map_with_path(cp, caches)


@dataclass
class _PrefixEntry:
    """One registered full KV block: the chain key addressing its token
    content, the physical block id, and LRU bookkeeping."""

    key: tuple
    block: int
    parent: Optional[tuple]     # chain key of the previous block (depth>0)
    children: int = 0           # live child entries (evict leaves first)
    last_used: int = 0


class PrefixIndex:
    """Content-addressed index over full KV blocks (shared prefixes).

    Keys are *cumulative chains*: block ``i`` of a prompt is addressed
    by ``(key_of_block_{i-1}, tokens[i*bs:(i+1)*bs])`` with ``()`` as
    the root — nested tuples compared by value, so a hit means the
    ENTIRE token prefix matches exactly (no hash-collision risk of
    serving another tenant's KV). Only full blocks are indexed: a
    partial tail block contains pad-position KV and is never shareable.

    The index itself holds no refcounts — :class:`PagedKVCache` pins
    one reference per indexed block and reclaims via
    :meth:`pop_lru_leaf` (leaf-first eviction keeps every remaining
    entry reachable: evicting an interior block would orphan its
    descendants into unreachable leaks).
    """

    def __init__(self, block_size: int):
        self.block_size = block_size
        self._entries: dict[tuple, _PrefixEntry] = {}
        self._clock = 0

    def __len__(self) -> int:
        return len(self._entries)

    def blocks(self) -> list[int]:
        return [e.block for e in self._entries.values()]

    def match(self, tokens, max_tokens: Optional[int] = None) -> list[int]:
        """Longest indexed full-block chain prefixing ``tokens``,
        capped at ``max_tokens`` tokens (the engine caps at
        ``len(tokens) - 1`` so a fully-cached prompt still has >= 1
        suffix token to prefill — an empty prefill is impossible).
        Returns the physical block ids and touches their LRU clocks."""
        bs = self.block_size
        limit = len(tokens) if max_tokens is None else min(len(tokens),
                                                           max_tokens)
        self._clock += 1
        key: tuple = ()
        out: list[int] = []
        for i in range(limit // bs):
            key = (key, tuple(int(t) for t in tokens[i * bs:(i + 1) * bs]))
            e = self._entries.get(key)
            if e is None:
                break
            e.last_used = self._clock
            out.append(e.block)
        return out

    def register(self, tokens, block_ids) -> list[int]:
        """Index the full blocks of a prompt held in ``block_ids``
        (position order). Chains already present are kept (dedup — the
        first registrant's block stays canonical); returns the block ids
        of NEWLY created entries, which the caller must pin (+1 ref)."""
        bs = self.block_size
        n_full = min(len(tokens) // bs, len(block_ids))
        self._clock += 1
        key: tuple = ()
        new: list[int] = []
        for i in range(n_full):
            pkey, key = key, (
                key, tuple(int(t) for t in tokens[i * bs:(i + 1) * bs]))
            e = self._entries.get(key)
            if e is None:
                e = _PrefixEntry(key=key, block=int(block_ids[i]),
                                 parent=pkey if i else None,
                                 last_used=self._clock)
                if i:
                    self._entries[pkey].children += 1
                self._entries[key] = e
                new.append(e.block)
            else:
                e.last_used = self._clock
        return new

    def pop_lru_leaf(self) -> Optional[_PrefixEntry]:
        """Remove and return the least-recently-used *leaf* entry (no
        children), or None when the index is empty. The caller unpins
        the returned block."""
        leaves = [e for e in self._entries.values() if e.children == 0]
        if not leaves:
            return None
        e = min(leaves, key=lambda x: x.last_used)
        del self._entries[e.key]
        if e.parent is not None:
            parent = self._entries.get(e.parent)
            if parent is not None:
                parent.children -= 1
        return e

    def pop_all(self) -> list[int]:
        """Drain the index; returns every indexed block id (to unpin)."""
        out = self.blocks()
        self._entries.clear()
        return out


class PagedKVCache:
    """Block-table KV cache: device pools + host allocator.

    Device state (built once, then only updated in place by the jitted
    serve programs through donation):

      * ``caches`` — the model cache tree with every attention k/v leaf
        replaced by a shared pool ``(L, n_blocks, block_size, Kh, Dh)``;
        SSM/conv state leaves keep their per-slot ``(L, n_slots, ...)``
        rows (they are O(1) per slot — paging buys nothing). The serve
        engine takes ownership of this tree on first use (its jitted
        programs donate it in place) and clears the attribute.
        ``kv_dtype="int8"`` stores the pool as int8 with per-block-
        per-head symmetric ``k_scale``/``v_scale`` leaves
        ``(L, n_blocks, Kh)`` f32 beside it: ~0.51x the bytes of the
        native (bf16/fp32-free) pool at equal block count, dequantized
        inside the paged kernels' KV loads. ``kv_dtype="fp32"`` means
        *unquantized at the model's native cache dtype* — NOT a literal
        float32 cast, which would break slotted-vs-paged stream
        bit-identity. ``pool_bytes`` / ``pool_bytes_fp`` /
        ``max_concurrency`` expose the capacity arithmetic to the bench
        metrics.
      * ``device_tables()`` — the ``(n_slots, max_blocks)`` int32 block
        table, re-uploaded only after alloc/free changed it.

    Host state: a free list and per-slot owned-block lists. ``ensure``
    grows a slot to a token capacity (raising :class:`CacheOOM` when the
    pool is exhausted — the engine's admission-control signal), ``free``
    returns a finished slot's blocks and points its table row back at
    the trash block 0. Neither touches the device, so growing a slot
    mid-generation costs nothing until the next table upload.

    The default pool size reserves worst-case capacity
    (``n_slots * ceil(max_len / block_size)`` + trash) so behaviour is
    drop-in for the slotted cache; pass ``n_blocks`` to oversubscribe —
    the real HBM lever: short requests only ever hold the blocks they
    touched, so the freed reservation admits more slots per byte. The
    serve engine admission-controls an oversubscribed pool: a request
    whose worst-case block demand exceeds the unreserved headroom is
    deferred back to the queue (``ServeEngine._admit_paged``) until
    finishing slots free blocks, so concurrent load that outgrows the
    pool queues instead of raising :class:`CacheOOM`. The exception
    remains the contract for direct allocator misuse (``ensure`` past
    an exhausted pool without going through admission).
    """

    def __init__(self, c: ModelConfig, n_slots: int, max_len: int,
                 params: Params, *, block_size: int = 16,
                 n_blocks: Optional[int] = None, kv_dtype: str = "fp32"):
        assert max_len % block_size == 0, (max_len, block_size)
        assert kv_dtype in ("fp32", "int8"), kv_dtype
        self.c, self.n_slots, self.max_len = c, n_slots, max_len
        self.block_size = block_size
        self.kv_dtype = kv_dtype
        self.max_blocks = max_len // block_size
        total = (1 + n_slots * self.max_blocks) if n_blocks is None \
            else int(n_blocks)
        assert total >= 1 + self.max_blocks, (
            f"pool of {total} blocks cannot hold even one full slot "
            f"({self.max_blocks} blocks) plus the trash block")
        self.n_blocks = total

        abstract = lm.init_abstract(c) if params is None else params
        (shapes, _), _ = abstract_caches(c, n_slots, max_len, abstract)

        def make(path, leaf):
            if _is_kv(path):
                shape = ((leaf.shape[0], total, block_size) + leaf.shape[3:])
                dt = jnp.int8 if kv_dtype == "int8" else leaf.dtype
                return jnp.zeros(shape, dt)
            return jnp.zeros(leaf.shape, leaf.dtype)

        caches = jax.tree_util.tree_map_with_path(make, shapes)
        if kv_dtype == "int8":
            caches = _add_scale_leaves(caches)
        self.caches = caches

        #: actual pool bytes (k/v blocks + scales when quantized) vs what
        #: the same block count costs at the model's native KV dtype —
        #: the capacity story the serve metrics report: at the fp byte
        #: budget an int8 pool holds ~2x the blocks, so ~2x the
        #: worst-case-length concurrent requests.
        self.pool_bytes = 0
        self.pool_bytes_fp = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(caches)[0]:
            if _is_pool(path):
                self.pool_bytes += leaf.size * leaf.dtype.itemsize
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            if _is_kv(path):
                elems = (leaf.shape[0] * total * block_size
                         * int(np.prod(leaf.shape[3:])))
                self.pool_bytes_fp += elems * jnp.dtype(leaf.dtype).itemsize
        self.bytes_per_block = self.pool_bytes // total
        # pure-SSM stacks have no attention KV leaves: no pool, no paging
        # capacity story to tell.
        self.max_concurrency = n_slots if self.bytes_per_block == 0 else int(
            self.pool_bytes_fp // (self.max_blocks * self.bytes_per_block))
        self.tables_np = np.zeros((n_slots, self.max_blocks), np.int32)
        self._tables = jnp.asarray(self.tables_np)
        self._dirty = False
        self._free = list(range(total - 1, 0, -1))   # block 0 = trash
        self._owned: list[list[int]] = [[] for _ in range(n_slots)]
        #: per-block reference count: one per owning slot (shared-prefix
        #: adoption makes that >1) plus one per prefix-index entry. A
        #: block returns to the free list only at refcount zero.
        self._ref = [0] * total
        self._prefix: Optional[PrefixIndex] = None

    # -- allocator -------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def owned(self, slot: int) -> int:
        return len(self._owned[slot])

    def max_owned(self) -> int:
        """Longest live slot, in blocks (>= 1: the idle-slot trash column
        still has to be gathered by the decode program)."""
        return max((len(o) for o in self._owned), default=1) or 1

    def ensure(self, slot: int, n_tokens: int) -> None:
        """Grow ``slot`` to hold ``n_tokens`` total tokens.

        When the free list is empty but the prefix index pins
        reclaimable blocks (index-only references), LRU index entries
        are evicted first — cached prefixes are an opportunistic use of
        spare capacity and must never starve live requests."""
        assert n_tokens <= self.max_len, (n_tokens, self.max_len)
        need = -(-n_tokens // self.block_size)
        owned = self._owned[slot]
        while len(owned) < need:
            if not self._free and not self._reclaim_prefix_block():
                raise CacheOOM(
                    f"paged pool exhausted: slot {slot} needs block "
                    f"{len(owned) + 1}/{need}, 0 of {self.n_blocks} free")
            blk = self._free.pop()
            self._ref[blk] = 1
            self.tables_np[slot, len(owned)] = blk
            owned.append(blk)
            self._dirty = True

    def free(self, slot: int) -> int:
        """Drop the slot's references; blocks whose refcount hits zero
        return to the free list (a block still shared by another slot or
        pinned by the prefix index stays live). The table row reverts to
        the trash block so in-flight rides write harmlessly.

        Returns the number of blocks that actually reached the free
        list — the preemption reclaim hook: evicting a victim whose
        blocks are mostly shared/index-pinned may free less than it
        owned, and the engine keeps preempting until the demand is
        covered.
        """
        freed = 0
        if self._owned[slot]:
            for blk in reversed(self._owned[slot]):
                self._ref[blk] -= 1
                assert self._ref[blk] >= 0, (slot, blk)
                if self._ref[blk] == 0:
                    self._free.append(blk)
                    freed += 1
            self._owned[slot] = []
            self.tables_np[slot] = 0
            self._dirty = True
        return freed

    def adopt(self, slot: int, block_ids) -> None:
        """Attach existing (prefix) blocks to ``slot`` — shared, read-
        only reuse: each block gains a reference and fills the next
        table columns. Must precede ``ensure`` for the slot (prefix
        blocks come first in position order)."""
        owned = self._owned[slot]
        assert not owned, f"adopt into non-empty slot {slot}"
        for blk in block_ids:
            blk = int(blk)
            assert self._ref[blk] > 0, f"adopting dead block {blk}"
            self._ref[blk] += 1
            self.tables_np[slot, len(owned)] = blk
            owned.append(blk)
        if owned:
            self._dirty = True

    def make_writable(self, slot: int, pos: int,
                      n_tokens: int = 1) -> tuple[list[int], list[int]]:
        """Copy-on-write: ensure the blocks covering writes at positions
        ``[pos, pos + n_tokens)`` are exclusively owned by ``slot``.

        Shared blocks (refcount > 1) in the write range are replaced by
        fresh allocations; returns the ``(src, dst)`` block-id pairs the
        caller must copy on device (:func:`copy_blocks`) before writing.
        Under the engine's block-aligned prefix sharing a decode write
        never lands in a shared block (prefixes are whole blocks and
        writes start at ``prompt_len > prefix_len``), so this is the
        safety net that makes divergent writes *correct* rather than a
        hot path."""
        bs = self.block_size
        owned = self._owned[slot]
        src: list[int] = []
        dst: list[int] = []
        for bi in range(pos // bs, (pos + n_tokens - 1) // bs + 1):
            assert bi < len(owned), (slot, pos, n_tokens, len(owned))
            old = owned[bi]
            if self._ref[old] <= 1:
                continue
            if not self._free and not self._reclaim_prefix_block():
                raise CacheOOM(
                    f"paged pool exhausted during copy-on-write for slot "
                    f"{slot} block {bi}")
            new = self._free.pop()
            self._ref[new] = 1
            self._ref[old] -= 1
            owned[bi] = new
            self.tables_np[slot, bi] = new
            self._dirty = True
            src.append(old)
            dst.append(new)
        return src, dst

    # -- prefix caching --------------------------------------------------
    @property
    def reclaimable_blocks(self) -> int:
        """Blocks held ONLY by the prefix index — evictable on demand,
        so admission control may treat them as headroom."""
        if self._prefix is None:
            return 0
        return sum(1 for blk in self._prefix.blocks() if self._ref[blk] == 1)

    @property
    def available_blocks(self) -> int:
        """Free blocks plus index-only (reclaimable) blocks: the figure
        admission control must budget against — counting only
        ``free_blocks`` would let a fully-pinned index defer the queue
        head forever even though ``ensure`` can always reclaim."""
        return len(self._free) + self.reclaimable_blocks

    def enable_prefix_cache(self) -> None:
        if self._prefix is None:
            self._prefix = PrefixIndex(self.block_size)

    @property
    def prefix_index(self) -> Optional[PrefixIndex]:
        return self._prefix

    def prefix_match(self, tokens) -> list[int]:
        """Longest cached full-block chain prefixing ``tokens``, capped
        at ``len(tokens) - 1`` tokens so at least one suffix token
        remains to prefill. Returns physical block ids for ``adopt``."""
        if self._prefix is None:
            return []
        return self._prefix.match(tokens, max_tokens=len(tokens) - 1)

    def prefix_register(self, slot: int, tokens) -> int:
        """Index ``slot``'s full prompt blocks for future sharing; each
        newly indexed block gains the index's pin reference. Returns the
        number of blocks newly registered."""
        if self._prefix is None:
            return 0
        n_full = len(tokens) // self.block_size
        new = self._prefix.register(tokens, self._owned[slot][:n_full])
        for blk in new:
            self._ref[blk] += 1
        return len(new)

    def _reclaim_prefix_block(self) -> bool:
        """Evict LRU leaf index entries until one block actually returns
        to the free list (an evicted entry's block may still be shared
        with a live slot). False when the index has nothing left."""
        if self._prefix is None:
            return False
        while True:
            e = self._prefix.pop_lru_leaf()
            if e is None:
                return False
            self._ref[e.block] -= 1
            if self._ref[e.block] == 0:
                self._free.append(e.block)
                return True

    def clear_prefix(self) -> None:
        """Drop every index entry (unpin; free refcount-zero blocks) —
        the engine's between-runs reset so measured cells start cold."""
        if self._prefix is None:
            return
        for blk in self._prefix.pop_all():
            self._ref[blk] -= 1
            if self._ref[blk] == 0:
                self._free.append(blk)

    def block_ids(self, slot: int, n_tokens: int) -> np.ndarray:
        """(ceil(n_tokens/bs),) physical ids covering [0, n_tokens)."""
        need = -(-n_tokens // self.block_size)
        assert len(self._owned[slot]) >= need, (slot, n_tokens)
        return self.tables_np[slot, :need].copy()

    # -- device views ----------------------------------------------------
    def device_tables(self) -> jax.Array:
        if self._dirty:
            self._tables = jnp.asarray(self.tables_np)
            self._dirty = False
        return self._tables
