"""Result tables and heatmaps (JUBE's `jube result` analog)."""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
from typing import Any, Iterable, Optional

from repro.power.frame import Frame


def table(records: list[dict], columns: Optional[list[str]] = None,
          floatfmt: str = "{:.2f}") -> str:
    """Markdown table from records."""
    if not records:
        return "(no results)\n"
    cols = columns or list(records[0].keys())

    def fmt(v):
        if isinstance(v, float):
            return floatfmt.format(v)
        return str(v)

    widths = {c: max(len(c), *(len(fmt(r.get(c, ""))) for r in records))
              for c in cols}
    head = "| " + " | ".join(c.ljust(widths[c]) for c in cols) + " |"
    sep = "|" + "|".join("-" * (widths[c] + 2) for c in cols) + "|"
    rows = ["| " + " | ".join(fmt(r.get(c, "")).rjust(widths[c]) for c in cols)
            + " |" for r in records]
    return "\n".join([head, sep, *rows]) + "\n"


def heatmap(records: list[dict], row_key: str, col_key: str, val_key: str,
            floatfmt: str = "{:.0f}") -> str:
    """ASCII heatmap (the paper's Fig. 4: dp x batch-size throughput)."""
    rows = sorted({r[row_key] for r in records})
    cols = sorted({r[col_key] for r in records})
    lookup = {(r[row_key], r[col_key]): r.get(val_key) for r in records}
    w = max(8, max(len(str(cv)) for cv in cols) + 2)
    out = [f"{row_key}\\{col_key}".ljust(12)
           + "".join(str(cv).rjust(w) for cv in cols)]
    for rv in rows:
        line = str(rv).ljust(12)
        for cv in cols:
            v = lookup.get((rv, cv))
            if v is None:
                line += "OOM".rjust(w)  # the paper marks infeasible as OOM
            else:
                line += floatfmt.format(v).rjust(w)
        out.append(line)
    return "\n".join(out) + "\n"


def atomic_write_text(path, text: str):
    """Write ``text`` to ``path`` via tmp file + ``os.replace``.

    ``save_results`` is called after every benchmark point; a plain
    ``write_text`` interrupted mid-write (ctrl-C, OOM kill) truncates the
    results of every point that already completed. ``os.replace`` is
    atomic on POSIX, so readers see either the old or the new file.
    """
    path = pathlib.Path(path)
    fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=f".{path.name}.")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def save_results(records: list[dict], out_dir, name: str):
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    atomic_write_text(out / f"{name}.json",
                      json.dumps(records, indent=1, default=str))
    atomic_write_text(out / f"{name}.csv", Frame.from_records(records).to_csv())
