"""Parameter-space expansion with constraints (JUBE's parameter sets).

A ``Space`` is a dict of axis-name -> list of values; ``expand`` yields the
cartesian product, filtered by constraints (e.g. the paper's
"global batch not divisible by micro_batch x dp" exclusion) and selected by
tags, like JUBE's tag system.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional


@dataclass
class Space:
    axes: dict[str, list]
    constraints: list[Callable[[dict], bool]] = field(default_factory=list)

    def expand(self) -> list[dict]:
        names = list(self.axes)
        out = []
        for combo in itertools.product(*(self.axes[n] for n in names)):
            pt = dict(zip(names, combo))
            if all(c(pt) for c in self.constraints):
                out.append(pt)
        return out

    def __len__(self) -> int:
        return len(self.expand())


def divisible_batch(pt: dict) -> bool:
    """The paper's constraint: global_batch % (micro_batch * dp) == 0."""
    gb = pt.get("global_batch", 0)
    mb = pt.get("micro_batch", 1)
    dp = pt.get("dp", 1)
    return gb % max(mb * dp, 1) == 0


def batch_at_least_dp(pt: dict) -> bool:
    return pt.get("global_batch", 1) >= pt.get("dp", 1)
