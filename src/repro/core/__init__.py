"""CARAML core: the paper's primary contribution — a compact, automated,
reproducible benchmark harness (JUBE analog) with jpwr-style energy
measurement. Substrate subsystems live in sibling subpackages."""
from repro.core.metrics import Throughput, images_per_s, mfu, tokens_per_s
from repro.core.params import Space, batch_at_least_dp, divisible_batch
from repro.core.results import heatmap, save_results, table
from repro.core.runner import Runner, StragglerWatchdog
from repro.core.suite import BenchmarkSuite, Step

__all__ = [
    "Throughput", "images_per_s", "mfu", "tokens_per_s", "Space",
    "batch_at_least_dp", "divisible_batch", "heatmap", "save_results",
    "table", "Runner", "StragglerWatchdog", "BenchmarkSuite", "Step",
]
