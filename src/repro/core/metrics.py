"""Figures of merit (the paper's metrics, Section IV) + serving energy.

  tokens/s  = global_batch * seq_len / iteration_time     (LLM)
  images/s  = global_batch / iteration_time               (ResNet50)
  tokens/Wh, images/Wh — energy-efficiency metrics
  MFU       = model_flops / (time * chips * peak)

Serving extensions (MLPerf-Power style, arXiv:2410.12032): the serve
engine records per-step windows (``StepRecord``) plus synchronous power
samples; ``attribute_energy`` integrates the sampled power over each
step window and splits it across the requests that received tokens in
that window, yielding Wh/token and Wh/request per served request.
"""
from __future__ import annotations

import bisect
import math
from dataclasses import dataclass
from typing import Optional, Sequence

from repro.roofline.analysis import PEAK_FLOPS_BF16

J_PER_WH = 3600.0


@dataclass
class Throughput:
    name: str
    items_per_s: float          # tokens/s or images/s
    unit: str                   # "tokens" | "images"
    iter_time_s: float
    energy_wh: float = 0.0      # total energy over the measured window
    duration_s: float = 0.0

    @property
    def items_per_wh(self) -> float:
        if self.energy_wh <= 0:
            return 0.0
        return self.items_per_s * self.duration_s / self.energy_wh


def tokens_per_s(global_batch: int, seq_len: int, iter_time_s: float) -> float:
    return global_batch * seq_len / max(iter_time_s, 1e-12)


def tokens_per_s_ipu(global_batch_tokens: int, iter_time_s: float) -> float:
    """Graphcore variant: global_batch given in tokens (paper Sec III-A1)."""
    return global_batch_tokens / max(iter_time_s, 1e-12)


def images_per_s(global_batch: int, iter_time_s: float) -> float:
    return global_batch / max(iter_time_s, 1e-12)


def mfu(model_flops_per_step: float, iter_time_s: float, n_chips: int,
        peak: float = PEAK_FLOPS_BF16) -> float:
    return model_flops_per_step / (max(iter_time_s, 1e-12) * n_chips * peak)


def percentile(xs: Sequence[float], q: float) -> float:
    """Nearest-rank percentile (``q`` in [0, 100]); 0.0 for an empty
    sequence. The single quantile rule shared by the serve summary and
    the SLO layer, so p95/p99 figures agree across reports.

    Nearest-rank: the q-th percentile of n samples is the value at rank
    ``ceil(q/100 * n)`` (1-indexed), i.e. the smallest sample with at
    least q percent of the data at or below it — p50 of [1,2,3,4] is 2,
    p100 is the max, p0 clamps to the min. The rank is snapped to the
    nearest integer before the ceil so exact-multiple ranks (q=25 of
    n=4 -> rank 1.0000000000000002 in floats) don't round up a bucket.
    """
    xs = sorted(xs)
    if not xs:
        return 0.0
    r = q / 100.0 * len(xs)
    if abs(r - round(r)) < 1e-9:
        r = round(r)
    i = min(max(math.ceil(r) - 1, 0), len(xs) - 1)
    return xs[i]


# ---------------------------------------------------------------------------
# Serving energy attribution
# ---------------------------------------------------------------------------


def _power_at(ts: Sequence[float], ws: Sequence[float], t: float) -> float:
    """Linear interpolation of sampled power at time t (clamped ends)."""
    if t <= ts[0]:
        return ws[0]
    if t >= ts[-1]:
        return ws[-1]
    i = bisect.bisect_right(ts, t)
    t0, t1 = ts[i - 1], ts[i]
    w0, w1 = ws[i - 1], ws[i]
    if t1 == t0:
        return w1
    return w0 + (w1 - w0) * (t - t0) / (t1 - t0)


def window_energy_wh(ts: Sequence[float], ws: Sequence[float],
                     t0: float, t1: float) -> float:
    """Trapezoid-integrate sampled power (watts) over [t0, t1] -> Wh.

    Exact for piecewise-linear P(t) whose breakpoints are sample times —
    which is what the serve engine produces by sampling synchronously at
    every step boundary (and what the triangle-wave test asserts).
    """
    if t1 <= t0 or len(ts) == 0:
        return 0.0
    if len(ts) == 1:
        return ws[0] * (t1 - t0) / J_PER_WH
    # integration nodes: window ends + interior sample times
    lo = bisect.bisect_right(ts, t0)
    hi = bisect.bisect_left(ts, t1)
    nodes = [t0] + list(ts[lo:hi]) + [t1]
    vals = [_power_at(ts, ws, t) for t in nodes]
    joules = sum(0.5 * (vals[i] + vals[i - 1]) * (nodes[i] - nodes[i - 1])
                 for i in range(1, len(nodes)))
    return joules / J_PER_WH


def attribute_energy(steps, ts: Sequence[float],
                     ws: Sequence[float]) -> dict:
    """Per-request energy (Wh) from step windows + power samples.

    ``steps``: iterable of records with ``t0``, ``t1`` and ``rids`` (the
    requests that received one token each in that window) — the serve
    scheduler's ``StepRecord``. Each window's energy splits equally
    across its rids (every rid gains exactly one token per window, both
    for decode steps and for the single-request prefill window).

    Energy outside any step window (queue idle, scheduler overhead) is
    deliberately unattributed: it is reported by the engine as
    ``overhead_wh`` so the per-request figures stay marginal costs.
    """
    out: dict = {}
    for s in steps:
        if not s.rids:
            continue
        share = window_energy_wh(ts, ws, s.t0, s.t1) / len(s.rids)
        for rid in s.rids:
            out[rid] = out.get(rid, 0.0) + share
    return out


@dataclass
class ServeSummary:
    """Aggregate serving figures of merit over one engine run."""

    n_requests: int
    n_tokens: int               # generated tokens (all requests)
    wall_s: float               # first admission -> last finish
    decode_s: float             # sum of decode step windows
    prefill_s: float            # sum of prefill windows
    total_energy_wh: float      # integrated over the whole run
    attributed_wh: float        # sum of per-request attributions
    mean_ttft_s: float
    p95_ttft_s: float
    #: mean decode-step batch occupancy: active slots / n_slots averaged
    #: over decode micro-steps. The scheduler-health figure — continuous
    #: refill should hold it near 1.0 under load while the fixed-batch
    #: barrier decays toward mean(batch)/max(batch); a regression here
    #: is a scheduling bug even when throughput noise masks it.
    mean_occupancy: float = 0.0

    @property
    def decode_tok_s(self) -> float:
        """Useful generated tokens per second of wall time."""
        return self.n_tokens / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def wh_per_token(self) -> float:
        return (self.attributed_wh / self.n_tokens) if self.n_tokens else 0.0

    @property
    def wh_per_request(self) -> float:
        return (self.attributed_wh / self.n_requests) if self.n_requests \
            else 0.0

    @property
    def overhead_wh(self) -> float:
        """Energy burned outside prefill/decode windows (idle, host)."""
        return max(self.total_energy_wh - self.attributed_wh, 0.0)


def serve_summary(results, steps, ts, ws,
                  n_slots: Optional[int] = None) -> ServeSummary:
    """Build the aggregate summary from per-request results + step log.

    ``n_slots`` enables the occupancy figure: each decode window credits
    one token per active slot per fused micro-step (``n_steps``), so
    mean per-step occupancy is total decode tokens over
    ``n_slots * total micro-steps``.
    """
    results = list(results)
    ttfts = sorted(r.ttft_s for r in results) or [0.0]
    wall = (max(r.finish_s for r in results)
            - min(r.admitted_s for r in results)) if results else 0.0
    total = window_energy_wh(ts, ws, ts[0], ts[-1]) if len(ts) > 1 else 0.0
    decode = [s for s in steps if s.kind == "decode"]
    micro = sum(getattr(s, "n_steps", 1) for s in decode)
    occupancy = (sum(s.n_tokens for s in decode) / (n_slots * micro)
                 if n_slots and micro else 0.0)
    return ServeSummary(
        n_requests=len(results),
        n_tokens=sum(r.n_tokens for r in results),
        wall_s=wall,
        decode_s=sum(s.duration_s for s in decode),
        prefill_s=sum(s.duration_s for s in steps if s.kind == "prefill"),
        total_energy_wh=total,
        attributed_wh=sum(r.energy_wh for r in results),
        mean_ttft_s=sum(ttfts) / len(ttfts),
        p95_ttft_s=percentile(ttfts, 95.0),
        mean_occupancy=occupancy,
    )
