"""Figures of merit (the paper's metrics, Section IV).

  tokens/s  = global_batch * seq_len / iteration_time     (LLM)
  images/s  = global_batch / iteration_time               (ResNet50)
  tokens/Wh, images/Wh — energy-efficiency metrics
  MFU       = model_flops / (time * chips * peak)
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.roofline.analysis import PEAK_FLOPS_BF16


@dataclass
class Throughput:
    name: str
    items_per_s: float          # tokens/s or images/s
    unit: str                   # "tokens" | "images"
    iter_time_s: float
    energy_wh: float = 0.0      # total energy over the measured window
    duration_s: float = 0.0

    @property
    def items_per_wh(self) -> float:
        if self.energy_wh <= 0:
            return 0.0
        return self.items_per_s * self.duration_s / self.energy_wh


def tokens_per_s(global_batch: int, seq_len: int, iter_time_s: float) -> float:
    return global_batch * seq_len / max(iter_time_s, 1e-12)


def tokens_per_s_ipu(global_batch_tokens: int, iter_time_s: float) -> float:
    """Graphcore variant: global_batch given in tokens (paper Sec III-A1)."""
    return global_batch_tokens / max(iter_time_s, 1e-12)


def images_per_s(global_batch: int, iter_time_s: float) -> float:
    return global_batch / max(iter_time_s, 1e-12)


def mfu(model_flops_per_step: float, iter_time_s: float, n_chips: int,
        peak: float = PEAK_FLOPS_BF16) -> float:
    return model_flops_per_step / (max(iter_time_s, 1e-12) * n_chips * peak)
