"""Reproducibility manifest — CARAML's automation records exactly what ran."""
from __future__ import annotations

import functools
import json
import os
import pathlib
import platform
import subprocess
import sys
import time


@functools.lru_cache(maxsize=1)
def git_sha() -> str | None:
    """Commit of the tree being benchmarked, or None outside a checkout.

    Stamped into every manifest and ResultRecord so cross-run comparison
    can always answer *which code* produced each side of a delta.
    """
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=5, cwd=pathlib.Path(__file__).resolve().parent)
    except (OSError, subprocess.SubprocessError):
        return None
    sha = proc.stdout.strip()
    return sha if proc.returncode == 0 and sha else None


def build_manifest(extra: dict | None = None) -> dict:
    import jax
    m = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "git_sha": git_sha(),
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "platform": platform.platform(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "argv": sys.argv,
    }
    if extra:
        m.update(extra)
    return m


def write_manifest(out_dir, extra: dict | None = None) -> dict:
    m = build_manifest(extra)
    p = pathlib.Path(out_dir)
    p.mkdir(parents=True, exist_ok=True)
    (p / "manifest.json").write_text(json.dumps(m, indent=1, default=str))
    return m
