"""Reproducibility manifest — CARAML's automation records exactly what ran."""
from __future__ import annotations

import json
import os
import pathlib
import platform
import sys
import time


def build_manifest(extra: dict | None = None) -> dict:
    import jax
    m = {
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "python": sys.version.split()[0],
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "n_devices": jax.device_count(),
        "platform": platform.platform(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "argv": sys.argv,
    }
    if extra:
        m.update(extra)
    return m


def write_manifest(out_dir, extra: dict | None = None) -> dict:
    m = build_manifest(extra)
    p = pathlib.Path(out_dir)
    p.mkdir(parents=True, exist_ok=True)
    (p / "manifest.json").write_text(json.dumps(m, indent=1, default=str))
    return m
