"""Suite runner: executes benchmark points with power measurement,
retries, straggler detection, and incremental result persistence.

This is the JUBE runtime analog: it expands the parameter space, runs each
(point x step), wraps execution in the jpwr-style get_power context, and
renders the final result table.

``repro.bench.runner.WorkloadRunner`` builds on the same retry machinery
(`run_attempts`) to execute declarative ``WorkloadSpec`` workloads.
"""
from __future__ import annotations

import json
import logging
import pathlib
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

from repro.core.manifest import write_manifest
from repro.core.results import save_results, table
from repro.core.suite import BenchmarkSuite, Step
from repro.power.ctxmgr import get_power
from repro.power.methods import PowerMethod

logger = logging.getLogger("repro.bench")


@dataclass
class StragglerWatchdog:
    """EWMA step-time monitor: flags steps slower than mean + k*std.

    At cluster scale this drives the mitigation policy (skip shard /
    checkpoint-and-rebalance); here it records events for the report and
    is unit-tested with simulated stragglers.

    Warmup samples seed both the mean AND the variance: judging the first
    post-warmup step against a zero-variance baseline would flag ordinary
    jitter whenever the warmup steps happened to disagree.
    """
    k: float = 3.0
    alpha: float = 0.2
    warmup: int = 3
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    events: list = field(default_factory=list)
    _warmup_m2: float = 0.0     # Welford sum of squared deviations

    def observe(self, step_idx: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            if self.n == 1:
                self.mean = dt
            else:
                delta = dt - self.mean
                self.mean += delta / self.n
                self._warmup_m2 += delta * (dt - self.mean)
                # sample variance of the warmup window so far
                self.var = self._warmup_m2 / (self.n - 1)
            return False
        straggler = dt > self.mean + self.k * max(self.var ** 0.5,
                                                  0.05 * self.mean)
        delta = dt - self.mean
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        if straggler:
            self.events.append({"step": step_idx, "dt": dt,
                                "mean": self.mean})
        return straggler

    def rel_std(self) -> float:
        """Observed relative step-time spread (std/mean), 0.0 until the
        warmup window has produced a variance estimate.

        This is the noise figure the cross-run comparison engine widens
        its per-metric tolerance by: a run whose own step times wobbled
        10% cannot support a 5% regression verdict.
        """
        if self.n < 2 or self.mean <= 0.0:
            return 0.0
        return max(self.var, 0.0) ** 0.5 / self.mean


#: exception types that retrying cannot fix: bad arguments/config, not
#: transient runtime conditions. Fail fast so a typo'd sweep doesn't
#: burn its retry budget per point.
_FATAL_TYPES = (ValueError, TypeError, KeyError, AssertionError)
#: transient-by-name: serve's CacheOOM is retryable but core must not
#: import serve (layering), so classify by class name.
_TRANSIENT_NAMES = ("CacheOOM",)


def classify_error(e: BaseException) -> bool:
    """True if ``e`` is worth retrying. An explicit ``transient``
    attribute (e.g. on injected faults) wins; then known-transient
    names; then known-fatal types; everything else is retried (the
    legacy default — an unknown crash may well be environmental)."""
    t = getattr(e, "transient", None)
    if t is not None:
        return bool(t)
    if type(e).__name__ in _TRANSIENT_NAMES:
        return True
    return not isinstance(e, _FATAL_TYPES)


@dataclass
class AttemptInfo:
    """How an attempted step actually went: attempts used, total backoff
    slept, and whether the final error was classified fatal."""
    attempts: int = 1
    backoff_s: float = 0.0
    fatal: bool = False


def run_attempts(name: str, fn: Callable[[], dict], retries: int,
                 *, log_prefix: str = "",
                 backoff_base: float = 0.0,
                 backoff_factor: float = 2.0,
                 backoff_max: float = 2.0,
                 jitter: float = 0.25,
                 seed: int = 0,
                 sleep_fn: Callable[[float], None] = time.sleep,
                 classify: Callable[[BaseException], bool] = classify_error):
    """Run ``fn`` up to ``retries`` times with exponential backoff.

    Returns ``(ok, metrics, info)`` where ``info`` is an
    :class:`AttemptInfo`. Errors the ``classify`` predicate calls fatal
    (``ValueError`` and friends) fail fast — no further attempts;
    transient ones (``CacheOOM``, injected faults) are retried after
    ``min(backoff_max, backoff_base * backoff_factor**(k-1))`` seconds
    scaled by ``1 + jitter*U[0,1)`` (seeded, so sweeps are
    reproducible; ``backoff_base=0`` keeps the legacy no-sleep
    behavior). Every failed attempt is logged (message + traceback at
    debug level); on exhaustion the last exception is summarized in
    the returned metrics.
    """
    import random as _random
    last_err: Optional[BaseException] = None
    retries = max(retries, 1)
    rng = _random.Random(seed)
    info = AttemptInfo()
    for attempt in range(1, retries + 1):
        info.attempts = attempt
        try:
            return True, fn(), info
        except Exception as e:  # noqa: BLE001 - benchmark must continue
            last_err = e
            transient = classify(e)
            logger.warning("%sstep %r attempt %d/%d failed (%s): %s: %s",
                           log_prefix, name, attempt, retries,
                           "transient" if transient else "fatal",
                           type(e).__name__, e)
            logger.debug("%sstep %r attempt %d traceback:\n%s",
                         log_prefix, name, attempt,
                         traceback.format_exc())
            if not transient:
                info.fatal = True
                break
            if attempt < retries and backoff_base > 0.0:
                delay = min(backoff_max,
                            backoff_base * backoff_factor ** (attempt - 1))
                delay *= 1.0 + jitter * rng.random()
                sleep_fn(delay)
                info.backoff_s += delay
    return False, {f"{name}_error":
                   f"{type(last_err).__name__}: {last_err}"}, info


class Runner:
    def __init__(self, suite: BenchmarkSuite, *,
                 power_methods: Sequence[PowerMethod] = (),
                 out_dir: str = "artifacts/bench",
                 tags: Optional[set] = None,
                 power_interval_ms: float = 50.0):
        self.suite = suite
        self.power_methods = list(power_methods)
        self.out = pathlib.Path(out_dir) / suite.name
        self.tags = tags
        self.power_interval_ms = power_interval_ms
        self.records: list[dict] = []

    def run(self, verbose: bool = True) -> list[dict]:
        self.out.mkdir(parents=True, exist_ok=True)
        write_manifest(self.out, {"suite": self.suite.name})
        steps = self.suite.select_steps(self.tags)
        points = self.suite.points()
        for i, pt in enumerate(points):
            context: dict = {"out_dir": str(self.out)}
            rec = dict(pt)
            for step in steps:
                ok, metrics = self._run_step(step, pt, context)
                rec.update(metrics)
                if not ok:
                    break
            self.records.append(rec)
            if verbose:
                print(f"[{self.suite.name}] {i + 1}/{len(points)} {rec}")
            save_results(self.records, self.out, "results")
        return self.records

    def _run_step(self, step: Step, pt: dict, context: dict):
        def attempt():
            if self.power_methods:
                with get_power(self.power_methods,
                               self.power_interval_ms) as scope:
                    metrics = step.fn(pt, context)
                edf, _ = scope.energy()
                metrics[f"{step.name}_energy_wh"] = float(
                    sum(edf.col("energy_wh")))
            else:
                metrics = step.fn(pt, context)
            return metrics

        ok, metrics, info = run_attempts(
            step.name, attempt, step.retries,
            log_prefix=f"[{self.suite.name}] ")
        metrics[f"{step.name}_attempts"] = info.attempts
        return ok, metrics

    def result_table(self) -> str:
        return table(self.records, self.suite.result_columns)
