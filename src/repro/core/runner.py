"""Suite runner: executes benchmark points with power measurement,
retries, straggler detection, and incremental result persistence.

This is the JUBE runtime analog: it expands the parameter space, runs each
(point x step), wraps execution in the jpwr-style get_power context, and
renders the final result table.
"""
from __future__ import annotations

import json
import pathlib
import time
import traceback
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

from repro.core.manifest import write_manifest
from repro.core.results import save_results, table
from repro.core.suite import BenchmarkSuite, Step
from repro.power.ctxmgr import get_power
from repro.power.methods import PowerMethod


@dataclass
class StragglerWatchdog:
    """EWMA step-time monitor: flags steps slower than mean + k*std.

    At cluster scale this drives the mitigation policy (skip shard /
    checkpoint-and-rebalance); here it records events for the report and
    is unit-tested with simulated stragglers.
    """
    k: float = 3.0
    alpha: float = 0.2
    warmup: int = 3
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    events: list = field(default_factory=list)

    def observe(self, step_idx: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            self.mean = dt if self.n == 1 else (
                self.mean + (dt - self.mean) / self.n)
            return False
        straggler = dt > self.mean + self.k * max(self.var ** 0.5,
                                                  0.05 * self.mean)
        delta = dt - self.mean
        self.mean += self.alpha * delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        if straggler:
            self.events.append({"step": step_idx, "dt": dt,
                                "mean": self.mean})
        return straggler


class Runner:
    def __init__(self, suite: BenchmarkSuite, *,
                 power_methods: Sequence[PowerMethod] = (),
                 out_dir: str = "artifacts/bench",
                 tags: Optional[set] = None,
                 power_interval_ms: float = 50.0):
        self.suite = suite
        self.power_methods = list(power_methods)
        self.out = pathlib.Path(out_dir) / suite.name
        self.tags = tags
        self.power_interval_ms = power_interval_ms
        self.records: list[dict] = []

    def run(self, verbose: bool = True) -> list[dict]:
        self.out.mkdir(parents=True, exist_ok=True)
        write_manifest(self.out, {"suite": self.suite.name})
        steps = self.suite.select_steps(self.tags)
        points = self.suite.points()
        for i, pt in enumerate(points):
            context: dict = {"out_dir": str(self.out)}
            rec = dict(pt)
            for step in steps:
                ok, metrics = self._run_step(step, pt, context)
                rec.update(metrics)
                if not ok:
                    break
            self.records.append(rec)
            if verbose:
                print(f"[{self.suite.name}] {i + 1}/{len(points)} {rec}")
            save_results(self.records, self.out, "results")
        return self.records

    def _run_step(self, step: Step, pt: dict, context: dict):
        last_err = None
        for attempt in range(step.retries):
            try:
                if self.power_methods:
                    with get_power(self.power_methods,
                                   self.power_interval_ms) as scope:
                        metrics = step.fn(pt, context)
                    edf, _ = scope.energy()
                    metrics[f"{step.name}_energy_wh"] = float(
                        sum(edf.col("energy_wh")))
                else:
                    metrics = step.fn(pt, context)
                return True, metrics
            except Exception as e:  # noqa: BLE001 - benchmark must continue
                last_err = e
        return False, {f"{step.name}_error":
                       f"{type(last_err).__name__}: {last_err}"}

    def result_table(self) -> str:
        return table(self.records, self.suite.result_columns)
