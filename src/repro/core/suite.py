"""BenchmarkSuite — the CARAML/JUBE automation layer.

A suite is a declarative benchmark description: a parameter Space, a set of
steps (setup -> run -> postprocess), tags for selecting subsets, and a
result specification. ``Runner`` (repro.core.runner) executes it with power
measurement, retries, and straggler detection, then renders result tables —
the whole jube run/continue/result flow in one python object.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core.params import Space


@dataclass
class Step:
    """One benchmark step. ``fn(point, context) -> dict`` returns metrics."""
    name: str
    fn: Callable[[dict, dict], dict]
    tags: frozenset = frozenset()
    retries: int = 1


@dataclass
class BenchmarkSuite:
    name: str
    space: Space
    steps: list[Step]
    tags: frozenset = frozenset()
    result_columns: Optional[list[str]] = None

    def select_steps(self, tags: Optional[set] = None) -> list[Step]:
        if not tags:
            return self.steps
        return [s for s in self.steps if not s.tags or s.tags & tags]

    def points(self) -> list[dict]:
        return self.space.expand()
