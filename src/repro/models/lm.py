"""Top-level language models: decoder-only (dense/moe/ssm/hybrid/vlm) and
encoder-decoder (whisper backbone). Pure functions of (config, params).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import blocks
from repro.models.common import (
    apply_norm, dtype_of, embed_tokens, embedding_init, norm_init, unembed,
)

Params = Any


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init(key, c: ModelConfig) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "embed": embedding_init(k1, c),
        "layers": blocks.stack_init(k2, c, cross=c.family == "encdec"),
        "final_norm": norm_init(c),
    }
    if c.family == "encdec":
        p["encoder"] = {
            "layers": blocks.enc_stack_init(k3, c),
            "norm": norm_init(c),
            # learned positions for encoder frames
            "pos": (jax.random.normal(k4, (c.enc_seq, c.d_model), jnp.float32)
                    * 0.02).astype(jnp.dtype(c.param_dtype)),
        }
    return p


def init_abstract(c: ModelConfig) -> Params:
    """Shape-only params (no allocation) — used by the dry-run."""
    return jax.eval_shape(lambda k: init(k, c), jax.random.key(0))


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------


def _inputs_to_embeds(c: ModelConfig, p: Params, tokens: jax.Array,
                      patch_embeds: Optional[jax.Array],
                      pos_offset: int = 0) -> jax.Array:
    b, s_text = tokens.shape
    positions = jnp.arange(s_text)[None, :] + pos_offset
    x = embed_tokens(c, p["embed"], tokens, positions)
    if c.family == "vlm" and patch_embeds is not None:
        x = jnp.concatenate([patch_embeds.astype(x.dtype), x], axis=1)
    return x


def encode(c: ModelConfig, p: Params, frames: jax.Array,
           unroll: bool = False):
    """Whisper-backbone encoder over precomputed frame embeddings.

    frames: (B, T_enc, D) — the conv frontend is a stub (precomputed).
    Returns encoder output and stacked per-decoder-layer cross K/V.
    """
    enc = p["encoder"]
    x = frames.astype(dtype_of(c)) + enc["pos"][None].astype(dtype_of(c))

    def body(x, layer):
        h = apply_norm(c, layer["norm1"], x)
        x = x + attn.self_attention(c, layer["attn"], h, causal=False)
        from repro.models.common import apply_mlp
        x = x + apply_mlp(c, layer["mlp"], apply_norm(c, layer["norm2"], x))
        return x, None

    # remat: without it the backward saves every encoder layer's O(T^2)
    # softmax internals (measured 15+ GiB on whisper train_4k)
    x, _ = jax.lax.scan(jax.checkpoint(body, policy=None), x, enc["layers"],
                        unroll=unroll)
    enc_out = apply_norm(c, enc["norm"], x)

    # Per-decoder-layer cross-attention K/V (stacked like the layer params)
    def kv_body(_, period_params):
        ekv = {}
        for i in range(blocks.period_of(c)):
            sp = period_params[f"slot{i}"]
            k, v = attn.encoder_kv(c, sp["cross"], enc_out)
            ekv[f"slot{i}"] = {"k": k, "v": v}
        return None, ekv

    _, enc_kv = jax.lax.scan(kv_body, None, p["layers"], unroll=unroll)
    return enc_out, enc_kv


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------


def forward(c: ModelConfig, p: Params, tokens: jax.Array, *,
            patch_embeds: Optional[jax.Array] = None,
            enc_frames: Optional[jax.Array] = None,
            impl: str = "repeat", remat: str = "full", unroll: bool = False):
    """Full causal forward. Returns (logits, aux_loss).

    vlm:    logits cover only the text positions (patches are prefix).
    encdec: enc_frames (B, T_enc, D) must be provided.
    """
    x = _inputs_to_embeds(c, p, tokens, patch_embeds)
    enc_kv = None
    if c.family == "encdec":
        assert enc_frames is not None
        _, enc_kv = encode(c, p, enc_frames, unroll=unroll)
    x, aux = blocks.stack_forward(c, p["layers"], x, causal=True, impl=impl,
                                  remat=remat, enc_kv_stacked=enc_kv,
                                  unroll=unroll)
    x = apply_norm(c, p["final_norm"], x)
    if c.family == "vlm" and patch_embeds is not None:
        x = x[:, patch_embeds.shape[1]:]
    logits = unembed(c, p["embed"], x)
    return logits, aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(c: ModelConfig, p: Params, tokens: jax.Array, *,
            patch_embeds: Optional[jax.Array] = None,
            enc_frames: Optional[jax.Array] = None, impl: str = "repeat",
            unroll: bool = False, last_pos: Optional[jax.Array] = None,
            prefix_kv: Params = None, pos_offset: int = 0,
            paged_prefix: Params = None,
            paged_tables: Optional[jax.Array] = None,
            paged_impl: str = "xla", paged_interpret: bool = False):
    """Process the prompt; return (last-position logits, caches, enc_kv).

    ``last_pos`` (B,) int32 overrides which position's logits are
    returned per row — the batched serve prefill right-pads prompts to a
    shared length bucket and reads each request's logits at its *true*
    last token (pad rows are never attended: causal masking hides them
    from real tokens, and decode overwrites them in place).

    ``prefix_kv`` + ``pos_offset`` select the prefix-cached *suffix*
    prefill: ``tokens`` holds only the suffix (global positions start at
    ``pos_offset``), ``prefix_kv`` is the stacked per-layer K/V of the
    cached ``pos_offset``-token prefix (see ``blocks.stack_prefill``),
    and the returned ``caches`` cover only the suffix.

    ``paged_prefix`` + ``paged_tables`` are the paged twin: the pool
    cache tree itself and the (B, npre) prefix block table — the prefix
    KV stays in the pool and attention walks the table via the paged
    prefill kernel (``paged_impl``/``paged_interpret`` select the
    xla-ref vs Pallas vs interpret dispatch).
    """
    assert (prefix_kv is None) or (paged_prefix is None)
    has_prefix = (prefix_kv is not None) or (paged_prefix is not None)
    assert has_prefix == (pos_offset > 0), (pos_offset,)
    x = _inputs_to_embeds(c, p, tokens, patch_embeds, pos_offset=pos_offset)
    enc_kv = None
    if c.family == "encdec":
        _, enc_kv = encode(c, p, enc_frames, unroll=unroll)
    positions = None
    if has_prefix:
        positions = jnp.arange(tokens.shape[1])[None, :] + pos_offset
    x, caches = blocks.stack_prefill(c, p["layers"], x, impl=impl,
                                     enc_kv_stacked=enc_kv,
                                     prefix_kv=prefix_kv,
                                     paged_prefix=paged_prefix,
                                     paged_tables=paged_tables,
                                     paged_impl=paged_impl,
                                     paged_interpret=paged_interpret,
                                     positions=positions, unroll=unroll)
    if last_pos is not None:
        x_last = jnp.take_along_axis(
            x, last_pos.astype(jnp.int32)[:, None, None], axis=1)
    else:
        x_last = x[:, -1:]
    x_last = apply_norm(c, p["final_norm"], x_last)
    logits = unembed(c, p["embed"], x_last)
    return logits, caches, enc_kv


def decode_step(c: ModelConfig, p: Params, token: jax.Array, caches: Params,
                pos: jax.Array, *, enc_kv: Params = None,
                impl: str = "grouped", unroll: bool = False,
                block_tables: Optional[jax.Array] = None,
                n_kv_blocks: Optional[int] = None,
                paged_impl: str = "xla", paged_interpret: bool = False):
    """token: (B, 1) int32; pos: scalar int32 OR per-row (B,) int32 (the
    continuous-batching engine decodes slots at independent positions).
    ``block_tables`` switches the attention layers onto the paged KV
    pool (see ``blocks.stack_decode``). Returns (logits, caches)."""
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None] if pos.ndim == 1 else jnp.full_like(token, pos)
    x = embed_tokens(c, p["embed"], token, positions)
    x, caches = blocks.stack_decode(c, p["layers"], x, caches, pos,
                                    impl=impl, enc_kv_stacked=enc_kv,
                                    unroll=unroll, block_tables=block_tables,
                                    n_kv_blocks=n_kv_blocks,
                                    paged_impl=paged_impl,
                                    paged_interpret=paged_interpret)
    x = apply_norm(c, p["final_norm"], x)
    logits = unembed(c, p["embed"], x)
    return logits, caches
