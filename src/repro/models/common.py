"""Shared layers: norms, activations, RoPE, MLP, embeddings, init helpers.

All models are pure functions ``apply(params, inputs) -> outputs`` over
nested-dict parameter pytrees. Initializers are plain functions of an rng
key so that ``jax.eval_shape`` can produce allocation-free abstract params
for the dry-run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Any  # nested dict of arrays


@jax.custom_jvp
def opt_barrier(x):
    """``optimization_barrier`` that differentiates as identity.

    jax.lax.optimization_barrier has no differentiation rule (through at
    least jax 0.4.x), so any barrier on the training forward path kills
    grad. The barrier only constrains XLA scheduling — mathematically it
    IS identity — so the tangent passes straight through unbarriered
    (a barriered tangent would need a transpose rule the primitive also
    lacks).
    """
    return jax.lax.optimization_barrier(x)


@opt_barrier.defjvp
def _opt_barrier_jvp(primals, tangents):
    (x,), (t,) = primals, tangents
    return opt_barrier(x), t


def dtype_of(c: ModelConfig):
    return jnp.dtype(c.dtype)


def param_dtype_of(c: ModelConfig):
    return jnp.dtype(c.param_dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_shape, dtype) -> jax.Array:
    """Variance-scaling (fan-in) init, matching Megatron's scaled init."""
    shape = (in_dim, *out_shape) if isinstance(out_shape, tuple) else (in_dim, out_shape)
    std = 1.0 / jnp.sqrt(jnp.asarray(in_dim, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_init(c: ModelConfig, dim: int | None = None) -> Params:
    dim = dim or c.d_model
    p = {"scale": jnp.ones((dim,), param_dtype_of(c))}
    if c.norm == "layernorm":
        p["bias"] = jnp.zeros((dim,), param_dtype_of(c))
    return p


def apply_norm(c: ModelConfig, p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    # barrier: stops XLA pulling this f32 cast back through the preceding
    # matmuls (it would convert whole stacked bf16 weights/caches to f32 and
    # hoist them out of the layer loop — measured 2x memory on 35B decode)
    x = opt_barrier(x)
    xf = x.astype(jnp.float32)
    if c.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)  # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_init(key, c: ModelConfig, d_ff: int) -> Params:
    pd = param_dtype_of(c)
    ks = jax.random.split(key, 3)
    if c.act == "swiglu":
        p = {
            "wi_gate": dense_init(ks[0], c.d_model, d_ff, pd),
            "wi_up": dense_init(ks[1], c.d_model, d_ff, pd),
            "wo": dense_init(ks[2], d_ff, c.d_model, pd),
        }
    else:
        p = {
            "wi": dense_init(ks[0], c.d_model, d_ff, pd),
            "wo": dense_init(ks[1], d_ff, c.d_model, pd),
        }
    if c.mlp_bias:
        p["bi"] = jnp.zeros((d_ff,), pd)
        p["bo"] = jnp.zeros((c.d_model,), pd)
    return p


def apply_mlp(c: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if c.act == "swiglu":
        g = x @ p["wi_gate"]
        u = x @ p["wi_up"]
        if "bi" in p:
            g = g + p["bi"]
        h = jax.nn.silu(g) * u
    else:
        h = x @ p["wi"]
        if "bi" in p:
            h = h + p["bi"]
        h = jax.nn.gelu(h)
    y = h @ p["wo"]
    if "bo" in p:
        y = y + p["bo"]
    return y


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embedding_init(key, c: ModelConfig) -> Params:
    pd = param_dtype_of(c)
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"tok": embed_init(k1, c.padded_vocab, c.d_model, pd)}
    if not c.tie_embeddings:
        p["head"] = embed_init(k2, c.padded_vocab, c.d_model, pd)
    if not c.use_rope:
        p["pos"] = embed_init(k3, c.max_position, c.d_model, pd)
    return p


def embed_tokens(c: ModelConfig, p: Params, tokens: jax.Array,
                 positions: jax.Array | None = None) -> jax.Array:
    x = jnp.take(p["tok"], tokens, axis=0).astype(dtype_of(c))
    if not c.use_rope and positions is not None:
        # gather keeps memory linear even for very long positions tables
        x = x + jnp.take(p["pos"], positions, axis=0).astype(dtype_of(c))
    return x


def unembed(c: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    table = p["tok"] if c.tie_embeddings else p["head"]
    logits = jnp.einsum("...d,vd->...v", x, table)
    if c.logits_softcap:
        logits = jnp.tanh(logits / c.logits_softcap) * c.logits_softcap
    # mask vocab padding so it never receives probability mass
    if c.padded_vocab != c.vocab:
        pad = c.padded_vocab - c.vocab
        mask = jnp.concatenate([
            jnp.zeros((c.vocab,), logits.dtype),
            jnp.full((pad,), jnp.finfo(jnp.float32).min, logits.dtype),
        ])
        logits = logits + mask
    return logits
