"""Attention: GQA / MHA, causal + windowed + cross, train/prefill/decode.

Two einsum formulations are provided, selected by the sharding plan:
  - "repeat":  KV heads repeated to H query heads; shards the H dim over the
               TP axis when ``n_heads % tp == 0`` (Megatron-style head TP).
  - "grouped": (Kh, G) grouped einsum; avoids materializing repeated KV and
               shards Kh when divisible, else replicates head compute.

The Pallas flash-attention kernel (repro.kernels) implements the same
contract for TPU; the XLA path here is the oracle and the dry-run path.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    apply_rope, dense_init, dtype_of, opt_barrier, param_dtype_of,
)

Params = Any
NEG_INF = float(jnp.finfo(jnp.float32).min)


# ---------------------------------------------------------------------------
# Sharding hints. GSPMD propagates layouts well in the forward pass but
# loses them inside remat (jax.checkpoint) recomputation in the backward
# while-loop — measured to replicate attention and all-reduce O(S*T) score
# tensors (EXPERIMENTS.md par.Perf). Explicit constraints on q/k/v/out pin
# the layout in both passes. The launch layer installs per-plan hints; with
# no hints installed (single-device tests) everything is a no-op.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AttnShardingHints:
    q_spec: Any = None        # (B, S, H, Dh)
    kv_spec: Any = None       # (B, T, Kh, Dh)
    out_spec: Any = None      # (B, S, H, Dh) post-attention
    cache_spec: Any = None    # decode KV cache (B, T, Kh, Dh)
    resid_spec: Any = None    # residual stream (B, S, D) — forces the
    #                           Megatron block all-reduce to happen in bf16
    #                           (before the fp32 norm), halving AR wire bytes


_HINTS: ContextVar[Optional[AttnShardingHints]] = ContextVar(
    "attn_sharding_hints", default=None)

# Perf-probe: replace the attention CORE (scores+softmax+pv) with zeros,
# keeping projections — compiling with/without isolates attention's
# contribution to the roofline terms (used by the hillclimb driver).
_SKIP_CORE: ContextVar[bool] = ContextVar("attn_skip_core", default=False)


@contextlib.contextmanager
def skip_attention_core():
    tok = _SKIP_CORE.set(True)
    try:
        yield
    finally:
        _SKIP_CORE.reset(tok)


@contextlib.contextmanager
def sharding_hints(hints: Optional[AttnShardingHints]):
    tok = _HINTS.set(hints)
    try:
        yield
    finally:
        _HINTS.reset(tok)


def _hint(x: jax.Array, which: str) -> jax.Array:
    h = _HINTS.get()
    spec = getattr(h, which, None) if h is not None else None
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def attn_init(key, c: ModelConfig) -> Params:
    pd = param_dtype_of(c)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], c.d_model, (c.n_heads, c.d_head), pd),
        "wk": dense_init(ks[1], c.d_model, (c.n_kv_heads, c.d_head), pd),
        "wv": dense_init(ks[2], c.d_model, (c.n_kv_heads, c.d_head), pd),
        # stored (H, Dh, D): contraction over (H, Dh)
        "wo": dense_init(ks[3], c.n_heads * c.d_head, c.d_model, pd).reshape(
            c.n_heads, c.d_head, c.d_model),
    }
    if c.qkv_bias:
        p["bq"] = jnp.zeros((c.n_heads, c.d_head), pd)
        p["bk"] = jnp.zeros((c.n_kv_heads, c.d_head), pd)
        p["bv"] = jnp.zeros((c.n_kv_heads, c.d_head), pd)
    return p


def qkv_proj(c: ModelConfig, p: Params, x: jax.Array,
             positions: Optional[jax.Array] = None):
    """x: (B, S, D) -> q (B,S,H,Dh), k/v (B,S,Kh,Dh) with RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if c.use_rope and positions is not None:
        # barrier: keep the f32 rope math from retroactively upcasting the
        # projection matmuls (and thus the stacked weights) to f32
        q, k = opt_barrier((q, k))
        q = apply_rope(q, positions, c.rope_theta)
        k = apply_rope(k, positions, c.rope_theta)
    return _hint(q, "q_spec"), _hint(k, "kv_spec"), _hint(v, "kv_spec")


def _mask_bias(mask: jax.Array, dtype) -> jax.Array:
    return jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)


def make_causal_mask(s: int, t: int, window: Optional[int] = None,
                     q_offset: int | jax.Array = 0) -> jax.Array:
    """(s, t) boolean mask. Query i (global pos q_offset+i) sees key j<=pos."""
    qpos = jnp.arange(s)[:, None] + q_offset
    kpos = jnp.arange(t)[None, :]
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m


def sdpa(q: jax.Array, k: jax.Array, v: jax.Array, mask: Optional[jax.Array],
         impl: str = "repeat") -> jax.Array:
    """Scaled dot-product attention.

    q: (B,S,H,Dh); k,v: (B,T,Kh,Dh); mask: broadcastable to (B,1,S,T) or None
    (None = full bidirectional). fp32 softmax.
    """
    b, s, h, dh = q.shape
    kh = k.shape[2]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32)).astype(q.dtype)
    q = q * scale
    if impl == "repeat" or h == kh:
        if h != kh:
            rep = h // kh
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        scores = opt_barrier(
            jnp.einsum("bshk,bthk->bhst", q, k)).astype(jnp.float32)
        if mask is not None:
            scores = scores + _mask_bias(mask, scores.dtype)
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bhst,bthk->bshk", w, v)
    else:
        g = h // kh
        qg = q.reshape(b, s, kh, g, dh)
        scores = opt_barrier(
            jnp.einsum("bskgd,btkd->bkgst", qg, k)).astype(jnp.float32)
        if mask is not None:
            scores = scores + _mask_bias(mask, scores.dtype)[:, None]
        w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
        out = jnp.einsum("bkgst,btkd->bskgd", w, v).reshape(b, s, h, dh)
    return out


def out_proj(p: Params, attn_out: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", attn_out, p["wo"])


# Score tensors larger than this (elements, per device-unaware global view)
# switch to the memory-bounded q-chunked path.
CHUNK_THRESHOLD = 1 << 31
Q_CHUNK = 1024


def sdpa_chunked_q(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, window: Optional[int], impl: str,
                   q_chunk: int = Q_CHUNK, unroll: bool = False) -> jax.Array:
    """Flash-style memory-bounded attention: scan over query chunks.

    Each chunk materializes only a (B, H, q_chunk, T_vis) score block —
    with causal+windowed masks the visible T is additionally sliced, making
    windowed attention honestly sub-quadratic. This is the XLA analog of
    the Pallas flash kernel (repro.kernels) used on real TPU.
    """
    b, s, h, dh = q.shape
    t = k.shape[1]
    nq = s // q_chunk
    assert s % q_chunk == 0, (s, q_chunk)
    qc = q.reshape(b, nq, q_chunk, h, dh).transpose(1, 0, 2, 3, 4)

    def chunk(i, qi):
        qi = _hint(qi, "q_spec")
        start = i * q_chunk
        if causal and window is None:
            # keys visible to this chunk: [0, start + q_chunk)
            t_vis = t  # static bound; mask handles the tail
            mask = make_causal_mask(q_chunk, t_vis, None, q_offset=start)
            return _hint(sdpa(qi, k, v, mask[None, None], impl=impl),
                         "out_spec")
        if causal and window is not None:
            w = min(window, t)
            vis = min(q_chunk + w, t)
            k_start = jnp.clip(start + q_chunk - vis, 0, t - vis)
            ks = jax.lax.dynamic_slice_in_dim(k, k_start, vis, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, k_start, vis, axis=1)
            qpos = start + jnp.arange(q_chunk)[:, None]
            kpos = k_start + jnp.arange(vis)[None, :]
            mask = (kpos <= qpos) & (kpos > qpos - window)
            return _hint(sdpa(qi, ks, vs, mask[None, None], impl=impl),
                         "out_spec")
        return _hint(sdpa(qi, k, v, None, impl=impl), "out_spec")

    # Remat each chunk: backward recomputes the chunk's scores instead of
    # saving fp32 softmax residuals stacked across all chunks (this is the
    # flash-attention backward strategy, in XLA form).
    chunk = jax.checkpoint(chunk, policy=None)

    def body(_, inp):
        i, qi = inp
        return None, chunk(i, qi)

    _, out = jax.lax.scan(body, None, (jnp.arange(nq), qc), unroll=unroll)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)


def _score_elems(c: ModelConfig, s: int, t: int) -> int:
    return c.n_heads * s * t


def attend(c: ModelConfig, q, k, v, *, causal: bool, impl: str,
           unroll: bool = False) -> jax.Array:
    """Select full vs q-chunked attention by score-tensor size.

    q_chunk is pass-adaptive: the metrics pass (unroll=True) uses few big
    chunks so the unrolled HLO stays compilable; the real/memory pass uses
    small chunks so the live score block is tightly bounded.
    """
    b, s = q.shape[:2]
    t = k.shape[1]
    if _SKIP_CORE.get():
        return jnp.zeros_like(q) + 0.0 * (jnp.sum(k[:, :1]) + jnp.sum(v[:, :1])).astype(q.dtype)
    big = b * _score_elems(c, s, t) > CHUNK_THRESHOLD
    q_chunk = max(s // 8, Q_CHUNK) if unroll else 256
    if big and s % q_chunk == 0:
        return sdpa_chunked_q(q, k, v, causal=causal, window=c.attn_window,
                              impl=impl, q_chunk=q_chunk, unroll=unroll)
    mask = None
    if causal:
        mask = make_causal_mask(s, t, c.attn_window)[None, None]
    return sdpa(q, k, v, mask, impl=impl)


# ---------------------------------------------------------------------------
# Full attention ops used by the blocks
# ---------------------------------------------------------------------------


def self_attention(c: ModelConfig, p: Params, x: jax.Array, *,
                   causal: bool = True, positions: Optional[jax.Array] = None,
                   impl: str = "repeat", unroll: bool = False) -> jax.Array:
    """Training/encoding self-attention over the full sequence."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = qkv_proj(c, p, x, positions if c.use_rope else None)
    return out_proj(p, attend(c, q, k, v, causal=causal, impl=impl,
                              unroll=unroll))


def cross_attention(c: ModelConfig, p: Params, x: jax.Array,
                    enc_kv: tuple[jax.Array, jax.Array],
                    impl: str = "repeat") -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    k, v = enc_kv
    return out_proj(p, sdpa(q, k, v, None, impl=impl))


def encoder_kv(c: ModelConfig, p: Params, enc_out: jax.Array):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def prefill_attention(c: ModelConfig, p: Params, x: jax.Array, *,
                      positions: Optional[jax.Array] = None,
                      impl: str = "repeat", unroll: bool = False,
                      prefix_kv: Optional[tuple] = None,
                      paged_prefix: Optional[tuple] = None):
    """Causal self-attention that also returns the K/V cache.

    ``prefix_kv`` = (pk, pv), each (B, T_pre, Kh, Dh): precomputed KV of
    a cached prompt prefix (prefix-cached suffix prefill). The queries
    are the *suffix* tokens at global positions ``T_pre + i`` (the
    caller passes RoPE ``positions`` with the offset applied); they
    attend over [prefix KV ++ suffix KV] under the causal mask shifted
    by ``q_offset=T_pre``. Only the suffix (k, v) is returned for the
    cache — the prefix blocks already live in the pool.

    ``paged_prefix`` = (k_pool, v_pool, k_scale, v_scale, tables,
    paged_impl, paged_interpret): same semantics, but the prefix KV
    stays IN the paged pool — ``kernels.ops.paged_prefill_attention``
    walks the slot's block table directly (scales non-None mark an int8
    pool, dequantized inside the kernel's KV load). Replaces the dense
    ``k_pool[tables]`` gather the engine used to do.
    """
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q, k, v = qkv_proj(c, p, x, positions if c.use_rope else None)
    assert prefix_kv is None or paged_prefix is None
    if paged_prefix is not None:
        from repro.kernels import ops as _kops
        k_pool, v_pool, k_scale, v_scale, tables, pimpl, pinterp = paged_prefix
        out = _kops.paged_prefill_attention(
            q, k, v, k_pool, v_pool, tables, window=c.attn_window,
            impl=pimpl, interpret=pinterp, k_scale=k_scale, v_scale=v_scale)
        return out_proj(p, out.astype(q.dtype)), (k, v)
    if prefix_kv is not None:
        pk, pv = prefix_kv
        t_pre = pk.shape[1]
        k_full = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v_full = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        mask = make_causal_mask(s, t_pre + s, c.attn_window,
                                q_offset=t_pre)[None, None]
        out = out_proj(p, sdpa(q, k_full, v_full, mask, impl=impl))
        return out, (k, v)
    out = out_proj(p, attend(c, q, k, v, causal=True, impl=impl,
                             unroll=unroll))
    return out, (k, v)


def _quantized_block_write(pool: jax.Array, scale: jax.Array,
                           new: jax.Array, blk: jax.Array, off: jax.Array):
    """Write one token (B, Kh, Dh) into int8 pool blocks at
    ``(blk[b], off[b])``, preserving the per-(block, head) symmetric
    scale invariant: dequantize the owning block, place the token,
    re-quantize under ``max(old_scale, maxabs(new)/127)``. The scale is
    MONOTONE, so when the new token fits the old range the block's other
    int8 codes are bit-unchanged (round(i*s/s) == i). Duplicate ``blk``
    entries only ever occur on the trash block 0 (idle slots), where the
    undefined scatter order is harmless."""
    newf = new.astype(jnp.float32)
    osc = jnp.take(scale, blk, axis=0).astype(jnp.float32)       # (B, Kh)
    deq = jnp.take(pool, blk, axis=0).astype(jnp.float32) \
        * osc[:, None, :, None]                                  # (B,bs,Kh,Dh)
    rows = jnp.arange(new.shape[0])
    deq = deq.at[rows, off].set(newf)
    nsc = jnp.maximum(osc, jnp.max(jnp.abs(newf), axis=-1) / 127.0)
    q = jnp.round(deq / jnp.where(nsc > 0.0, nsc, 1.0)[:, None, :, None])
    q = jnp.clip(q, -127, 127).astype(pool.dtype)
    return (pool.at[blk].set(q, mode="drop"),
            scale.at[blk].set(nsc.astype(scale.dtype), mode="drop"))


def decode_attention(c: ModelConfig, p: Params, x: jax.Array,
                     cache_k: jax.Array, cache_v: jax.Array,
                     pos: jax.Array, *, impl: str = "grouped",
                     block_tables: Optional[jax.Array] = None,
                     n_kv_blocks: Optional[int] = None,
                     paged_impl: str = "xla",
                     paged_interpret: bool = False,
                     cache_k_scale: Optional[jax.Array] = None,
                     cache_v_scale: Optional[jax.Array] = None):
    """One-token decode against a fixed-size KV cache.

    x: (B, 1, D); cache_k/v: (B, T, Kh, Dh); pos: scalar int32 (step
    index, shared by all rows) OR an int32 vector (B,) of per-row
    positions — the continuous-batching serve engine tracks an
    independent write position per slot.
    Returns (out (B,1,D), new_cache_k, new_cache_v).

    Scalar pos + windowed attention slices the cache to the last
    ``window`` entries (O(window) per step); otherwise the new token
    attends to all cached positions <= pos under a (per-row) mask
    (O(T) per step — linear, not quadratic).

    Paged path (``block_tables`` given): cache_k/v are *shared block
    pools* ``(n_blocks, bs, Kh, Dh)`` and ``block_tables`` is the
    ``(B, max_blocks)`` per-slot table (``serve.cache.PagedKVCache``).
    The new token is scattered into its slot's current block; attention
    walks only the first ``n_kv_blocks`` (static — the engine buckets it
    to the longest live slot) table columns via
    ``kernels.ops.paged_decode_attention``, masked by true per-slot
    length — never the ``max_len``-padded row. ``pos`` must be the
    per-slot vector; idle slots park at a position whose table column is
    the trash block 0.

    ``cache_k_scale``/``cache_v_scale`` (n_blocks, Kh) f32 mark an int8
    pool: the token write goes through :func:`_quantized_block_write`
    and the return value grows to a 5-tuple
    ``(out, cache_k, cache_v, cache_k_scale, cache_v_scale)``.
    """
    b = x.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    positions = pos[:, None] if per_slot else jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = qkv_proj(c, p, x, positions if c.use_rope else None)

    if block_tables is not None:
        assert per_slot, "paged decode requires per-slot positions"
        from repro.kernels import ops as _kops
        bs_blk = cache_k.shape[1]
        nb = n_kv_blocks if n_kv_blocks is not None else block_tables.shape[1]
        blk = jnp.take_along_axis(block_tables, pos[:, None] // bs_blk,
                                  axis=1)[:, 0]
        off = pos % bs_blk
        quantized = cache_k_scale is not None
        if quantized:
            cache_k, cache_k_scale = _quantized_block_write(
                cache_k, cache_k_scale, k_new[:, 0], blk, off)
            cache_v, cache_v_scale = _quantized_block_write(
                cache_v, cache_v_scale, v_new[:, 0], blk, off)
        else:
            cache_k = cache_k.at[blk, off].set(
                k_new[:, 0].astype(cache_k.dtype), mode="drop")
            cache_v = cache_v.at[blk, off].set(
                v_new[:, 0].astype(cache_v.dtype), mode="drop")
        cache_k = _hint(cache_k, "cache_spec")
        cache_v = _hint(cache_v, "cache_spec")
        out = _kops.paged_decode_attention(
            q[:, 0], cache_k, cache_v, block_tables[:, :nb], pos + 1,
            window=c.attn_window, impl=paged_impl, interpret=paged_interpret,
            k_scale=cache_k_scale, v_scale=cache_v_scale)
        out = out_proj(p, out[:, None].astype(q.dtype))
        if quantized:
            return out, cache_k, cache_v, cache_k_scale, cache_v_scale
        return out, cache_k, cache_v

    if per_slot:
        # independent write position per batch row (slot): row scatter
        rows = jnp.arange(b)
        cache_k = cache_k.at[rows, pos].set(k_new[:, 0])
        cache_v = cache_v.at[rows, pos].set(v_new[:, 0])
    else:
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, pos,
                                                      axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, pos,
                                                      axis=1)

    cache_k = _hint(cache_k, "cache_spec")
    cache_v = _hint(cache_v, "cache_spec")
    t = cache_k.shape[1]
    if (not per_slot and c.attn_window is not None and c.attn_window < t):
        w = c.attn_window
        start = jnp.clip(pos - w + 1, 0, t - w)
        k_att = jax.lax.dynamic_slice_in_dim(cache_k, start, w, axis=1)
        v_att = jax.lax.dynamic_slice_in_dim(cache_v, start, w, axis=1)
        kpos = start + jnp.arange(w)
        mask = (kpos <= pos)[None, None, None, :]  # (1,1,1,W)
    else:
        k_att, v_att = cache_k, cache_v
        kpos = jnp.arange(t)
        if per_slot:
            m = kpos[None, :] <= positions  # (B, T)
            if c.attn_window is not None and c.attn_window < t:
                # per-row starts preclude a shared slice; mask instead
                m = m & (kpos[None, :] > positions - c.attn_window)
            mask = m[:, None, None, :]  # (B,1,1,T)
        else:
            mask = (kpos <= pos)[None, None, None, :]  # (1,1,1,T)
    out = out_proj(p, sdpa(q, k_att, v_att, mask, impl=impl))
    return out, cache_k, cache_v
