"""Mamba2 — SSD (state-space duality) blocks.

Training/prefill uses the chunked SSD algorithm (quadratic within a chunk,
linear across chunks via a ``lax.scan`` state recurrence). Decode is the
O(1)-per-token state recurrence. ngroups=1 (B/C shared across heads), as in
the published mamba2-1.3b config.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, dtype_of, opt_barrier, param_dtype_of

Params = Any


def mamba_init(key, c: ModelConfig) -> Params:
    pd = param_dtype_of(c)
    di, ns, nh, kw = c.d_inner, c.ssm_state, c.ssm_nheads, c.ssm_conv
    conv_ch = di + 2 * ns
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], c.d_model, 2 * di + 2 * ns + nh, pd),
        "conv_w": (jax.random.normal(ks[1], (kw, conv_ch), jnp.float32)
                   * (1.0 / kw)).astype(pd),
        "conv_b": jnp.zeros((conv_ch,), pd),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), pd),
        "out_proj": dense_init(ks[2], di, c.d_model, pd),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. xbc: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    # unrolled taps: K is tiny (4); avoids conv layout headaches under SPMD
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _conv_step(state: jax.Array, x_new: jax.Array, w: jax.Array, b: jax.Array):
    """Single-token conv. state: (B, K-1, C); x_new: (B, 1, C)."""
    window = jnp.concatenate([state, x_new], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    out = jax.nn.silu(out.astype(jnp.float32)).astype(x_new.dtype)
    return out[:, None, :], window[:, 1:, :]


def _split_proj(c: ModelConfig, zxbcdt: jax.Array):
    di, ns, nh = c.d_inner, c.ssm_state, c.ssm_nheads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * ns]
    dt = zxbcdt[..., di + di + 2 * ns:]
    return z, xbc, dt


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float = 1e-5) -> jax.Array:
    y, z = opt_barrier((y, z))  # see common.apply_norm
    g = (y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return (g * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(y.dtype)


# ---------------------------------------------------------------------------
# SSD core (chunked scan)
# ---------------------------------------------------------------------------


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., L). Returns (..., L, L) with sum_{j<i..i} decays, -inf above diag."""
    l = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    ss = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, ss, -jnp.inf)


def ssd_chunked(xdt: jax.Array, dA: jax.Array, B: jax.Array, C: jax.Array,
                chunk: int, h0: jax.Array | None = None,
                unroll: bool = False):
    """Chunked SSD as a scan over chunks (memory-bounded).

    xdt: (b, s, h, p) — inputs pre-multiplied by dt
    dA:  (b, s, h)    — dt * A (negative)
    B,C: (b, s, n)    — shared across heads (ngroups=1)
    Returns y: (b, s, h, p) and final state (b, h, p, n).

    Each scan step materializes only ONE chunk's (l, l) decay matrix; the
    body is remat'd so the backward recomputes it instead of saving fp32
    decay blocks stacked across chunks (same strategy as the q-chunked
    attention — see EXPERIMENTS.md par.Perf).
    """
    b, s, nh, p = xdt.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xdt_c = xdt.reshape(b, nc, chunk, nh, p).transpose(1, 0, 2, 3, 4)
    dA_c = dA.reshape(b, nc, chunk, nh).transpose(1, 0, 2, 3)
    B_c = B.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    C_c = C.reshape(b, nc, chunk, n).transpose(1, 0, 2, 3)
    if h0 is None:
        h0 = jnp.zeros((b, nh, p, n), jnp.float32)

    def body(h, inp):
        xc, dac, bc, cc = inp          # (b,l,h,p) (b,l,h) (b,l,n) (b,l,n)
        dac = dac.astype(jnp.float32)
        da_cs = jnp.cumsum(dac, axis=1)                    # (b,l,h)
        # intra-chunk (one (l,l) decay block, transient)
        L = jnp.exp(_segsum(dac.transpose(0, 2, 1)))       # (b,h,l,l)
        scores = jnp.einsum("bln,bsn->bls", cc, bc)        # (b,l,s)
        y = jnp.einsum("bls,bhls,bshp->blhp", scores, L,
                       xc.astype(jnp.float32), optimize="optimal")
        # carried-in state contribution
        y = y + jnp.einsum("bln,bhpn,blh->blhp", cc.astype(jnp.float32),
                           h, jnp.exp(da_cs), optimize="optimal")
        # state update
        end = da_cs[:, -1]                                  # (b,h)
        decay_to_end = jnp.exp(end[:, None] - da_cs)        # (b,l,h)
        h_new = (h * jnp.exp(end)[..., None, None]
                 + jnp.einsum("bln,blh,blhp->bhpn", bc.astype(jnp.float32),
                              decay_to_end, xc.astype(jnp.float32),
                              optimize="optimal"))
        return h_new, y.astype(xdt.dtype)

    body = jax.checkpoint(body, policy=None)
    # metrics pass: cap the unroll at 16 chunk bodies — the SSD core is
    # <10% of a mamba block's FLOPs (the projections outside this scan
    # dominate), so the residual undercount on long sequences is bounded
    # and documented in EXPERIMENTS.md par.Dry-run; full unroll of 128
    # chunks x 14 layers made XLA:CPU compiles take tens of minutes.
    u = min(16, nc) if unroll else 1
    h_fin, ys = jax.lax.scan(body, h0.astype(jnp.float32),
                             (xdt_c, dA_c, B_c, C_c),
                             unroll=(True if (unroll and nc <= 16) else u))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, p)
    return y, h_fin  # state stays fp32 (prefill->decode continuity)


def ssd_decode_step(h: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
                    B: jax.Array, C: jax.Array):
    """One-token SSD recurrence.

    h: (b, nh, p, n); x: (b, nh, p); dt: (b, nh); A: (nh,); B/C: (b, n).
    """
    dA = jnp.exp(dt * A)  # (b, nh)
    dBx = jnp.einsum("bh,bhp,bn->bhpn", dt, x, B)
    h_new = h * dA[..., None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", h_new, C)
    return y, h_new


# ---------------------------------------------------------------------------
# Full Mamba2 block
# ---------------------------------------------------------------------------


def mamba_forward(c: ModelConfig, p: Params, x: jax.Array,
                  h0: jax.Array | None = None, return_state: bool = False,
                  unroll: bool = False):
    """x: (B, S, D) -> (B, S, D). Chunked SSD over the sequence."""
    b, s, _ = x.shape
    di, ns, nh, hp = c.d_inner, c.ssm_state, c.ssm_nheads, c.ssm_headdim
    z, xbc_raw, dt_raw = _split_proj(c, x @ p["in_proj"])
    conv_tail = xbc_raw[:, -(c.ssm_conv - 1):, :]  # for decode continuity
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xin, B, C = xbc[..., :di], xbc[..., di:di + ns], xbc[..., di + ns:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (b,s,nh)
    A = -jnp.exp(p["A_log"])  # (nh,)
    xh = xin.reshape(b, s, nh, hp)
    xdt = xh * dt[..., None].astype(xh.dtype)
    dA = dt * A
    chunk = min(c.ssm_chunk, s)
    while s % chunk:  # largest divisor of s not above ssm_chunk
        chunk -= 1
    y, h_fin = ssd_chunked(xdt, dA, B, C, chunk, h0=h0, unroll=unroll)
    y = y.astype(xh.dtype) + xh * p["D"].astype(xh.dtype)[:, None]
    y = y.reshape(b, s, di)
    y = _gated_norm(y, z, p["norm_scale"])
    out = (y @ p["out_proj"]).astype(x.dtype)
    if return_state:
        return out, (conv_tail, h_fin)
    return out


def mamba_decode(c: ModelConfig, p: Params, x: jax.Array,
                 conv_state: jax.Array, ssm_state: jax.Array):
    """One-token decode. x: (B, 1, D). Returns (out, conv_state, ssm_state)."""
    b = x.shape[0]
    di, ns, nh, hp = c.d_inner, c.ssm_state, c.ssm_nheads, c.ssm_headdim
    z, xbc, dt_raw = _split_proj(c, x @ p["in_proj"])
    xbc, conv_state = _conv_step(conv_state, xbc, p["conv_w"], p["conv_b"])
    xin, B, C = xbc[..., :di], xbc[..., di:di + ns], xbc[..., di + ns:]
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b,nh)
    A = -jnp.exp(p["A_log"])
    xh = xin[:, 0].reshape(b, nh, hp)
    in_state_dtype = ssm_state.dtype
    y, ssm_state = ssd_decode_step(
        ssm_state, xh, dt.astype(jnp.float32), A, B[:, 0], C[:, 0])
    ssm_state = ssm_state.astype(in_state_dtype)
    y = y.astype(xh.dtype) + xh * p["D"].astype(xh.dtype)[:, None]
    y = y[:, None].reshape(b, 1, di)
    y = _gated_norm(y, z, p["norm_scale"])
    return (y @ p["out_proj"]).astype(x.dtype), conv_state, ssm_state


def mamba_state_shapes(c: ModelConfig, batch: int, dtype):
    conv = (batch, c.ssm_conv - 1, c.d_inner + 2 * c.ssm_state)
    ssm = (batch, c.ssm_nheads, c.ssm_headdim, c.ssm_state)
    return conv, ssm
