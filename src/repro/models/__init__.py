from repro.models import attention, blocks, common, lm, moe, resnet, ssm

__all__ = ["attention", "blocks", "common", "lm", "moe", "resnet", "ssm"]
