"""Decoder blocks + period-pattern LayerStack (scan over layers).

Heterogeneous layer patterns (Jamba's 1-attn-per-8, llama4's MoE-every-2nd)
are handled by unrolling one *period* of the pattern inside the scan body
and scanning over ``n_layers // period`` stacked parameter pytrees. This
keeps the HLO compact (compile time ~O(period), not O(n_layers)) and gives
natural full-activation-recomputation boundaries (the paper's Megatron
setup enables activation recomputation).
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import apply_mlp, apply_norm, mlp_init, norm_init

Params = Any


def period_of(c: ModelConfig) -> int:
    p = 1
    if c.family == "hybrid":
        p = c.attn_layer_period
    if c.n_experts:
        p = max(p, c.moe_layer_step)
        assert p % c.moe_layer_step == 0, "incompatible layer pattern"
    assert c.n_layers % p == 0, (c.n_layers, p)
    return p


def slot_kinds(c: ModelConfig) -> list[tuple[str, Optional[str]]]:
    """Per-slot (mixer, ffn) kinds for one period of the layer pattern."""
    kinds = []
    for i in range(period_of(c)):
        mixer = "attn" if c.is_attn_layer(i) else "mamba"
        if c.family == "ssm":
            ffn = None
        elif c.is_moe_layer(i):
            ffn = "moe"
        elif c.d_ff:
            ffn = "mlp"
        else:
            ffn = None
        kinds.append((mixer, ffn))
    return kinds


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _slot_init(key, c: ModelConfig, mixer: str, ffn: Optional[str],
               cross: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    p: dict = {"norm1": norm_init(c)}
    if mixer == "attn":
        p["attn"] = attn.attn_init(ks[0], c)
    else:
        p["mamba"] = ssm_mod.mamba_init(ks[0], c)
    if cross:
        p["norm_x"] = norm_init(c)
        p["cross"] = attn.attn_init(ks[1], c)
    if ffn == "mlp":
        p["norm2"] = norm_init(c)
        p["mlp"] = mlp_init(ks[2], c, c.d_ff)
    elif ffn == "moe":
        p["norm2"] = norm_init(c)
        p["moe"] = moe_mod.moe_init(ks[3], c)
    return p


def stack_init(key, c: ModelConfig, cross: bool = False) -> Params:
    """Stacked layer params: leaf leading dim = n_periods."""
    period = period_of(c)
    n_periods = c.n_layers // period
    kinds = slot_kinds(c)

    def one_period(k):
        kslots = jax.random.split(k, period)
        return {f"slot{i}": _slot_init(kslots[i], c, *kinds[i], cross=cross)
                for i in range(period)}

    keys = jax.random.split(key, n_periods)
    periods = [one_period(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *periods)


def enc_stack_init(key, c: ModelConfig) -> Params:
    """Encoder stack (bidirectional attn + mlp), its own depth."""
    keys = jax.random.split(key, c.n_enc_layers)
    layers = [_slot_init(k, c, "attn", "mlp") for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


# ---------------------------------------------------------------------------
# Forward (training / full sequence)
# ---------------------------------------------------------------------------


def _apply_slot(c: ModelConfig, sp: Params, x: jax.Array, *, mixer: str,
                ffn: Optional[str], causal: bool, impl: str,
                positions=None, enc_kv=None, unroll: bool = False):
    rh = lambda t: attn._hint(t, "resid_spec")  # bf16 block all-reduce
    h = apply_norm(c, sp["norm1"], x)
    if mixer == "attn":
        h = attn.self_attention(c, sp["attn"], h, causal=causal,
                                positions=positions, impl=impl,
                                unroll=unroll)
    else:
        h = ssm_mod.mamba_forward(c, sp["mamba"], h, unroll=unroll)
    x = x + rh(h)
    aux = jnp.zeros((), jnp.float32)
    if enc_kv is not None:
        h = apply_norm(c, sp["norm_x"], x)
        x = x + rh(attn.cross_attention(c, sp["cross"], h, enc_kv, impl=impl))
    if ffn == "mlp":
        x = x + rh(apply_mlp(c, sp["mlp"], apply_norm(c, sp["norm2"], x)))
    elif ffn == "moe":
        y, aux = moe_mod.moe_forward(c, sp["moe"], apply_norm(c, sp["norm2"], x))
        x = x + rh(y)
    return x, aux


def stack_forward(c: ModelConfig, layers: Params, x: jax.Array, *,
                  causal: bool = True, impl: str = "repeat",
                  remat: str = "full", positions=None,
                  enc_kv_stacked=None, unroll: bool = False):
    """Run the full layer stack. x: (B, S, D) -> (B, S, D), aux_loss."""
    kinds = slot_kinds(c)

    def body(carry, inp):
        x, aux = carry
        if enc_kv_stacked is not None:
            period_params, ekv = inp
        else:
            period_params, ekv = inp, None
        for i, (mixer, ffn) in enumerate(kinds):
            x, a = _apply_slot(c, period_params[f"slot{i}"], x, mixer=mixer,
                               ffn=ffn, causal=causal, impl=impl,
                               positions=positions, unroll=unroll,
                               enc_kv=None if ekv is None else
                               (ekv[f"slot{i}"]["k"], ekv[f"slot{i}"]["v"]))
            aux = aux + a
        return (x, aux), None

    if remat == "full":
        body = jax.checkpoint(body, policy=None)  # recompute everything
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    xs = layers if enc_kv_stacked is None else (layers, enc_kv_stacked)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs,
                               unroll=unroll)
    return x, aux


# ---------------------------------------------------------------------------
# Prefill / decode (serving)
# ---------------------------------------------------------------------------


def stack_prefill(c: ModelConfig, layers: Params, x: jax.Array, *,
                  impl: str = "repeat", positions=None, enc_kv_stacked=None,
                  prefix_kv=None, paged_prefix=None, paged_tables=None,
                  paged_impl: str = "xla", paged_interpret: bool = False,
                  unroll: bool = False):
    """Full-sequence causal pass that also emits per-layer caches.

    ``prefix_kv`` threads per-layer cached-prefix K/V (stacked like the
    caches tree: leading axis = n_periods, per-slot ``{"k","v"}`` of
    shape (B, T_pre, Kh, Dh)) into every attention slot — the suffix
    prefill of prefix caching. Attention-only stacks: the SSD
    recurrence/conv state of mamba mixers depends on the whole sequence
    and cannot skip the prefix.

    ``paged_prefix`` is the paged twin: the engine's pool cache tree
    itself (k/v leaves (n_periods, n_blocks, bs, Kh, Dh), plus
    k_scale/v_scale when int8) rides the scan as xs while the shared
    ``paged_tables`` (B, npre) addresses each row's prefix blocks —
    attention dispatches ``kernels.ops.paged_prefill_attention`` and the
    dense prefix KV is never gathered out of the pool.
    """
    kinds = slot_kinds(c)
    assert enc_kv_stacked is None or prefix_kv is None
    assert prefix_kv is None or paged_prefix is None

    def body(carry, inp):
        x = carry
        ekv = pkv = ppx = None
        if enc_kv_stacked is not None:
            period_params, ekv = inp
        elif prefix_kv is not None:
            period_params, pkv = inp
        elif paged_prefix is not None:
            period_params, ppx = inp
        else:
            period_params = inp
        caches = {}
        for i, (mixer, ffn) in enumerate(kinds):
            sp = period_params[f"slot{i}"]
            h = apply_norm(c, sp["norm1"], x)
            if mixer == "attn":
                pp = None
                if ppx is not None:
                    d = ppx[f"slot{i}"]
                    pp = (d["k"], d["v"], d.get("k_scale"), d.get("v_scale"),
                          paged_tables, paged_impl, paged_interpret)
                h, (k, v) = attn.prefill_attention(
                    c, sp["attn"], h, positions=positions,
                    impl=impl, unroll=unroll,
                    prefix_kv=None if pkv is None else
                    (pkv[f"slot{i}"]["k"], pkv[f"slot{i}"]["v"]),
                    paged_prefix=pp)
                caches[f"slot{i}"] = {"k": k, "v": v}
            else:
                assert pkv is None and ppx is None, (
                    "prefix caching requires attention-only stacks")
                h, (conv_tail, hstate) = ssm_mod.mamba_forward(
                    c, sp["mamba"], h, return_state=True, unroll=unroll)
                caches[f"slot{i}"] = {"ssm": hstate, "conv": conv_tail}
            x = x + h
            if ekv is not None:
                hx = apply_norm(c, sp["norm_x"], x)
                x = x + attn.cross_attention(
                    c, sp["cross"], hx,
                    (ekv[f"slot{i}"]["k"], ekv[f"slot{i}"]["v"]), impl=impl)
            if ffn == "mlp":
                x = x + apply_mlp(c, sp["mlp"], apply_norm(c, sp["norm2"], x))
            elif ffn == "moe":
                y, _ = moe_mod.moe_forward(c, sp["moe"],
                                           apply_norm(c, sp["norm2"], x))
                x = x + y
        return x, caches

    if enc_kv_stacked is not None:
        xs = (layers, enc_kv_stacked)
    elif prefix_kv is not None:
        xs = (layers, prefix_kv)
    elif paged_prefix is not None:
        xs = (layers, paged_prefix)
    else:
        xs = layers
    x, caches = jax.lax.scan(body, x, xs, unroll=unroll)
    return x, caches


def stack_decode(c: ModelConfig, layers: Params, x: jax.Array, caches: Params,
                 pos: jax.Array, *, impl: str = "grouped",
                 enc_kv_stacked=None, unroll: bool = False,
                 block_tables=None, n_kv_blocks: Optional[int] = None,
                 paged_impl: str = "xla", paged_interpret: bool = False):
    """One-token decode through the stack, updating caches in place.

    ``block_tables`` selects the paged KV path: attention k/v cache
    leaves are shared block pools and every layer reads the same
    ``(B, max_blocks)`` table (see ``attention.decode_attention``);
    SSM/conv state leaves stay per-slot rows in either layout.
    """
    kinds = slot_kinds(c)

    def body(x, inp):
        if enc_kv_stacked is not None:
            period_params, cache, ekv = inp
        else:
            (period_params, cache), ekv = inp, None
        new_cache = {}
        for i, (mixer, ffn) in enumerate(kinds):
            sp = period_params[f"slot{i}"]
            sc = cache[f"slot{i}"]
            h = apply_norm(c, sp["norm1"], x)
            if mixer == "attn":
                if "k_scale" in sc:
                    h, ck, cv, ksc, vsc = attn.decode_attention(
                        c, sp["attn"], h, sc["k"], sc["v"], pos, impl=impl,
                        block_tables=block_tables, n_kv_blocks=n_kv_blocks,
                        paged_impl=paged_impl,
                        paged_interpret=paged_interpret,
                        cache_k_scale=sc["k_scale"],
                        cache_v_scale=sc["v_scale"])
                    new_cache[f"slot{i}"] = {"k": ck, "v": cv,
                                             "k_scale": ksc, "v_scale": vsc}
                else:
                    h, ck, cv = attn.decode_attention(
                        c, sp["attn"], h, sc["k"], sc["v"], pos, impl=impl,
                        block_tables=block_tables, n_kv_blocks=n_kv_blocks,
                        paged_impl=paged_impl,
                        paged_interpret=paged_interpret)
                    new_cache[f"slot{i}"] = {"k": ck, "v": cv}
            else:
                h, conv_s, ssm_s = ssm_mod.mamba_decode(c, sp["mamba"], h,
                                                        sc["conv"], sc["ssm"])
                new_cache[f"slot{i}"] = {"conv": conv_s, "ssm": ssm_s}
            x = x + h
            if ekv is not None:
                hx = apply_norm(c, sp["norm_x"], x)
                x = x + attn.cross_attention(
                    c, sp["cross"], hx,
                    (ekv[f"slot{i}"]["k"], ekv[f"slot{i}"]["v"]), impl=impl)
            if ffn == "mlp":
                x = x + apply_mlp(c, sp["mlp"], apply_norm(c, sp["norm2"], x))
            elif ffn == "moe":
                y, _ = moe_mod.moe_forward(c, sp["moe"],
                                           apply_norm(c, sp["norm2"], x))
                x = x + y
        return x, new_cache

    if enc_kv_stacked is None:
        x, new_caches = jax.lax.scan(body, x, (layers, caches), unroll=unroll)
    else:
        x, new_caches = jax.lax.scan(body, x, (layers, caches, enc_kv_stacked),
                                     unroll=unroll)
    return x, new_caches
